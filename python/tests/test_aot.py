"""AOT pipeline: lowering, manifest integrity, fingerprint skipping."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot


HERE = os.path.dirname(os.path.abspath(__file__))
PKG_ROOT = os.path.dirname(HERE)


def test_catalog_names_unique():
    names = [name for name, *_ in aot.build_catalog()]
    assert len(names) == len(set(names))


def test_catalog_covers_table_i_configs():
    """Every Table I configuration must have a training executable."""
    names = {name for name, *_ in aot.build_catalog()}
    for want in [
        "easi_full_norm_m32_n16_b256",
        "easi_full_norm_m32_n8_b256",
        "rp_easi_norm_m32_p24_n16_b256",
        "rp_easi_norm_m32_p16_n8_b256",
    ]:
        assert want in names, f"missing {want}"


def test_catalog_has_tail_variants():
    """b=1 variants exist so stream tails never require zero-padding
    (padding corrupts the whitening term)."""
    names = {name for name, *_ in aot.build_catalog()}
    assert "easi_full_norm_m32_n16_b1" in names
    assert "rp_easi_norm_m32_p16_n8_b1" in names


def test_quick_lowering_roundtrip(tmp_path):
    """--quick catalogue lowers to parseable HLO text + valid manifest."""
    out = tmp_path / "artifacts"
    env = dict(os.environ, PYTHONPATH=PKG_ROOT)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--quick"],
        check=True, cwd=PKG_ROOT, env=env, capture_output=True,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert len(manifest["artifacts"]) >= 5
    for entry in manifest["artifacts"]:
        path = out / entry["file"]
        text = path.read_text()
        assert text.startswith("HloModule"), entry["name"]
        assert entry["inputs"], entry["name"]
        assert entry["outputs"], entry["name"]
        # The Rust loader needs concrete dims.
        for spec in entry["inputs"] + entry["outputs"]:
            assert all(isinstance(d, int) and d >= 1 for d in spec["shape"])
            assert spec["dtype"] == "f32"


def test_lower_variant_produces_hlo_text():
    import jax
    import jax.numpy as jnp
    from compile import model

    spec = jax.ShapeDtypeStruct((2, 4), jnp.float32)
    mu = jax.ShapeDtypeStruct((1,), jnp.float32)
    text = aot.lower_variant(model.easi_variant(True, True), [spec,
                                                              jax.ShapeDtypeStruct((3, 4), jnp.float32),
                                                              mu])
    assert "HloModule" in text
    # Sequential semantics lower to a while loop, not an unrolled chain.
    assert "while" in text


def test_fingerprint_stable():
    assert aot.input_fingerprint() == aot.input_fingerprint()
