"""Layer-2 correctness: the composed training-step graphs."""

import numpy as np
import jax.numpy as jnp
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype=jnp.float32)


def test_rp_easi_step_equals_project_then_rotate():
    """The fused proposed-pipeline executable must equal RP followed by
    rotation-only EASI run separately."""
    rng = np.random.default_rng(21)
    m, p, n, batch = 12, 8, 4, 16
    b = jnp.asarray(np.eye(n, p) + 0.02 * rng.normal(size=(n, p)), dtype=jnp.float32)
    r = jnp.asarray(rng.choice([-1.0, 0.0, 1.0], size=(p, m), p=[.1, .8, .1]),
                    dtype=jnp.float32)
    xs = rand(rng, batch, m)
    fused = model.rp_easi_train_step(b, r, xs, 1e-3, normalized=True)
    staged = ref.easi_minibatch_ref(b, ref.rp_apply_ref(r, xs), 1e-3,
                                    whiten=False, rotate=True, normalized=True)
    assert_allclose(np.asarray(fused), np.asarray(staged), rtol=1e-5, atol=1e-6)


def test_rp_transform_cascade():
    rng = np.random.default_rng(22)
    m, p, n, batch = 10, 6, 3, 8
    b = rand(rng, n, p)
    r = jnp.asarray(rng.choice([-1.0, 0.0, 1.0], size=(p, m)), dtype=jnp.float32)
    xs = rand(rng, batch, m)
    got = model.rp_transform(b, r, xs)
    want = ref.transform_ref(b, ref.rp_apply_ref(r, xs))
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def _mlp_params(rng, d, h, c):
    names = ["w1", "b1", "w2", "b2", "w3", "b3"]
    shapes = [(h, d), (h,), (h, h), (h,), (c, h), (c,)]
    params = {}
    for name, shape in zip(names, shapes):
        scale = 0.5 if name.startswith("w") else 0.0
        params[name] = rand(rng, *shape, scale=scale)
        params["v" + name] = jnp.zeros(shape, jnp.float32)
    return params


def test_mlp_train_step_matches_ref():
    """The flat-argument PJRT variant must equal the dict-based oracle."""
    rng = np.random.default_rng(23)
    d, h, c, batch = 5, 8, 3, 16
    params = _mlp_params(rng, d, h, c)
    xs = rand(rng, batch, d)
    labels = rng.integers(0, c, size=batch)
    onehot = jnp.asarray(np.eye(c)[labels], dtype=jnp.float32)

    flat_in = [params[k] for k in
               ["w1", "b1", "w2", "b2", "w3", "b3",
                "vw1", "vb1", "vw2", "vb2", "vw3", "vb3"]]
    outs = model.mlp_train_step(*flat_in, xs, onehot,
                                jnp.asarray([0.05], jnp.float32),
                                jnp.asarray([0.9], jnp.float32))
    new_ref, loss_ref = ref.mlp_train_step_ref(params, xs, onehot, 0.05, 0.9)

    # Output order: w1, vw1, b1, vb1, w2, vw2, b2, vb2, w3, vw3, b3, vb3, loss
    order = ["w1", "vw1", "b1", "vb1", "w2", "vw2", "b2", "vb2",
             "w3", "vw3", "b3", "vb3"]
    for got, key in zip(outs[:-1], order):
        assert_allclose(np.asarray(got), np.asarray(new_ref[key]),
                        rtol=1e-5, atol=1e-6, err_msg=key)
    assert_allclose(float(outs[-1]), float(loss_ref), rtol=1e-5)


def test_mlp_training_reduces_loss():
    """A few steps on separable blobs must reduce the loss."""
    rng = np.random.default_rng(24)
    d, h, c, batch = 2, 64, 2, 32
    params = _mlp_params(rng, d, h, c)
    flat = [params[k] for k in
            ["w1", "b1", "w2", "b2", "w3", "b3",
             "vw1", "vb1", "vw2", "vb2", "vw3", "vb3"]]
    lr = jnp.asarray([0.1], jnp.float32)
    mom = jnp.asarray([0.9], jnp.float32)
    losses = []
    for step in range(30):
        labels = rng.integers(0, 2, size=batch)
        centers = np.where(labels[:, None] == 0, -2.0, 2.0)
        xs = jnp.asarray(centers + 0.3 * rng.normal(size=(batch, 2)),
                         dtype=jnp.float32)
        onehot = jnp.asarray(np.eye(2)[labels], dtype=jnp.float32)
        outs = model.mlp_train_step(*flat, xs, onehot, lr, mom)
        flat = list(outs[:-1])
        # Reorder: outputs come as w1, vw1, b1, vb1, ... but inputs are
        # w1..b3 then vw1..vb3.
        by_name = dict(zip(
            ["w1", "vw1", "b1", "vb1", "w2", "vw2", "b2", "vb2",
             "w3", "vw3", "b3", "vb3"], outs[:-1]))
        flat = [by_name[k] for k in
                ["w1", "b1", "w2", "b2", "w3", "b3",
                 "vw1", "vb1", "vw2", "vb2", "vw3", "vb3"]]
        losses.append(float(outs[-1]))
    assert losses[-1] < 0.3 * losses[0], losses[::10]


def test_easi_variant_names():
    assert model.easi_variant(True, True).__name__ == "easi_step_full"
    assert model.easi_variant(True, False).__name__ == "easi_step_whiten"
    assert model.easi_variant(False, True, normalized=True).__name__ == "easi_step_rot_norm"


def test_variant_functions_return_tuples():
    """AOT lowering requires tuple outputs (return_tuple=True unwrap on
    the Rust side)."""
    rng = np.random.default_rng(25)
    b = rand(rng, 3, 6, scale=0.1)
    xs = rand(rng, 4, 6)
    out = model.easi_variant(True, True)(b, xs, jnp.asarray([1e-3], jnp.float32))
    assert isinstance(out, tuple) and len(out) == 1
    out = model.transform_variant()(b, xs)
    assert isinstance(out, tuple) and len(out) == 1
