"""Layer-1 correctness: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps the shape/batch space; numpy.testing.assert_allclose
is the acceptance criterion. This is the CORE correctness signal for
the compile path — if these pass, the HLO artifacts the Rust runtime
executes encode exactly the math of ref.py (which in turn is what the
Rust-native implementation computes; see rust/tests/).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels import easi_kernel, mlp_kernel, ref, rp_kernel

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype=jnp.float32)


def stable_b(rng, n, m):
    """Near-identity init — the regime the streaming algorithm runs in."""
    return jnp.asarray(np.eye(n, m) + 0.02 * rng.normal(size=(n, m)),
                       dtype=jnp.float32)


# ------------------------------------------------------------- EASI

@settings(**SETTINGS)
@given(
    n=st.integers(2, 12),
    extra=st.integers(0, 12),
    batch=st.integers(1, 32),
    whiten=st.booleans(),
    rotate=st.booleans(),
    normalized=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_easi_minibatch_matches_ref(n, extra, batch, whiten, rotate, normalized, seed):
    if not whiten and not rotate:
        return  # empty datapath — not a valid mux setting
    m = n + extra
    rng = np.random.default_rng(seed)
    b = stable_b(rng, n, m)
    xs = rand(rng, batch, m)
    got = easi_kernel.easi_minibatch(
        b, xs, 1e-3, whiten=whiten, rotate=rotate, normalized=normalized)
    want = ref.easi_minibatch_ref(b, xs, 1e-3, whiten, rotate, normalized)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_easi_single_sample_matches_naive_eq6():
    """batch=1 kernel == the literal Eq. 6 with explicit F and F@B."""
    rng = np.random.default_rng(7)
    b = stable_b(rng, 4, 9)
    x = rand(rng, 9)
    got = easi_kernel.easi_minibatch(b, x[None, :], 2e-3, whiten=True, rotate=True)
    want = ref.easi_step_ref(b, x, 2e-3, True, True)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_easi_sequential_semantics():
    """One batch of 2 == two consecutive batches of 1 (the FPGA feedback
    path: sample t+1 sees the B updated by sample t)."""
    rng = np.random.default_rng(8)
    b = stable_b(rng, 3, 5)
    xs = rand(rng, 2, 5)
    fused = easi_kernel.easi_minibatch(b, xs, 1e-3)
    b1 = easi_kernel.easi_minibatch(b, xs[0:1], 1e-3)
    b2 = easi_kernel.easi_minibatch(b1, xs[1:2], 1e-3)
    assert_allclose(np.asarray(fused), np.asarray(b2), rtol=1e-5, atol=1e-6)


def test_easi_mode_mux_decomposition():
    """For one sample the full update is whiten-delta + rotate-delta
    (the paper's datapath mux adds the two terms)."""
    rng = np.random.default_rng(9)
    b = stable_b(rng, 4, 6)
    x = rand(rng, 1, 6)
    full = np.asarray(easi_kernel.easi_minibatch(b, x, 1e-3, whiten=True, rotate=True))
    wh = np.asarray(easi_kernel.easi_minibatch(b, x, 1e-3, whiten=True, rotate=False))
    ro = np.asarray(easi_kernel.easi_minibatch(b, x, 1e-3, whiten=False, rotate=True))
    b_np = np.asarray(b)
    assert_allclose(full, wh + ro - b_np, rtol=1e-5, atol=1e-6)


def test_easi_whitening_converges():
    """Training on correlated data drives output covariance toward I."""
    rng = np.random.default_rng(10)
    n_samples, dim = 4000, 4
    a = rng.normal(size=(dim, dim))
    xs = jnp.asarray(rng.normal(size=(n_samples, dim)) @ a.T, dtype=jnp.float32)
    b = jnp.asarray(0.3 * np.eye(dim), dtype=jnp.float32)
    for _ in range(6):
        b = easi_kernel.easi_minibatch(b, xs, 2e-3, whiten=True, rotate=False)
    z = np.asarray(xs @ b.T)
    cov = z.T @ z / n_samples
    assert np.max(np.abs(cov - np.eye(dim))) < 0.15, f"cov:\n{cov}"


@settings(**SETTINGS)
@given(
    n=st.integers(1, 8),
    extra=st.integers(0, 16),
    batch=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_transform_matches_ref(n, extra, batch, seed):
    m = n + extra
    rng = np.random.default_rng(seed)
    b = rand(rng, n, m)
    xs = rand(rng, batch, m)
    assert_allclose(
        np.asarray(easi_kernel.transform(b, xs)),
        np.asarray(ref.transform_ref(b, xs)),
        rtol=1e-5, atol=1e-6,
    )


# --------------------------------------------------------------- RP

@settings(**SETTINGS)
@given(
    p=st.integers(1, 16),
    extra=st.integers(0, 48),
    batch=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_rp_apply_matches_ref(p, extra, batch, seed):
    m = p + extra
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.choice([-1.0, 0.0, 1.0], size=(p, m), p=[.1, .8, .1]),
                    dtype=jnp.float32)
    xs = rand(rng, batch, m)
    assert_allclose(
        np.asarray(rp_kernel.rp_apply(r, xs)),
        np.asarray(ref.rp_apply_ref(r, xs)),
        rtol=1e-5, atol=1e-6,
    )


@settings(**SETTINGS)
@given(
    p=st.integers(1, 8),
    m=st.integers(9, 512),
    batch=st.integers(1, 16),
    block=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rp_blocked_matches_ref(p, m, batch, block, seed):
    """The BlockSpec reduction grid must agree with the dense oracle for
    every (m, block) combination, including non-divisible padding."""
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.choice([-1.0, 0.0, 1.0], size=(p, m), p=[.1, .8, .1]),
                    dtype=jnp.float32)
    xs = rand(rng, batch, m)
    assert_allclose(
        np.asarray(rp_kernel.rp_apply_blocked(r, xs, block_m=block)),
        np.asarray(ref.rp_apply_ref(r, xs)),
        rtol=1e-4, atol=1e-5,
    )


def test_rp_ternary_preserves_norms_in_expectation():
    """E||Rx||^2 = ||x||^2 for the Fox et al. distribution — the paper's
    second-order-statistics argument."""
    rng = np.random.default_rng(11)
    m, p, trials = 256, 32, 200
    x = rng.normal(size=m).astype(np.float32)
    ratios = []
    prob = 1.0 / (2 * p)
    for _ in range(trials):
        u = rng.random(size=(p, m))
        r = np.where(u < prob, 1.0, np.where(u < 2 * prob, -1.0, 0.0)).astype(np.float32)
        y = np.asarray(rp_kernel.rp_apply(jnp.asarray(r), jnp.asarray(x[None, :])))[0]
        ratios.append(np.sum(y * y) / np.sum(x * x))
    assert abs(np.mean(ratios) - 1.0) < 0.15, np.mean(ratios)


# -------------------------------------------------------------- MLP

@settings(**SETTINGS)
@given(
    d=st.integers(1, 16),
    h=st.sampled_from([8, 64]),
    c=st.integers(2, 10),
    batch=st.integers(1, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_mlp_logits_matches_ref(d, h, c, batch, seed):
    rng = np.random.default_rng(seed)
    w1, b1 = rand(rng, h, d, scale=0.5), rand(rng, h, scale=0.1)
    w2, b2 = rand(rng, h, h, scale=0.5), rand(rng, h, scale=0.1)
    w3, b3 = rand(rng, c, h, scale=0.5), rand(rng, c, scale=0.1)
    xs = rand(rng, batch, d)
    assert_allclose(
        np.asarray(mlp_kernel.mlp_logits(w1, b1, w2, b2, w3, b3, xs)),
        np.asarray(ref.mlp_logits_ref(w1, b1, w2, b2, w3, b3, xs)),
        rtol=1e-4, atol=1e-5,
    )


def test_mlp_relu_actually_clips():
    """Negative pre-activations must be zeroed (catches a max/min swap)."""
    d = 2
    w1 = jnp.asarray(-np.eye(8, d), dtype=jnp.float32)
    b1 = jnp.zeros(8, jnp.float32)
    w2 = jnp.asarray(np.eye(8), dtype=jnp.float32)
    b2 = jnp.zeros(8, jnp.float32)
    w3 = jnp.asarray(np.ones((3, 8)), dtype=jnp.float32)
    b3 = jnp.zeros(3, jnp.float32)
    xs = jnp.asarray([[1.0, 1.0]], dtype=jnp.float32)  # all h1 pre-acts negative
    out = np.asarray(mlp_kernel.mlp_logits(w1, b1, w2, b2, w3, b3, xs))
    assert_allclose(out, np.zeros((1, 3)), atol=1e-7)


# ------------------------------------------------------ composed DR unit

from compile.kernels import dr_kernel


@settings(**SETTINGS)
@given(
    n=st.integers(2, 10),
    extra=st.integers(0, 12),
    batch=st.integers(1, 16),
    rotate=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_dr_minibatch_matches_ref(n, extra, batch, rotate, seed):
    m = n + extra
    rng = np.random.default_rng(seed)
    w = stable_b(rng, n, m)
    var = jnp.ones(n, jnp.float32)
    u = jnp.eye(n, dtype=jnp.float32)
    xs = rand(rng, batch, m)
    mus = jnp.asarray([5e-3, 5e-3, 1e-3], jnp.float32)
    got = dr_kernel.dr_minibatch(w, var, u, xs, mus, rotate=rotate)
    want = ref.dr_minibatch_ref(w, var, u, xs, 5e-3, 5e-3, 1e-3, rotate)
    for g, r_ in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(r_), rtol=2e-5, atol=1e-6)


def test_dr_whiten_mode_leaves_u_untouched():
    rng = np.random.default_rng(31)
    w = stable_b(rng, 4, 8)
    var = jnp.ones(4, jnp.float32)
    u = rand(rng, 4, 4)
    xs = rand(rng, 16, 8)
    mus = jnp.asarray([5e-3, 5e-3, 1e-3], jnp.float32)
    _, _, u2 = dr_kernel.dr_minibatch(w, var, u, xs, mus, rotate=False)
    assert_allclose(np.asarray(u2), np.asarray(u))


def test_dr_gha_half_learns_principal_direction():
    # One dominant direction; W must align with it after a few batches.
    rng = np.random.default_rng(32)
    m, n = 6, 2
    direction = rng.normal(size=m).astype(np.float32)
    direction /= np.linalg.norm(direction)
    xs = np.outer(rng.normal(size=2000).astype(np.float32) * 3.0, direction)
    xs += 0.2 * rng.normal(size=xs.shape).astype(np.float32)
    w = stable_b(rng, n, m)
    var = jnp.ones(n, jnp.float32)
    u = jnp.eye(n, dtype=jnp.float32)
    mus = jnp.asarray([5e-3, 5e-3, 1e-3], jnp.float32)
    for start in range(0, 2000, 250):
        w, var, u = dr_kernel.dr_minibatch(
            w, var, u, jnp.asarray(xs[start:start + 250]), mus, rotate=False)
    w0 = np.asarray(w)[0]
    alignment = abs(float(np.dot(w0, direction))) / np.linalg.norm(w0)
    assert alignment > 0.95, alignment


def test_dr_sequential_semantics():
    rng = np.random.default_rng(33)
    w = stable_b(rng, 3, 5)
    var = jnp.ones(3, jnp.float32)
    u = jnp.eye(3, dtype=jnp.float32)
    xs = rand(rng, 2, 5)
    mus = jnp.asarray([5e-3, 5e-3, 1e-3], jnp.float32)
    fused = dr_kernel.dr_minibatch(w, var, u, xs, mus, rotate=True)
    s1 = dr_kernel.dr_minibatch(w, var, u, xs[0:1], mus, rotate=True)
    s2 = dr_kernel.dr_minibatch(*s1, xs[1:2], mus, rotate=True)
    for f, s in zip(fused, s2):
        assert_allclose(np.asarray(f), np.asarray(s), rtol=1e-5, atol=1e-6)
