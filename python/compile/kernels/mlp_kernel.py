"""Fused MLP-forward Pallas kernel (Layer 1).

The downstream classifier (paper section V.B: 2 hidden layers x 64
neurons) serves the inference path of the deployed system. All three
layers are fused into one kernel so the activations never leave VMEM —
for the paper's dimensions (n<=32 inputs, 64 hidden, <=10 classes) the
whole parameter set is ~20 KiB, far below the ~16 MiB VMEM budget, so a
single-tile program is the right shape (blocking would only add grid
overhead).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp_logits_kernel(w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, x_ref, o_ref):
    """Fused 3-layer forward pass: relu(relu(x W1^T + b1) W2^T + b2) W3^T + b3."""
    h1 = jnp.maximum(x_ref[...] @ w1_ref[...].T + b1_ref[...], 0.0)
    h2 = jnp.maximum(h1 @ w2_ref[...].T + b2_ref[...], 0.0)
    o_ref[...] = h2 @ w3_ref[...].T + b3_ref[...]


@jax.jit
def mlp_logits(w1, b1, w2, b2, w3, b3, xs):
    """Batch logits: (batch, in) -> (batch, classes)."""
    batch = xs.shape[0]
    classes = w3.shape[0]
    return pl.pallas_call(
        _mlp_logits_kernel,
        out_shape=jax.ShapeDtypeStruct((batch, classes), xs.dtype),
        interpret=True,
    )(w1, b1, w2, b2, w3, b3, xs)
