"""Fused EASI training-step Pallas kernel (Layer 1).

The paper's compute hot-spot is the five-stage EASI datapath (Fig. 3):
``y = Bx``, ``g = y^3``, the relative gradient
``F = [yy^T - I] + [g y^T - y g^T]``, the product ``F @ B`` and the
update ``B <- B - mu F B`` — all for one streamed sample, with the
updated ``B`` fed back for the next sample.

Hardware adaptation (DESIGN.md section "Hardware-Adaptation"): on the
FPGA this is a spatial pipeline; on TPU we fuse the *whole minibatch
recurrence* into a single Pallas program so `B` stays resident in VMEM
for the entire batch — one HBM read of (B, X) and one HBM write of the
new B, instead of per-sample round-trips. The sequential dependence
(sample t+1 needs the B updated by sample t) is expressed with a
`fori_loop` inside the kernel, mirroring the feedback path of the
datapath. The datapath mux of the paper (EASI / PCA-whitening /
rotation-only) becomes compile-time `whiten` / `rotate` flags: each mode
is AOT-lowered to its own executable, and the Rust coordinator swaps
executables at run time.

The rank-2 factored form used here is algebraically identical to Eq. 6
(see rust/src/easi/mod.rs for the derivation):

    u = B^T y,  v = B^T g
    [yy^T - I] B      = y u^T - B
    [g y^T - y g^T] B = g u^T - y v^T

which turns the O(n^2 m) matrix product into O(nm) outer products —
exactly the shape the MXU prefers (tall-skinny outer products
accumulating into the B tile held in VMEM).

Must be lowered with ``interpret=True``: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _easi_minibatch_kernel(b_ref, x_ref, mu_ref, o_ref, *, whiten, rotate, normalized):
    """Pallas kernel body: sequential EASI over the whole minibatch.

    b_ref:  (n, m) separation matrix (input)
    x_ref:  (batch, m) samples
    mu_ref: (1,) learning rate
    o_ref:  (n, m) updated separation matrix (output)
    """
    batch = x_ref.shape[0]
    b0 = b_ref[...]
    mu = mu_ref[0]

    def step(t, b):
        x = x_ref[t, :]                      # (m,)
        y = b @ x                            # (n,)  stage 1
        g = y * y * y                        # (n,)  stage 2
        u = b.T @ y                          # (m,)  shared factor
        delta = jnp.zeros_like(b)
        if whiten:
            dw = jnp.outer(y, u) - b         # [yy^T - I] B
            if normalized:
                dw = dw / (1.0 + mu * jnp.dot(y, y))
            delta = delta + dw
        if rotate:
            v = b.T @ g                      # (m,)
            dr = jnp.outer(g, u) - jnp.outer(y, v)
            if normalized:
                dr = dr / (1.0 + mu * jnp.abs(jnp.dot(y, g)))
            delta = delta + dr
        return b - mu * delta                # stage 5

    o_ref[...] = jax.lax.fori_loop(0, batch, step, b0)


@functools.partial(jax.jit, static_argnames=("whiten", "rotate", "normalized"))
def easi_minibatch(b, xs, mu, whiten=True, rotate=True, normalized=False):
    """Run the fused EASI minibatch kernel.

    Args:
      b: (n, m) separation matrix.
      xs: (batch, m) samples, consumed in order.
      mu: learning rate (scalar or shape-(1,) array, traced).
      whiten/rotate: the paper's datapath mux (static → baked into the
        lowered executable; one AOT artifact per mode).
      normalized: Cardoso's stabilised recursion.

    Returns the updated (n, m) separation matrix.
    """
    n, m = b.shape
    mu_arr = jnp.reshape(jnp.asarray(mu, dtype=b.dtype), (1,))
    kernel = functools.partial(
        _easi_minibatch_kernel,
        whiten=whiten,
        rotate=rotate,
        normalized=normalized,
    )
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, m), b.dtype),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(b, xs, mu_arr)


def _transform_kernel(b_ref, x_ref, o_ref):
    """y = x @ B^T for a whole batch — the inference path (Eq. 4)."""
    o_ref[...] = x_ref[...] @ b_ref[...].T


@jax.jit
def transform(b, xs):
    """Batch inference through the separation matrix: (batch, m) -> (batch, n)."""
    batch = xs.shape[0]
    n = b.shape[0]
    return pl.pallas_call(
        _transform_kernel,
        out_shape=jax.ShapeDtypeStruct((batch, n), xs.dtype),
        interpret=True,
    )(b, xs)
