"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the pytest suite checks the kernels against,
and they double as readable specifications of the math:

* EASI (paper Eq. 6):  ``B <- B - mu * F(y) @ B`` with
  ``F = [y y^T - I]*whiten + [g(y) y^T - y g(y)^T]*rotate``, ``g = y^3``.
* Random projection (paper Eq. 1): ``y = R x`` with ternary ``R``.
* MLP (paper section V.B): 2x64 ReLU classifier forward pass.

All functions are batch-first (rows are samples) to match the Rust
coordinator's memory layout.
"""

import jax
import jax.numpy as jnp


def cubic(y):
    """The paper's HOS nonlinearity g(y) = y^3."""
    return y * y * y


def easi_relative_gradient(y, whiten: bool, rotate: bool):
    """F = [yy^T - I]*whiten + [g y^T - y g^T]*rotate for one sample y (n,)."""
    n = y.shape[0]
    f = jnp.zeros((n, n), dtype=y.dtype)
    if whiten:
        f = f + jnp.outer(y, y) - jnp.eye(n, dtype=y.dtype)
    if rotate:
        g = cubic(y)
        f = f + jnp.outer(g, y) - jnp.outer(y, g)
    return f


def easi_step_ref(b, x, mu, whiten: bool, rotate: bool, normalized: bool = False):
    """One literal Eq. 6 update for a single sample x (m,). Returns new B."""
    y = b @ x
    if normalized:
        g = cubic(y)
        s2 = 1.0 / (1.0 + mu * jnp.dot(y, y))
        s4 = 1.0 / (1.0 + mu * jnp.abs(jnp.dot(y, g)))
        n = y.shape[0]
        f = jnp.zeros((n, n), dtype=y.dtype)
        if whiten:
            f = f + s2 * (jnp.outer(y, y) - jnp.eye(n, dtype=y.dtype))
        if rotate:
            f = f + s4 * (jnp.outer(g, y) - jnp.outer(y, g))
    else:
        f = easi_relative_gradient(y, whiten, rotate)
    return b - mu * f @ b


def easi_minibatch_ref(b, xs, mu, whiten: bool, rotate: bool, normalized: bool = False):
    """Sequential (streaming) EASI over a minibatch xs (batch, m).

    The FPGA pipeline consumes one sample per clock with the update fed
    back; semantically that is a sequential scan, which is what this
    reference (and the kernel) implement.
    """

    def step(carry, x):
        return easi_step_ref(carry, x, mu, whiten, rotate, normalized), None

    b_final, _ = jax.lax.scan(step, b, xs)
    return b_final


def rp_apply_ref(r, xs):
    """Random projection of a batch: (batch, m) @ (p, m)^T -> (batch, p)."""
    return xs @ r.T


def transform_ref(b, xs):
    """y = B x for a batch of samples: (batch, m) -> (batch, n)."""
    return xs @ b.T


def mlp_logits_ref(w1, b1, w2, b2, w3, b3, xs):
    """2-hidden-layer ReLU MLP forward pass.

    Weight convention matches the Rust implementation: ``wK`` has shape
    (out, in), so a layer computes ``relu(x @ wK.T + bK)``.
    """
    h1 = jnp.maximum(xs @ w1.T + b1, 0.0)
    h2 = jnp.maximum(h1 @ w2.T + b2, 0.0)
    return h2 @ w3.T + b3


def softmax_xent_ref(logits, labels_onehot):
    """Mean softmax cross-entropy."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(labels_onehot * logp, axis=-1))


def mlp_train_step_ref(params, xs, ys_onehot, lr, momentum):
    """One SGD+momentum minibatch step with manual backprop.

    ``params`` is a dict with w1,b1,w2,b2,w3,b3,vw1,vb1,...; returns
    (new_params, mean_loss). Manual gradients mirror the Rust trainer
    exactly (no reliance on AD through the kernel path).
    """
    w1, b1 = params["w1"], params["b1"]
    w2, b2 = params["w2"], params["b2"]
    w3, b3 = params["w3"], params["b3"]
    batch = xs.shape[0]

    # Forward, keeping activations.
    a1 = xs @ w1.T + b1
    h1 = jnp.maximum(a1, 0.0)
    a2 = h1 @ w2.T + b2
    h2 = jnp.maximum(a2, 0.0)
    logits = h2 @ w3.T + b3
    probs = jax.nn.softmax(logits, axis=-1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.sum(ys_onehot * logp, axis=-1))

    # Backward (mean over batch).
    d3 = (probs - ys_onehot) / batch          # (batch, c)
    gw3 = d3.T @ h2                           # (c, h)
    gb3 = jnp.sum(d3, axis=0)
    d2 = (d3 @ w3) * (a2 > 0.0)               # (batch, h)
    gw2 = d2.T @ h1
    gb2 = jnp.sum(d2, axis=0)
    d1 = (d2 @ w2) * (a1 > 0.0)               # (batch, h)
    gw1 = d1.T @ xs
    gb1 = jnp.sum(d1, axis=0)

    new = dict(params)
    for name, g in [
        ("w1", gw1), ("b1", gb1),
        ("w2", gw2), ("b2", gb2),
        ("w3", gw3), ("b3", gb3),
    ]:
        v = momentum * params["v" + name] - lr * g
        new["v" + name] = v
        new[name] = params[name] + v
    return new, loss


# ------------------------------------------------------ composed DR unit


def dr_step_ref(w, var, u, x, mu_w, beta, mu_rot, rotate,
                gha_clip=0.1, rot_clip=0.05, z_clamp=4.0):
    """One sample of the composed GHA + rotation unit (see dr_kernel.py
    and rust/src/pipeline/unit.rs). Returns (w', var', u')."""
    y = w @ x
    tril_yy = jnp.tril(jnp.outer(y, y))
    dw = mu_w * (jnp.outer(y, x) - tril_yy @ w)
    wn = jnp.sqrt(jnp.sum(w * w))
    dn = jnp.sqrt(jnp.sum(dw * dw))
    scale = jnp.minimum(1.0, gha_clip * wn / jnp.maximum(dn, 1e-30))
    w2 = w + scale * dw
    var2 = (1.0 - beta) * var + beta * y * y
    if not rotate:
        return w2, var2, u
    n = u.shape[0]
    z = (w2 @ x) / jnp.sqrt(jnp.maximum(var2, 1e-9))
    z = jnp.clip(z, -z_clamp, z_clamp)
    yr = u @ z
    g = yr ** 3
    uv = u.T @ yr
    vv = u.T @ g
    s4 = 1.0 / (1.0 + mu_rot * jnp.abs(jnp.dot(yr, g)))
    du = mu_rot * s4 * (jnp.outer(g, uv) - jnp.outer(yr, vv))
    un = jnp.sqrt(jnp.sum(u * u))
    dn2 = jnp.sqrt(jnp.sum(du * du))
    scale2 = jnp.minimum(1.0, rot_clip * un / jnp.maximum(dn2, 1e-30))
    u2 = u - scale2 * du
    un2 = jnp.sqrt(jnp.sum(u2 * u2))
    max_norm = 4.0 * jnp.sqrt(jnp.asarray(n, dtype=u.dtype))
    u2 = jnp.where(un2 > max_norm, u2 * (max_norm / un2), u2)
    return w2, var2, u2


def dr_minibatch_ref(w, var, u, xs, mu_w, beta, mu_rot, rotate):
    """Sequential scan of dr_step_ref over a minibatch."""
    for t in range(xs.shape[0]):
        w, var, u = dr_step_ref(w, var, u, xs[t], mu_w, beta, mu_rot, rotate)
    return w, var, u
