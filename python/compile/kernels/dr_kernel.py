"""Fused DR-unit training-step Pallas kernel (Layer 1).

One executable = one minibatch of the composed pipeline of
rust/src/pipeline/unit.rs (see the module docs there and DESIGN.md for
why the whitening half is Sanger's GHA rather than the paper's
multiplicative Eq. 3):

    per sample x:
      GHA:      y = W x
                dW = mu_w * (y x^T - tril(y y^T) W)      (Sanger)
                relative clip ||dW|| <= 0.1 ||W||
                W <- W + dW
                var <- (1-beta) var + beta y^2            (lambda-hat)
      rotation: z = clamp((W x)/sqrt(var), +-4)           (whitened)
                y_r = U z ; g = y_r^3
                dU = mu_rot/(1+mu_rot|y_r.g|) * (g u^T - y_r v^T)
                     with u = U^T y_r, v = U^T g           (EASI HOS term)
                relative clip ||dU|| <= 0.05 ||U||
                U <- U - dU ;  ||U|| clamped to 4 sqrt(n)

The whole minibatch recurrence runs inside one kernel (single VMEM
residency for W, var, U), with `rotate` a compile-time flag — the
paper's datapath mux becomes a choice of executable, which the Rust
coordinator swaps at run time (including for the rotation warm-up).

This must match rust/src/{gha,easi,pipeline/unit} step-for-step: the
cross-backend integration test (rust/tests/) trains both on identical
streams and compares state.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GHA_CLIP = 0.1
ROT_CLIP = 0.05
Z_CLAMP = 4.0


def _dr_kernel(w_ref, var_ref, u_ref, x_ref, mus_ref, ow_ref, ovar_ref, ou_ref, *, rotate):
    batch = x_ref.shape[0]
    n = w_ref.shape[0]
    mu_w = mus_ref[0]
    beta = mus_ref[1]
    mu_rot = mus_ref[2]
    max_u_norm = 4.0 * jnp.sqrt(jnp.asarray(n, dtype=w_ref.dtype))

    def step(t, carry):
        w, var, u = carry
        x = x_ref[t, :]
        # ---- GHA (Sanger) ----
        y = w @ x
        tril_yy = jnp.tril(jnp.outer(y, y))          # includes diagonal
        dw = mu_w * (jnp.outer(y, x) - tril_yy @ w)
        wn = jnp.sqrt(jnp.sum(w * w))
        dn = jnp.sqrt(jnp.sum(dw * dw))
        scale = jnp.minimum(1.0, GHA_CLIP * wn / jnp.maximum(dn, 1e-30))
        w2 = w + scale * dw
        var2 = (1.0 - beta) * var + beta * y * y
        if rotate:
            # ---- EASI rotation on the whitened output ----
            z = (w2 @ x) / jnp.sqrt(jnp.maximum(var2, 1e-9))
            z = jnp.clip(z, -Z_CLAMP, Z_CLAMP)
            yr = u @ z
            g = yr * yr * yr
            uv = u.T @ yr
            vv = u.T @ g
            s4 = 1.0 / (1.0 + mu_rot * jnp.abs(jnp.dot(yr, g)))
            du = mu_rot * s4 * (jnp.outer(g, uv) - jnp.outer(yr, vv))
            un = jnp.sqrt(jnp.sum(u * u))
            dn2 = jnp.sqrt(jnp.sum(du * du))
            scale2 = jnp.minimum(1.0, ROT_CLIP * un / jnp.maximum(dn2, 1e-30))
            u2 = u - scale2 * du
            un2 = jnp.sqrt(jnp.sum(u2 * u2))
            u2 = jnp.where(un2 > max_u_norm, u2 * (max_u_norm / un2), u2)
        else:
            u2 = u
        return (w2, var2, u2)

    w_fin, var_fin, u_fin = jax.lax.fori_loop(
        0, batch, step, (w_ref[...], var_ref[...], u_ref[...])
    )
    ow_ref[...] = w_fin
    ovar_ref[...] = var_fin
    ou_ref[...] = u_fin


@functools.partial(jax.jit, static_argnames=("rotate",))
def dr_minibatch(w, var, u, xs, mus, rotate=True):
    """Run the fused DR-unit minibatch kernel.

    Args:
      w:   (n, m) GHA subspace.
      var: (n,) lambda-hat variance estimates.
      u:   (n, n) rotation.
      xs:  (batch, m) samples, consumed in order.
      mus: (3,) = (mu_w, var beta, mu_rot).
      rotate: datapath mux (static; one executable per setting).

    Returns (w', var', u').
    """
    n, m = w.shape
    kernel = functools.partial(_dr_kernel, rotate=rotate)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n, m), w.dtype),
            jax.ShapeDtypeStruct((n,), var.dtype),
            jax.ShapeDtypeStruct((n, n), u.dtype),
        ),
        interpret=True,  # CPU-PJRT cannot run Mosaic custom-calls
    )(w, var, u, xs, mus)
