"""Random-projection Pallas kernel (Layer 1).

The paper's RP front end (Eq. 1, distribution of Fox et al. FPT'16) is
multiplication-free in hardware: the ternary matrix R gates a network of
adders/subtractors. On TPU the hardware-honest analogue is a dense
matmul against the (mostly zero) ternary matrix — the MXU's systolic
array handles the zeros for free, so the "mult-free" saving translates
to *storage* sparsity, not FLOP sparsity (see DESIGN.md
"Hardware-Adaptation"). The kernel therefore takes R as a dense (p, m)
f32 tile of {-1, 0, +1} values already scaled by the distribution's
isometry factor.

For large m (MNIST 784, Ads 1558) the input tile is split along m with
a BlockSpec grid so each block fits VMEM comfortably, accumulating the
partial products into the output tile.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rp_kernel(r_ref, x_ref, o_ref):
    """o = x @ r^T over one (batch_block, m) x (p, m) tile pair."""
    o_ref[...] = x_ref[...] @ r_ref[...].T


@jax.jit
def rp_apply(r, xs):
    """Project a batch: (batch, m) with (p, m) -> (batch, p).

    Small/medium m: single-tile kernel (the whole problem fits VMEM —
    for the paper's m=32, p=16 the tiles are a few KiB).
    """
    batch = xs.shape[0]
    p = r.shape[0]
    return pl.pallas_call(
        _rp_kernel,
        out_shape=jax.ShapeDtypeStruct((batch, p), xs.dtype),
        interpret=True,
    )(r, xs)


def _rp_blocked_kernel(r_ref, x_ref, o_ref):
    """Accumulating blocked kernel: grid walks the m (contraction) axis.

    Block b contributes x[:, b] @ r[:, b]^T; the first block initialises
    the output tile, later blocks accumulate — the standard Pallas
    reduction-grid idiom.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ r_ref[...].T


def rp_apply_blocked(r, xs, block_m=256):
    """Blocked projection for large input dimensionality.

    Splits the contraction axis m into `block_m`-wide tiles so each
    VMEM-resident block stays small; the output (batch, p) tile lives in
    VMEM across the whole reduction (revisited by every grid step).
    """
    batch, m = xs.shape
    p = r.shape[0]
    if m % block_m != 0:
        # Pad the contraction axis with zeros (zeros contribute nothing).
        pad = block_m - m % block_m
        xs = jnp.pad(xs, ((0, 0), (0, pad)))
        r = jnp.pad(r, ((0, 0), (0, pad)))
        m = m + pad
    grid = (m // block_m,)
    return pl.pallas_call(
        _rp_blocked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, block_m), lambda i: (0, i)),
            pl.BlockSpec((batch, block_m), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((batch, p), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, p), xs.dtype),
        interpret=True,
    )(r, xs)
