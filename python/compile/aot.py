"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the Rust runtime.

Run once at build time (``make artifacts``); Python never appears on the
request path. Every experiment configuration gets its own executable
variant (static shapes + static datapath mode — the software analogue of
the paper's FPGA bitstream + mux settings).

Interchange format is **HLO text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Output:
    artifacts/<name>.hlo.txt   one per variant
    artifacts/manifest.json    shapes/dtypes/arity for the Rust loader

Usage:
    python -m compile.aot --out-dir ../artifacts [--quick]
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32

# Training minibatch consumed by one EASI step executable. 256 amortises
# PJRT dispatch overhead; a b=1 variant handles stream tails (padding is
# NOT safe for the whitening term — a zero sample still applies -I).
EASI_BATCHES = (256, 1)
# Inference batches.
TRANSFORM_BATCHES = (256, 1)
# Classifier minibatch (matches the Rust trainer's default).
MLP_BATCH = 32
MLP_PREDICT_BATCHES = (256, 1)
MLP_HIDDEN = 64

# (m, n) for plain-EASI variants — Table I rows 1 and 3.
EASI_DIMS = ((32, 16), (32, 8))
# (m, p, n) for the proposed RP+EASI variants — Table I rows 2 and 4.
RP_EASI_DIMS = ((32, 24, 16), (32, 16, 8))
# Classifier input dims (the DR output dims) and classes (waveform: 3).
MLP_DIMS = (16, 8)
MLP_CLASSES = 3


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(fn, arg_specs):
    return to_hlo_text(jax.jit(fn).lower(*arg_specs))


def dims_of(s):
    return list(s.shape)


def build_catalog(quick=False):
    """Yield (name, fn, arg_specs, description) for every variant."""
    easi_batches = EASI_BATCHES if not quick else (8,)
    transform_batches = TRANSFORM_BATCHES if not quick else (8,)
    predict_batches = MLP_PREDICT_BATCHES if not quick else (8,)
    easi_dims = EASI_DIMS if not quick else ((8, 4),)
    rp_dims = RP_EASI_DIMS if not quick else ((8, 6, 4),)
    mlp_dims = MLP_DIMS if not quick else (4,)

    catalog = []
    # Composed DR-unit steps (the production training path).
    for m, n in easi_dims:
        for b in easi_batches:
            for rotate in (True, False):
                tag = "full" if rotate else "whiten"
                catalog.append((
                    f"dr_{tag}_m{m}_n{n}_b{b}",
                    model.dr_variant(rotate),
                    [spec(n, m), spec(n), spec(n, n), spec(b, m), spec(3)],
                    f"Composed DR unit ({'GHA+rotation' if rotate else 'GHA whitening only'}), "
                    f"{m}->{n}, batch {b}; state (W, var, U), mus=(mu_w, beta, mu_rot)",
                ))
    for m, p, n in rp_dims:
        for b in easi_batches:
            for rotate in (True, False):
                tag = "full" if rotate else "whiten"
                catalog.append((
                    f"rp_dr_{tag}_m{m}_p{p}_n{n}_b{b}",
                    model.rp_dr_variant(rotate),
                    [spec(n, p), spec(n), spec(n, n), spec(p, m), spec(b, m), spec(3)],
                    f"RP front end + DR unit ({tag}), {m}->{p}->{n}, batch {b}",
                ))
    # Literal Eq. 6 EASI datapath variants (paper-faithful; kept for the
    # kernel benches and the frozen-subspace ablation).
    for m, n in easi_dims:
        for b in easi_batches:
            catalog.append((
                f"easi_full_norm_m{m}_n{n}_b{b}",
                model.easi_variant(True, True, normalized=True),
                [spec(n, m), spec(b, m), spec(1)],
                f"Full EASI (Eq. 6, normalised) minibatch step, {m}->{n}, batch {b}",
            ))
            catalog.append((
                f"easi_whiten_m{m}_n{n}_b{b}",
                model.easi_variant(True, False),
                [spec(n, m), spec(b, m), spec(1)],
                f"PCA-whitening mode (Eq. 3 — HOS term muxed out), {m}->{n}, batch {b}",
            ))
        for b in transform_batches:
            catalog.append((
                f"transform_m{m}_n{n}_b{b}",
                model.transform_variant(),
                [spec(n, m), spec(b, m)],
                f"Inference Y = X B^T, {m}->{n}, batch {b}",
            ))
    for m, p, n in rp_dims:
        for b in easi_batches:
            catalog.append((
                f"rp_easi_norm_m{m}_p{p}_n{n}_b{b}",
                model.rp_easi_variant(normalized=True),
                [spec(n, p), spec(p, m), spec(b, m), spec(1)],
                f"Proposed pipeline: ternary RP {m}->{p} then rotation-only "
                f"EASI {p}->{n} (one fused executable), batch {b}",
            ))
        for b in transform_batches:
            catalog.append((
                f"rp_transform_m{m}_p{p}_n{n}_b{b}",
                model.rp_transform_variant(),
                [spec(n, p), spec(p, m), spec(b, m)],
                f"Inference through RP + B cascade, {m}->{p}->{n}, batch {b}",
            ))
    for d in mlp_dims:
        h, c = MLP_HIDDEN, MLP_CLASSES
        params = [
            spec(h, d), spec(h),      # w1, b1
            spec(h, h), spec(h),      # w2, b2
            spec(c, h), spec(c),      # w3, b3
        ]
        velocities = [
            spec(h, d), spec(h),
            spec(h, h), spec(h),
            spec(c, h), spec(c),
        ]
        b = MLP_BATCH if not quick else 8
        catalog.append((
            f"mlp_train_in{d}_h{h}_c{c}_b{b}",
            model.mlp_train_variant(),
            params + velocities + [spec(b, d), spec(b, c), spec(1), spec(1)],
            f"One SGD+momentum step of the 2x{h} classifier, in={d}, batch {b}; "
            "returns 12 updated tensors + mean loss",
        ))
        for pb in predict_batches:
            catalog.append((
                f"mlp_predict_in{d}_h{h}_c{c}_b{pb}",
                model.mlp_predict_variant(),
                params + [spec(pb, d)],
                f"Classifier logits, in={d}, batch {pb}",
            ))
    return catalog


def input_fingerprint():
    """Hash of the compile-path sources — lets `make` skip rebuilds."""
    here = os.path.dirname(os.path.abspath(__file__))
    digest = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    digest.update(fh.read())
    return digest.hexdigest()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes only (CI smoke)")
    ap.add_argument("--only", default=None,
                    help="substring filter on variant names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    fingerprint = input_fingerprint()
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    if os.path.exists(manifest_path) and args.only is None:
        try:
            with open(manifest_path) as fh:
                old = json.load(fh)
            if old.get("fingerprint") == fingerprint and not args.quick:
                print(f"artifacts up to date ({len(old['artifacts'])} variants); skipping")
                return
        except (json.JSONDecodeError, KeyError):
            pass

    catalog = build_catalog(quick=args.quick)
    if args.only:
        catalog = [c for c in catalog if args.only in c[0]]
    entries = []
    for name, fn, arg_specs, desc in catalog:
        lowered_name = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, lowered_name)
        print(f"lowering {name} ...", flush=True)
        text = lower_variant(fn, arg_specs)
        with open(path, "w") as fh:
            fh.write(text)
        # Output arity: run shape inference via jax.eval_shape.
        out_shapes = jax.eval_shape(fn, *arg_specs)
        entries.append({
            "name": name,
            "file": lowered_name,
            "description": desc,
            "inputs": [{"shape": dims_of(s), "dtype": "f32"} for s in arg_specs],
            "outputs": [{"shape": dims_of(s), "dtype": "f32"} for s in out_shapes],
        })
    manifest = {
        "version": 1,
        "fingerprint": fingerprint,
        "jax_version": jax.__version__,
        "artifacts": entries,
    }
    with open(manifest_path, "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"wrote {len(entries)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    sys.exit(main())
