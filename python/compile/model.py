"""Layer 2 — the JAX compute graphs that get AOT-lowered to HLO text.

Each public function here is one *executable variant* loaded by the Rust
runtime. They compose the Layer-1 Pallas kernels into the training /
inference steps of the paper's system:

* ``easi_train_step``    — one fused minibatch of EASI training
                            (any datapath mode, static flags)
* ``rp_easi_train_step`` — the paper's proposal: ternary RP front end
                            then rotation-only EASI, one executable
* ``transform``          — Eq. 4 inference ``Y = X B^T``
* ``rp_transform``       — RP + transform inference cascade
* ``mlp_train_step``     — one SGD+momentum minibatch of the downstream
                            classifier (manual backprop, matches the
                            Rust trainer bit-for-bit in structure)
* ``mlp_logits``         — classifier inference (fused Pallas kernel)

Conventions (shared with the Rust side, see rust/src/runtime):
rows are samples; matrices are row-major; weights are (out, in);
``mu``/``lr`` are shape-(1,) f32 inputs so the coordinator can anneal
them at run time without recompiling.
"""

import jax
import jax.numpy as jnp

from compile.kernels import dr_kernel, easi_kernel, mlp_kernel, rp_kernel


# ---------------------------------------------------------------- EASI


def easi_train_step(b, xs, mu, *, whiten=True, rotate=True, normalized=False):
    """One minibatch of streaming EASI training; returns the new B.

    The whole sequential recurrence runs inside a single fused Pallas
    kernel (one VMEM residency for B — see easi_kernel.py).
    """
    return easi_kernel.easi_minibatch(
        b, xs, mu, whiten=whiten, rotate=rotate, normalized=normalized
    )


def rp_easi_train_step(b, r, xs, mu, *, normalized=False):
    """The paper's proposed pipeline as one executable: project the batch
    through the ternary R (m -> p), then rotation-only EASI (p -> n).

    XLA fuses the projection into the scan's operand; R is a run-time
    input so re-drawing the projection does not require recompilation.
    """
    projected = rp_kernel.rp_apply(r, xs)
    return easi_kernel.easi_minibatch(
        b, projected, mu, whiten=False, rotate=True, normalized=normalized
    )


def transform(b, xs):
    """Inference: Y = X @ B^T (Eq. 4)."""
    return easi_kernel.transform(b, xs)


def rp_transform(b, r, xs):
    """Inference through the full proposed cascade: RP then B."""
    return easi_kernel.transform(b, rp_kernel.rp_apply(r, xs))


# ----------------------------------------------------------------- MLP


def mlp_logits(w1, b1, w2, b2, w3, b3, xs):
    """Classifier inference (fused Pallas kernel)."""
    return mlp_kernel.mlp_logits(w1, b1, w2, b2, w3, b3, xs)


def mlp_train_step(w1, b1, w2, b2, w3, b3,
                   vw1, vb1, vw2, vb2, vw3, vb3,
                   xs, ys_onehot, lr, momentum):
    """One SGD+momentum minibatch step of the 2x64 classifier.

    Flat-argument form (12 params + batch + hyper-params) because the
    PJRT boundary passes positional buffers; returns the 12 updated
    tensors plus the scalar mean loss. Manual backprop — identical
    structure to rust/src/mlp (and to ref.mlp_train_step_ref, which the
    tests check against).
    """
    batch = xs.shape[0]
    lr = jnp.reshape(lr, ())
    momentum = jnp.reshape(momentum, ())

    a1 = xs @ w1.T + b1
    h1 = jnp.maximum(a1, 0.0)
    a2 = h1 @ w2.T + b2
    h2 = jnp.maximum(a2, 0.0)
    logits = h2 @ w3.T + b3
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.sum(ys_onehot * logp, axis=-1))
    probs = jnp.exp(logp)

    d3 = (probs - ys_onehot) / batch
    gw3 = d3.T @ h2
    gb3 = jnp.sum(d3, axis=0)
    d2 = (d3 @ w3) * (a2 > 0.0)
    gw2 = d2.T @ h1
    gb2 = jnp.sum(d2, axis=0)
    d1 = (d2 @ w2) * (a1 > 0.0)
    gw1 = d1.T @ xs
    gb1 = jnp.sum(d1, axis=0)

    outs = []
    for p, v, g in [
        (w1, vw1, gw1), (b1, vb1, gb1),
        (w2, vw2, gw2), (b2, vb2, gb2),
        (w3, vw3, gw3), (b3, vb3, gb3),
    ]:
        v_new = momentum * v - lr * g
        outs.append(p + v_new)
        outs.append(v_new)
    # Order: w1, vw1, b1, vb1, w2, vw2, ... then loss.
    return tuple(outs) + (loss,)


# -------------------------------------------------- composed DR unit


def dr_train_step(w, var, u, xs, mus, *, rotate=True):
    """One minibatch of the composed GHA + rotation unit (the production
    training step; see dr_kernel.py)."""
    return dr_kernel.dr_minibatch(w, var, u, xs, mus, rotate=rotate)


def rp_dr_train_step(w, var, u, r, xs, mus, *, rotate=True):
    """The paper's proposed pipeline as one executable: ternary RP
    projection fused in front of the DR unit."""
    projected = rp_kernel.rp_apply(r, xs)
    return dr_kernel.dr_minibatch(w, var, u, projected, mus, rotate=rotate)


def dr_variant(rotate):
    def fn(w, var, u, xs, mus):
        return dr_train_step(w, var, u, xs, mus, rotate=rotate)

    fn.__name__ = "dr_step_" + ("full" if rotate else "whiten")
    return fn


def rp_dr_variant(rotate):
    def fn(w, var, u, r, xs, mus):
        return rp_dr_train_step(w, var, u, r, xs, mus, rotate=rotate)

    fn.__name__ = "rp_dr_step_" + ("full" if rotate else "whiten")
    return fn


# ------------------------------------------------- variant registry


def easi_variant(whiten, rotate, normalized=False):
    """Return a positional-args function for AOT lowering of one EASI
    datapath mode (static flags baked in)."""

    def fn(b, xs, mu):
        return (easi_train_step(
            b, xs, mu, whiten=whiten, rotate=rotate, normalized=normalized
        ),)

    mode = {
        (True, True): "full",
        (True, False): "whiten",
        (False, True): "rot",
    }[(whiten, rotate)]
    fn.__name__ = f"easi_step_{mode}" + ("_norm" if normalized else "")
    return fn


def rp_easi_variant(normalized=False):
    def fn(b, r, xs, mu):
        return (rp_easi_train_step(b, r, xs, mu, normalized=normalized),)

    fn.__name__ = "rp_easi_step" + ("_norm" if normalized else "")
    return fn


def transform_variant():
    def fn(b, xs):
        return (transform(b, xs),)

    fn.__name__ = "transform"
    return fn


def rp_transform_variant():
    def fn(b, r, xs):
        return (rp_transform(b, r, xs),)

    fn.__name__ = "rp_transform"
    return fn


def mlp_predict_variant():
    def fn(w1, b1, w2, b2, w3, b3, xs):
        return (mlp_logits(w1, b1, w2, b2, w3, b3, xs),)

    fn.__name__ = "mlp_predict"
    return fn


def mlp_train_variant():
    def fn(*args):
        return mlp_train_step(*args)

    fn.__name__ = "mlp_train_step"
    return fn
