//! Integration tests for the multi-tenant serving layer.
//!
//! The load-bearing claim is the first test: a fixed-point session that
//! is checkpoint-evicted mid-training and transparently restored
//! continues **bit-exactly** — its forward transform and separation
//! matrix equal an uninterrupted oracle run word for word, across
//! uniform and mixed precision plans and both quantization modes
//! (bit-exact and STE). That is what makes eviction a safe memory cap
//! rather than a numerics event.

use dimred::config::ExperimentConfig;
use dimred::coordinator::{Batch, Session};
use dimred::fxp::Precision;
use dimred::linalg::Mat;
use dimred::serve::workload::{self, ArrivalPattern, ServeOptions};
use dimred::serve::{SessionRegistry, Shard, ShardOptions};

fn cfg(precision: &str) -> ExperimentConfig {
    ExperimentConfig {
        precision: Precision::parse(precision).unwrap(),
        rot_warmup: 32,
        train_classifier: false,
        ..Default::default()
    }
}

fn batch(dim: usize, salt: usize) -> Batch {
    Batch::Full(Mat::from_fn(64, dim, |i, j| {
        ((i * 31 + j * 7 + salt * 13) % 17) as f32 / 17.0 - 0.5
    }))
}

#[test]
fn evicted_sessions_restore_bit_exactly() {
    // Uniform bit-exact, uniform STE, and a mixed-width plan with STE:
    // every checkpointed quantity is raw fixed-point words, so restore
    // must be exact in all three.
    for precision in [
        "q4.12",
        "rp=q4.12,whiten=q4.12,rot=q4.12,qat=ste",
        "rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste",
    ] {
        let c = cfg(precision);
        let probe = Mat::from_fn(48, c.input_dim, |i, j| {
            ((i * 13 + j * 5) % 23) as f32 / 23.0 - 0.5
        });

        // Oracle: one uninterrupted session over 8 batches.
        let mut oracle = Session::new(&c, None).unwrap();
        for salt in 0..8 {
            oracle.ingest(&batch(c.input_dim, salt)).unwrap();
        }

        // Test path: same stream, but collapsed to a checkpoint after
        // batch 4 and transparently restored by the next touch.
        let mut reg = SessionRegistry::new();
        reg.create("t", &c).unwrap();
        for salt in 0..4 {
            let s = reg.session_mut("t").unwrap();
            s.ingest(&batch(c.input_dim, salt)).unwrap();
        }
        reg.evict("t").unwrap();
        assert!(!reg.is_live("t"));
        for salt in 4..8 {
            let s = reg.session_mut("t").unwrap();
            s.ingest(&batch(c.input_dim, salt)).unwrap();
        }
        assert_eq!(reg.restores("t"), 1);

        let restored = reg.session_mut("t").unwrap();
        assert_eq!(
            oracle.metrics().samples_in,
            restored.metrics().samples_in,
            "metrics diverged for {precision}"
        );
        let a = oracle.trainer().transform_rows(&probe);
        let b = restored.trainer().transform_rows(&probe);
        assert_eq!(
            a.as_slice(),
            b.as_slice(),
            "forward transform diverged after evict/restore for {precision}"
        );
        assert_eq!(
            oracle.trainer().separation_matrix().as_slice(),
            restored.trainer().separation_matrix().as_slice(),
            "separation matrix diverged after evict/restore for {precision}"
        );
    }
}

#[test]
fn round_robin_quantum_prevents_starvation() {
    // A heavy tenant with a 10:1 backlog must not starve the light one:
    // the per-round quantum hands each live tenant the same share.
    let c = cfg("f32");
    let mut shard = Shard::new(
        0,
        ShardOptions {
            queue_depth: 128,
            quantum: 2,
            ..Default::default()
        },
    );
    let heavy = shard.add_tenant("heavy", &c).unwrap();
    let light = shard.add_tenant("light", &c).unwrap();
    for i in 0..100 {
        heavy.send(batch(c.input_dim, i)).unwrap();
    }
    for i in 0..10 {
        light.send(batch(c.input_dim, i)).unwrap();
    }
    drop(heavy);
    drop(light);

    for round in 0..5 {
        let stats = shard.poll_round().unwrap();
        assert!(stats.batches > 0, "round {round} did no work");
    }
    // 5 rounds × quantum 2: perfectly even shares, despite the 10:1
    // backlog skew.
    assert_eq!(shard.registry().metrics_of("heavy").unwrap().batches, 10);
    assert_eq!(shard.registry().metrics_of("light").unwrap().batches, 10);

    shard.run_to_completion().unwrap();
    assert_eq!(shard.registry().metrics_of("heavy").unwrap().batches, 100);
    assert_eq!(shard.registry().metrics_of("light").unwrap().batches, 10);
}

#[test]
fn multi_tenant_workload_reports_and_validates() {
    // Threaded end-to-end pass: 8 tenants (mixed f32/fxp preset) on 2
    // shards, skewed arrivals, per-tenant telemetry — and the report
    // must survive its own golden-schema validation.
    let opts = ServeOptions {
        tenants: 8,
        shards: 2,
        batch: 32,
        batches_per_tenant: 3,
        arrival: ArrivalPattern::Skewed { ratio: 3 },
        telemetry: true,
        ..ServeOptions::default()
    };
    let r = workload::run(&opts).unwrap();
    assert_eq!(r.tenants.len(), 8);
    assert_eq!(r.shards, 2);
    // Tenant 0 carried the skew; everyone else sent the base count.
    assert_eq!(r.tenants[0].batches, 9);
    assert!(r.tenants[1..].iter().all(|t| t.batches == 3));
    // The preset really does put mixed graph shapes in flight at once.
    assert!(r.tenants.iter().any(|t| t.precision == "f32"));
    assert!(r.tenants.iter().any(|t| t.precision != "f32"));
    assert!(r.tenants.iter().all(|t| t.telemetry.is_some()));
    assert!(r.aggregate_samples_per_s > 0.0);

    let json = dimred::serve::report::to_json(&opts, &r);
    let parsed = dimred::util::json::Json::parse(&json.to_string_pretty()).unwrap();
    dimred::serve::report::validate(&parsed, true).unwrap();
}
