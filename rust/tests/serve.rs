//! Integration tests for the multi-tenant serving layer.
//!
//! The load-bearing claim is the first test: a fixed-point session that
//! is checkpoint-evicted mid-training and transparently restored
//! continues **bit-exactly** — its forward transform and separation
//! matrix equal an uninterrupted oracle run word for word, across
//! uniform and mixed precision plans and both quantization modes
//! (bit-exact and STE). That is what makes eviction a safe memory cap
//! rather than a numerics event.

use dimred::config::ExperimentConfig;
use dimred::coordinator::{Batch, Session};
use dimred::fxp::Precision;
use dimred::linalg::Mat;
use dimred::serve::workload::{self, ArrivalPattern, ServeOptions};
use dimred::serve::{SessionRegistry, Shard, ShardOptions};

fn cfg(precision: &str) -> ExperimentConfig {
    ExperimentConfig {
        precision: Precision::parse(precision).unwrap(),
        rot_warmup: 32,
        train_classifier: false,
        ..Default::default()
    }
}

fn batch(dim: usize, salt: usize) -> Batch {
    Batch::Full(Mat::from_fn(64, dim, |i, j| {
        ((i * 31 + j * 7 + salt * 13) % 17) as f32 / 17.0 - 0.5
    }))
}

#[test]
fn evicted_sessions_restore_bit_exactly() {
    // Uniform bit-exact, uniform STE, and a mixed-width plan with STE:
    // every checkpointed quantity is raw fixed-point words, so restore
    // must be exact in all three.
    for precision in [
        "q4.12",
        "rp=q4.12,whiten=q4.12,rot=q4.12,qat=ste",
        "rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste",
    ] {
        let c = cfg(precision);
        let probe = Mat::from_fn(48, c.input_dim, |i, j| {
            ((i * 13 + j * 5) % 23) as f32 / 23.0 - 0.5
        });

        // Oracle: one uninterrupted session over 8 batches.
        let mut oracle = Session::new(&c, None).unwrap();
        for salt in 0..8 {
            oracle.ingest(&batch(c.input_dim, salt)).unwrap();
        }

        // Test path: same stream, but collapsed to a checkpoint after
        // batch 4 and transparently restored by the next touch.
        let mut reg = SessionRegistry::new();
        reg.create("t", &c).unwrap();
        for salt in 0..4 {
            let s = reg.session_mut("t").unwrap();
            s.ingest(&batch(c.input_dim, salt)).unwrap();
        }
        reg.evict("t").unwrap();
        assert!(!reg.is_live("t"));
        for salt in 4..8 {
            let s = reg.session_mut("t").unwrap();
            s.ingest(&batch(c.input_dim, salt)).unwrap();
        }
        assert_eq!(reg.restores("t"), 1);

        let restored = reg.session_mut("t").unwrap();
        assert_eq!(
            oracle.metrics().samples_in,
            restored.metrics().samples_in,
            "metrics diverged for {precision}"
        );
        let a = oracle.trainer().transform_rows(&probe);
        let b = restored.trainer().transform_rows(&probe);
        assert_eq!(
            a.as_slice(),
            b.as_slice(),
            "forward transform diverged after evict/restore for {precision}"
        );
        assert_eq!(
            oracle.trainer().separation_matrix().as_slice(),
            restored.trainer().separation_matrix().as_slice(),
            "separation matrix diverged after evict/restore for {precision}"
        );
    }
}

#[test]
fn round_robin_quantum_prevents_starvation() {
    // A heavy tenant with a 10:1 backlog must not starve the light one:
    // the per-round quantum hands each live tenant the same share.
    let c = cfg("f32");
    let mut shard = Shard::new(
        0,
        ShardOptions {
            queue_depth: 128,
            quantum: 2,
            ..Default::default()
        },
    );
    let heavy = shard.add_tenant("heavy", &c).unwrap();
    let light = shard.add_tenant("light", &c).unwrap();
    for i in 0..100 {
        heavy.send(batch(c.input_dim, i)).unwrap();
    }
    for i in 0..10 {
        light.send(batch(c.input_dim, i)).unwrap();
    }
    drop(heavy);
    drop(light);

    for round in 0..5 {
        let stats = shard.poll_round().unwrap();
        assert!(stats.batches > 0, "round {round} did no work");
    }
    // 5 rounds × quantum 2: perfectly even shares, despite the 10:1
    // backlog skew.
    assert_eq!(shard.registry().metrics_of("heavy").unwrap().batches, 10);
    assert_eq!(shard.registry().metrics_of("light").unwrap().batches, 10);

    shard.run_to_completion().unwrap();
    assert_eq!(shard.registry().metrics_of("heavy").unwrap().batches, 100);
    assert_eq!(shard.registry().metrics_of("light").unwrap().batches, 10);
}

#[test]
fn multi_tenant_workload_reports_and_validates() {
    // Threaded end-to-end pass: 8 tenants (mixed f32/fxp preset) on 2
    // shards, skewed arrivals, per-tenant telemetry — and the report
    // must survive its own golden-schema validation.
    let opts = ServeOptions {
        tenants: 8,
        shards: 2,
        batch: 32,
        batches_per_tenant: 3,
        arrival: ArrivalPattern::Skewed { ratio: 3 },
        telemetry: true,
        ..ServeOptions::default()
    };
    let r = workload::run(&opts).unwrap();
    assert_eq!(r.tenants.len(), 8);
    assert_eq!(r.shards, 2);
    // Tenant 0 carried the skew; everyone else sent the base count.
    assert_eq!(r.tenants[0].batches, 9);
    assert!(r.tenants[1..].iter().all(|t| t.batches == 3));
    // The preset really does put mixed graph shapes in flight at once.
    assert!(r.tenants.iter().any(|t| t.precision == "f32"));
    assert!(r.tenants.iter().any(|t| t.precision != "f32"));
    assert!(r.tenants.iter().all(|t| t.telemetry.is_some()));
    assert!(r.aggregate_samples_per_s > 0.0);

    let json = dimred::serve::report::to_json(&opts, &r);
    let parsed = dimred::util::json::Json::parse(&json.to_string_pretty()).unwrap();
    dimred::serve::report::validate(&parsed, true).unwrap();
}

#[test]
fn pipelined_shard_is_bit_identical_to_serial_under_faults() {
    // The pipelined scheduler's load-bearing claim: overlapping
    // staging with commits and fusing same-plan batches into mega-tiles
    // must change NOTHING observable — trainer state word for word,
    // per-tenant metrics, per-stage telemetry sample counts, and fault
    // containment — across uniform bit-exact, uniform STE, mixed-width
    // STE and f32 plans, with a permanently faulting tenant in the mix.
    for precision in [
        "f32",
        "q4.12",
        "rp=q4.12,whiten=q4.12,rot=q4.12,qat=ste",
        "rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste",
    ] {
        let mk = |pipeline: bool| {
            let mut shard = Shard::new(
                0,
                ShardOptions {
                    queue_depth: 16,
                    quantum: 4,
                    pipeline,
                    ..Default::default()
                },
            );
            let c_main = ExperimentConfig {
                telemetry: true,
                ..cfg(precision)
            };
            let c_f32 = ExperimentConfig {
                telemetry: true,
                ..cfg("f32")
            };
            let a = shard.add_tenant("t_main", &c_main).unwrap();
            let b = shard.add_tenant("t_f32", &c_f32).unwrap();
            let bad = shard.add_tenant("t_bad", &c_f32).unwrap();
            shard.set_fault_plan(
                dimred::serve::FaultPlan::parse("t_bad:ingest@1").unwrap(),
                2018,
            );
            for salt in 0..8 {
                a.send(batch(c_main.input_dim, salt)).unwrap();
                b.send(batch(c_f32.input_dim, 100 + salt)).unwrap();
                bad.send(batch(c_f32.input_dim, 200 + salt)).unwrap();
            }
            drop(a);
            drop(b);
            drop(bad);
            shard.run_to_completion().unwrap();
            shard
        };
        let mut serial = mk(false);
        let mut piped = mk(true);
        assert!(
            piped.pipeline_stats().fused_tiles > 0,
            "{precision}: pipelined run must fuse mega-tiles"
        );

        let dim = cfg("f32").input_dim;
        let probe = Mat::from_fn(32, dim, |i, j| ((i * 13 + j * 5) % 23) as f32 / 23.0 - 0.5);
        for tenant in ["t_main", "t_f32"] {
            let (samples, batches, fwd, sep, tel_s) = {
                let s = serial.registry_mut().session_mut(tenant).unwrap();
                (
                    s.metrics().samples_in,
                    s.metrics().batches,
                    s.trainer().transform_rows(&probe),
                    s.trainer().separation_matrix(),
                    s.trainer().telemetry_snapshot().unwrap(),
                )
            };
            let p = piped.registry_mut().session_mut(tenant).unwrap();
            assert_eq!(samples, p.metrics().samples_in, "{precision}/{tenant} samples");
            assert_eq!(batches, p.metrics().batches, "{precision}/{tenant} batches");
            assert_eq!(
                fwd.as_slice(),
                p.trainer().transform_rows(&probe).as_slice(),
                "{precision}/{tenant}: forward transform diverged under pipelining"
            );
            assert_eq!(
                sep.as_slice(),
                p.trainer().separation_matrix().as_slice(),
                "{precision}/{tenant}: separation matrix diverged under pipelining"
            );
            // Per-stage telemetry sample attribution survives fusion:
            // a mega-tile's rows are credited exactly like the serial
            // per-batch tiles.
            let tel_p = p.trainer().telemetry_snapshot().unwrap();
            let counts = |snap: &dimred::telemetry::TelemetrySnapshot| {
                snap.all()
                    .map(|s| (s.name.clone(), s.samples))
                    .collect::<Vec<_>>()
            };
            assert_eq!(
                counts(&tel_s),
                counts(&tel_p),
                "{precision}/{tenant}: telemetry sample counts diverged"
            );
        }
        // Fault containment is scheduler-independent: same breaker
        // arithmetic, same drop accounting, nothing ingested.
        let outcome = |shard: &Shard, tenant: &str| {
            shard
                .tenant_outcomes()
                .into_iter()
                .find(|o| o.tenant == tenant)
                .unwrap()
        };
        let (bs, bp) = (outcome(&serial, "t_bad"), outcome(&piped, "t_bad"));
        assert!(bs.health.quarantined && bp.health.quarantined);
        assert_eq!(bs.health.faults, bp.health.faults, "{precision} faults");
        assert_eq!(bs.health.dropped_batches, bp.health.dropped_batches);
        assert_eq!(bs.samples, 0);
        assert_eq!(bp.samples, 0);
    }
}
