//! Golden mapping tests: every legacy `StageSpec` / pipeline-mode form,
//! rebuilt as a stage graph, must reproduce the pre-graph fused
//! datapath **bit for bit**.
//!
//! The legacy oracle is reconstructed inline from the kernels the fused
//! paths were made of (`ingress_tile` + `FxpDrUnit` / `FxpEasiRot` for
//! fixed point, `RandomProjection` + `DrUnit` / `EasiTrainer` for f32)
//! — exactly the arithmetic the old `DrPipeline::fit_fixed` /
//! `NativeTrainer` engines executed. Raw-word identity is asserted
//! through exact `f32` equality of the dequantized outputs (dequantize
//! is injective at these widths), across uniform and mixed
//! `PrecisionPlan`s and both training modes (BitExact + STE).

use dimred::config::{ExperimentConfig, PipelineMode};
use dimred::coordinator::{Batch, Trainer};
use dimred::easi::EasiMode;
use dimred::fxp::kernels::ingress_tile;
use dimred::fxp::{FxpDrUnit, FxpEasiRot, FxpRp, FxpUnitConfig, Precision, PrecisionPlan, Scratch};
use dimred::linalg::Mat;
use dimred::pipeline::unit::{DrUnit, DrUnitConfig};
use dimred::pipeline::{DrPipeline, PipelineSpec, RpStage, StageSpec};
use dimred::rp::{RandomProjection, RpDistribution};
use dimred::stage::GraphSpec;

const M: usize = 32;
const P: usize = 16;
const N: usize = 8;

fn data(rows: usize, seed: u64) -> Mat {
    Mat::from_fn(rows, M, |i, j| {
        (((i as u64 * 31 + j as u64 * 7 + seed * 13) % 97) as f32 / 97.0 - 0.5) * 2.0
    })
}

/// The plan grid the acceptance criterion names: uniform and mixed,
/// bit-exact and STE.
fn plan_grid() -> Vec<Precision> {
    [
        "q4.12",
        "rp=q8.16,whiten=q4.12,rot=q1.15",
        "q4.4,qat=ste",
        "rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste",
    ]
    .iter()
    .map(|s| Precision::parse(s).expect("static plan"))
    .collect()
}

/// The legacy fixed-point ingress: quantize at the entry format,
/// project through the quantized RP network, requantize into the
/// trained stage's format (copied from the pre-graph `fit_fixed`).
fn legacy_ingress(
    frp: &FxpRp,
    plan: &PrecisionPlan,
    stage_in_spec: dimred::fxp::FxpSpec,
    x: &Mat,
) -> (Vec<i32>, f32) {
    let entry = plan.rp;
    let prescale = plan.entry_prescale(true, &stage_in_spec);
    let mut ingress = Scratch::new();
    ingress_tile(
        Some(frp),
        &entry,
        &stage_in_spec,
        prescale,
        x.as_slice(),
        x.rows_count(),
        &mut ingress,
    );
    (ingress.stage.clone(), prescale)
}

#[test]
fn ica_fixed_graph_is_bit_identical_to_fused_unit() {
    let x = data(500, 3);
    let (seed, epochs) = (7u64, 2usize);
    for precision in plan_grid() {
        let plan = precision.plan().unwrap();
        // ---- legacy oracle: the pre-graph fit_fixed arithmetic.
        let rp = RandomProjection::new(M, P, RpDistribution::Ternary, seed).unit_variance();
        let frp = FxpRp::from_rp(&rp, plan.rp);
        let (staged, _) = legacy_ingress(&frp, &plan, plan.whiten, &x);
        let rows = x.rows_count();
        let mut unit = FxpDrUnit::new(FxpUnitConfig {
            input_dim: P,
            output_dim: N,
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rotate: true,
            rot_warmup: (rows / 2).min(2000) as u64,
            seed,
            whiten_spec: plan.whiten,
            rot_spec: plan.rot,
            quant: plan.quant,
        });
        for _ in 0..epochs {
            unit.step_tile_raw(&staged, rows);
        }
        let out_spec = unit.output_spec();
        // ---- graph under test: the legacy Ica StageSpec mapped onto
        // rp → whiten → rot.
        let spec = PipelineSpec {
            input_dim: M,
            rp: Some(RpStage {
                intermediate_dim: P,
                distribution: RpDistribution::Ternary,
            }),
            stage: StageSpec::Ica {
                mu_w: 5e-3,
                mu_rot: 1e-3,
                epochs,
            },
            output_dim: N,
            seed,
            precision,
        };
        let pipe = DrPipeline::fit(spec, &x);
        let tiled = pipe.transform_rows(&x);
        for i in 0..rows {
            let want = out_spec
                .dequantize_vec(&unit.transform_raw(&staged[i * P..(i + 1) * P].to_vec()));
            let got = pipe.transform(x.row(i));
            assert_eq!(
                got,
                want,
                "row {i} diverged under plan {}",
                precision.label()
            );
            assert_eq!(
                tiled.row(i),
                want.as_slice(),
                "tiled row {i} diverged under plan {}",
                precision.label()
            );
        }
    }
}

#[test]
fn easi_fixed_graph_is_bit_identical_to_fused_kernel() {
    // The paper's proposed config (rotation-only EASI behind RP), the
    // legacy StageSpec::Easi fixed path.
    let x = data(400, 5);
    let (seed, epochs, mu) = (9u64, 2usize, 1e-3f32);
    for precision in plan_grid() {
        let plan = precision.plan().unwrap();
        let rp = RandomProjection::new(M, P, RpDistribution::Ternary, seed).unit_variance();
        let frp = FxpRp::from_rp(&rp, plan.rp);
        let (staged, prescale) = legacy_ingress(&frp, &plan, plan.rot, &x);
        let rows = x.rows_count();
        let mu_eff = mu / prescale.powi(4);
        let mut rot = FxpEasiRot::new(P, N, mu_eff, Some(seed), plan.rot, plan.quant);
        for _ in 0..epochs {
            rot.step_tile_raw(&staged, rows);
        }
        let spec = PipelineSpec::proposed(M, P, N, mu, epochs, seed).with_precision(precision);
        let pipe = DrPipeline::fit(spec, &x);
        for i in (0..rows).step_by(7) {
            let want = plan
                .rot
                .dequantize_vec(&rot.transform_raw(&staged[i * P..(i + 1) * P].to_vec()));
            let got = pipe.transform(x.row(i));
            assert_eq!(got, want, "row {i} diverged under plan {}", precision.label());
        }
    }
}

#[test]
fn identity_fixed_graph_is_bit_identical() {
    let x = data(120, 11);
    let precision = Precision::parse("rp=q8.16,whiten=q4.12,rot=q1.15").unwrap();
    let plan = precision.plan().unwrap();
    let seed = 1u64;
    // Legacy: entry/stage format are both the RP accumulator's; the
    // staged words *are* the output.
    let rp = RandomProjection::new(M, N, RpDistribution::Ternary, seed);
    let frp = FxpRp::from_rp(&rp, plan.rp);
    let (staged, _) = legacy_ingress(&frp, &plan, plan.rp, &x);
    let spec = PipelineSpec {
        input_dim: M,
        rp: Some(RpStage {
            intermediate_dim: N,
            distribution: RpDistribution::Ternary,
        }),
        stage: StageSpec::Identity,
        output_dim: N,
        seed,
        precision,
    };
    let pipe = DrPipeline::fit(spec, &x);
    for i in 0..x.rows_count() {
        let want = plan.rp.dequantize_vec(&staged[i * N..(i + 1) * N].to_vec());
        assert_eq!(pipe.transform(x.row(i)), want, "row {i}");
    }
}

#[test]
fn ica_f32_graph_is_bit_identical_to_fused_unit() {
    let x = data(600, 13);
    let (seed, epochs) = (17u64, 2usize);
    // Legacy oracle: the pre-graph f32 fit (RP staged once, the fused
    // DrUnit stepped over it).
    let rp = RandomProjection::new(M, P, RpDistribution::Ternary, seed).unit_variance();
    let staged = rp.apply_rows(&x);
    let mut unit = DrUnit::new(DrUnitConfig {
        input_dim: P,
        output_dim: N,
        mu_w: 5e-3,
        mu_rot: 1e-3,
        rotate: true,
        rot_warmup: (staged.rows_count() / 2).min(2000) as u64,
        seed,
    });
    for _ in 0..epochs {
        unit.step_rows(&staged);
    }
    let spec = PipelineSpec {
        input_dim: M,
        rp: Some(RpStage {
            intermediate_dim: P,
            distribution: RpDistribution::Ternary,
        }),
        stage: StageSpec::Ica {
            mu_w: 5e-3,
            mu_rot: 1e-3,
            epochs,
        },
        output_dim: N,
        seed,
        precision: Precision::F32,
    };
    let pipe = DrPipeline::fit(spec, &x);
    for i in 0..x.rows_count() {
        let want = unit.transform(staged.row(i));
        assert_eq!(pipe.transform(x.row(i)), want, "row {i}");
    }
}

#[test]
fn easi_f32_graph_is_bit_identical_to_fused_trainer() {
    // Both legacy EasiTrainer forms: full EASI (Table I) and the
    // proposed rotation-only datapath behind RP.
    use dimred::easi::{EasiConfig, EasiTrainer};
    let x = data(400, 19);
    let (seed, epochs, mu) = (23u64, 2usize, 1e-3f32);

    // Full EASI, no RP.
    let mut t = EasiTrainer::new(EasiConfig {
        input_dim: M,
        output_dim: P,
        mu,
        mode: EasiMode::Full,
        normalized: true,
        max_norm: 1e4,
        clip: 0.05,
        random_init: Some(seed),
    });
    for _ in 0..epochs {
        t.step_rows(&x);
    }
    let pipe = DrPipeline::fit(PipelineSpec::easi_only(M, P, mu, epochs, seed), &x);
    for i in (0..x.rows_count()).step_by(11) {
        assert_eq!(pipe.transform(x.row(i)), t.transform(x.row(i)), "row {i}");
    }

    // Rotation-only behind RP (the proposed config).
    let rp = RandomProjection::new(M, P, RpDistribution::Ternary, seed).unit_variance();
    let staged = rp.apply_rows(&x);
    let mut t = EasiTrainer::new(EasiConfig {
        input_dim: P,
        output_dim: N,
        mu,
        mode: EasiMode::RotationOnly,
        normalized: true,
        max_norm: 4.0 * (N as f32).sqrt(),
        clip: 0.05,
        random_init: Some(seed),
    });
    for _ in 0..epochs {
        t.step_rows(&staged);
    }
    let pipe = DrPipeline::fit(PipelineSpec::proposed(M, P, N, mu, epochs, seed), &x);
    for i in (0..x.rows_count()).step_by(11) {
        assert_eq!(pipe.transform(x.row(i)), t.transform(staged.row(i)), "row {i}");
    }
}

#[test]
fn trainer_graph_is_bit_identical_to_fused_engine() {
    // The coordinator's generic tile loop vs the legacy fused engines,
    // fixed point: same batches, same warm-up, identical raw words out
    // of transform_rows and an identical folded separation matrix.
    let precision = Precision::parse("rp=q8.16,whiten=q4.12,rot=q4.12").unwrap();
    let plan = precision.plan().unwrap();
    let cfg = ExperimentConfig {
        mode: PipelineMode::RpEasi,
        precision,
        rot_warmup: 100,
        train_classifier: false,
        ..Default::default()
    };
    let x = data(512, 29);
    let mut t = Trainer::from_config(&cfg, None).unwrap();
    // Two half-batches, like the streaming loop would deliver.
    let first = Mat::from_vec(256, M, x.as_slice()[..256 * M].to_vec());
    let second = Mat::from_vec(256, M, x.as_slice()[256 * M..].to_vec());
    t.step(&Batch::Full(first.clone())).unwrap();
    t.step(&Batch::Full(second.clone())).unwrap();

    // Legacy fused engine: shared ingress + FxpDrUnit per batch tile.
    let rp = RandomProjection::new(M, P, RpDistribution::Ternary, cfg.seed).unit_variance();
    let frp = FxpRp::from_rp(&rp, plan.rp);
    let mut unit = FxpDrUnit::new(FxpUnitConfig {
        input_dim: P,
        output_dim: N,
        mu_w: cfg.mu_w,
        mu_rot: cfg.mu,
        rotate: true,
        rot_warmup: cfg.rot_warmup as u64,
        seed: cfg.seed,
        whiten_spec: plan.whiten,
        rot_spec: plan.rot,
        quant: plan.quant,
    });
    for batch in [&first, &second] {
        let (staged, _) = legacy_ingress(&frp, &plan, plan.whiten, batch);
        unit.step_tile_raw(&staged, batch.rows_count());
    }
    let (staged, _) = legacy_ingress(&frp, &plan, plan.whiten, &x);
    let mut raw = Vec::new();
    unit.transform_tile_raw_multilane(&staged, x.rows_count(), 1, &mut raw);
    let out_spec = unit.output_spec();
    let want = Mat::from_vec(
        x.rows_count(),
        N,
        raw.iter().map(|&w| out_spec.dequantize(w)).collect(),
    );
    let got = t.transform_rows(&x);
    assert_eq!(got.as_slice(), want.as_slice(), "fxp trainer outputs diverged");
    assert_eq!(
        t.separation_matrix().as_slice(),
        unit.effective_matrix().as_slice(),
        "fxp separation matrices diverged"
    );

    // And the f32 engine: staged dense RP + fused unit, folded matrix.
    let cfg = ExperimentConfig {
        mode: PipelineMode::RpEasi,
        rot_warmup: 100,
        train_classifier: false,
        ..Default::default()
    };
    let mut t = Trainer::from_config(&cfg, None).unwrap();
    t.step(&Batch::Full(first.clone())).unwrap();
    t.step(&Batch::Full(second.clone())).unwrap();
    let mut unit = DrUnit::new(DrUnitConfig {
        input_dim: P,
        output_dim: N,
        mu_w: cfg.mu_w,
        mu_rot: cfg.mu,
        rotate: true,
        rot_warmup: cfg.rot_warmup as u64,
        seed: cfg.seed,
    });
    for batch in [&first, &second] {
        unit.step_rows(&rp.apply_rows(batch));
    }
    let rp_dense = rp.to_dense();
    let want = unit.effective_matrix().apply_rows(&rp_dense.apply_rows(&x));
    let got = t.transform_rows(&x);
    assert_eq!(got.as_slice(), want.as_slice(), "f32 trainer outputs diverged");
}

#[test]
fn checkpoint_restore_continues_bit_exactly() {
    // Stage-state save/restore: a graph restored from a mid-stream
    // checkpoint must continue exactly where the saved one stopped —
    // including STE shadow weights (the sub-LSB accumulation survives
    // the round-trip).
    let x = data(600, 31);
    let first = Mat::from_vec(300, M, x.as_slice()[..300 * M].to_vec());
    let second = Mat::from_vec(300, M, x.as_slice()[300 * M..].to_vec());
    for prec in ["q4.12", "rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste"] {
        let gspec = GraphSpec {
            input_dim: M,
            output_dim: N,
            stages: dimred::stage::spec::parse_stage_list("rp:ternary/16,whiten:gha,rot:easi")
                .unwrap(),
            seed: 3,
            precision: Precision::parse(prec).unwrap(),
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rot_warmup: Some(50),
            epochs: 1,
        };
        // Continuous run.
        let mut full = gspec.build(None).unwrap();
        full.step_rows(&first);
        let snapshot = full.save_state();
        full.step_rows(&second);
        let want = full.transform_rows(&x);
        // Restored run: fresh graph + checkpoint + the second half.
        let mut resumed = gspec.build(None).unwrap();
        resumed.restore_state(&snapshot).unwrap();
        resumed.step_rows(&second);
        let got = resumed.transform_rows(&x);
        assert_eq!(
            got.as_slice(),
            want.as_slice(),
            "checkpointed continuation diverged under {prec}"
        );
    }
}
