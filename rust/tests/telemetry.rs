//! Integration contract for the telemetry layer.
//!
//! Two invariants ride above the unit tests inside `src/telemetry/`:
//!
//! 1. **Bit identity** — instrumentation observes the datapath, it
//!    never participates in it. A trainer with telemetry enabled must
//!    produce *exactly* the same outputs (raw-for-raw in fixed point,
//!    bit-for-bit in f32) as an uninstrumented twin.
//! 2. **End-to-end surface** — a `TrainingService` run with
//!    `cfg.telemetry` yields a populated `TrainReport::telemetry`
//!    whose JSON snapshot passes its own golden-schema validator.

use dimred::config::{ExperimentConfig, PipelineMode};
use dimred::coordinator::{Batch, Trainer, TrainingService};
use dimred::datasets::waveform::WaveformConfig;
use dimred::fxp::Precision;
use dimred::linalg::Mat;
use dimred::util::json::Json;

fn fixed_batch(rows: usize, dim: usize) -> Batch {
    Batch::Full(Mat::from_fn(rows, dim, |i, j| {
        ((i * 31 + j * 7) % 23) as f32 / 23.0 - 0.5
    }))
}

/// Train two trainers from the same config/seed — one instrumented,
/// one not — and demand identical transforms.
fn assert_bit_identity(mut cfg: ExperimentConfig) {
    cfg.train_classifier = false;
    let mut plain_cfg = cfg.clone();
    plain_cfg.telemetry = false;
    let mut instr_cfg = cfg;
    instr_cfg.telemetry = true;

    let mut plain = Trainer::from_config(&plain_cfg, None).unwrap();
    let mut instr = Trainer::from_config(&instr_cfg, None).unwrap();
    let batch = fixed_batch(192, plain_cfg.input_dim);
    for _ in 0..6 {
        plain.step(&batch).unwrap();
        instr.step(&batch).unwrap();
    }
    let x = Mat::from_fn(64, plain_cfg.input_dim, |i, j| {
        ((i * 13 + j * 5) % 19) as f32 / 19.0 - 0.5
    });
    let a = plain.transform_rows(&x);
    let b = instr.transform_rows(&x);
    assert_eq!(a.shape(), b.shape());
    assert_eq!(
        a.as_slice(),
        b.as_slice(),
        "telemetry changed the datapath output"
    );

    // The instrumented twin must actually have recorded the work.
    let snap = instr.telemetry_snapshot().expect("snapshot");
    assert!(snap.all().any(|s| s.samples > 0));
    assert!(plain.telemetry_snapshot().is_none());
}

#[test]
fn instrumented_fxp_trainer_is_bit_identical() {
    assert_bit_identity(ExperimentConfig {
        mode: PipelineMode::RpEasi,
        precision: Precision::parse("q4.12").unwrap(),
        rot_warmup: 0,
        ..Default::default()
    });
}

#[test]
fn instrumented_f32_trainer_is_bit_identical() {
    assert_bit_identity(ExperimentConfig {
        mode: PipelineMode::RpEasi,
        ..Default::default()
    });
}

#[test]
fn service_run_surfaces_validated_snapshot() {
    let data = WaveformConfig {
        samples: 600,
        train: 500,
        ..WaveformConfig::paper()
    }
    .generate();
    let cfg = ExperimentConfig {
        epochs: 2,
        batch: 64,
        train_classifier: false,
        telemetry: true,
        precision: Precision::parse("q4.12").unwrap(),
        ..Default::default()
    };
    let report = TrainingService::new(cfg.clone(), None).run(&data).unwrap();
    let snap = report.telemetry.as_ref().expect("telemetry requested");

    // Per-stage slots exist, carry names, and saw the whole stream.
    assert!(!snap.stages.is_empty());
    assert!(snap.stages.iter().all(|s| !s.name.is_empty()));
    assert!(snap.stages.iter().any(|s| s.samples >= 1000));
    // Fixed-point run: the ingress quantizer histogrammed raw words.
    assert!(snap.ingress.words > 0);
    assert!(snap.ingress.max_bits() > 0);

    // The serialized snapshot passes its own golden-schema validator
    // after a parse round-trip (what `--telemetry-out` writes).
    let json = dimred::telemetry::snapshot::to_json(cfg.to_json(), &report.metrics, snap);
    let parsed = Json::parse(&json.to_string_pretty()).unwrap();
    dimred::telemetry::snapshot::validate(&parsed).unwrap();
}

#[test]
fn untelemetered_run_reports_none() {
    let data = WaveformConfig {
        samples: 240,
        train: 200,
        ..WaveformConfig::paper()
    }
    .generate();
    let cfg = ExperimentConfig {
        epochs: 1,
        batch: 64,
        train_classifier: false,
        ..Default::default()
    };
    let report = TrainingService::new(cfg, None).run(&data).unwrap();
    assert!(report.telemetry.is_none());
}
