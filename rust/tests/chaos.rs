//! Chaos suite: fault injection against the serving layer.
//!
//! The load-bearing claim is *blast-radius containment*: under injected
//! faults (poisoned batches, synthetic ingest failures) the shard run
//! still completes, the faulted tenants are retried and/or quarantined
//! with their last-good checkpoints intact — and every tenant outside
//! the blast radius finishes **bit-identical** to a fault-free oracle
//! run, across uniform and mixed precision plans and both quantization
//! modes (bit-exact and STE). A retried-then-recovered tenant must be
//! bit-identical too: retries preserve per-tenant FIFO order and
//! injected ingest faults fire before the session is touched.
//!
//! Every scenario runs under both schedulers — serial and the two-slot
//! stage/commit pipeline — because fault containment must not depend on
//! *when* an attempt happens, only on its FIFO position in the stream.

use dimred::config::ExperimentConfig;
use dimred::coordinator::{Batch, Session};
use dimred::fxp::Precision;
use dimred::linalg::Mat;
use dimred::serve::faults::{corrupt, FaultKind, FaultPlan};
use dimred::serve::workload::{self, ServeOptions};
use dimred::serve::{Shard, ShardOptions};

fn cfg(precision: &str) -> ExperimentConfig {
    ExperimentConfig {
        precision: Precision::parse(precision).unwrap(),
        rot_warmup: 32,
        train_classifier: false,
        ..Default::default()
    }
}

fn batch(dim: usize, salt: usize) -> Batch {
    Batch::Full(Mat::from_fn(64, dim, |i, j| {
        ((i * 31 + j * 7 + salt * 13) % 17) as f32 / 17.0 - 0.5
    }))
}

#[test]
fn unaffected_tenants_stay_bit_identical_under_faults() {
    const BATCHES: usize = 12;
    // Plans cover the checkpoint surface: uniform bit-exact, uniform
    // STE, mixed-width STE. `t_ing` takes synthetic ingest faults and
    // must *recover* bit-exactly; `t_nan` goes NaN after 2 clean
    // batches and must be quarantined on its last-good checkpoint.
    let tenants = [
        ("t_q412", "q4.12", 0usize),
        ("t_ste", "rp=q4.12,whiten=q4.12,rot=q4.12,qat=ste", 100),
        ("t_mix", "rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste", 200),
        ("t_ing", "q4.12", 300),
    ];

    // Oracles: uninterrupted fault-free sessions over the same streams.
    let mut oracles = Vec::new();
    for (_, precision, base) in &tenants {
        let c = cfg(precision);
        let mut s = Session::new(&c, None).unwrap();
        for i in 0..BATCHES {
            s.ingest(&batch(c.input_dim, base + i)).unwrap();
        }
        oracles.push(s);
    }

    // Both schedulers must contain the blast radius the same way: the
    // pipelined stage/commit overlap may change *when* an attempt round
    // happens, never what it produces or how failures are charged.
    for pipeline in [false, true] {
        let sched = if pipeline { "pipelined" } else { "serial" };
        // Test path: one shard, everything in flight at once, faults
        // armed. max_retries is generous so the ingest-faulted tenant
        // always rides out its (seeded, deterministic) failure streaks
        // — at rate 0.5 a 33-long streak is effectively impossible,
        // while t_nan's rejection run is sized below to exceed any cap.
        let mut shard = Shard::new(
            0,
            ShardOptions {
                queue_depth: 64,
                quantum: 2,
                max_retries: 32,
                pipeline,
                ..Default::default()
            },
        );
        let mut ingresses = Vec::new();
        for (name, precision, _) in &tenants {
            ingresses.push(shard.add_tenant(name, &cfg(precision)).unwrap());
        }
        let c_nan = cfg("q4.12");
        let nan_ingress = shard.add_tenant("t_nan", &c_nan).unwrap();
        shard.set_fault_plan(FaultPlan::parse("t_ing:ingest@0.5").unwrap(), 77);

        for (ingress, (_, precision, base)) in ingresses.iter().zip(&tenants) {
            let c = cfg(precision);
            for i in 0..BATCHES {
                ingress.send(batch(c.input_dim, base + i)).unwrap();
            }
        }
        // 2 clean batches then 40 NaN ones — more than max_retries
        // *consecutive* rejections, so the breaker is guaranteed to
        // trip (the full stream still fits the depth-64 queue: these
        // sends are blocking, from this thread, before the shard
        // starts draining).
        for i in 0..42 {
            let b = batch(c_nan.input_dim, 400 + i);
            let b = if i < 2 { b } else { corrupt(b, FaultKind::Nan) };
            nan_ingress.send(b).unwrap();
        }
        drop(ingresses);
        drop(nan_ingress);

        // The run must complete despite the faults — no abort.
        shard.run_to_completion().unwrap();

        let outcomes: std::collections::HashMap<String, _> = shard
            .tenant_outcomes()
            .into_iter()
            .map(|o| (o.tenant.clone(), o))
            .collect();

        // The poisoned tenant was quarantined on its last-good
        // checkpoint: the two clean batches survive, the NaN ones
        // never touched state.
        let nan = &outcomes["t_nan"];
        assert!(
            nan.health.quarantined,
            "NaN tenant must be quarantined ({sched})"
        );
        assert!(nan.health.rejected_batches > 0);
        assert_eq!(nan.samples, 2 * 64, "last-good checkpoint ({sched})");
        assert!(nan.completed_at_s.is_none());

        // The ingest-faulted tenant was retried (not quarantined) and
        // finished its full stream.
        let ing = &outcomes["t_ing"];
        assert!(!ing.health.quarantined, "t_ing quarantined ({sched})");
        assert!(
            ing.health.faults > 0,
            "seeded plan must actually fire ({sched})"
        );
        assert!(ing.health.retries > 0);
        assert_eq!(ing.samples, (BATCHES * 64) as u64, "t_ing stream ({sched})");

        // Bit-identity: every tenant outside the blast radius —
        // including the recovered one — matches its oracle word for
        // word.
        for ((name, precision, _), oracle) in tenants.iter().zip(&oracles) {
            let c = cfg(precision);
            let probe = Mat::from_fn(48, c.input_dim, |i, j| {
                ((i * 13 + j * 5) % 23) as f32 / 23.0 - 0.5
            });
            let session = shard.registry_mut().session_mut(name).unwrap();
            assert_eq!(
                oracle.metrics().samples_in,
                session.metrics().samples_in,
                "samples diverged for {name} ({sched})"
            );
            assert_eq!(
                oracle.trainer().transform_rows(&probe).as_slice(),
                session.trainer().transform_rows(&probe).as_slice(),
                "forward transform diverged under faults for {name} ({sched})"
            );
            assert_eq!(
                oracle.trainer().separation_matrix().as_slice(),
                session.trainer().separation_matrix().as_slice(),
                "separation matrix diverged under faults for {name} ({sched})"
            );
        }
    }
}

#[test]
fn threaded_workload_survives_faults_and_reports_them() {
    // End-to-end threaded run: t1 sends pure NaN traffic. The breaker
    // quarantines it mid-stream (16 batches through a depth-4 queue
    // cannot all be in flight when the breaker trips), so its producer
    // must observe the hang-up and exit cleanly instead of erroring the
    // whole run. Run under both schedulers: quarantine, drop accounting
    // and the golden report schema are pipeline-independent.
    for pipeline in [false, true] {
        let sched = if pipeline { "pipelined" } else { "serial" };
        let opts = ServeOptions {
            tenants: 4,
            shards: 2,
            batch: 16,
            batches_per_tenant: 16,
            queue_depth: 4,
            telemetry: true,
            faults: Some("t1:nan".into()),
            pipeline,
            ..ServeOptions::default()
        };
        let r = workload::run(&opts).unwrap();
        assert_eq!(
            r.producer_hangups, 1,
            "t1's producer observes the hang-up ({sched})"
        );
        assert!(r.injected_batches >= 4);
        assert_eq!(r.pipeline, pipeline);

        for t in &r.tenants {
            if t.tenant == "t1" {
                assert!(t.health.quarantined, "t1 not quarantined ({sched})");
                assert!(t.health.rejected_batches > 0);
                assert!(t.completed_at_s.is_none());
            } else {
                assert!(
                    !t.health.quarantined,
                    "{} caught in blast radius ({sched})",
                    t.tenant
                );
                assert_eq!(t.health.faults, 0);
                assert_eq!(t.samples, 16 * 16, "{} samples ({sched})", t.tenant);
                assert!(t.completed_at_s.is_some());
            }
        }

        // The report round-trips the golden schema, faults section
        // included.
        let json = dimred::serve::report::to_json(&opts, &r);
        let parsed = dimred::util::json::Json::parse(&json.to_string_pretty()).unwrap();
        dimred::serve::report::validate(&parsed, true).unwrap();
        let faults = parsed.field("faults").unwrap();
        assert_eq!(faults.field("quarantined").unwrap().as_u64().unwrap(), 1);
        assert_eq!(faults.field("spec").unwrap().as_str().unwrap(), "t1:nan@1");
    }
}
