//! Scalar-vs-vectorized identity properties for the fixed-point core.
//!
//! The vectorized backend (`fxp::simd`) promises *bit-identical* raw
//! words — its width-aware block accumulation regroups an exact integer
//! sum, so no format, overflow policy, rounding mode, vector length or
//! adversarial input may ever produce a different word than the scalar
//! reference, and the telemetry saturation/wrap counters must agree
//! event-for-event (only the single final `fit` observes overflow on
//! either path).
//!
//! Everything lives in ONE `#[test]`: the dispatch toggle
//! (`simd::set_force_scalar`) is process-global, so concurrent tests
//! flipping it could leave a measurement on an unintended backend.
//! (Results would still match — that is the point of the identity — but
//! the test would no longer be exercising both paths deliberately.)
//! A dedicated integration-test binary keeps the toggle isolated from
//! the library's unit tests, mirroring `tests/alloc_free.rs`.

use dimred::fxp::{simd, FxpMat, FxpSpec, Overflow, Rounding};
use dimred::linalg::Mat;
use dimred::telemetry::events;

/// Deterministic raw-word generator spanning the format's full range,
/// with a bias toward the extremes (the words that stress carries,
/// saturation and the blocked spill points).
fn words(spec: &FxpSpec, n: usize, seed: u64) -> Vec<i32> {
    let (lo, hi) = (spec.format.min_raw() as i64, spec.format.max_raw() as i64);
    let span = (hi - lo + 1) as u64;
    let mut state = seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1);
    (0..n)
        .map(|_| {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            let r = state >> 33;
            match r % 8 {
                0 => lo as i32,
                1 => hi as i32,
                2 => 0,
                _ => (lo + (r % span) as i64) as i32,
            }
        })
        .collect()
}

/// Run `f` with the vectorized dispatch forced off, then in its natural
/// state; assert the outputs and the per-thread (sat, wrap) telemetry
/// deltas match exactly. Returns the scalar run's output and deltas.
fn assert_both_paths<T: PartialEq + std::fmt::Debug>(
    ctx: &str,
    mut f: impl FnMut() -> T,
) -> (T, (u64, u64)) {
    let mut run = |f: &mut dyn FnMut() -> T| {
        let (s0, w0) = events::snapshot();
        let out = f();
        let (s1, w1) = events::snapshot();
        (out, (s1 - s0, w1 - w0))
    };
    simd::set_force_scalar(true);
    let (s_out, s_ev) = run(&mut f);
    simd::set_force_scalar(false);
    let (v_out, v_ev) = run(&mut f);
    assert_eq!(s_out, v_out, "raw words diverged scalar vs simd: {ctx}");
    assert_eq!(s_ev, v_ev, "telemetry counts diverged scalar vs simd: {ctx}");
    (s_out, s_ev)
}

#[test]
fn vectorized_core_is_bit_identical_to_scalar() {
    // Width grid: narrow (q8.8), the deployment formats (q4.12, q1.15),
    // and the wide words whose products leave no i64 lane headroom
    // (q16.16, q8.24 — 32-bit, where the blocked path must spill every
    // element).
    let formats = [(8u8, 8u8), (4, 12), (1, 15), (16, 16), (8, 24)];
    let policies = [Overflow::Saturate, Overflow::Wrap];
    let roundings = [Rounding::Nearest, Rounding::Truncate];
    // Lengths straddling the 8-lane boundary, the block spill cadence
    // and a long tail.
    let lengths = [0usize, 1, 7, 8, 9, 63, 64, 65, 257, 1000];

    for (ib, fb) in formats {
        for overflow in policies {
            for rounding in roundings {
                let mut spec = FxpSpec::q(ib, fb);
                spec.overflow = overflow;
                spec.rounding = rounding;
                let ctx = format!("q{ib}.{fb} {overflow:?} {rounding:?}");
                for (k, &n) in lengths.iter().enumerate() {
                    let seed = ((ib as u64) << 24) | ((fb as u64) << 16) | (k as u64);
                    let a = words(&spec, n, seed);
                    let b = words(&spec, n, seed ^ 0x5eed);
                    assert_both_paths(&format!("dot n={n} {ctx}"), || spec.dot_raw(&a, &b));

                    // Adversarial: every word at the same extreme — the
                    // worst case for accumulator growth (all products
                    // at ±2^(2B-2)) and for the saturating fit.
                    let lo = vec![spec.format.min_raw(); n];
                    let hi = vec![spec.format.max_raw(); n];
                    for (x, y) in [(&lo, &lo), (&lo, &hi), (&hi, &hi)] {
                        assert_both_paths(&format!("extremal dot n={n} {ctx}"), || {
                            spec.dot_raw(x, y)
                        });
                    }
                }

                // Matrix kernels on the same spec: matvec (row dots)
                // and the blocked transposed matvec, against an
                // extremal-striped matrix.
                let (rows, cols) = (37usize, 130usize);
                let mut m = FxpMat::quantize(&Mat::zeros(rows, cols), spec);
                let stripe = words(&spec, rows * cols, ((ib as u64) << 8) | (fb as u64));
                m.as_raw_mut().copy_from_slice(&stripe);
                let x_cols = words(&spec, cols, 0xc01);
                let x_rows = words(&spec, rows, 0xc02);
                assert_both_paths(&format!("matvec {ctx}"), || {
                    let mut out = vec![0i32; rows];
                    m.matvec_raw_into(&x_cols, &mut out);
                    out
                });
                assert_both_paths(&format!("matvec_t {ctx}"), || {
                    let mut out = vec![0i32; cols];
                    m.matvec_t_raw_into(&x_rows, &mut out);
                    out
                });
            }
        }
    }

    // Make the telemetry half of the contract non-vacuous: an extremal
    // saturating dot must actually overflow, and both paths counted it.
    let spec = FxpSpec::q(4, 12);
    let hi = vec![spec.format.max_raw(); 64];
    let (word, (sat, _wrap)) = assert_both_paths("saturating q4.12 dot", || spec.dot_raw(&hi, &hi));
    assert_eq!(word, spec.format.max_raw(), "extremal dot should clamp");
    assert!(sat > 0, "extremal q4.12 dot should saturate");
}
