//! Steady-state allocation audit for the fixed-point training path.
//!
//! The tiled-datapath refactor's contract is that once the scratch
//! workspaces have been sized, a training step performs **zero heap
//! allocations per sample** — including the periodic host-side cadences
//! (whitening-coefficient refresh, rotation retraction), which reuse
//! member buffers. This binary installs a counting global allocator and
//! asserts the contract at four levels: the raw `FxpDrUnit` kernel loop
//! (bit-exact and STE), the coordinator's `NativeTrainer` consuming
//! whole `Batch` tiles, the batcher's producer thread once a recycling
//! consumer has primed the buffer-return lane, and the serving shard's
//! `poll_round` scheduler once its round scratch is warm.
//!
//! Kept as a single `#[test]` on purpose: the counter is global, and a
//! sibling test running on another harness thread would pollute the
//! measurement window.

use dimred::config::{ExperimentConfig, PipelineMode};
use dimred::coordinator::batcher::{spawn_producer, EpochSource};
use dimred::coordinator::{Batch, Trainer};
use dimred::fxp::{FxpDrUnit, FxpSpec, FxpUnitConfig, Precision, QuantMode};
use dimred::linalg::Mat;
use dimred::serve::{Shard, ShardOptions};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

fn unit_is_allocation_free(quant: QuantMode) {
    let spec = FxpSpec::q(4, 12);
    let mut unit = FxpDrUnit::new(FxpUnitConfig {
        input_dim: 16,
        output_dim: 8,
        mu_w: 5e-3,
        mu_rot: 1e-3,
        rotate: true,
        rot_warmup: 10,
        seed: 3,
        whiten_spec: spec,
        rot_spec: spec,
        quant,
    });
    // 700 rows: several rotation-retract and coefficient-refresh
    // boundaries fall inside every pass, so the measured window proves
    // the host cadences are allocation-free too, not just the MACs.
    let rows = 700usize;
    let tile: Vec<i32> = (0..rows * 16)
        .map(|i| (((i * 37) % 1601) as i32) - 800)
        .collect();
    // Warm-up pass: past the rotation gate, every code path taken once.
    unit.step_tile_raw(&tile, rows);
    let before = allocs();
    unit.step_tile_raw(&tile, rows);
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "{quant:?} fxp unit allocated {delta} times over {rows} steady-state samples"
    );
}

fn trainer_is_allocation_free(telemetry: bool) {
    let cfg = ExperimentConfig {
        mode: PipelineMode::RpEasi,
        precision: Precision::parse("q4.12").unwrap(),
        rot_warmup: 0,
        train_classifier: false,
        telemetry,
        ..Default::default()
    };
    let mut t = Trainer::from_config(&cfg, None).unwrap();
    let batch = Batch::Full(Mat::from_fn(256, 32, |i, j| {
        ((i * 31 + j * 7) % 17) as f32 / 17.0 - 0.5
    }));
    // First step sizes the ingress scratch; second crosses the
    // refresh/retract cadences with warm buffers.
    t.step(&batch).unwrap();
    t.step(&batch).unwrap();
    let before = allocs();
    t.step(&batch).unwrap();
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "NativeTrainer fxp step (telemetry={telemetry}) allocated {delta} times \
         on a warm 256-row batch"
    );
    if telemetry {
        // Prove the instrumented path was actually measured: the
        // preallocated counters must have seen every stepped sample.
        let snap = t.telemetry_snapshot().expect("telemetry enabled");
        assert!(snap.all().any(|s| s.samples >= 3 * 256));
    }
}

fn producer_recycling_is_allocation_free() {
    // 64 rows × 8 epochs = 512 rows → 64 full batches of 8, depth 2.
    let data = Arc::new(Mat::from_fn(64, 8, |i, j| {
        ((i * 31 + j * 7) % 17) as f32 / 17.0 - 0.5
    }));
    let src = EpochSource::new(data, 8);
    let queue_depth = 2usize;
    let (rx, prod) = spawn_producer(Box::new(src), 8, queue_depth);

    // Prime the return lane by *withholding* recycling: while nothing
    // has been returned, every batch boundary is a recycle miss, and
    // each miss adds one buffer to circulation. queue_depth + 2 misses
    // cover every buffer that can be in flight at once (producer's own
    // + queued + one at the consumer), so after this no poll of the
    // lane can ever come up empty again.
    let held: Vec<Batch> = (0..queue_depth + 2).map(|_| rx.recv().unwrap()).collect();
    // While the consumer sits on the held batches, the producer is
    // guaranteed to find the queue full and take the blocking-send path
    // at least once (the wait counter is bumped before the block) — so
    // the channel's one-time waker registration is also paid for before
    // the measured window opens.
    while prod.backpressure_waits.load(Ordering::Relaxed) == 0 {
        assert!(
            !prod.handle.is_finished(),
            "producer exited without ever blocking"
        );
        std::thread::yield_now();
    }
    std::thread::sleep(std::time::Duration::from_millis(25));
    for b in held {
        prod.recycle(b);
    }

    // Measured steady-state window: 50 batches through a non-blocking
    // recv → recycle loop (try_recv never registers a waker, so the
    // consumer side cannot allocate either). The window deliberately
    // ends while the producer is still mid-stream (it runs at most
    // queue_depth + 1 batches ahead of the consumer), so thread-exit
    // bookkeeping cannot pollute the count.
    let before = allocs();
    for _ in 0..50 {
        let b = loop {
            match rx.try_recv() {
                Ok(b) => break b,
                Err(std::sync::mpsc::TryRecvError::Empty) => std::thread::yield_now(),
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    panic!("producer ended inside the measured window")
                }
            }
        };
        prod.recycle(b);
    }
    let delta = allocs() - before;

    let mut tail = 0usize;
    for b in rx.iter() {
        tail += 1;
        prod.recycle(b);
    }
    prod.handle.join().unwrap().unwrap();
    assert!(tail > 0, "window must close before the stream ends");
    assert_eq!(
        delta, 0,
        "recycling producer allocated {delta} times over 50 steady-state batches"
    );
}

fn shard_poll_round_is_allocation_free() {
    // Serial scheduler, telemetry off: once the round scratch (work
    // list, backlog ring, per-tenant flag vectors) and the trainer's
    // workspaces are warm, a poll_round that drains, sorts and commits
    // a batch must not touch the heap. The ingress wire is a bounded
    // sync channel, so receiving a batch is allocation-free too.
    let cfg = ExperimentConfig {
        mode: PipelineMode::RpEasi,
        precision: Precision::parse("q4.12").unwrap(),
        rot_warmup: 0,
        train_classifier: false,
        ..Default::default()
    };
    let mut shard = Shard::new(
        0,
        ShardOptions {
            queue_depth: 16,
            quantum: 1,
            ..Default::default()
        },
    );
    let ingress = shard.add_tenant("t0", &cfg).unwrap();
    let batch = Batch::Full(Mat::from_fn(64, cfg.input_dim, |i, j| {
        ((i * 31 + j * 7) % 17) as f32 / 17.0 - 0.5
    }));
    // All 16 batches buffered on the wire up front: every Mat clone
    // happens here, outside the measured window.
    for _ in 0..16 {
        ingress.send(batch.clone()).unwrap();
    }
    drop(ingress);

    // Warm-up: 10 rounds at quantum 1 commit batches 1..=10 — sizing
    // the backlog/work scratch and crossing the batch-8 convergence-
    // trace push (its Vec growth is amortized, paid once here).
    for _ in 0..10 {
        let stats = shard.poll_round().unwrap();
        assert_eq!(stats.batches, 1);
    }
    // Measured window: batches 11..=14, clear of the %8 trace cadence.
    let before = allocs();
    for _ in 0..4 {
        let stats = shard.poll_round().unwrap();
        assert_eq!(stats.batches, 1);
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "shard poll_round allocated {delta} times over 4 warm rounds"
    );

    shard.run_to_completion().unwrap();
    assert_eq!(
        shard.registry().metrics_of("t0").unwrap().samples_in,
        16 * 64
    );
}

#[test]
fn steady_state_fxp_training_is_allocation_free() {
    unit_is_allocation_free(QuantMode::BitExact);
    unit_is_allocation_free(QuantMode::Ste);
    // The telemetry contract is "zero-alloc in steady state" too: the
    // atomic counters and occupancy histogram are preallocated at
    // enable time, so instrumentation must not cost a single alloc on
    // the hot path.
    trainer_is_allocation_free(false);
    trainer_is_allocation_free(true);
    // And the producer side of the bounded queue: once the consumer
    // returns drained buffers, batch production allocates nothing.
    producer_recycling_is_allocation_free();
    // Finally the serving shard's scheduler: a warm poll_round (drain,
    // shape-sort, commit) rides entirely on hoisted round scratch.
    shard_poll_round_is_allocation_free();
}
