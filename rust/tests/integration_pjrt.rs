//! Integration tests over the PJRT runtime + coordinator: the AOT
//! artifacts must load, execute, and agree with the native Rust
//! implementation step-for-step.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a message) when the manifest is absent so `cargo test` works
//! on a fresh checkout.

use dimred::config::{Backend, ExperimentConfig, PipelineMode};
use dimred::coordinator::TrainingService;
use dimred::datasets::waveform::WaveformConfig;
use dimred::linalg::Mat;
use dimred::runtime::{Runtime, Tensor};
use std::path::{Path, PathBuf};

fn artifact_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn small_waveform() -> dimred::datasets::Dataset {
    let mut d = WaveformConfig {
        samples: 1600,
        train: 1500,
        ..WaveformConfig::paper()
    }
    .generate();
    d.standardize();
    d
}

#[test]
fn runtime_loads_and_lists_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    assert!(rt.manifest().artifacts.len() >= 20);
    assert_eq!(rt.platform().to_lowercase().contains("cpu"), true);
}

#[test]
fn transform_artifact_matches_native_matvec() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let b = Mat::from_fn(16, 32, |i, j| ((i * 13 + j * 7) % 11) as f32 / 11.0 - 0.5);
    let x = Mat::from_fn(256, 32, |i, j| ((i + j * 3) % 17) as f32 / 17.0 - 0.5);
    let out = rt
        .execute1(
            "transform_m32_n16_b256",
            &[Tensor::from_mat(&b), Tensor::from_mat(&x)],
        )
        .unwrap()
        .into_mat()
        .unwrap();
    let expect = b.apply_rows(&x);
    let diff = dimred::linalg::max_abs_diff(&out, &expect);
    assert!(diff < 1e-4, "transform mismatch {diff}");
}

#[test]
fn executable_reuse_is_cached() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    rt.warm(&["transform_m32_n8_b1"]).unwrap();
    let b = Mat::eye(8, 32);
    let x = Mat::from_fn(1, 32, |_, j| j as f32);
    for _ in 0..3 {
        let out = rt
            .execute1(
                "transform_m32_n8_b1",
                &[Tensor::from_mat(&b), Tensor::from_mat(&x)],
            )
            .unwrap();
        assert_eq!(out.shape, vec![1, 8]);
        assert_eq!(out.data[3], 3.0);
    }
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let bad = Tensor::new(vec![4, 4], vec![0.0; 16]);
    let err = rt.execute("transform_m32_n16_b256", &[bad.clone(), bad]);
    assert!(err.is_err());
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("does not match manifest"), "{msg}");
}

#[test]
fn pjrt_training_agrees_with_native() {
    // The core cross-backend contract: identical config + stream ⇒
    // near-identical learned state (fp32 association-order differences
    // only). Warm-up chosen as a multiple of the batch so the rotation
    // engages at the same sample on both backends.
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let data = small_waveform();
    let mk = |backend| ExperimentConfig {
        dataset: "waveform".into(),
        input_dim: 32,
        intermediate_dim: 16,
        output_dim: 8,
        mode: PipelineMode::RpEasi,
        backend,
        epochs: 2,
        batch: 256,
        rot_warmup: 512,
        train_classifier: false,
        ..Default::default()
    };
    let native = TrainingService::new(mk(Backend::Native), None)
        .run(&data)
        .unwrap();
    let pjrt = TrainingService::new(mk(Backend::Pjrt), Some(&rt))
        .run(&data)
        .unwrap();

    assert_eq!(native.metrics.samples_in, pjrt.metrics.samples_in);
    let diff = dimred::linalg::max_abs_diff(&native.separation, &pjrt.separation);
    let scale = native.separation.fro_norm();
    assert!(
        diff / scale < 5e-2,
        "native vs PJRT separation matrices diverge: {diff} (scale {scale})"
    );
    // And the RP matrices are identical (same seed, host-generated).
    let d2 = dimred::linalg::max_abs_diff(
        native.rp.as_ref().unwrap(),
        pjrt.rp.as_ref().unwrap(),
    );
    assert_eq!(d2, 0.0);
}

#[test]
fn pjrt_whiten_only_mode_runs() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let data = small_waveform();
    let cfg = ExperimentConfig {
        input_dim: 32,
        intermediate_dim: 16,
        output_dim: 16,
        mode: PipelineMode::PcaWhiten,
        backend: Backend::Pjrt,
        epochs: 1,
        batch: 256,
        train_classifier: false,
        ..Default::default()
    };
    let report = TrainingService::new(cfg, Some(&rt)).run(&data).unwrap();
    assert_eq!(report.separation.shape(), (16, 32));
    assert!(report
        .separation
        .as_slice()
        .iter()
        .all(|v| v.is_finite()));
}

#[test]
fn pjrt_tail_batches_run_through_b1_variant() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let mut data = WaveformConfig {
        samples: 700,
        train: 600, // 600 % 256 = 88-sample tail per epoch
        ..WaveformConfig::paper()
    }
    .generate();
    data.standardize();
    let cfg = ExperimentConfig {
        input_dim: 32,
        intermediate_dim: 16,
        output_dim: 8,
        mode: PipelineMode::RpEasi,
        backend: Backend::Pjrt,
        epochs: 1,
        batch: 256,
        rot_warmup: 0,
        train_classifier: false,
        ..Default::default()
    };
    let report = TrainingService::new(cfg, Some(&rt)).run(&data).unwrap();
    assert_eq!(report.metrics.samples_in, 600);
    assert!(report.metrics.tail_samples > 0);
}

#[test]
fn pjrt_mlp_train_step_reduces_loss() {
    // Drive the classifier training artifact directly: loss after some
    // steps must drop (the full MLP-on-PJRT path).
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::load(&dir).unwrap();
    let (d, h, c, b) = (8usize, 64usize, 3usize, 32usize);
    let name = format!("mlp_train_in{d}_h{h}_c{c}_b{b}");
    if rt.manifest().get(&name).is_err() {
        eprintln!("skipping: {name} not in manifest");
        return;
    }
    use dimred::rng::{Pcg64, Rng, RngExt};
    let mut rng = Pcg64::seed(5);
    let he = |fan_in: usize| (2.0 / fan_in as f64).sqrt();
    let mut params: Vec<Tensor> = vec![
        Tensor::new(vec![h, d], (0..h * d).map(|_| (rng.next_gaussian() * he(d)) as f32).collect()),
        Tensor::new(vec![h], vec![0.0; h]),
        Tensor::new(vec![h, h], (0..h * h).map(|_| (rng.next_gaussian() * he(h)) as f32).collect()),
        Tensor::new(vec![h], vec![0.0; h]),
        Tensor::new(vec![c, h], (0..c * h).map(|_| (rng.next_gaussian() * he(h)) as f32).collect()),
        Tensor::new(vec![c], vec![0.0; c]),
    ];
    let mut velocities: Vec<Tensor> = params
        .iter()
        .map(|t| Tensor::new(t.shape.clone(), vec![0.0; t.data.len()]))
        .collect();

    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for _ in 0..60 {
        // Blobs: class = argmax of first c coords + noise.
        let mut xs = Vec::with_capacity(b * d);
        let mut onehot = vec![0.0f32; b * c];
        for i in 0..b {
            let class = rng.next_below(c as u64) as usize;
            for j in 0..d {
                let center = if j == class { 2.0 } else { 0.0 };
                xs.push(center + rng.next_gaussian() as f32 * 0.5);
            }
            onehot[i * c + class] = 1.0;
        }
        let mut inputs = params.clone();
        inputs.extend(velocities.clone());
        inputs.push(Tensor::new(vec![b, d], xs));
        inputs.push(Tensor::new(vec![b, c], onehot));
        inputs.push(Tensor::scalar(0.1));
        inputs.push(Tensor::scalar(0.9));
        let outs = rt.execute(&name, &inputs).unwrap();
        assert_eq!(outs.len(), 13);
        // outputs: w1, vw1, b1, vb1, w2, vw2, b2, vb2, w3, vw3, b3, vb3, loss
        for (k, slot) in [0usize, 2, 4, 6, 8, 10].iter().enumerate() {
            params[k] = outs[*slot].clone();
            velocities[k] = outs[slot + 1].clone();
        }
        last_loss = outs[12].data[0];
        if first_loss.is_none() {
            first_loss = Some(last_loss);
        }
    }
    let first = first_loss.unwrap();
    assert!(
        last_loss < first * 0.5,
        "loss did not drop: {first} -> {last_loss}"
    );
}
