//! Bench P1 (DESIGN.md §5): end-to-end training-service throughput —
//! the coordinator's samples/second through the full producer → bounded
//! queue → trainer path, native vs PJRT backends, across batch sizes,
//! plus the tiled / multi-lane kernel grid of `dimred bench` (per-sample
//! vs tiled vs multilane, f32 vs fixed point). The §Perf section of
//! EXPERIMENTS.md tracks these numbers; the FPGA reference point is
//! 106.64 Msamples/s (one sample per clock).

use dimred::config::{Backend, ExperimentConfig, PipelineMode};
use dimred::coordinator::TrainingService;
use dimred::datasets::waveform::WaveformConfig;
use dimred::runtime::Runtime;
use std::path::Path;

fn run_once(cfg: ExperimentConfig, runtime: Option<&Runtime>) -> (f64, u64) {
    let mut data = WaveformConfig::paper().generate();
    data.standardize();
    let report = TrainingService::new(cfg, runtime).run(&data).expect("run");
    (
        report.metrics.throughput(),
        report.metrics.backpressure_waits,
    )
}

fn main() {
    let quick = std::env::var("DIMRED_BENCH_QUICK").is_ok();
    let epochs = if quick { 1 } else { 4 };
    let base = ExperimentConfig {
        mode: PipelineMode::RpEasi,
        intermediate_dim: 16,
        output_dim: 8,
        epochs,
        rot_warmup: 512,
        train_classifier: false,
        ..Default::default()
    };

    println!("end-to-end coordinator throughput (waveform, rp16+easi8, {epochs} epochs)");
    println!("FPGA reference (paper, modelled): 106.64 Msamples/s\n");

    for batch in [64usize, 256, 1024] {
        let cfg = ExperimentConfig {
            batch,
            backend: Backend::Native,
            ..base.clone()
        };
        let (tput, bp) = run_once(cfg, None);
        println!("native  batch={batch:<5} {tput:>12.0} samples/s   backpressure {bp}");
    }

    // The fixed-point tiled trainer through the same coordinator path.
    for batch in [64usize, 256] {
        let cfg = ExperimentConfig {
            batch,
            backend: Backend::Native,
            precision: dimred::fxp::Precision::parse("q4.12").unwrap(),
            ..base.clone()
        };
        let (tput, bp) = run_once(cfg, None);
        println!("native  q4.12 batch={batch:<5} {tput:>12.0} samples/s   backpressure {bp}");
    }

    // Kernel-level grid: per-sample vs tiled vs multi-lane, f32 vs
    // fixed point — the same harness `dimred bench` runs, so `cargo
    // bench` covers the tiled paths alongside the coordinator numbers.
    let opts = dimred::experiments::bench::BenchOptions {
        datasets: vec!["waveform".into()],
        tile: 256,
        lanes: 4,
        smoke: quick,
        seed: 2018,
    };
    match dimred::experiments::bench::run(&opts) {
        Ok(results) => print!("{}", dimred::experiments::bench::render(&opts, &results)),
        Err(e) => println!("tiled kernel bench skipped ({e:#})"),
    }

    match Runtime::load(Path::new("artifacts")) {
        Ok(rt) => {
            for batch in [256usize] {
                let cfg = ExperimentConfig {
                    batch,
                    backend: Backend::Pjrt,
                    ..base.clone()
                };
                let (tput, bp) = run_once(cfg, Some(&rt));
                println!("pjrt    batch={batch:<5} {tput:>12.0} samples/s   backpressure {bp}");
            }
            // Queue-depth sensitivity (backpressure behaviour).
            for depth in [1usize, 4, 16] {
                let cfg = ExperimentConfig {
                    batch: 256,
                    queue_depth: depth,
                    backend: Backend::Pjrt,
                    ..base.clone()
                };
                let (tput, bp) = run_once(cfg, Some(&rt));
                println!("pjrt    queue={depth:<5} {tput:>12.0} samples/s   backpressure {bp}");
            }
        }
        Err(e) => println!("pjrt    skipped ({e:#})"),
    }
    println!("--- bench_throughput done ---");
}
