//! Bench T1 (DESIGN.md §5): regenerates the paper's Table I — the four
//! waveform accuracy configurations — and measures the DR-stage
//! training cost of each (per-sample latency of the streaming trainer,
//! native backend; the PJRT path is timed in bench_kernels /
//! bench_throughput).
//!
//! Run: `cargo bench --bench bench_table1` (DIMRED_BENCH_QUICK=1 for a
//! fast pass).

use dimred::config::{Backend, ExperimentConfig, PipelineMode};
use dimred::coordinator::{Batch, Trainer};
use dimred::datasets::waveform::WaveformConfig;
use dimred::util::bench::Bench;

fn main() {
    // ------- the accuracy table itself (once; not timed) -------------
    let quick = std::env::var("DIMRED_BENCH_QUICK").is_ok();
    let epochs = if quick { 2 } else { 8 };
    let rows = dimred::experiments::table1::run(None, Backend::Native, epochs, 2018)
        .expect("table 1 run");
    println!("{}", dimred::experiments::table1::render(&rows));
    if let Err(e) = dimred::experiments::table1::check_shape(&rows, 13.0) {
        println!("shape check: FAILED — {e}");
    } else {
        println!("shape check: OK");
    }
    println!();

    // ------- per-configuration training cost --------------------------
    let mut data = WaveformConfig::paper().generate();
    data.standardize();
    let mut bench = Bench::new("table1-dr-training");
    for &(mode, p, n, _) in &dimred::experiments::table1::CONFIGS {
        let cfg = ExperimentConfig {
            input_dim: 32,
            intermediate_dim: if p == 0 { n } else { p },
            output_dim: n,
            mode,
            rot_warmup: 0,
            ..Default::default()
        };
        let label = match mode {
            PipelineMode::RpEasi => format!("rp{p}+easi{n} step(batch=256)"),
            _ => format!("easi{n} step(batch=256)"),
        };
        let batch = Batch::Full(dimred::linalg::Mat::from_fn(256, 32, |i, j| {
            data.train_x.get(i % data.train_x.rows_count(), j)
        }));
        let mut trainer = Trainer::from_config(&cfg, None).unwrap();
        bench.run(&label, || {
            trainer.step(&batch).unwrap();
        });
    }
    bench.finish();
}
