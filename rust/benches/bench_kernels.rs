//! Kernel-level benchmarks: the PJRT-executed AOT step artifacts vs
//! their native-Rust equivalents, per batch size — isolates the XLA
//! dispatch overhead from the algorithmic cost, which drives the
//! batch-size policy in §Perf of EXPERIMENTS.md.
//!
//! Also times the literal Eq. 6 EASI step (the paper's datapath) vs the
//! factored O(nm) update — the software image of the paper's O(m·n²)
//! hardware-complexity argument.

use dimred::config::{Backend, ExperimentConfig, PipelineMode};
use dimred::coordinator::{Batch, Trainer};
use dimred::easi::{naive_step, EasiConfig, EasiMode, EasiTrainer};
use dimred::linalg::Mat;
use dimred::runtime::{Runtime, Tensor};
use dimred::util::bench::Bench;
use std::path::Path;

fn main() {
    let mut bench = Bench::new("kernels");

    // ------- native: factored vs naive EASI update ---------------------
    let (m, n) = (32usize, 8usize);
    let x: Vec<f32> = (0..m).map(|i| ((i * 37) % 17) as f32 / 17.0 - 0.5).collect();
    let mut trainer = EasiTrainer::new(EasiConfig {
        input_dim: m,
        output_dim: n,
        ..Default::default()
    });
    bench.run("native easi step factored O(nm) 32→8", || trainer.step(&x));
    let b0 = Mat::eye(n, m);
    bench.run("native easi step naive O(n²m) 32→8 (paper datapath)", || {
        naive_step(&b0, &x, 1e-3, EasiMode::Full)
    });

    // ------- native composed DR unit -----------------------------------
    let cfg = ExperimentConfig {
        mode: PipelineMode::RpEasi,
        intermediate_dim: 16,
        output_dim: 8,
        rot_warmup: 0,
        ..Default::default()
    };
    let batch256 = Batch::Full(Mat::from_fn(256, 32, |i, j| {
        ((i * 31 + j * 7) % 23) as f32 / 23.0 - 0.5
    }));
    let mut native = Trainer::from_config(&cfg, None).unwrap();
    bench.run("native rp16+dr8 batch=256", || native.step(&batch256));

    // ------- PJRT step executables -------------------------------------
    let Ok(rt) = Runtime::load(Path::new("artifacts")) else {
        println!("(PJRT benches skipped: run `make artifacts`)");
        bench.finish();
        return;
    };
    let mut pjrt = Trainer::from_config(
        &ExperimentConfig {
            backend: Backend::Pjrt,
            ..cfg.clone()
        },
        Some(&rt),
    )
    .unwrap();
    bench.run("pjrt rp16+dr8 batch=256 (fused artifact)", || {
        pjrt.step(&batch256).unwrap()
    });
    let batch1 = Batch::Tail(Mat::from_fn(1, 32, |_, j| j as f32 / 32.0));
    bench.run("pjrt rp16+dr8 batch=1 (tail artifact)", || {
        pjrt.step(&batch1).unwrap()
    });

    // Inference artifacts.
    let b = Mat::eye(16, 32);
    let x256 = Mat::from_fn(256, 32, |i, j| ((i + j) % 13) as f32 / 13.0);
    let tb = Tensor::from_mat(&b);
    let tx = Tensor::from_mat(&x256);
    rt.warm(&["transform_m32_n16_b256"]).unwrap();
    bench.run("pjrt transform 32→16 batch=256", || {
        rt.execute1("transform_m32_n16_b256", &[tb.clone(), tx.clone()])
            .unwrap()
    });
    // Native equivalent for the dispatch-overhead comparison.
    bench.run("native transform 32→16 batch=256", || b.apply_rows(&x256));

    bench.finish();
}
