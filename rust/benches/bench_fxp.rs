//! Quantized vs f32 kernel throughput: the software-side payoff of the
//! integer datapath (and a regression guard on the fxp hot paths).
//!
//! Measures, per kernel, the f32 reference against its bit-accurate
//! fixed-point image at 16-bit Q4.12: RP apply, the GHA step, the
//! rotation-only EASI step, the composed unit step, and the dense
//! matvec. Inputs are pre-quantized so the fxp numbers reflect the
//! steady-state streaming cost (the boundary quantization happens once
//! per sample at ingress in the real pipeline and is measured
//! separately).

use dimred::easi::{EasiConfig, EasiMode, EasiTrainer};
use dimred::fxp::{FxpDrUnit, FxpEasiRot, FxpGha, FxpMat, FxpRp, FxpSpec, FxpUnitConfig, QuantMode};
use dimred::gha::{GhaConfig, GhaWhitener};
use dimred::linalg::Mat;
use dimred::pipeline::{DrUnit, DrUnitConfig};
use dimred::rp::{RandomProjection, RpDistribution};
use dimred::util::bench::Bench;

fn main() {
    let mut bench = Bench::new("fxp-kernels");
    let spec = FxpSpec::q(4, 12);
    let (m, p, n) = (32usize, 16usize, 8usize);

    let x: Vec<f32> = (0..m).map(|i| ((i * 37) % 17) as f32 / 17.0 - 0.5).collect();
    let xq = spec.quantize_vec(&x);

    // ----- boundary cost --------------------------------------------
    bench.run("quantize 32-dim sample (ingress)", || spec.quantize_vec(&x));

    // ----- RP: f32 sparse adds vs integer adds ----------------------
    let rp = RandomProjection::new(m, p, RpDistribution::Ternary, 7).unit_variance();
    let frp = FxpRp::from_rp(&rp, spec);
    bench.run("f32 rp apply 32→16", || rp.apply(&x));
    bench.run("fxp rp apply 32→16 (q4.12)", || frp.apply_raw(&xq));

    // ----- GHA step -------------------------------------------------
    let xp: Vec<f32> = (0..p).map(|i| ((i * 29) % 13) as f32 / 13.0 - 0.5).collect();
    let xpq = spec.quantize_vec(&xp);
    let mut gha = GhaWhitener::new(GhaConfig {
        input_dim: p,
        output_dim: n,
        ..Default::default()
    });
    bench.run("f32 gha step 16→8", || gha.step(&xp));
    let mut fgha = FxpGha::new(p, n, 5e-3, 5e-3, 2018, spec, QuantMode::BitExact);
    bench.run("fxp gha step 16→8 (q4.12)", || fgha.step_raw(&xpq));
    let mut fgha_ste = FxpGha::new(p, n, 5e-3, 5e-3, 2018, spec, QuantMode::Ste);
    bench.run("fxp gha step 16→8 (q4.12, STE)", || fgha_ste.step_raw(&xpq));

    // ----- rotation-only EASI step ----------------------------------
    let zn: Vec<f32> = (0..n).map(|i| ((i * 11) % 7) as f32 / 7.0 - 0.5).collect();
    let znq = spec.quantize_vec(&zn);
    let mut rot = EasiTrainer::new(EasiConfig {
        input_dim: n,
        output_dim: n,
        mode: EasiMode::RotationOnly,
        ..Default::default()
    });
    bench.run("f32 easi rotation step 8→8", || rot.step(&zn));
    let mut frot = FxpEasiRot::new(n, n, 1e-3, None, spec, QuantMode::BitExact);
    bench.run("fxp easi rotation step 8→8 (q4.12)", || frot.step_raw(&znq));
    let mut frot_ste = FxpEasiRot::new(n, n, 1e-3, None, spec, QuantMode::Ste);
    bench.run("fxp easi rotation step 8→8 (q4.12, STE)", || frot_ste.step_raw(&znq));

    // ----- composed unit --------------------------------------------
    let mut unit = DrUnit::new(DrUnitConfig {
        input_dim: p,
        output_dim: n,
        rot_warmup: 0,
        ..Default::default()
    });
    bench.run("f32 unit step 16→8", || unit.step(&xp));
    let mut funit = FxpDrUnit::new(FxpUnitConfig {
        input_dim: p,
        output_dim: n,
        mu_w: 5e-3,
        mu_rot: 1e-3,
        rotate: true,
        rot_warmup: 0,
        seed: 2018,
        whiten_spec: spec,
        rot_spec: spec,
        quant: QuantMode::BitExact,
    });
    bench.run("fxp unit step 16→8 (q4.12)", || funit.step_raw(&xpq));
    let mut funit_mixed = FxpDrUnit::new(FxpUnitConfig {
        input_dim: p,
        output_dim: n,
        mu_w: 5e-3,
        mu_rot: 1e-3,
        rotate: true,
        rot_warmup: 0,
        seed: 2018,
        whiten_spec: FxpSpec::q(8, 16),
        rot_spec: spec,
        quant: QuantMode::Ste,
    });
    let xpq_wide = FxpSpec::q(8, 16).quantize_vec(&xp);
    bench.run("fxp unit step 16→8 (mixed q8.16/q4.12, STE)", || {
        funit_mixed.step_raw(&xpq_wide)
    });

    // ----- dense matvec (inference path) ----------------------------
    let b = Mat::from_fn(n, m, |i, j| ((i * m + j) as f32 * 0.13).sin());
    let bq = FxpMat::quantize(&b, spec);
    bench.run("f32 matvec 32→8", || b.matvec(&x));
    bench.run("fxp matvec 32→8 (q4.12)", || bq.matvec_raw(&xq));

    bench.finish();
}
