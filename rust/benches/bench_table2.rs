//! Bench T2 (DESIGN.md §5): regenerates the paper's Table II from the
//! calibrated Arria-10 model, checks every cell against the published
//! numbers, and times the cost-model evaluation itself (it sits on the
//! design-space-exploration path of the scalability sweep, so its own
//! throughput matters).

use dimred::hwmodel::{
    paper_table_ii_configs, table_ii, Arria10Model, HwConfig, PAPER_TABLE_II,
};
use dimred::util::bench::Bench;

fn main() {
    // ------- the table itself + paper deltas (once) -------------------
    let rows = table_ii(&paper_table_ii_configs());
    println!("Table II (model vs paper):");
    let mut worst: f64 = 0.0;
    for (row, paper) in rows.iter().zip(PAPER_TABLE_II.iter()) {
        let rel = |got: u64, want: u64| (got as f64 - want as f64).abs() / want as f64;
        let w = rel(row.dsps, paper.0)
            .max(rel(row.alms, paper.1))
            .max(rel(row.register_bits, paper.2));
        worst = worst.max(w);
        println!(
            "  m={} p={:?} n={}: {} DSPs / {} ALMs / {} reg bits  (paper {} / {} / {})  Δmax {:.1}%",
            row.input, row.intermediate, row.output,
            row.dsps, row.alms, row.register_bits,
            paper.0, paper.1, paper.2, w * 100.0
        );
    }
    println!(
        "DSP saving {:.2}× (paper {:.2}×); worst cell error {:.1}%\n",
        rows[0].dsps as f64 / rows[1].dsps as f64,
        PAPER_TABLE_II[0].0 as f64 / PAPER_TABLE_II[1].0 as f64,
        worst * 100.0
    );

    // ------- model evaluation cost -------------------------------------
    let model = Arria10Model::paper_calibrated();
    let mut bench = Bench::new("table2-cost-model");
    bench.run("cost(EASI 32→8)", || model.cost(&HwConfig::easi(32, 8)).dsps);
    bench.run("cost(RP 32→16 + EASI 16→8)", || {
        model.cost(&HwConfig::rp_easi(32, 16, 8)).dsps
    });
    bench.run("sweep 64 configs", || {
        let mut acc = 0u64;
        for m in (32..=512).step_by(32) {
            for p in [m / 2, m / 4] {
                if p >= 8 {
                    acc += model.cost(&HwConfig::rp_easi(m, p, 8)).dsps;
                }
            }
        }
        acc
    });
    bench.finish();
}
