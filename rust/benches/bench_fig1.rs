//! Bench F1 (DESIGN.md §5): regenerates a compact Fig. 1 (two points
//! per dataset so the full bench stays in CI budget; the example
//! regenerates denser series) and times the fit+transform of each DR
//! algorithm at the figure's scale — the cost axis the paper's
//! hardware argument is about.

use dimred::datasets::mnist_like::MnistLikeConfig;
use dimred::pipeline::{DrPipeline, PipelineSpec, StageSpec};
use dimred::rp::{RandomProjection, RpDistribution};
use dimred::util::bench::Bench;

fn main() {
    let quick = std::env::var("DIMRED_BENCH_QUICK").is_ok();
    let points = if quick { 2 } else { 3 };

    // ------- compact accuracy series (once) ---------------------------
    for ds in ["mnist", "har", "ads"] {
        match dimred::experiments::fig1::run(ds, points, 2018) {
            Ok(series) => println!("{}", dimred::experiments::fig1::render(ds, &series)),
            Err(e) => println!("fig1 {ds}: ERROR {e}"),
        }
    }

    // ------- per-algorithm fit/apply cost at MNIST scale ---------------
    let mut data = MnistLikeConfig {
        train: if quick { 300 } else { 1000 },
        test: 100,
        ..Default::default()
    }
    .generate();
    data.standardize();
    let m = data.input_dim();
    let n = 64;

    let mut bench = Bench::new("fig1-dr-algorithms");
    bench.run("rp-ternary fit(784→64)", || {
        RandomProjection::new(m, n, RpDistribution::Ternary, 7).nnz()
    });
    let rp = RandomProjection::new(m, n, RpDistribution::Ternary, 7);
    bench.run("rp-ternary apply(1 sample)", || rp.apply(data.train_x.row(0)));
    let pca_spec = PipelineSpec {
        input_dim: m,
        rp: None,
        stage: StageSpec::Pca,
        output_dim: n,
        seed: 7,
        precision: dimred::fxp::Precision::F32,
    };
    bench.run("pca fit(784→64, subspace-iter)", || {
        DrPipeline::fit(pca_spec.clone(), &data.train_x).spec.output_dim
    });
    let ica_spec = PipelineSpec {
        input_dim: m,
        rp: Some(dimred::pipeline::RpStage {
            intermediate_dim: 4 * n,
            distribution: RpDistribution::Ternary,
        }),
        stage: StageSpec::Ica {
            mu_w: 5e-3,
            mu_rot: 1e-3,
            epochs: 1,
        },
        output_dim: n,
        seed: 7,
        precision: dimred::fxp::Precision::F32,
    };
    bench.run("ica fit(784→256→64, 1 epoch)", || {
        DrPipeline::fit(ica_spec.clone(), &data.train_x).spec.output_dim
    });
    let fitted = DrPipeline::fit(ica_spec, &data.train_x);
    bench.run("ica transform(1 sample)", || fitted.transform(data.train_x.row(0)));
    bench.finish();
}
