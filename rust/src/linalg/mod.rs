//! Small dense linear algebra, written from scratch.
//!
//! The dimensionalities in the paper are modest (m ≤ 1558, n ≤ 784,
//! typically m = 32, n ∈ {8, 16}), so a simple row-major `f32` matrix
//! with cache-friendly kernels is more than sufficient and keeps the
//! crate dependency-free. `f64` is used internally where numerical
//! robustness matters (Jacobi eigendecomposition, metrics).

mod jacobi;
mod mat;
mod metrics;
mod subspace;

pub use jacobi::{symmetric_eigen, Eigen};
pub use subspace::subspace_eigen;
pub use mat::Mat;
pub use metrics::{amari_index, max_abs_diff, off_diagonality, whiteness_error};

/// Dot product of two equal-length slices.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulation: keeps fp32 error growth O(n/4) and
    // lets LLVM vectorize without -ffast-math.
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Modified Gram–Schmidt on the rows of a matrix, in place. Shared by
/// the EASI/PJRT retraction paths and the fixed-point kernels'
/// host-side retraction (see `fxp::kernels`).
pub fn orthonormalize_rows(m: &mut Mat) {
    let (n, cols) = m.shape();
    for i in 0..n {
        for j in 0..i {
            let proj = dot(m.row(i), m.row(j));
            for k in 0..cols {
                let v = m.get(i, k) - proj * m.get(j, k);
                m.set(i, k, v);
            }
        }
        let norm = norm2(m.row(i)).max(1e-12);
        for k in 0..cols {
            let v = m.get(i, k) / norm;
            m.set(i, k, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.25 - 3.0).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32).sin()).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 20.0, 30.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0, 36.0]);
    }

    #[test]
    fn norm2_pythagorean() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn orthonormalize_rows_produces_orthonormal_rows() {
        let mut m = Mat::from_vec(2, 3, vec![3.0, 0.0, 0.0, 1.0, 1.0, 0.5]);
        orthonormalize_rows(&mut m);
        assert!((dot(m.row(0), m.row(0)) - 1.0).abs() < 1e-5);
        assert!((dot(m.row(1), m.row(1)) - 1.0).abs() < 1e-5);
        assert!(dot(m.row(0), m.row(1)).abs() < 1e-5);
    }
}
