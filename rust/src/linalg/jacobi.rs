//! Cyclic Jacobi eigendecomposition for symmetric matrices.
//!
//! Used as the *batch* PCA oracle (Fig. 1's "PCA" series and the
//! whitening-correctness tests). Internally `f64` for robustness; the
//! public API converts from/to the crate's `f32` [`Mat`].

use super::Mat;

/// Eigendecomposition of a symmetric matrix: `A = V diag(λ) Vᵀ`.
#[derive(Debug, Clone)]
pub struct Eigen {
    /// Eigenvalues, sorted descending.
    pub values: Vec<f64>,
    /// Eigenvectors as *rows* (row `i` pairs with `values[i]`).
    pub vectors: Mat,
}

/// Compute all eigenpairs of a symmetric matrix via cyclic Jacobi
/// rotations. Panics if `a` is not square; symmetry is assumed (the
/// strictly-lower triangle is ignored).
pub fn symmetric_eigen(a: &Mat) -> Eigen {
    let (n, m) = a.shape();
    assert_eq!(n, m, "symmetric_eigen needs a square matrix");
    // Work in f64.
    let mut s: Vec<f64> = a.as_slice().iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let idx = |i: usize, j: usize| i * n + j;
    let max_sweeps = 64;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius mass; stop when negligible relative to
        // the diagonal.
        let mut off = 0.0f64;
        let mut diag = 0.0f64;
        for i in 0..n {
            diag += s[idx(i, i)].abs();
            for j in (i + 1)..n {
                off += s[idx(i, j)] * s[idx(i, j)];
            }
        }
        if off.sqrt() <= 1e-14 * (diag + 1e-300) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = s[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = s[idx(p, p)];
                let aqq = s[idx(q, q)];
                // Classic stable rotation computation (Golub & Van Loan).
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let sn = t * c;

                // Update rows/cols p and q of S (full symmetric update).
                for k in 0..n {
                    let skp = s[idx(k, p)];
                    let skq = s[idx(k, q)];
                    s[idx(k, p)] = c * skp - sn * skq;
                    s[idx(k, q)] = sn * skp + c * skq;
                }
                for k in 0..n {
                    let spk = s[idx(p, k)];
                    let sqk = s[idx(q, k)];
                    s[idx(p, k)] = c * spk - sn * sqk;
                    s[idx(q, k)] = sn * spk + c * sqk;
                }
                // Accumulate the rotation into V (V rows are eigvecs^T
                // accumulation; we store V as column accumulation then
                // transpose on exit — here accumulate columns).
                for k in 0..n {
                    let vkp = v[idx(k, p)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p)] = c * vkp - sn * vkq;
                    v[idx(k, q)] = sn * vkp + c * vkq;
                }
            }
        }
    }

    // Extract eigenvalues and sort descending.
    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (s[idx(i, i)], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let values: Vec<f64> = pairs.iter().map(|&(l, _)| l).collect();
    // Row i of `vectors` = eigenvector for values[i] = column pairs[i].1
    // of V.
    let vectors = Mat::from_fn(n, n, |i, j| v[idx(j, pairs[i].1)] as f32);
    Eigen { values, vectors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;

    fn reconstruct(e: &Eigen, n: usize) -> Mat {
        // A = sum_i λ_i v_i v_iᵀ
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            let vi = e.vectors.row(i).to_vec();
            let li = e.values[i] as f32;
            for r in 0..n {
                for c in 0..n {
                    let v = a.get(r, c) + li * vi[r] * vi[c];
                    a.set(r, c, v);
                }
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix_is_its_own_decomposition() {
        let a = Mat::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let e = symmetric_eigen(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-9);
        assert!((e.values[1] - 2.0).abs() < 1e-9);
        assert!((e.values[2] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → λ = 3, 1 ; v = (1,1)/√2, (1,-1)/√2
        let a = Mat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = symmetric_eigen(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-9);
        assert!((e.values[1] - 1.0).abs() < 1e-9);
        let v0 = e.vectors.row(0);
        assert!((v0[0].abs() - std::f32::consts::FRAC_1_SQRT_2).abs() < 1e-5);
        assert!((v0[0] - v0[1]).abs() < 1e-5, "components equal up to sign");
    }

    #[test]
    fn reconstruction_and_orthonormality() {
        // Symmetric random-ish matrix.
        let base = Mat::from_fn(6, 6, |i, j| ((i * 31 + j * 17) % 13) as f32 / 13.0);
        let a = Mat::from_fn(6, 6, |i, j| base.get(i, j) + base.get(j, i));
        let e = symmetric_eigen(&a);
        let r = reconstruct(&e, 6);
        for (x, y) in r.as_slice().iter().zip(a.as_slice()) {
            assert!((x - y).abs() < 1e-4, "reconstruction {x} vs {y}");
        }
        // Orthonormal rows.
        for i in 0..6 {
            for j in 0..6 {
                let d = dot(e.vectors.row(i), e.vectors.row(j));
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((d - expect).abs() < 1e-4, "v{i}·v{j} = {d}");
            }
        }
    }

    #[test]
    fn eigenvalues_sorted_descending() {
        let a = Mat::from_fn(5, 5, |i, j| if i == j { (5 - i) as f32 } else { 0.1 });
        let e = symmetric_eigen(&a);
        for w in e.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn trace_preserved() {
        let base = Mat::from_fn(8, 8, |i, j| ((i + 2 * j) % 7) as f32 * 0.3);
        let a = Mat::from_fn(8, 8, |i, j| base.get(i, j) + base.get(j, i));
        let e = symmetric_eigen(&a);
        let trace: f32 = (0..8).map(|i| a.get(i, i)).sum();
        let sum: f64 = e.values.iter().sum();
        assert!((trace as f64 - sum).abs() < 1e-4);
    }
}
