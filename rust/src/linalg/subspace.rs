//! Subspace (blocked power) iteration — top-k eigenpairs of a symmetric
//! PSD matrix.
//!
//! Full cyclic Jacobi is O(m³) per sweep, prohibitive for the Fig. 1
//! datasets (MNIST m=784, Ads m=1558). PCA only needs the leading k
//! eigenvectors, and the covariance is PSD, so orthogonal iteration
//! converges geometrically at rate λ_{k+1}/λ_k. O(m²k) per iteration.

use super::{dot, Mat};
use crate::rng::{Pcg64, RngExt};

/// Leading-k eigenpairs of symmetric PSD `a` (values descending,
/// vectors as rows).
pub fn subspace_eigen(a: &Mat, k: usize, iters: usize, seed: u64) -> super::Eigen {
    let (m, m2) = a.shape();
    assert_eq!(m, m2, "subspace_eigen needs a square matrix");
    assert!(k >= 1 && k <= m);

    // Random start, orthonormalised.
    let mut rng = Pcg64::seed_stream(seed, 0x5355_4253); // "SUBS"
    let mut q: Vec<Vec<f32>> = (0..k)
        .map(|_| (0..m).map(|_| rng.next_gaussian() as f32).collect())
        .collect();
    orthonormalize(&mut q);

    for _ in 0..iters {
        // Z = A Q^T (column-block product), then re-orthonormalise.
        let mut z: Vec<Vec<f32>> = q.iter().map(|qi| a.matvec(qi)).collect();
        orthonormalize(&mut z);
        q = z;
    }

    // Rayleigh quotients + final sort.
    let mut pairs: Vec<(f64, Vec<f32>)> = q
        .into_iter()
        .map(|qi| {
            let aq = a.matvec(&qi);
            (dot(&qi, &aq) as f64, qi)
        })
        .collect();
    pairs.sort_by(|x, y| y.0.partial_cmp(&x.0).unwrap());

    let values: Vec<f64> = pairs.iter().map(|(l, _)| *l).collect();
    let vectors = Mat::from_fn(k, m, |i, j| pairs[i].1[j]);
    super::Eigen { values, vectors }
}

/// Modified Gram–Schmidt, in place. Near-dependent vectors are
/// re-randomised deterministically from their index (rare; only matters
/// when k approaches the effective rank).
fn orthonormalize(vs: &mut [Vec<f32>]) {
    let m = vs[0].len();
    for i in 0..vs.len() {
        for j in 0..i {
            let (head, tail) = vs.split_at_mut(i);
            let proj = dot(&tail[0], &head[j]);
            for (t, &h) in tail[0].iter_mut().zip(&head[j]) {
                *t -= proj * h;
            }
        }
        let norm = super::norm2(&vs[i]);
        if norm < 1e-10 {
            // Deterministic fallback basis vector.
            for (idx, v) in vs[i].iter_mut().enumerate() {
                *v = if idx == i % m { 1.0 } else { 0.0 };
            }
        } else {
            for v in &mut vs[i] {
                *v /= norm;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::symmetric_eigen;

    fn spd_matrix(m: usize) -> Mat {
        // A = G Gᵀ + diag boost — strictly PD with decaying spectrum.
        let mut rng = Pcg64::seed(91);
        let g = Mat::from_fn(m, m, |i, _| rng.next_gaussian() as f32 / (1.0 + i as f32));
        let mut a = g.matmul_nt(&g);
        for i in 0..m {
            let v = a.get(i, i) + 0.1;
            a.set(i, i, v);
        }
        a
    }

    #[test]
    fn matches_jacobi_leading_pairs() {
        let a = spd_matrix(12);
        let full = symmetric_eigen(&a);
        let top = subspace_eigen(&a, 3, 200, 1);
        for i in 0..3 {
            let rel = (top.values[i] - full.values[i]).abs() / full.values[i].max(1e-9);
            assert!(rel < 1e-3, "eigenvalue {i}: {} vs {}", top.values[i], full.values[i]);
            // Vectors agree up to sign.
            let d = dot(top.vectors.row(i), full.vectors.row(i)).abs();
            assert!(d > 0.99, "eigvec {i} alignment {d}");
        }
    }

    #[test]
    fn vectors_orthonormal() {
        let a = spd_matrix(20);
        let e = subspace_eigen(&a, 5, 100, 2);
        for i in 0..5 {
            for j in 0..5 {
                let d = dot(e.vectors.row(i), e.vectors.row(j));
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-3, "({i},{j}) dot {d}");
            }
        }
    }

    #[test]
    fn values_descending_nonnegative() {
        let a = spd_matrix(16);
        let e = subspace_eigen(&a, 6, 100, 3);
        for w in e.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-9);
        }
        assert!(e.values.iter().all(|&l| l > 0.0));
    }
}
