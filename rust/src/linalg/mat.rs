//! Row-major dense `f32` matrix.

use super::dot;

/// Row-major dense matrix of `f32`.
///
/// Row-major matches both the C ABI the PJRT literals use and the
/// streaming access pattern of the coordinator (samples are rows).
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity-like matrix: ones on the main diagonal, zero elsewhere.
    /// Works for rectangular shapes (used to initialise B = [I 0]).
    pub fn eye(rows: usize, cols: usize) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows.min(cols) {
            m.data[i * cols + i] = 1.0;
        }
        m
    }

    /// Build from a row-major vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build from a function of (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Self { rows, cols, data }
    }

    pub fn rows_count(&self) -> usize {
        self.rows
    }

    pub fn cols_count(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Borrow the backing row-major slice.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Mutably borrow the backing row-major slice.
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the backing vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Iterator over rows.
    pub fn rows(&self) -> impl Iterator<Item = &[f32]> {
        self.data.chunks_exact(self.cols)
    }

    /// Iterate over column `j`, top to bottom — column access without a
    /// temporary vector (callers that need a buffer collect explicitly).
    pub fn col(&self, j: usize) -> impl Iterator<Item = f32> + '_ {
        assert!(j < self.cols, "col index out of range");
        (0..self.rows).map(move |i| self.get(i, j))
    }

    /// Matrix–vector product `self * x`.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows];
        self.matvec_into(x, &mut out);
        out
    }

    /// [`Mat::matvec`] into a caller-owned buffer — the allocation-free
    /// form the tiled datapath runs on (identical arithmetic).
    ///
    /// Register-blocked four rows at a time: `x` is loaded once per
    /// quad instead of once per row, and each row keeps the exact
    /// 4-lane accumulation order of [`dot`] (same partials, same final
    /// combine), so the outputs are bit-identical to the per-row form
    /// whatever the blocking.
    pub fn matvec_into(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.cols, "matvec shape mismatch");
        assert_eq!(out.len(), self.rows, "matvec out shape mismatch");
        let cols = self.cols;
        let chunks = cols / 4;
        let mut r = 0usize;
        while r + 4 <= self.rows {
            let rows = [self.row(r), self.row(r + 1), self.row(r + 2), self.row(r + 3)];
            let mut acc = [[0.0f32; 4]; 4];
            for c in 0..chunks {
                let j = c * 4;
                for (a, row) in acc.iter_mut().zip(&rows) {
                    a[0] += row[j] * x[j];
                    a[1] += row[j + 1] * x[j + 1];
                    a[2] += row[j + 2] * x[j + 2];
                    a[3] += row[j + 3] * x[j + 3];
                }
            }
            for (k, (a, row)) in acc.iter().zip(&rows).enumerate() {
                let mut s = (a[0] + a[1]) + (a[2] + a[3]);
                for j in chunks * 4..cols {
                    s += row[j] * x[j];
                }
                out[r + k] = s;
            }
            r += 4;
        }
        while r < self.rows {
            out[r] = dot(self.row(r), x);
            r += 1;
        }
    }

    /// Transposed matrix–vector product `selfᵀ * x`.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.rows, "matvec_t shape mismatch");
        let mut out = vec![0.0; self.cols];
        for (i, r) in self.rows().enumerate() {
            let xi = x[i];
            for (o, &rij) in out.iter_mut().zip(r) {
                *o += xi * rij;
            }
        }
        out
    }

    /// Matrix product `self * other`, ikj loop order (streams the rhs
    /// row-wise — cache-friendly for row-major storage).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self.get(i, k);
                if aik == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let orow = out.row_mut(i);
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += aik * b;
                }
            }
        }
        out
    }

    /// Transpose.
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.get(j, i))
    }

    /// `self * otherᵀ`.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        Mat::from_fn(self.rows, other.rows, |i, j| dot(self.row(i), other.row(j)))
    }

    /// Outer product of two vectors.
    pub fn outer(a: &[f32], b: &[f32]) -> Mat {
        Mat::from_fn(a.len(), b.len(), |i, j| a[i] * b[j])
    }

    /// In-place scaled add: `self += alpha * other`.
    pub fn add_scaled(&mut self, alpha: f32, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Scale all entries.
    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Sample covariance of the rows: `Xᵀ X / N` (or `/(N-1)` if
    /// `unbiased`), after removing the column means if `center`.
    pub fn covariance(&self, center: bool, unbiased: bool) -> Mat {
        let n = self.rows as f32;
        assert!(self.rows >= 2, "need at least two samples");
        let mut means = vec![0.0f32; self.cols];
        if center {
            for r in self.rows() {
                for (m, &x) in means.iter_mut().zip(r) {
                    *m += x;
                }
            }
            for m in &mut means {
                *m /= n;
            }
        }
        let mut cov = Mat::zeros(self.cols, self.cols);
        let mut centered = vec![0.0f32; self.cols];
        for r in self.rows() {
            for ((c, &x), &m) in centered.iter_mut().zip(r).zip(&means) {
                *c = x - m;
            }
            // rank-1 update of the upper triangle
            for i in 0..self.cols {
                let ci = centered[i];
                let row = cov.row_mut(i);
                for j in i..self.cols {
                    row[j] += ci * centered[j];
                }
            }
        }
        let denom = if unbiased { n - 1.0 } else { n };
        for i in 0..self.cols {
            for j in i..self.cols {
                let v = cov.get(i, j) / denom;
                cov.set(i, j, v);
                cov.set(j, i, v);
            }
        }
        cov
    }

    /// Column means of the rows.
    pub fn col_means(&self) -> Vec<f32> {
        let mut means = vec![0.0f32; self.cols];
        for r in self.rows() {
            for (m, &x) in means.iter_mut().zip(r) {
                *m += x;
            }
        }
        let n = self.rows as f32;
        for m in &mut means {
            *m /= n;
        }
        means
    }

    /// Apply `self` (as a linear map) to every row of `x`, producing a
    /// new sample matrix: `out[i] = self * x[i]` — i.e. `X * selfᵀ`.
    pub fn apply_rows(&self, x: &Mat) -> Mat {
        let mut out = Mat::zeros(x.rows, self.rows);
        self.apply_rows_into(x, &mut out);
        out
    }

    /// [`Mat::apply_rows`] into a caller-owned output matrix
    /// (`x.rows × self.rows`) — the tile form reused across batches so
    /// the steady-state training loop stops allocating a projected
    /// matrix per minibatch.
    pub fn apply_rows_into(&self, x: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, x.cols, "apply_rows shape mismatch");
        assert_eq!(out.shape(), (x.rows, self.rows), "apply_rows out shape");
        // One blocked matvec per sample row (bit-identical to the
        // per-(row, output) dot loop — see `matvec_into`).
        for i in 0..x.rows {
            let xr = x.row(i);
            self.matvec_into(xr, out.row_mut(i));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_rectangular() {
        let e = Mat::eye(2, 4);
        assert_eq!(e.row(0), &[1.0, 0.0, 0.0, 0.0]);
        assert_eq!(e.row(1), &[0.0, 1.0, 0.0, 0.0]);
    }

    #[test]
    fn matvec_known() {
        let m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(m.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
    }

    #[test]
    fn matvec_t_is_transpose_matvec() {
        let m = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let x = [2.0, -1.0];
        assert_eq!(m.matvec_t(&x), m.transpose().matvec(&x));
    }

    #[test]
    fn matvec_blocked_bit_identical_to_per_row_dot() {
        // The 4-row register blocking must keep each row's accumulation
        // order exactly `dot`'s — bitwise, not approximately.
        for (rows, cols) in [(1usize, 1usize), (3, 5), (4, 8), (7, 33), (18, 19)] {
            let m = Mat::from_fn(rows, cols, |i, j| ((i * 31 + j * 17) as f32 * 0.37).sin());
            let x: Vec<f32> = (0..cols).map(|j| ((j * 13) as f32 * 0.11).cos()).collect();
            let mut blocked = vec![0.0f32; rows];
            m.matvec_into(&x, &mut blocked);
            for i in 0..rows {
                assert_eq!(blocked[i].to_bits(), dot(m.row(i), &x).to_bits(), "row {i}");
            }
        }
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let a = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32 * 0.5);
        let b = Mat::from_fn(5, 4, |i, j| (i + j) as f32 - 2.0);
        let c1 = a.matmul_nt(&b);
        let c2 = a.matmul(&b.transpose());
        for (x, y) in c1.as_slice().iter().zip(c2.as_slice()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |i, j| (i * 7 + j) as f32);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn outer_shape_and_values() {
        let o = Mat::outer(&[1.0, 2.0], &[3.0, 4.0, 5.0]);
        assert_eq!(o.shape(), (2, 3));
        assert_eq!(o.get(1, 2), 10.0);
    }

    #[test]
    fn covariance_of_whitened_identity() {
        // Construct samples with exactly identity covariance: orthonormal
        // pattern scaled by sqrt(N/2).
        let n = 1000;
        let mut data = Vec::new();
        for i in 0..n {
            let phase = i as f32 * std::f32::consts::TAU / n as f32;
            data.push(2f32.sqrt() * phase.cos());
            data.push(2f32.sqrt() * phase.sin());
        }
        let x = Mat::from_vec(n, 2, data);
        let cov = x.covariance(true, false);
        assert!((cov.get(0, 0) - 1.0).abs() < 1e-2);
        assert!((cov.get(1, 1) - 1.0).abs() < 1e-2);
        assert!(cov.get(0, 1).abs() < 1e-2);
    }

    #[test]
    fn covariance_is_symmetric_psd_diag() {
        let x = Mat::from_fn(50, 4, |i, j| ((i * 13 + j * 7) % 11) as f32 - 5.0);
        let cov = x.covariance(true, true);
        for i in 0..4 {
            assert!(cov.get(i, i) >= 0.0);
            for j in 0..4 {
                assert!((cov.get(i, j) - cov.get(j, i)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn apply_rows_matches_per_row_matvec() {
        let w = Mat::from_fn(2, 3, |i, j| (i + j) as f32);
        let x = Mat::from_fn(4, 3, |i, j| (i * 3 + j) as f32);
        let y = w.apply_rows(&x);
        assert_eq!(y.shape(), (4, 2));
        for i in 0..4 {
            assert_eq!(y.row(i), w.matvec(x.row(i)).as_slice());
        }
    }

    #[test]
    #[should_panic(expected = "matvec shape mismatch")]
    fn matvec_bad_shape_panics() {
        Mat::zeros(2, 3).matvec(&[1.0, 2.0]);
    }
}
