//! Quality metrics for dimensionality-reduction / source-separation
//! outputs: whiteness, off-diagonality, and the Amari separation index.

use super::Mat;

/// Whiteness error `‖E[zzᵀ] − I‖_F / n` of a sample matrix (rows are
/// samples). Zero iff the samples are perfectly spatially white — the
/// criterion Eq. 3 of the paper drives to zero.
pub fn whiteness_error(z: &Mat) -> f64 {
    let cov = z.covariance(false, false);
    let n = cov.rows_count();
    let mut err = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let target = if i == j { 1.0 } else { 0.0 };
            let d = cov.get(i, j) as f64 - target;
            err += d * d;
        }
    }
    err.sqrt() / n as f64
}

/// Relative off-diagonal mass of a square matrix:
/// `‖offdiag(A)‖_F / ‖diag(A)‖_F`. Zero for diagonal matrices.
pub fn off_diagonality(a: &Mat) -> f64 {
    let (n, m) = a.shape();
    assert_eq!(n, m, "off_diagonality needs a square matrix");
    let mut off = 0.0f64;
    let mut diag = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let v = a.get(i, j) as f64;
            if i == j {
                diag += v * v;
            } else {
                off += v * v;
            }
        }
    }
    (off.sqrt()) / (diag.sqrt() + 1e-30)
}

/// Amari separation index of the global system `P = B·A` (separation ×
/// mixing). Zero iff `P` is a scaled permutation — i.e. the sources are
/// perfectly separated up to order/scale, the invariance class of ICA.
///
/// Standard form (Amari et al., NIPS'96), normalised to `[0, 1]`-ish:
/// the sum of row-wise and column-wise "how far from a one-hot" scores.
pub fn amari_index(p: &Mat) -> f64 {
    let (n, m) = p.shape();
    assert_eq!(n, m, "amari_index needs a square global matrix");
    let nf = n as f64;
    let mut total = 0.0f64;
    // Row term.
    for i in 0..n {
        let row_max = (0..n).map(|j| p.get(i, j).abs() as f64).fold(0.0, f64::max);
        let row_sum: f64 = (0..n).map(|j| p.get(i, j).abs() as f64).sum();
        total += row_sum / (row_max + 1e-30) - 1.0;
    }
    // Column term.
    for j in 0..n {
        let col_max = (0..n).map(|i| p.get(i, j).abs() as f64).fold(0.0, f64::max);
        let col_sum: f64 = (0..n).map(|i| p.get(i, j).abs() as f64).sum();
        total += col_sum / (col_max + 1e-30) - 1.0;
    }
    total / (2.0 * nf * (nf - 1.0))
}

/// Maximum absolute elementwise difference between two equal-shape
/// matrices — the tolerance metric used to cross-check the native Rust
/// implementations against the PJRT-executed artifacts.
pub fn max_abs_diff(a: &Mat, b: &Mat) -> f32 {
    assert_eq!(a.shape(), b.shape(), "max_abs_diff shape mismatch");
    a.as_slice()
        .iter()
        .zip(b.as_slice())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, RngExt};

    #[test]
    fn whiteness_of_gaussian_iid_is_small() {
        let mut rng = Pcg64::seed(10);
        let x = Mat::from_fn(20_000, 4, |_, _| rng.next_gaussian() as f32);
        assert!(whiteness_error(&x) < 0.02);
    }

    #[test]
    fn whiteness_detects_correlation() {
        let mut rng = Pcg64::seed(11);
        let x = Mat::from_fn(5_000, 2, |_, _| rng.next_gaussian() as f32);
        // Correlate the columns strongly.
        let y = Mat::from_fn(5_000, 2, |i, j| {
            if j == 0 {
                x.get(i, 0)
            } else {
                0.9 * x.get(i, 0) + 0.1 * x.get(i, 1)
            }
        });
        assert!(whiteness_error(&y) > 0.3);
    }

    #[test]
    fn amari_zero_for_scaled_permutation() {
        // P = permutation with scales — perfect separation.
        let p = Mat::from_vec(3, 3, vec![0.0, 2.0, 0.0, -3.0, 0.0, 0.0, 0.0, 0.0, 0.5]);
        assert!(amari_index(&p) < 1e-9);
    }

    #[test]
    fn amari_positive_for_mixing() {
        let p = Mat::from_vec(2, 2, vec![1.0, 0.5, 0.5, 1.0]);
        assert!(amari_index(&p) > 0.2);
    }

    #[test]
    fn amari_max_for_uniform() {
        // All-equal |entries| is the worst case; index → (n-1)·2n/(2n(n-1)) = 1.
        let p = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert!((amari_index(&p) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn off_diagonality_basics() {
        let d = Mat::from_vec(2, 2, vec![2.0, 0.0, 0.0, 3.0]);
        assert!(off_diagonality(&d) < 1e-12);
        let m = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        assert!(off_diagonality(&m) > 0.9);
    }

    #[test]
    fn max_abs_diff_basics() {
        let a = Mat::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Mat::from_vec(1, 3, vec![1.5, 2.0, 2.0]);
        assert_eq!(max_abs_diff(&a, &b), 1.0);
    }
}
