//! Tiny CLI argument parser (offline environment — no clap).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and
//! positional arguments, with typed accessors and a usage-error path.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without the program
    /// name). `flag_names` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, flag_names: &[&str]) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminator: everything after is positional.
                    args.positional.extend(iter);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    args.flags.push(rest.to_string());
                } else {
                    let v = iter
                        .next()
                        .ok_or_else(|| anyhow!("option --{rest} needs a value"))?;
                    args.options.insert(rest.to_string(), v);
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    /// Parse the process's own arguments.
    pub fn from_env(flag_names: &[&str]) -> Result<Args> {
        Self::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.opt_str(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt_str(name) {
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer: {e}")),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt_str(name) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} expects an integer: {e}")),
            None => Ok(default),
        }
    }

    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        match self.opt_str(name) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{name} expects a float: {e}")),
            None => Ok(default),
        }
    }

    /// Error if unknown options were passed (catches typos).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        for f in &self.flags {
            if !known.contains(&f.as_str()) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], flags: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse(
            &["train", "--mu", "0.001", "--epochs=5", "--verbose", "extra"],
            &["verbose"],
        );
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.f32_or("mu", 0.0).unwrap(), 0.001);
        assert_eq!(a.usize_or("epochs", 0).unwrap(), 5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(["--mu".to_string()], &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&[], &[]);
        assert_eq!(a.usize_or("batch", 256).unwrap(), 256);
        assert_eq!(a.str_or("mode", "full"), "full");
    }

    #[test]
    fn unknown_option_detected() {
        let a = parse(&["--typo", "x"], &[]);
        assert!(a.ensure_known(&["mu"]).is_err());
        assert!(a.ensure_known(&["typo"]).is_ok());
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--mu", "1", "--", "--not-an-option"], &[]);
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }
}
