//! Minimal JSON parser + writer (RFC 8259 subset sufficient for the
//! artifact manifest, experiment configs and report output).
//!
//! Supports: objects, arrays, strings (with escapes), numbers, booleans,
//! null. Numbers are parsed as `f64`; integer accessors check
//! round-tripping. No serde in this offline environment — see
//! Cargo.toml.

use anyhow::{anyhow, bail, ensure, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are sorted (BTreeMap) so output is
/// deterministic — convenient for golden tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ------------------------------------------------------ accessors

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {}", other.type_name()),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => bail!("expected number, got {}", other.type_name()),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        ensure!(
            x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64,
            "expected non-negative integer, got {x}"
        );
        Ok(x as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {}", other.type_name()),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {}", other.type_name()),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {}", other.type_name()),
        }
    }

    /// Mandatory object field.
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing field '{key}'"))
    }

    /// Optional object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    fn type_name(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    // --------------------------------------------------- constructors

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // -------------------------------------------------------- parsing

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        ensure!(p.pos == p.bytes.len(), "trailing garbage at byte {}", p.pos);
        Ok(v)
    }

    // -------------------------------------------------------- writing

    /// Compact serialisation.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialisation with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        ensure!(
            self.peek() == Some(b),
            "expected '{}' at byte {}",
            b as char,
            self.pos
        );
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => bail!("unexpected byte '{}' at {}", b as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        ensure!(
            self.bytes[self.pos..].starts_with(word.as_bytes()),
            "invalid literal at byte {}",
            self.pos
        );
        self.pos += word.len();
        Ok(value)
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            ensure!(
                                self.pos + 4 < self.bytes.len(),
                                "truncated \\u escape"
                            );
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            // Surrogate pairs unsupported (manifest never
                            // contains astral-plane chars); reject cleanly.
                            let c = char::from_u32(code)
                                .ok_or_else(|| anyhow!("invalid \\u{hex}"))?;
                            s.push(c);
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
                None => bail!("unterminated string"),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.field("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.field("a").unwrap().as_arr().unwrap()[2]
                .field("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "c"
        );
        assert!(!v.field("d").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""line\nbreak \"quoted\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "line\nbreak \"quoted\" A");
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("name", Json::str("easi_full")),
            ("shape", Json::Arr(vec![Json::num(16.0), Json::num(32.0)])),
            ("ok", Json::Bool(true)),
            ("note", Json::str("a\"b\\c\n")),
        ]);
        for text in [v.to_string(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn as_usize_checks_integrality() {
        assert_eq!(Json::Num(7.0).as_usize().unwrap(), 7);
        assert!(Json::Num(7.5).as_usize().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
          "version": 1,
          "artifacts": [
            {"name": "x", "file": "x.hlo.txt",
             "inputs": [{"shape": [4, 8], "dtype": "f32"}],
             "outputs": [{"shape": [4, 8], "dtype": "f32"}]}
          ]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.field("version").unwrap().as_usize().unwrap(), 1);
        let arts = v.field("artifacts").unwrap().as_arr().unwrap();
        let shape: Vec<usize> = arts[0].field("inputs").unwrap().as_arr().unwrap()[0]
            .field("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|d| d.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![4, 8]);
    }
}
