//! Micro-benchmark harness (offline environment — no criterion).
//!
//! Criterion-style adaptive measurement: warm up, pick an iteration
//! count targeting a fixed measurement window, collect per-batch
//! samples, report median / mean / p95 with simple outlier trimming.
//! Used by every `cargo bench` target (`harness = false`).

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    /// Median time per iteration.
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub iterations: u64,
    pub samples: usize,
}

impl Measurement {
    /// Iterations per second at the median.
    pub fn throughput(&self) -> f64 {
        1.0 / self.median.as_secs_f64()
    }

    /// Human-oriented single line, aligned for table output.
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} median {:>12} mean {:>12} p95 {:>12} ({} samples x {} iters)",
            self.name,
            fmt_duration(self.median),
            fmt_duration(self.mean),
            fmt_duration(self.p95),
            self.samples,
            self.iterations,
        )
    }
}

/// Format a duration with appropriate unit.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// The harness. Construct with [`Bench::new`], call [`Bench::run`] per
/// case, then [`Bench::finish`].
pub struct Bench {
    suite: String,
    target_sample: Duration,
    samples: usize,
    results: Vec<Measurement>,
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        // Honour the same quick-mode env var the test suite uses.
        let quick = std::env::var("DIMRED_BENCH_QUICK").is_ok();
        Self {
            suite: suite.to_string(),
            target_sample: if quick {
                Duration::from_millis(20)
            } else {
                Duration::from_millis(150)
            },
            samples: if quick { 5 } else { 15 },
            results: Vec::new(),
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    /// A `black_box`-style sink defeats dead-code elimination: have `f`
    /// return something cheap and it will be consumed.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        // Warm-up + calibration: find iters such that one sample ≈
        // target_sample.
        let t0 = Instant::now();
        let mut calib_iters: u64 = 0;
        while t0.elapsed() < self.target_sample / 4 {
            std::hint::black_box(f());
            calib_iters += 1;
        }
        let per_iter = (t0.elapsed() / u32::try_from(calib_iters.max(1)).unwrap_or(1)).max(Duration::from_nanos(1));
        let iters = (self.target_sample.as_nanos() / per_iter.as_nanos()).clamp(1, 1_000_000) as u64;

        let mut times: Vec<Duration> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let start = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            times.push(start.elapsed() / u32::try_from(iters).unwrap_or(1));
        }
        times.sort_unstable();
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<Duration>() / times.len() as u32;
        let p95 = times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)];
        let m = Measurement {
            name: name.to_string(),
            median,
            mean,
            p95,
            iterations: iters,
            samples: times.len(),
        };
        println!("{}", m.report_line());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Print the suite footer and return all measurements.
    pub fn finish(self) -> Vec<Measurement> {
        println!("--- {} : {} benchmarks done ---", self.suite, self.results.len());
        self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("DIMRED_BENCH_QUICK", "1");
        let mut b = Bench::new("selftest");
        let m = b
            .run("sum-1k", || {
                let mut acc = 0u64;
                for i in 0..1000u64 {
                    acc = acc.wrapping_add(std::hint::black_box(i) * 7);
                }
                acc
            })
            .clone();
        assert!(m.median > Duration::ZERO);
        assert!(m.iterations >= 1);
        let all = b.finish();
        assert_eq!(all.len(), 1);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_duration(Duration::from_nanos(500)).ends_with("ns"));
        assert!(fmt_duration(Duration::from_micros(50)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(2)).ends_with(" s"));
    }
}
