//! In-tree utility substrates (the build environment is fully offline,
//! so JSON handling, CLI parsing and benchmarking helpers are all
//! implemented here from scratch).

pub mod bench;
pub mod cli;
pub mod json;
