//! Random projection — the paper's multiplication-free front end.
//!
//! Implements the ternary distribution of Fox et al. (FPT'16) used by
//! the paper (§III.B), plus Achlioptas (√3-sparse) and dense Gaussian
//! variants for the Fig. 1 comparisons. The ternary/Achlioptas
//! projections are stored in a sparse sign representation so `apply`
//! uses only additions and subtractions — exactly the hardware-cost
//! argument the paper makes (DSP-free datapath).

mod sparse;

pub use sparse::SparseSignMatrix;

use crate::linalg::Mat;
use crate::rng::{Pcg64, RngExt};

/// The element distribution used to build the projection matrix `R`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RpDistribution {
    /// Fox et al. FPT'16 (the paper's choice): ±1 w.p. 1/(2n) each,
    /// 0 otherwise. Scale factor √n on apply keeps E[‖Rx‖²] = ‖x‖².
    Ternary,
    /// Achlioptas 2001: ±√3 w.p. 1/6 each, 0 w.p. 2/3 (scale √(3)⁻¹·√? —
    /// folded into `scale`).
    Achlioptas,
    /// Dense `N(0, 1/p)` entries — the JL baseline.
    Gaussian,
}

/// A random projection `x ↦ scale · R x` from `in_dim` to `out_dim`.
#[derive(Debug, Clone)]
pub struct RandomProjection {
    pub in_dim: usize,
    pub out_dim: usize,
    pub distribution: RpDistribution,
    /// Sparse ±1 pattern (ternary / Achlioptas); `None` for Gaussian.
    sparse: Option<SparseSignMatrix>,
    /// Dense matrix for the Gaussian variant; also materialised for the
    /// sparse variants on demand (artifact export).
    dense: Option<Mat>,
    /// Output scaling applied after the matrix; restores isometry in
    /// expectation.
    pub scale: f32,
}

impl RandomProjection {
    /// Draw a projection matrix. `seed` fully determines `R` — the
    /// paper's point that `R` is computed offline with no knowledge of
    /// the data.
    pub fn new(in_dim: usize, out_dim: usize, distribution: RpDistribution, seed: u64) -> Self {
        assert!(out_dim >= 1 && in_dim >= out_dim, "need m >= n >= 1");
        let mut rng = Pcg64::seed_stream(seed, 0x5250_4D41); // "RPMA"
        match distribution {
            RpDistribution::Ternary => {
                // With r ∈ {0,±1} and P(±1) = 1/(2n) each, E[r²] = 1/n,
                // so E[(Rx)_i²] = ‖x‖²/n and E[‖Rx‖²] = ‖x‖² already:
                // the distribution is self-normalising, no scale needed
                // (and none is cheap in hardware — the paper's point).
                let sparse = SparseSignMatrix::sample_ternary(&mut rng, out_dim, in_dim);
                Self {
                    in_dim,
                    out_dim,
                    distribution,
                    sparse: Some(sparse),
                    dense: None,
                    scale: 1.0,
                }
            }
            RpDistribution::Achlioptas => {
                // r ∈ {0, ±√3} w.p. {2/3, 1/6, 1/6} ⇒ E[r²] = 1, so
                // E[‖Rx‖²] = k‖x‖² and the isometry scale is 1/√k
                // (k = out_dim). We store only the ±1 signs, folding the
                // √3 magnitude into the scale: s = √(3/out_dim).
                let sparse = SparseSignMatrix::sample_achlioptas(&mut rng, out_dim, in_dim);
                Self {
                    in_dim,
                    out_dim,
                    distribution,
                    sparse: Some(sparse),
                    dense: None,
                    scale: (3.0 / out_dim as f32).sqrt(),
                }
            }
            RpDistribution::Gaussian => {
                let dense = Mat::from_fn(out_dim, in_dim, |_, _| {
                    rng.next_gaussian() as f32 / (out_dim as f32).sqrt()
                });
                Self {
                    in_dim,
                    out_dim,
                    distribution,
                    sparse: None,
                    dense: Some(dense),
                    scale: 1.0,
                }
            }
        }
    }

    /// Apply to a single sample: `y = scale · R x`. For sparse variants
    /// this is pure add/sub — the hardware-friendly path.
    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0f32; self.out_dim];
        self.apply_into(x, &mut y);
        y
    }

    /// [`RandomProjection::apply`] into a caller-owned buffer — the
    /// allocation-free form of the add/sub network (identical
    /// arithmetic).
    pub fn apply_into(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.in_dim, "rp apply shape mismatch");
        assert_eq!(out.len(), self.out_dim, "rp apply out shape mismatch");
        match &self.sparse {
            Some(s) => s.apply_into(x, out),
            None => self.dense.as_ref().unwrap().matvec_into(x, out),
        }
        if self.scale != 1.0 {
            for v in out.iter_mut() {
                *v *= self.scale;
            }
        }
    }

    /// Apply to every row of a sample matrix.
    pub fn apply_rows(&self, x: &Mat) -> Mat {
        let mut out = Mat::zeros(x.rows_count(), self.out_dim);
        self.apply_rows_into(x, &mut out);
        out
    }

    /// [`RandomProjection::apply_rows`] into a caller-owned matrix
    /// (`x.rows × out_dim`) — the tile form the trainer reuses across
    /// minibatches.
    pub fn apply_rows_into(&self, x: &Mat, out: &mut Mat) {
        assert_eq!(
            out.shape(),
            (x.rows_count(), self.out_dim),
            "rp apply_rows out shape"
        );
        for i in 0..x.rows_count() {
            self.apply_into(x.row(i), out.row_mut(i));
        }
    }

    /// Materialise `scale·R` as a dense matrix (artifact export, cascade
    /// composition, and the JAX-side kernel input).
    pub fn to_dense(&self) -> Mat {
        let mut m = match &self.sparse {
            Some(s) => s.to_dense(),
            None => self.dense.clone().unwrap(),
        };
        m.scale(self.scale);
        m
    }

    /// The sparse ±1 pattern, if this is a sparse (ternary/Achlioptas)
    /// projection — used by the fixed-point kernels to run the exact
    /// add/sub network on raw words (`fxp::FxpRp`).
    pub fn sparse_pattern(&self) -> Option<&SparseSignMatrix> {
        self.sparse.as_ref()
    }

    /// Number of nonzero entries (adder inputs in hardware).
    pub fn nnz(&self) -> usize {
        match &self.sparse {
            Some(s) => s.nnz(),
            None => self.in_dim * self.out_dim,
        }
    }

    /// Rescale the projection so that *standardised* inputs (unit
    /// per-feature variance) produce unit-variance outputs.
    ///
    /// All three distributions preserve ‖x‖² in expectation, which puts
    /// per-coordinate output variance at m/p; the adaptive EASI stage
    /// behind the projection assumes unit-variance inputs (its cubic
    /// nonlinearity amplifies excess variance into divergence), so the
    /// trainers apply `s = √(p/m)`. One constant multiplier per output
    /// — in hardware it folds into the learning rate μ, keeping the RP
    /// module itself multiplication-free.
    pub fn unit_variance(mut self) -> Self {
        self.scale *= (self.out_dim as f32 / self.in_dim as f32).sqrt();
        self
    }
}

/// Empirical Johnson–Lindenstrauss distortion diagnostics: the
/// min / mean / max of `‖f(x_i)−f(x_j)‖² / ‖x_i−x_j‖²` over sampled
/// pairs. Values concentrated near 1 mean the projection preserves
/// pairwise distances (the property the paper leans on for second-order
/// statistics).
#[derive(Debug, Clone, Copy)]
pub struct Distortion {
    pub min: f64,
    pub mean: f64,
    pub max: f64,
    pub pairs: usize,
}

/// Measure distortion of `rp` on up to `max_pairs` random pairs of rows.
pub fn measure_distortion(
    rp: &RandomProjection,
    x: &Mat,
    max_pairs: usize,
    seed: u64,
) -> Distortion {
    let n = x.rows_count();
    assert!(n >= 2, "need at least two samples");
    let mut rng = Pcg64::seed_stream(seed, 0x4A4C_4449); // "JLDI"
    let y = rp.apply_rows(x);
    let mut min = f64::INFINITY;
    let mut max = 0.0f64;
    let mut sum = 0.0f64;
    let mut count = 0usize;
    for _ in 0..max_pairs {
        let i = rng.next_below(n as u64) as usize;
        let mut j = rng.next_below(n as u64) as usize;
        if i == j {
            j = (j + 1) % n;
        }
        let dx: f64 = x
            .row(i)
            .iter()
            .zip(x.row(j))
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        if dx < 1e-12 {
            continue;
        }
        let dy: f64 = y
            .row(i)
            .iter()
            .zip(y.row(j))
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let ratio = dy / dx;
        min = min.min(ratio);
        max = max.max(ratio);
        sum += ratio;
        count += 1;
    }
    Distortion {
        min,
        mean: sum / count.max(1) as f64,
        max,
        pairs: count,
    }
}


#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        for dist in [
            RpDistribution::Ternary,
            RpDistribution::Achlioptas,
            RpDistribution::Gaussian,
        ] {
            let rp = RandomProjection::new(32, 16, dist, 1);
            assert_eq!(rp.apply(&vec![1.0; 32]).len(), 16);
            let dense = rp.to_dense();
            assert_eq!(dense.shape(), (16, 32));
        }
    }

    #[test]
    fn sparse_apply_matches_dense() {
        let rp = RandomProjection::new(40, 12, RpDistribution::Ternary, 3);
        let x: Vec<f32> = (0..40).map(|i| (i as f32 * 0.7).sin()).collect();
        let sparse_y = rp.apply(&x);
        let dense_y = rp.to_dense().matvec(&x);
        for (a, b) in sparse_y.iter().zip(&dense_y) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = RandomProjection::new(32, 8, RpDistribution::Ternary, 9).to_dense();
        let b = RandomProjection::new(32, 8, RpDistribution::Ternary, 9).to_dense();
        assert_eq!(a.as_slice(), b.as_slice());
        let c = RandomProjection::new(32, 8, RpDistribution::Ternary, 10).to_dense();
        assert_ne!(a.as_slice(), c.as_slice());
    }

    #[test]
    fn ternary_nnz_matches_distribution() {
        // Expected density 1/n ⇒ nnz ≈ rows·cols/n = cols.
        let (m, n) = (512, 16);
        let rp = RandomProjection::new(m, n, RpDistribution::Ternary, 5);
        let expected = (m * n) as f64 / n as f64;
        assert!(
            (rp.nnz() as f64 - expected).abs() < expected * 0.5,
            "nnz {} expected ~{expected}",
            rp.nnz()
        );
    }

    #[test]
    fn distortion_near_one_for_gaussian() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seed(17);
        let x = Mat::from_fn(200, 128, |_, _| rng.next_gaussian() as f32);
        let rp = RandomProjection::new(128, 64, RpDistribution::Gaussian, 2);
        let d = measure_distortion(&rp, &x, 500, 1);
        assert!((d.mean - 1.0).abs() < 0.15, "mean distortion {}", d.mean);
    }

    #[test]
    fn distortion_near_one_for_ternary() {
        use crate::rng::Pcg64;
        let mut rng = Pcg64::seed(18);
        let x = Mat::from_fn(200, 256, |_, _| rng.next_gaussian() as f32);
        let rp = RandomProjection::new(256, 64, RpDistribution::Ternary, 2);
        let d = measure_distortion(&rp, &x, 500, 1);
        assert!((d.mean - 1.0).abs() < 0.3, "mean distortion {}", d.mean);
    }

    #[test]
    #[should_panic(expected = "rp apply shape mismatch")]
    fn apply_wrong_dim_panics() {
        RandomProjection::new(8, 4, RpDistribution::Ternary, 1).apply(&[0.0; 7]);
    }
}
