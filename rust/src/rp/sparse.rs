//! Sparse ±1 matrix — the storage format of the hardware-friendly
//! projection. Each row keeps two index lists (plus / minus); applying
//! the matrix is then a chain of additions and subtractions, the exact
//! operation count the FPGA datapath of Fox et al. uses (no DSPs).

use crate::linalg::Mat;
use crate::rng::{Pcg64, RngExt};

/// Row-compressed ±1 sparse matrix.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SparseSignMatrix {
    rows: usize,
    cols: usize,
    /// Per row: column indices with +1.
    plus: Vec<Vec<u32>>,
    /// Per row: column indices with −1.
    minus: Vec<Vec<u32>>,
}

impl SparseSignMatrix {
    /// Sample with the Fox et al. ternary distribution
    /// (±1 w.p. 1/(2·rows) each — `rows` is the output dimensionality
    /// `n` in the paper's notation).
    pub fn sample_ternary(rng: &mut Pcg64, rows: usize, cols: usize) -> Self {
        Self::sample_with(rng, rows, cols, |rng| rng.next_ternary(rows))
    }

    /// Sample with the Achlioptas sign pattern (±1 w.p. 1/6 each).
    pub fn sample_achlioptas(rng: &mut Pcg64, rows: usize, cols: usize) -> Self {
        Self::sample_with(rng, rows, cols, |rng| rng.next_achlioptas())
    }

    fn sample_with(
        rng: &mut Pcg64,
        rows: usize,
        cols: usize,
        mut draw: impl FnMut(&mut Pcg64) -> i8,
    ) -> Self {
        let mut plus = vec![Vec::new(); rows];
        let mut minus = vec![Vec::new(); rows];
        for (r, (p, m)) in plus.iter_mut().zip(minus.iter_mut()).enumerate() {
            let _ = r;
            for c in 0..cols {
                match draw(rng) {
                    1 => p.push(c as u32),
                    -1 => m.push(c as u32),
                    _ => {}
                }
            }
        }
        Self {
            rows,
            cols,
            plus,
            minus,
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Total nonzeros — the number of adder inputs in hardware.
    pub fn nnz(&self) -> usize {
        self.plus.iter().map(Vec::len).sum::<usize>()
            + self.minus.iter().map(Vec::len).sum::<usize>()
    }

    /// `y = R x` using only additions and subtractions.
    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0f32; self.rows];
        self.apply_into(x, &mut y);
        y
    }

    /// [`SparseSignMatrix::apply`] into a caller-owned buffer — the
    /// allocation-free form the tiled f32 datapath runs on.
    pub fn apply_into(&self, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), self.cols, "sparse apply shape mismatch");
        assert_eq!(out.len(), self.rows, "sparse apply out shape mismatch");
        for ((p, m), o) in self.plus.iter().zip(&self.minus).zip(out.iter_mut()) {
            let mut acc = 0.0f32;
            for &c in p {
                acc += x[c as usize];
            }
            for &c in m {
                acc -= x[c as usize];
            }
            *o = acc;
        }
    }

    /// `y = R x` on raw fixed-point words: the same conditional add/sub
    /// network, with each output accumulated at full precision in i64
    /// (pure integer adds are exact — the fixed-point RP datapath loses
    /// nothing). The caller rounds/saturates the sums into its format.
    pub fn apply_raw(&self, x: &[i32]) -> Vec<i64> {
        assert_eq!(x.len(), self.cols, "sparse apply shape mismatch");
        let mut y = Vec::with_capacity(self.rows);
        self.apply_raw_each(x, |_, acc| y.push(acc));
        y
    }

    /// Visit each output row's exact i64 add/sub sum without
    /// allocating — the primitive behind both [`Self::apply_raw`] and
    /// the tiled fixed-point RP kernel. Calls `sink(row, sum)` in row
    /// order.
    pub fn apply_raw_each(&self, x: &[i32], mut sink: impl FnMut(usize, i64)) {
        assert_eq!(x.len(), self.cols, "sparse apply shape mismatch");
        for (i, (p, m)) in self.plus.iter().zip(&self.minus).enumerate() {
            let mut acc = 0i64;
            for &c in p {
                acc += x[c as usize] as i64;
            }
            for &c in m {
                acc -= x[c as usize] as i64;
            }
            sink(i, acc);
        }
    }

    /// Densify (for artifact export and cross-checks).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for (i, (p, mi)) in self.plus.iter().zip(&self.minus).enumerate() {
            for &c in p {
                m.set(i, c as usize, 1.0);
            }
            for &c in mi {
                m.set(i, c as usize, -1.0);
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_matches_dense() {
        let mut rng = Pcg64::seed(21);
        let s = SparseSignMatrix::sample_ternary(&mut rng, 8, 64);
        let x: Vec<f32> = (0..64).map(|i| (i as f32).cos()).collect();
        let y1 = s.apply(&x);
        let y2 = s.to_dense().matvec(&x);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn apply_raw_matches_f32_apply_on_integer_grid() {
        // Raw words through the add/sub network are exact integer sums,
        // so they must agree bit-for-bit with the f32 path on inputs
        // that are small integers (exactly representable both ways).
        let mut rng = Pcg64::seed(24);
        let s = SparseSignMatrix::sample_ternary(&mut rng, 8, 64);
        let xi: Vec<i32> = (0..64).map(|i| (i as i32 % 17) - 8).collect();
        let xf: Vec<f32> = xi.iter().map(|&v| v as f32).collect();
        let raw = s.apply_raw(&xi);
        let f = s.apply(&xf);
        for (a, b) in raw.iter().zip(&f) {
            assert_eq!(*a as f32, *b, "{a} vs {b}");
        }
    }

    #[test]
    fn plus_minus_disjoint() {
        let mut rng = Pcg64::seed(22);
        let s = SparseSignMatrix::sample_ternary(&mut rng, 4, 128);
        for (p, m) in s.plus.iter().zip(&s.minus) {
            for c in p {
                assert!(!m.contains(c));
            }
        }
    }

    #[test]
    fn achlioptas_density() {
        let mut rng = Pcg64::seed(23);
        let s = SparseSignMatrix::sample_achlioptas(&mut rng, 16, 512);
        // Expected nonzero fraction 1/3.
        let density = s.nnz() as f64 / (16.0 * 512.0);
        assert!((density - 1.0 / 3.0).abs() < 0.05, "density {density}");
    }

    #[test]
    fn empty_rows_allowed() {
        // With high sparsity some rows may be all-zero; apply must not
        // panic and must return zeros there.
        let s = SparseSignMatrix {
            rows: 2,
            cols: 3,
            plus: vec![vec![], vec![0]],
            minus: vec![vec![], vec![2]],
        };
        assert_eq!(s.apply(&[5.0, 6.0, 7.0]), vec![0.0, -2.0]);
    }
}
