//! Run-level metrics for the training service: counters, throughput and
//! latency percentiles over a sliding reservoir. Absorbed from the old
//! `coordinator::metrics` module so run- and stage-level telemetry live
//! side by side; `coordinator` re-exports these names for callers.

use std::time::{Duration, Instant};

/// Latency reservoir with percentile queries (sorted copy on demand —
/// fine at coordinator rates).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    samples: Vec<Duration>,
    capacity: usize,
    /// Total observations ever (reservoir keeps the most recent
    /// `capacity`).
    pub count: u64,
}

impl LatencyHistogram {
    pub fn new(capacity: usize) -> Self {
        Self {
            samples: Vec::with_capacity(capacity),
            capacity: capacity.max(1),
            count: 0,
        }
    }

    pub fn record(&mut self, d: Duration) {
        if self.samples.len() == self.capacity {
            // Ring behaviour: overwrite the oldest slot.
            let idx = (self.count % self.capacity as u64) as usize;
            self.samples[idx] = d;
        } else {
            self.samples.push(d);
        }
        self.count += 1;
    }

    pub fn percentile(&self, p: f64) -> Option<Duration> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_unstable();
        let idx = ((sorted.len() as f64 - 1.0) * p / 100.0).round() as usize;
        Some(sorted[idx.min(sorted.len() - 1)])
    }

    /// Mean of the retained window. Summed in u128 nanoseconds: the old
    /// `sum::<Duration>() / len as u32` form could panic on `Duration`
    /// sum overflow and truncated `len` through the `u32` cast.
    pub fn mean(&self) -> Option<Duration> {
        if self.samples.is_empty() {
            return None;
        }
        let total: u128 = self.samples.iter().map(Duration::as_nanos).sum();
        Some(Duration::from_nanos(
            (total / self.samples.len() as u128) as u64,
        ))
    }
}

/// Aggregated metrics for one training run. `Clone` so a session
/// checkpoint can carry its counters and latency reservoir across an
/// evict/restore cycle (the `started` instant is copied too: a restored
/// session's elapsed time spans the whole logical run, eviction
/// included).
#[derive(Debug, Clone)]
pub struct Metrics {
    started: Instant,
    pub samples_in: u64,
    pub batches: u64,
    /// Batches the producer had to wait to enqueue (backpressure events).
    pub backpressure_waits: u64,
    /// Bound of the producer→trainer queue, for reading the
    /// backpressure count in context.
    pub queue_depth: usize,
    /// Stream-tail samples processed through the b=1 executable.
    pub tail_samples: u64,
    /// Batches refused by ingest validation (empty / wrong dimension /
    /// non-finite payload) before touching trainer state.
    pub rejected_batches: u64,
    pub step_latency: LatencyHistogram,
    /// Convergence signal snapshots: (samples_seen, update_magnitude).
    pub convergence_trace: Vec<(u64, f64)>,
    /// Reconfiguration events: (samples_seen, new mode label).
    pub reconfigurations: Vec<(u64, String)>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            samples_in: 0,
            batches: 0,
            backpressure_waits: 0,
            queue_depth: 0,
            tail_samples: 0,
            rejected_batches: 0,
            step_latency: LatencyHistogram::new(4096),
            convergence_trace: Vec::new(),
            reconfigurations: Vec::new(),
        }
    }

    pub fn elapsed(&self) -> Duration {
        self.started.elapsed()
    }

    /// Steady-state training throughput, samples/s.
    pub fn throughput(&self) -> f64 {
        self.samples_in as f64 / self.elapsed().as_secs_f64().max(1e-9)
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let p50 = self
            .step_latency
            .percentile(50.0)
            .map(crate::util::bench::fmt_duration)
            .unwrap_or_else(|| "-".into());
        let p99 = self
            .step_latency
            .percentile(99.0)
            .map(crate::util::bench::fmt_duration)
            .unwrap_or_else(|| "-".into());
        format!(
            "samples={} batches={} throughput={:.0}/s step_p50={} step_p99={} backpressure={} rejected={} reconfigs={}",
            self.samples_in,
            self.batches,
            self.throughput(),
            p50,
            p99,
            self.backpressure_waits,
            self.rejected_batches,
            self.reconfigurations.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut h = LatencyHistogram::new(100);
        for i in 1..=100u64 {
            h.record(Duration::from_micros(i));
        }
        let p50 = h.percentile(50.0).unwrap();
        let p99 = h.percentile(99.0).unwrap();
        assert!(p50 < p99);
        assert_eq!(h.count, 100);
    }

    #[test]
    fn reservoir_wraps() {
        let mut h = LatencyHistogram::new(4);
        for i in 0..10u64 {
            h.record(Duration::from_micros(i));
        }
        assert_eq!(h.count, 10);
        // Only the last 4 samples are retained; min is >= 6µs.
        assert!(h.percentile(0.0).unwrap() >= Duration::from_micros(6));
    }

    #[test]
    fn wrapped_reservoir_mean_covers_retained_window_only() {
        let mut h = LatencyHistogram::new(4);
        for i in 0..10u64 {
            h.record(Duration::from_micros(i));
        }
        // Retained: 6, 7, 8, 9 µs → mean 7.5µs.
        assert_eq!(h.mean().unwrap(), Duration::from_nanos(7_500));
    }

    #[test]
    fn percentile_edges_p0_p100_and_single_sample() {
        let mut h = LatencyHistogram::new(16);
        h.record(Duration::from_micros(42));
        // A single sample is every percentile and the mean.
        assert_eq!(h.percentile(0.0).unwrap(), Duration::from_micros(42));
        assert_eq!(h.percentile(50.0).unwrap(), Duration::from_micros(42));
        assert_eq!(h.percentile(100.0).unwrap(), Duration::from_micros(42));
        assert_eq!(h.mean().unwrap(), Duration::from_micros(42));
        for i in 1..=9u64 {
            h.record(Duration::from_micros(i));
        }
        // p0 = min, p100 = max of the window.
        assert_eq!(h.percentile(0.0).unwrap(), Duration::from_micros(1));
        assert_eq!(h.percentile(100.0).unwrap(), Duration::from_micros(42));
    }

    #[test]
    fn mean_is_exact_in_nanoseconds() {
        let mut h = LatencyHistogram::new(8);
        h.record(Duration::from_secs(1));
        h.record(Duration::from_secs(2));
        h.record(Duration::from_secs(4));
        // 7s / 3 — exact integer-nanosecond division, no cast truncation.
        assert_eq!(h.mean().unwrap(), Duration::from_nanos(2_333_333_333));
    }

    #[test]
    fn empty_histogram_is_none() {
        let h = LatencyHistogram::new(8);
        assert!(h.percentile(50.0).is_none());
        assert!(h.mean().is_none());
    }

    #[test]
    fn metrics_summary_smoke() {
        let mut m = Metrics::new();
        m.samples_in = 512;
        m.batches = 2;
        m.step_latency.record(Duration::from_millis(1));
        let s = m.summary();
        assert!(s.contains("samples=512"), "{s}");
    }
}
