//! `TELEMETRY_snapshot.json` — end-of-run telemetry under a golden,
//! validated schema, mirroring the `BENCH_throughput.json` pattern: the
//! CLI validates its own output before writing, and CI validates the
//! uploaded artifact, so a drifting writer can never silently break the
//! cross-PR trajectory.

use super::{Metrics, StageSnapshot, TelemetrySnapshot, OCCUPANCY_BUCKETS};
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};

fn stage_json(index: Option<usize>, s: &StageSnapshot) -> Json {
    Json::obj(vec![
        ("name", Json::str(s.name.clone())),
        (
            "index",
            match index {
                Some(i) => Json::num(i as f64),
                None => Json::Null,
            },
        ),
        (
            "format",
            match &s.format {
                Some(f) => Json::str(f.label()),
                None => Json::Null,
            },
        ),
        ("tiles", Json::num(s.tiles as f64)),
        ("samples", Json::num(s.samples as f64)),
        ("step_ns", Json::num(s.step_ns as f64)),
        ("transform_ns", Json::num(s.transform_ns as f64)),
        ("sat_events", Json::num(s.sat_events as f64)),
        ("wrap_events", Json::num(s.wrap_events as f64)),
        ("words", Json::num(s.words as f64)),
        ("sat_per_sample", Json::num(s.sat_per_sample())),
        (
            "occupancy",
            Json::Arr(s.occupancy.iter().map(|&c| Json::num(c as f64)).collect()),
        ),
        ("max_bits", Json::num(s.max_bits() as f64)),
        (
            "headroom_bits",
            match s.headroom_bits() {
                Some(h) => Json::num(h as f64),
                None => Json::Null,
            },
        ),
    ])
}

/// Serialise one run's telemetry. `config` is the run configuration as
/// JSON (opaque here — whatever the experiment config serialises to).
pub fn to_json(config: Json, m: &Metrics, t: &TelemetrySnapshot) -> Json {
    let lat = &m.step_latency;
    let ns = |d: std::time::Duration| d.as_nanos() as f64;
    Json::obj(vec![
        ("experiment", Json::str("telemetry_snapshot")),
        ("schema_version", Json::num(1.0)),
        ("config", config),
        (
            "run",
            Json::obj(vec![
                ("samples", Json::num(m.samples_in as f64)),
                ("batches", Json::num(m.batches as f64)),
                ("tail_samples", Json::num(m.tail_samples as f64)),
                ("backpressure_waits", Json::num(m.backpressure_waits as f64)),
                ("queue_depth", Json::num(m.queue_depth as f64)),
                ("elapsed_s", Json::num(m.elapsed().as_secs_f64())),
                ("throughput", Json::num(m.throughput())),
                (
                    "step_latency_ns",
                    Json::obj(vec![
                        ("count", Json::num(lat.count as f64)),
                        ("mean", lat.mean().map(ns).map(Json::num).unwrap_or(Json::Null)),
                        (
                            "p50",
                            lat.percentile(50.0)
                                .map(ns)
                                .map(Json::num)
                                .unwrap_or(Json::Null),
                        ),
                        (
                            "p99",
                            lat.percentile(99.0)
                                .map(ns)
                                .map(Json::num)
                                .unwrap_or(Json::Null),
                        ),
                    ]),
                ),
                (
                    "reconfigurations",
                    Json::Arr(
                        m.reconfigurations
                            .iter()
                            .map(|(at, mode)| {
                                Json::obj(vec![
                                    ("at_samples", Json::num(*at as f64)),
                                    ("mode", Json::str(mode.clone())),
                                ])
                            })
                            .collect(),
                    ),
                ),
                (
                    "convergence",
                    Json::Arr(
                        m.convergence_trace
                            .iter()
                            .map(|(at, mag)| {
                                Json::Arr(vec![Json::num(*at as f64), Json::num(*mag)])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        ("ingress", stage_json(None, &t.ingress)),
        (
            "stages",
            Json::Arr(
                t.stages
                    .iter()
                    .enumerate()
                    .map(|(i, s)| stage_json(Some(i), s))
                    .collect(),
            ),
        ),
    ])
}

fn validate_stage(s: &Json) -> Result<()> {
    s.field("name")?.as_str()?;
    if !matches!(s.field("format")?, Json::Null) {
        s.field("format")?.as_str().context("format")?;
    }
    for key in [
        "tiles",
        "samples",
        "step_ns",
        "transform_ns",
        "sat_events",
        "wrap_events",
        "words",
        "max_bits",
    ] {
        s.field(key)?.as_u64().with_context(|| key.to_string())?;
    }
    let rate = s.field("sat_per_sample")?.as_f64()?;
    ensure!(
        rate.is_finite() && rate >= 0.0,
        "sat_per_sample must be a finite non-negative rate"
    );
    let occ = s.field("occupancy")?.as_arr()?;
    ensure!(
        occ.len() == OCCUPANCY_BUCKETS,
        "occupancy must have {OCCUPANCY_BUCKETS} buckets, got {}",
        occ.len()
    );
    for b in occ {
        b.as_u64().context("occupancy bucket")?;
    }
    if !matches!(s.field("headroom_bits")?, Json::Null) {
        s.field("headroom_bits")?.as_u64().context("headroom_bits")?;
    }
    Ok(())
}

/// Golden-schema check for `TELEMETRY_snapshot.json`.
pub fn validate(v: &Json) -> Result<()> {
    ensure!(
        v.field("experiment")?.as_str()? == "telemetry_snapshot",
        "wrong experiment tag"
    );
    ensure!(
        v.field("schema_version")?.as_usize()? == 1,
        "unknown schema version"
    );
    v.field("config")?.as_obj().context("config")?;
    let run = v.field("run")?;
    for key in [
        "samples",
        "batches",
        "tail_samples",
        "backpressure_waits",
        "queue_depth",
    ] {
        run.field(key)?.as_u64().with_context(|| key.to_string())?;
    }
    run.field("elapsed_s")?.as_f64()?;
    run.field("throughput")?.as_f64()?;
    let lat = run.field("step_latency_ns")?;
    lat.field("count")?.as_u64()?;
    for key in ["mean", "p50", "p99"] {
        if !matches!(lat.field(key)?, Json::Null) {
            lat.field(key)?.as_f64().with_context(|| key.to_string())?;
        }
    }
    for rc in run.field("reconfigurations")?.as_arr()? {
        rc.field("at_samples")?.as_u64()?;
        rc.field("mode")?.as_str()?;
    }
    run.field("convergence")?.as_arr()?;
    validate_stage(v.field("ingress")?).context("ingress")?;
    let stages = v.field("stages")?.as_arr()?;
    ensure!(!stages.is_empty(), "stages must be non-empty");
    for (i, s) in stages.iter().enumerate() {
        validate_stage(s).with_context(|| format!("stage {i}"))?;
        ensure!(
            s.field("index")?.as_usize()? == i,
            "stage index out of order"
        );
    }
    Ok(())
}

/// One compact JSONL progress event, emitted periodically by the
/// training service when `--telemetry` is on. Overflow totals are the
/// training thread's cumulative counters — a cheap live health signal
/// between snapshots.
pub fn progress_event(m: &Metrics, update_magnitude: f64) -> Json {
    let (sat, wrap) = super::events::snapshot();
    Json::obj(vec![
        ("event", Json::str("telemetry")),
        ("samples", Json::num(m.samples_in as f64)),
        ("batches", Json::num(m.batches as f64)),
        ("throughput", Json::num(m.throughput())),
        ("backpressure_waits", Json::num(m.backpressure_waits as f64)),
        ("sat_events", Json::num(sat as f64)),
        ("wrap_events", Json::num(wrap as f64)),
        ("update_magnitude", Json::num(update_magnitude)),
    ])
}

#[cfg(test)]
mod tests {
    use super::super::Telemetry;
    use super::*;
    use crate::fxp::FxpSpec;

    fn sample_snapshot() -> (Metrics, TelemetrySnapshot) {
        let mut m = Metrics::new();
        m.samples_in = 128;
        m.batches = 2;
        m.queue_depth = 4;
        m.step_latency.record(std::time::Duration::from_micros(80));
        m.reconfigurations.push((64, "pca-whiten".into()));
        m.convergence_trace.push((64, 0.5));
        let t = Telemetry::for_stages(
            vec![
                ("rp".into(), Some(FxpSpec::q(4, 12))),
                ("whiten:gha".into(), Some(FxpSpec::q(4, 12))),
            ],
            Some(FxpSpec::q(4, 12)),
        );
        t.record_step(None, t.begin(), 64, Some(&[1, -200, 4095]));
        t.record_step(Some(0), t.begin(), 64, Some(&[5, 80]));
        t.record_step(Some(1), t.begin(), 64, None);
        (m, t.snapshot().unwrap())
    }

    #[test]
    fn snapshot_round_trips_and_validates() {
        let (m, snap) = sample_snapshot();
        let cfg = Json::obj(vec![("mode", Json::str("rp-easi"))]);
        let json = to_json(cfg, &m, &snap);
        let parsed = Json::parse(&json.to_string_pretty()).unwrap();
        validate(&parsed).unwrap();
        // Spot-check derived fields survive serialisation.
        let stages = parsed.field("stages").unwrap().as_arr().unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(
            parsed
                .field("ingress")
                .unwrap()
                .field("max_bits")
                .unwrap()
                .as_usize()
                .unwrap(),
            12 // |4095| needs 12 bits
        );
        assert_eq!(
            stages[1].field("format").unwrap().as_str().unwrap(),
            "q4.12"
        );
    }

    #[test]
    fn validate_rejects_drifted_schema() {
        let (m, snap) = sample_snapshot();
        let good = to_json(Json::obj(vec![]), &m, &snap);
        // Wrong tag.
        let mut map = good.as_obj().unwrap().clone();
        map.insert("experiment".into(), Json::str("bench_throughput"));
        assert!(validate(&Json::Obj(map)).is_err());
        // Missing stages.
        let mut map = good.as_obj().unwrap().clone();
        map.remove("stages");
        assert!(validate(&Json::Obj(map)).is_err());
        // Empty stages.
        let mut map = good.as_obj().unwrap().clone();
        map.insert("stages".into(), Json::Arr(vec![]));
        assert!(validate(&Json::Obj(map)).is_err());
        // Occupancy bucket count drifted.
        let mut map = good.as_obj().unwrap().clone();
        let mut ing = map["ingress"].as_obj().unwrap().clone();
        ing.insert("occupancy".into(), Json::Arr(vec![Json::num(0.0)]));
        map.insert("ingress".into(), Json::Obj(ing));
        assert!(validate(&Json::Obj(map)).is_err());
    }

    #[test]
    fn progress_event_is_compact_jsonl() {
        let (m, _) = sample_snapshot();
        let line = progress_event(&m, 0.25).to_string();
        assert!(!line.contains('\n'));
        assert!(line.contains("\"event\":"), "{line}");
    }
}
