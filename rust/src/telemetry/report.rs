//! `dimred report` — the per-stage profiling table: time share,
//! samples/s, saturation rate, raw-word occupancy, and a headroom
//! recommendation per stage. Pure rendering over a
//! [`TelemetrySnapshot`]; the CLI drives a telemetry-enabled training
//! run and hands the snapshot here.

use super::{Metrics, StageSnapshot, TelemetrySnapshot};

/// Compact occupancy summary: the non-empty magnitude buckets as
/// `bits:count` pairs (`-` when no raw words were histogrammed).
fn occupancy_line(s: &StageSnapshot) -> String {
    if s.words == 0 {
        return "-".into();
    }
    s.occupancy
        .iter()
        .enumerate()
        .filter(|(_, &c)| c > 0)
        .map(|(b, &c)| format!("{b}:{c}"))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Per-stage health verdict for the table's last column.
fn recommendation(s: &StageSnapshot) -> String {
    if s.sat_events > 0 || s.wrap_events > 0 {
        return format!(
            "OVERFLOWING ({} sat, {} wrap) — widen int bits",
            s.sat_events, s.wrap_events
        );
    }
    match s.headroom_bits() {
        Some(h) if h >= 2 && s.words > 0 => {
            format!("{h} spare magnitude bits — int width could drop by {h}")
        }
        Some(_) if s.words > 0 => "healthy".into(),
        _ => "-".into(),
    }
}

fn samples_per_s(s: &StageSnapshot) -> String {
    let ns = s.total_ns();
    if ns == 0 {
        return "-".into();
    }
    format!("{:.0}", s.samples as f64 / (ns as f64 * 1e-9))
}

/// Render the full profiling report: run summary, per-stage table,
/// occupancy histograms, and headroom recommendations.
pub fn render(m: &Metrics, t: &TelemetrySnapshot) -> String {
    let mut out = String::from("dimred report — per-stage telemetry\n\n");
    out.push_str(&format!("run: {}\n", m.summary()));
    if let Some(mean) = m.step_latency.mean() {
        out.push_str(&format!(
            "step latency mean: {}\n",
            crate::util::bench::fmt_duration(mean)
        ));
    }
    out.push('\n');

    let total_ns = t.total_ns().max(1);
    out.push_str(&format!(
        "{:<14} {:<8} {:>6} {:>9} {:>12} {:>12} {:>8} {:>9}\n",
        "stage", "format", "time%", "tiles", "samples", "samples/s", "sat/smp", "headroom"
    ));
    for s in t.all() {
        let fmt = s
            .format
            .map(|f| f.label())
            .unwrap_or_else(|| "f32".into());
        let share = 100.0 * s.total_ns() as f64 / total_ns as f64;
        let headroom = s
            .headroom_bits()
            .map(|h| format!("{h}b"))
            .unwrap_or_else(|| "-".into());
        out.push_str(&format!(
            "{:<14} {:<8} {:>6.1} {:>9} {:>12} {:>12} {:>8.3} {:>9}\n",
            s.name,
            fmt,
            share,
            s.tiles,
            s.samples,
            samples_per_s(s),
            s.sat_per_sample(),
            headroom
        ));
    }

    out.push_str("\nraw-word occupancy (magnitude bit-length : words)\n");
    for s in t.all() {
        out.push_str(&format!("  {:<14} {}\n", s.name, occupancy_line(s)));
    }

    out.push_str("\nrecommendations\n");
    for s in t.all() {
        out.push_str(&format!("  {:<14} {}\n", s.name, recommendation(s)));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::Telemetry;
    use super::*;
    use crate::fxp::FxpSpec;

    #[test]
    fn report_renders_share_saturation_and_occupancy() {
        let mut m = Metrics::new();
        m.samples_in = 256;
        m.batches = 4;
        let spec = FxpSpec::q(4, 12);
        let t = Telemetry::for_stages(
            vec![
                ("whiten:gha".into(), Some(spec)),
                ("rot:easi".into(), None),
            ],
            Some(spec),
        );
        t.record_step(None, t.begin(), 128, Some(&[0, 900, -4000]));
        // One saturation inside the whitener's window.
        let max = spec.format.max_raw();
        let mark = t.begin();
        spec.add(max, max);
        t.record_step(Some(0), mark, 128, Some(&[12, -7000]));
        t.record_step(Some(1), t.begin(), 128, None);
        let snap = t.snapshot().unwrap();
        let text = render(&m, &snap);
        assert!(text.contains("ingress"), "{text}");
        assert!(text.contains("whiten:gha"), "{text}");
        assert!(text.contains("q4.12"), "{text}");
        // The whitener saturated → flagged.
        assert!(text.contains("OVERFLOWING"), "{text}");
        // Occupancy buckets render as bits:count pairs (|-4000| = 12 bits).
        assert!(text.contains("12:1"), "{text}");
        // Stage without raw words shows a placeholder histogram.
        assert!(text.contains("rot:easi       -"), "{text}");
    }

    #[test]
    fn healthy_stage_gets_headroom_recommendation() {
        let t = Telemetry::for_stages(
            vec![("whiten:gha".into(), Some(FxpSpec::q(4, 12)))],
            None,
        );
        // Max magnitude 5 bits on a 16-bit format → 10 spare bits.
        t.record_step(Some(0), t.begin(), 64, Some(&[17, -20, 3]));
        let snap = t.snapshot().unwrap();
        let text = render(&Metrics::new(), &snap);
        assert!(text.contains("int width could drop by 10"), "{text}");
    }
}
