//! Telemetry — low-overhead instrumentation for the whole datapath.
//!
//! The one thing a fixed-point *training* datapath must expose to be
//! trusted at scale is its numeric health: saturation, wrap, and
//! raw-word occupancy per stage are exactly the signals that decide
//! whether a Q-format plan is safe, and they are the search signal for
//! the automated precision-plan search (ROADMAP item 3). This module
//! provides that instrumentation in three layers:
//!
//! * [`events`] — thread-local saturation/wrap counters bumped on the
//!   *cold* path of [`crate::fxp::FxpSpec::fit`] (only when a value
//!   actually overflows). Because they are thread-local, a
//!   snapshot/delta around a stage call attributes events to that stage
//!   exactly, even inside the multi-lane forward's worker threads.
//! * [`StageStats`] / [`Telemetry`] — a per-stage registry owned by
//!   [`crate::stage::StageGraph`]: tiles, samples, cumulative step and
//!   transform nanoseconds, saturation/wrap events, and a preallocated
//!   power-of-two raw-word magnitude histogram (33 buckets, one per
//!   magnitude bit-length) giving per-stage integer-bit occupancy. All
//!   counters are relaxed atomics, so recording works through `&self`
//!   on every path (sequential training, tiled forward, scoped lanes)
//!   and allocates nothing in steady state. The [`Telemetry::Disabled`]
//!   mode short-circuits to a single branch per stage call — nothing
//!   measurable on the hot path (enforced by `tests/alloc_free.rs` and
//!   the bench's bit-identity grid).
//! * [`run`] — run-level metrics for the training service (samples,
//!   batches, backpressure, step-latency reservoir, convergence trace,
//!   reconfiguration events), absorbed here from the old
//!   `coordinator::metrics` so datapath and coordinator telemetry live
//!   in one module.
//!
//! Surfaces: `dimred train --telemetry[-out]` (periodic JSONL events +
//! a schema-validated `TELEMETRY_snapshot.json`, see [`snapshot`]),
//! `dimred report` (per-stage text table, see [`report`]), and
//! per-scenario health rows in `dimred bench`.

pub mod report;
pub mod run;
pub mod snapshot;

pub use run::{LatencyHistogram, Metrics};

use crate::fxp::FxpSpec;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Thread-local fixed-point overflow event counters. Bumped by
/// [`crate::fxp::FxpSpec::fit`] (and the infinite-input branch of
/// `quantize`) only when a value actually saturates or wraps, so the
/// non-overflow fast path pays nothing beyond the range compare it
/// already performed. Deliberate domain clamps (e.g. the whitener's
/// ±4σ output clamp) are *not* counted — only format overflow.
pub mod events {
    use std::cell::Cell;

    thread_local! {
        static SAT: Cell<u64> = const { Cell::new(0) };
        static WRAP: Cell<u64> = const { Cell::new(0) };
    }

    /// One saturation event (value clamped to the format range).
    #[inline]
    pub fn note_sat() {
        SAT.with(|c| c.set(c.get() + 1));
    }

    /// One wrap event (value changed by keep-low-bits wraparound).
    #[inline]
    pub fn note_wrap() {
        WRAP.with(|c| c.set(c.get() + 1));
    }

    /// Current (saturation, wrap) totals for this thread.
    #[inline]
    pub fn snapshot() -> (u64, u64) {
        (SAT.with(Cell::get), WRAP.with(Cell::get))
    }
}

/// Number of magnitude-histogram buckets: bucket `b` counts raw words
/// whose absolute value has bit-length `b` (bucket 0 = zero words);
/// an `i32` magnitude needs at most 32 bits.
pub const OCCUPANCY_BUCKETS: usize = 33;

/// Magnitude bit-length of a raw word — its histogram bucket.
#[inline]
fn bucket_of(raw: i32) -> usize {
    (64 - (raw as i64).unsigned_abs().leading_zeros()) as usize
}

/// Start-of-stage-call marker: wall clock plus this thread's overflow
/// counters, so the end-of-call delta is exactly the stage's own.
#[derive(Debug, Clone, Copy)]
pub struct StageMark {
    t0: Instant,
    sat0: u64,
    wrap0: u64,
}

impl StageMark {
    fn now() -> Self {
        let (sat0, wrap0) = events::snapshot();
        Self {
            t0: Instant::now(),
            sat0,
            wrap0,
        }
    }
}

/// Which path a recording belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Step,
    Transform,
}

/// Per-stage counters. Everything is preallocated at
/// [`Telemetry::for_stages`] time and updated with relaxed atomics, so
/// steady-state recording is allocation-free and works through `&self`
/// from lane threads.
#[derive(Debug)]
pub struct StageStats {
    /// Stage name (graph order; `"ingress"` for the entry quantizer).
    pub name: String,
    /// The stage's output arithmetic, when running fixed point.
    pub format: Option<FxpSpec>,
    tiles: AtomicU64,
    samples: AtomicU64,
    step_ns: AtomicU64,
    transform_ns: AtomicU64,
    sat_events: AtomicU64,
    wrap_events: AtomicU64,
    words: AtomicU64,
    occupancy: [AtomicU64; OCCUPANCY_BUCKETS],
}

impl StageStats {
    fn new(name: String, format: Option<FxpSpec>) -> Self {
        Self {
            name,
            format,
            tiles: AtomicU64::new(0),
            samples: AtomicU64::new(0),
            step_ns: AtomicU64::new(0),
            transform_ns: AtomicU64::new(0),
            sat_events: AtomicU64::new(0),
            wrap_events: AtomicU64::new(0),
            words: AtomicU64::new(0),
            occupancy: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn record(&self, kind: Kind, mark: StageMark, rows: usize, words: Option<&[i32]>) {
        let ns = mark.t0.elapsed().as_nanos() as u64;
        let (sat, wrap) = events::snapshot();
        self.record_external(kind, ns, sat - mark.sat0, wrap - mark.wrap0, rows, words);
    }

    /// Record a call whose wall time and overflow deltas were measured
    /// elsewhere — the staged-ingress path: the entry quantizer ran on a
    /// stager thread (which captured its own thread-local deltas), and
    /// the graph attributes them to the ingress slot at commit time.
    fn record_external(
        &self,
        kind: Kind,
        ns: u64,
        sat: u64,
        wrap: u64,
        rows: usize,
        words: Option<&[i32]>,
    ) {
        let r = Ordering::Relaxed;
        self.tiles.fetch_add(1, r);
        self.samples.fetch_add(rows as u64, r);
        match kind {
            Kind::Step => self.step_ns.fetch_add(ns, r),
            Kind::Transform => self.transform_ns.fetch_add(ns, r),
        };
        self.sat_events.fetch_add(sat, r);
        self.wrap_events.fetch_add(wrap, r);
        if let Some(w) = words {
            self.words.fetch_add(w.len() as u64, r);
            for &v in w {
                self.occupancy[bucket_of(v)].fetch_add(1, r);
            }
        }
    }

    /// Plain-value copy for reporting.
    pub fn snapshot(&self) -> StageSnapshot {
        let r = Ordering::Relaxed;
        StageSnapshot {
            name: self.name.clone(),
            format: self.format,
            tiles: self.tiles.load(r),
            samples: self.samples.load(r),
            step_ns: self.step_ns.load(r),
            transform_ns: self.transform_ns.load(r),
            sat_events: self.sat_events.load(r),
            wrap_events: self.wrap_events.load(r),
            words: self.words.load(r),
            occupancy: std::array::from_fn(|i| self.occupancy[i].load(r)),
        }
    }
}

/// A point-in-time copy of one stage's counters, plus the derived
/// health signals the precision-plan search consumes.
#[derive(Debug, Clone)]
pub struct StageSnapshot {
    pub name: String,
    pub format: Option<FxpSpec>,
    pub tiles: u64,
    pub samples: u64,
    pub step_ns: u64,
    pub transform_ns: u64,
    pub sat_events: u64,
    pub wrap_events: u64,
    /// Raw words histogrammed (fixed-point paths only).
    pub words: u64,
    /// Magnitude histogram: `occupancy[b]` = words of bit-length `b`.
    pub occupancy: [u64; OCCUPANCY_BUCKETS],
}

impl StageSnapshot {
    pub fn total_ns(&self) -> u64 {
        self.step_ns + self.transform_ns
    }

    /// Saturation events per processed sample (events fire per scalar
    /// op, so rates above 1.0 are possible and mean trouble).
    pub fn sat_per_sample(&self) -> f64 {
        self.sat_events as f64 / (self.samples.max(1)) as f64
    }

    /// Highest occupied magnitude bit-length (0 = all words were zero,
    /// or no raw words seen).
    pub fn max_bits(&self) -> u32 {
        (1..OCCUPANCY_BUCKETS)
            .rev()
            .find(|&b| self.occupancy[b] > 0)
            .unwrap_or(0) as u32
    }

    /// Unused top magnitude bits relative to the stage's format: the
    /// number of integer bits the format could shed while still
    /// representing every word observed. Negative is impossible (words
    /// fit the format by construction); `None` without a format.
    pub fn headroom_bits(&self) -> Option<u32> {
        let f = self.format?;
        let avail = f.format.width() as u32 - 1;
        Some(avail.saturating_sub(self.max_bits()))
    }
}

/// The registry a [`crate::stage::StageGraph`] owns: one slot per
/// stage plus an `ingress` slot for the entry quantizer.
#[derive(Debug)]
pub struct TelemetryInner {
    pub ingress: StageStats,
    pub stages: Vec<StageStats>,
}

/// Graph-side instrumentation handle. `Disabled` short-circuits every
/// recording call to one branch; `Enabled` records into preallocated
/// atomic counters (no steady-state allocations, `&self` everywhere).
#[derive(Debug, Default)]
pub enum Telemetry {
    #[default]
    Disabled,
    Enabled(TelemetryInner),
}

impl Telemetry {
    /// Build an enabled registry for a stage cascade:
    /// `(name, output format)` per stage, plus the entry format of the
    /// ingress quantizer (None for f32 graphs).
    pub fn for_stages(
        stages: Vec<(String, Option<FxpSpec>)>,
        ingress_format: Option<FxpSpec>,
    ) -> Self {
        Telemetry::Enabled(TelemetryInner {
            ingress: StageStats::new("ingress".into(), ingress_format),
            stages: stages
                .into_iter()
                .map(|(name, fmt)| StageStats::new(name, fmt))
                .collect(),
        })
    }

    pub fn is_enabled(&self) -> bool {
        matches!(self, Telemetry::Enabled(_))
    }

    /// Start a stage-call measurement. `None` when disabled — the hot
    /// path pays exactly this one branch.
    #[inline]
    pub fn begin(&self) -> Option<StageMark> {
        match self {
            Telemetry::Disabled => None,
            Telemetry::Enabled(_) => Some(StageMark::now()),
        }
    }

    #[inline]
    fn slot(&self, stage: Option<usize>) -> Option<&StageStats> {
        match self {
            Telemetry::Disabled => None,
            Telemetry::Enabled(inner) => Some(match stage {
                Some(i) => &inner.stages[i],
                None => &inner.ingress,
            }),
        }
    }

    /// Record a training-path stage call (`stage = None` → ingress).
    /// `words` is the stage's raw output tile, when one exists.
    #[inline]
    pub fn record_step(
        &self,
        stage: Option<usize>,
        mark: Option<StageMark>,
        rows: usize,
        words: Option<&[i32]>,
    ) {
        if let (Some(slot), Some(m)) = (self.slot(stage), mark) {
            slot.record(Kind::Step, m, rows, words);
        }
    }

    /// Record a staged entry-quantize into the ingress slot from
    /// externally measured deltas: `ns`/`sat`/`wrap` were captured on
    /// the stager thread around the quantize pass (the thread-local
    /// overflow counters make the deltas exact there), and `words` is
    /// the committed raw tile, histogrammed here so occupancy stays on
    /// the graph's own registry.
    #[inline]
    pub fn record_staged_ingress(
        &self,
        ns: u64,
        sat: u64,
        wrap: u64,
        rows: usize,
        words: Option<&[i32]>,
    ) {
        if let Some(slot) = self.slot(None) {
            slot.record_external(Kind::Step, ns, sat, wrap, rows, words);
        }
    }

    /// Record a forward-path stage call (`stage = None` → ingress).
    #[inline]
    pub fn record_transform(
        &self,
        stage: Option<usize>,
        mark: Option<StageMark>,
        rows: usize,
        words: Option<&[i32]>,
    ) {
        if let (Some(slot), Some(m)) = (self.slot(stage), mark) {
            slot.record(Kind::Transform, m, rows, words);
        }
    }

    /// Snapshot every slot (None when disabled).
    pub fn snapshot(&self) -> Option<TelemetrySnapshot> {
        match self {
            Telemetry::Disabled => None,
            Telemetry::Enabled(inner) => Some(TelemetrySnapshot {
                ingress: inner.ingress.snapshot(),
                stages: inner.stages.iter().map(StageStats::snapshot).collect(),
            }),
        }
    }
}

/// Point-in-time copy of a whole registry — what reports, snapshots
/// and bench health rows consume.
#[derive(Debug, Clone)]
pub struct TelemetrySnapshot {
    pub ingress: StageSnapshot,
    pub stages: Vec<StageSnapshot>,
}

impl TelemetrySnapshot {
    /// Ingress + stages, in datapath order.
    pub fn all(&self) -> impl Iterator<Item = &StageSnapshot> {
        std::iter::once(&self.ingress).chain(self.stages.iter())
    }

    /// Total instrumented nanoseconds across all slots (time-share
    /// denominator).
    pub fn total_ns(&self) -> u64 {
        self.all().map(StageSnapshot::total_ns).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fxp::{FxpSpec, Overflow};

    #[test]
    fn fit_overflow_bumps_thread_local_counters() {
        let spec = FxpSpec::q(1, 15);
        let (s0, w0) = events::snapshot();
        // In-range ops leave the counters alone.
        assert_eq!(spec.add(100, 200), 300);
        assert_eq!(events::snapshot(), (s0, w0));
        // Saturating add: one event.
        let max = spec.format.max_raw();
        assert_eq!(spec.add(max, max), max);
        assert_eq!(events::snapshot(), (s0 + 1, w0));
        // Infinite quantize counts as saturation too.
        spec.quantize(f32::INFINITY);
        assert_eq!(events::snapshot(), (s0 + 2, w0));
        // Wrap mode counts wraps, not sats.
        let mut wspec = FxpSpec::q(1, 7);
        wspec.overflow = Overflow::Wrap;
        assert_eq!(wspec.add(127, 1), -128);
        assert_eq!(events::snapshot(), (s0 + 2, w0 + 1));
        // A wrap-mode value that fits is not an event.
        assert_eq!(wspec.add(1, 1), 2);
        assert_eq!(events::snapshot(), (s0 + 2, w0 + 1));
    }

    #[test]
    fn occupancy_buckets_by_bit_length() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(-1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(255), 8);
        assert_eq!(bucket_of(256), 9);
        assert_eq!(bucket_of(i32::MAX), 31);
        assert_eq!(bucket_of(i32::MIN), 32);
    }

    #[test]
    fn stage_stats_record_and_derive() {
        let t = Telemetry::for_stages(
            vec![("whiten:gha".into(), Some(FxpSpec::q(4, 12)))],
            Some(FxpSpec::q(4, 12)),
        );
        let mark = t.begin();
        assert!(mark.is_some());
        // 4 words: 0, |1| (1 bit), |255| (8 bits), |-4096| (13 bits).
        t.record_step(Some(0), mark, 2, Some(&[0, 1, 255, -4096]));
        let mark = t.begin();
        t.record_transform(Some(0), mark, 3, Some(&[7, -7]));
        let snap = t.snapshot().unwrap();
        let s = &snap.stages[0];
        assert_eq!(s.tiles, 2);
        assert_eq!(s.samples, 5);
        assert_eq!(s.words, 6);
        assert_eq!(s.occupancy[0], 1);
        assert_eq!(s.occupancy[1], 1);
        assert_eq!(s.occupancy[3], 2); // |7| twice
        assert_eq!(s.occupancy[8], 1);
        assert_eq!(s.occupancy[13], 1);
        assert_eq!(s.max_bits(), 13);
        // Q4.12: width 16, 15 magnitude bits, 13 used → 2 spare.
        assert_eq!(s.headroom_bits(), Some(2));
        assert_eq!(s.sat_events, 0);
        // Ingress untouched.
        assert_eq!(snap.ingress.tiles, 0);
        assert_eq!(snap.ingress.name, "ingress");
    }

    #[test]
    fn sat_events_attributed_to_the_recorded_stage() {
        let spec = FxpSpec::q(1, 15);
        let t = Telemetry::for_stages(
            vec![("a".into(), Some(spec)), ("b".into(), Some(spec))],
            None,
        );
        let mark = t.begin();
        let max = spec.format.max_raw();
        spec.add(max, max); // one saturation inside stage 1's window
        t.record_step(Some(1), mark, 1, None);
        let snap = t.snapshot().unwrap();
        assert_eq!(snap.stages[0].sat_events, 0);
        assert_eq!(snap.stages[1].sat_events, 1);
        assert!(snap.stages[1].sat_per_sample() >= 1.0);
    }

    #[test]
    fn disabled_mode_records_nothing() {
        let t = Telemetry::default();
        assert!(!t.is_enabled());
        let mark = t.begin();
        assert!(mark.is_none());
        t.record_step(Some(0), mark, 8, Some(&[1, 2, 3]));
        assert!(t.snapshot().is_none());
    }

    #[test]
    fn headroom_without_format_is_none() {
        let t = Telemetry::for_stages(vec![("rp".into(), None)], None);
        t.record_step(Some(0), t.begin(), 1, None);
        let snap = t.snapshot().unwrap();
        assert_eq!(snap.stages[0].headroom_bits(), None);
        assert_eq!(snap.stages[0].max_bits(), 0);
    }
}
