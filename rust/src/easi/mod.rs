//! EASI — Equivariant Adaptive Separation via Independence (Cardoso &
//! Laheld '96), the paper's training algorithm, in all three datapath
//! configurations of §IV:
//!
//! * [`EasiMode::Full`] — Eq. 6: `B ← B − μ[yyᵀ − I + g(y)yᵀ − y g(y)ᵀ]B`
//! * [`EasiMode::WhitenOnly`] — Eq. 3 (PCA whitening): HOS term bypassed
//! * [`EasiMode::RotationOnly`] — the paper's *modified datapath*: the
//!   `yyᵀ − I` term is bypassed because a random-projection front end
//!   already handled second-order statistics
//!
//! The three modes are the software image of the paper's datapath mux —
//! same state, same update skeleton, terms enabled per configuration.
//!
//! Two computational paths are provided:
//! * [`EasiTrainer::step`] — factored rank-2 update, O(nm) per sample
//!   (the software-optimal form; see `update.rs`);
//! * [`naive_step`] — literal Eq. 6 with explicit n×n `F` and `F·B`
//!   product, O(n²m) per sample — the arithmetic the FPGA datapath
//!   implements and the oracle our property tests compare against.

mod update;

pub use update::{naive_step, relative_gradient};

use crate::linalg::{Mat, whiteness_error};

/// Datapath configuration (the paper's reconfigurable mux).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EasiMode {
    /// Full EASI (Eq. 6): whitening + rotation in one update.
    Full,
    /// Second-order only (Eq. 3): adaptive PCA whitening.
    WhitenOnly,
    /// Higher-order only: rotation of already-white(ish) inputs — used
    /// after the random-projection front end in the proposed pipeline.
    RotationOnly,
}

impl EasiMode {
    /// Whether the `yyᵀ − I` (second-order) term is active.
    pub fn has_whitening(self) -> bool {
        !matches!(self, EasiMode::RotationOnly)
    }

    /// Whether the `g(y)yᵀ − y g(y)ᵀ` (HOS) term is active.
    pub fn has_rotation(self) -> bool {
        !matches!(self, EasiMode::WhitenOnly)
    }
}

/// Cubic nonlinearity `g(y) = y³` — the paper's choice (Alg. 1 step 3);
/// introduces the higher-order statistics.
#[inline]
pub fn cubic(y: &[f32], out: &mut [f32]) {
    for (o, &v) in out.iter_mut().zip(y) {
        *o = v * v * v;
    }
}

/// Trainer configuration.
#[derive(Debug, Clone)]
pub struct EasiConfig {
    /// Input dimensionality (paper's `m`, or `p` after the RP front end).
    pub input_dim: usize,
    /// Output dimensionality (paper's `n`).
    pub output_dim: usize,
    /// Learning rate μ (constant across iterations, §III.D).
    pub mu: f32,
    /// Which datapath terms are active.
    pub mode: EasiMode,
    /// Use Cardoso's normalised update (divides each term by a
    /// data-dependent factor) — keeps the fixed-μ recursion stable for
    /// heavy-tailed inputs. Off by default to match the paper's Eq. 6.
    pub normalized: bool,
    /// Clamp on ‖B‖_F as a divergence guard (0 disables).
    pub max_norm: f32,
    /// Per-sample relative step clip: rescale the update so that
    /// ‖ΔB‖ ≤ clip·‖B‖ (0 disables). The multiplicative recursion
    /// `B ← (I − μF)B` is only contraction-safe while μ‖F‖ ≪ 1; the
    /// cubic nonlinearity makes ‖F‖ ∝ |y|⁴, so a single heavy-tailed
    /// sample can otherwise apply an O(1) rotation+scaling and destroy
    /// the fit (classic robust-EASI guard; see DESIGN.md §8).
    pub clip: f32,
    /// Initialise `B` with seeded random orthonormal rows instead of
    /// the identity embedding `[I 0]`. The multiplicative update can
    /// never leave the row space of the initial `B`, so for n < m the
    /// identity init pins training to the first n input coordinates
    /// forever; a random orthonormal subspace generically overlaps the
    /// informative latent directions.
    pub random_init: Option<u64>,
}

impl Default for EasiConfig {
    fn default() -> Self {
        Self {
            input_dim: 32,
            output_dim: 8,
            mu: 1e-3,
            mode: EasiMode::Full,
            normalized: false,
            max_norm: 1e4,
            clip: 0.0,
            random_init: None,
        }
    }
}

/// Seeded random-orthonormal `n×m` matrix (Gaussian rows + modified
/// Gram–Schmidt) — the recommended EASI init for n < m, shared by the
/// native trainer and the PJRT backend so both backends start from the
/// same point.
pub fn random_orthonormal(n: usize, m: usize, seed: u64) -> Mat {
    use crate::rng::{Pcg64, RngExt};
    assert!(n <= m);
    let mut rng = Pcg64::seed_stream(seed, 0x4249_4E49); // "BINI"
    let mut rows: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..m).map(|_| rng.next_gaussian() as f32).collect())
        .collect();
    for i in 0..n {
        for j in 0..i {
            let (head, tail) = rows.split_at_mut(i);
            let proj = crate::linalg::dot(&tail[0], &head[j]);
            for (t, &h) in tail[0].iter_mut().zip(&head[j]) {
                *t -= proj * h;
            }
        }
        let norm = crate::linalg::norm2(&rows[i]).max(1e-12);
        for v in &mut rows[i] {
            *v /= norm;
        }
    }
    Mat::from_vec(n, m, rows.into_iter().flatten().collect())
}

/// Streaming EASI trainer: owns the separation matrix `B (n×m)` and
/// applies one update per sample, exactly like the FPGA pipeline
/// consumes one sample per clock.
#[derive(Debug, Clone)]
pub struct EasiTrainer {
    pub config: EasiConfig,
    /// Separation matrix `B`, row-major `n×m`. Initialised to `[I 0]`
    /// (the identity embedding), the customary EASI start.
    b: Mat,
    /// Samples consumed.
    steps: u64,
    /// EMA of the relative update magnitude ‖ΔB‖/‖B‖ — convergence
    /// signal surfaced to the coordinator.
    update_ema: f64,
    // Scratch buffers (avoid per-sample allocation on the hot path).
    scratch_y: Vec<f32>,
    scratch_g: Vec<f32>,
    scratch_u: Vec<f32>,
    scratch_v: Vec<f32>,
    scratch_delta: Vec<f32>,
}

impl EasiTrainer {
    pub fn new(config: EasiConfig) -> Self {
        assert!(config.input_dim >= config.output_dim, "need m >= n");
        assert!(config.mu > 0.0, "mu must be positive");
        let b = match config.random_init {
            Some(seed) => random_orthonormal(config.output_dim, config.input_dim, seed),
            None => Mat::eye(config.output_dim, config.input_dim),
        };
        let (n, m) = (config.output_dim, config.input_dim);
        Self {
            config,
            b,
            steps: 0,
            update_ema: 1.0,
            scratch_y: vec![0.0; n],
            scratch_g: vec![0.0; n],
            scratch_u: vec![0.0; m],
            scratch_v: vec![0.0; m],
            scratch_delta: vec![0.0; n * m],
        }
    }

    /// Current separation matrix.
    pub fn separation_matrix(&self) -> &Mat {
        &self.b
    }

    /// Replace the separation matrix (checkpoint restore / PJRT
    /// round-trip). Panics on shape mismatch.
    pub fn set_separation_matrix(&mut self, b: Mat) {
        assert_eq!(b.shape(), self.b.shape(), "separation matrix shape");
        self.b = b;
    }

    /// Samples consumed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Restore the sample count (checkpoint restore) — without it a
    /// restored trainer would re-run step-count-gated cadences (the
    /// composed unit's rotation retraction) from zero.
    pub fn set_steps(&mut self, steps: u64) {
        self.steps = steps;
    }

    /// EMA of ‖ΔB‖_F/‖B‖_F — approaches 0 as training converges.
    pub fn update_magnitude(&self) -> f64 {
        self.update_ema
    }

    /// Transform one sample into the output space: `y = Bx`.
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        self.b.matvec(x)
    }

    /// Transform a whole sample matrix (rows are samples).
    pub fn transform_rows(&self, x: &Mat) -> Mat {
        self.b.apply_rows(x)
    }

    /// One EASI update for a single sample — the factored O(nm) form.
    ///
    /// Derivation: with `u = Bᵀy` and `v = Bᵀg(y)`,
    /// `[yyᵀ − I]B = y uᵀ − B` and `[g yᵀ − y gᵀ]B = g uᵀ − y vᵀ`, so the
    /// full Eq. 6 update is the rank-2 correction
    /// `B ← B − μ(y uᵀ + g uᵀ − y vᵀ − B)` with terms gated by mode.
    pub fn step(&mut self, x: &[f32]) {
        assert_eq!(x.len(), self.config.input_dim, "easi step shape mismatch");
        let n = self.config.output_dim;
        let m = self.config.input_dim;
        let mu = self.config.mu;
        let mode = self.config.mode;

        // y = Bx
        for i in 0..n {
            self.scratch_y[i] = crate::linalg::dot(self.b.row(i), x);
        }
        let (y, g) = (&mut self.scratch_y, &mut self.scratch_g);
        cubic(y, g);

        // u = Bᵀ y ; v = Bᵀ g
        self.scratch_u.iter_mut().for_each(|u| *u = 0.0);
        self.scratch_v.iter_mut().for_each(|v| *v = 0.0);
        for i in 0..n {
            let (yi, gi) = (y[i], g[i]);
            let row = self.b.row(i);
            for j in 0..m {
                self.scratch_u[j] += yi * row[j];
                self.scratch_v[j] += gi * row[j];
            }
        }

        // Normalisation factors (Cardoso's stabilised recursion).
        let (s2, s4) = if self.config.normalized {
            let yty: f32 = y.iter().map(|v| v * v).sum();
            let ytg: f32 = y.iter().zip(g.iter()).map(|(a, b)| a * b).sum();
            (1.0 / (1.0 + mu * yty), 1.0 / (1.0 + mu * ytg.abs()))
        } else {
            (1.0, 1.0)
        };

        // Assemble per-row: ΔB_i = μ[ s2·(y_i·u − B_i) + s4·(g_i·u − y_i·v) ]
        // (two passes when clipping: norms first, then apply — the step
        // may need rescaling before it touches B).
        let mut delta2 = 0.0f64; // ‖ΔB‖² accumulator
        let mut b_norm2_pre = 0.0f64;
        for i in 0..n {
            let (yi, gi) = (y[i], g[i]);
            let row = self.b.row(i);
            for j in 0..m {
                let mut d = 0.0f32;
                if mode.has_whitening() {
                    d += s2 * (yi * self.scratch_u[j] - row[j]);
                }
                if mode.has_rotation() {
                    d += s4 * (gi * self.scratch_u[j] - yi * self.scratch_v[j]);
                }
                self.scratch_delta[i * m + j] = mu * d;
                delta2 += (mu * d) as f64 * (mu * d) as f64;
                b_norm2_pre += (row[j] as f64) * (row[j] as f64);
            }
        }

        // Per-sample step clip: ‖ΔB‖ ≤ clip·‖B‖.
        let mut scale = 1.0f32;
        if self.config.clip > 0.0 {
            let limit = self.config.clip as f64 * b_norm2_pre.sqrt();
            let dn = delta2.sqrt();
            if dn > limit {
                scale = (limit / dn) as f32;
                delta2 = limit * limit;
            }
        }

        let mut b_norm2 = 0.0f64;
        for (bij, &dij) in self
            .b
            .as_mut_slice()
            .iter_mut()
            .zip(self.scratch_delta.iter())
        {
            *bij -= scale * dij;
            b_norm2 += (*bij as f64) * (*bij as f64);
        }

        // Divergence guard: rescale B if its norm exploded.
        if self.config.max_norm > 0.0 {
            let norm = (b_norm2 as f32).sqrt();
            if norm > self.config.max_norm {
                self.b.scale(self.config.max_norm / norm);
            }
        }

        let rel = (delta2.sqrt()) / (b_norm2.sqrt() + 1e-30);
        self.update_ema = 0.99 * self.update_ema + 0.01 * rel;
        self.steps += 1;
    }

    /// Consume every row of a sample matrix in order (one epoch of
    /// streaming training).
    pub fn step_rows(&mut self, x: &Mat) {
        let rows = x.rows_count();
        for i in 0..rows {
            self.step(x.row(i));
        }
    }

    /// Project `B`'s rows back to an orthonormal set (modified
    /// Gram–Schmidt). Used by the rotation-only datapath: each update
    /// `(I − μF)B` with skew `F` has singular values ≥ 1, so numerical
    /// drift off the rotation manifold compounds multiplicatively;
    /// periodic retraction keeps `U` a genuine rotation. O(n²m).
    pub fn reorthonormalize(&mut self) {
        let (n, m) = self.b.shape();
        debug_assert!(n <= m);
        crate::linalg::orthonormalize_rows(&mut self.b);
    }

    /// Whiteness of the trainer's outputs on the given samples — the
    /// convergence criterion for the second-order part.
    pub fn output_whiteness(&self, x: &Mat) -> f64 {
        whiteness_error(&self.transform_rows(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::amari_index;
    use crate::rng::{Pcg64, RngExt};

    /// Mix independent non-Gaussian sources through a random matrix.
    fn mixed_sources(n_src: usize, m: usize, samples: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg64::seed(seed);
        // Sources: uniform on [-√3, √3] (unit variance, negative
        // kurtosis — cubic-g EASI separates sub-Gaussian sources).
        let s = Mat::from_fn(samples, n_src, |_, _| {
            (rng.next_f32() * 2.0 - 1.0) * 3f32.sqrt()
        });
        let a = Mat::from_fn(m, n_src, |_, _| rng.next_gaussian() as f32);
        // x = A s  (rows are samples) → X = S Aᵀ
        let x = a.apply_rows(&s);
        (x, a)
    }

    #[test]
    fn whiten_only_whitens() {
        let (x, _) = mixed_sources(4, 4, 6000, 31);
        let mut t = EasiTrainer::new(EasiConfig {
            input_dim: 4,
            output_dim: 4,
            mu: 2e-3,
            mode: EasiMode::WhitenOnly,
            ..Default::default()
        });
        for _ in 0..3 {
            t.step_rows(&x);
        }
        let w = t.output_whiteness(&x);
        assert!(w < 0.1, "whiteness error {w}");
    }

    #[test]
    fn full_easi_separates_sources() {
        let (x, a) = mixed_sources(3, 3, 8000, 33);
        let mut t = EasiTrainer::new(EasiConfig {
            input_dim: 3,
            output_dim: 3,
            mu: 1.5e-3,
            mode: EasiMode::Full,
            normalized: true,
            ..Default::default()
        });
        for _ in 0..4 {
            t.step_rows(&x);
        }
        // Global system P = B·A must approach a scaled permutation.
        let p = t.separation_matrix().matmul(&a);
        let idx = amari_index(&p);
        assert!(idx < 0.12, "amari index {idx}");
    }

    #[test]
    fn update_magnitude_decreases() {
        // The relative update EMA must settle well below its start value
        // (1.0) and stay bounded as training converges.
        let (x, _) = mixed_sources(3, 3, 4000, 35);
        let mut t = EasiTrainer::new(EasiConfig {
            input_dim: 3,
            output_dim: 3,
            mu: 1e-3,
            normalized: true,
            ..Default::default()
        });
        for i in 0..200 {
            t.step(x.row(i));
        }
        let early = t.update_magnitude();
        for _ in 0..6 {
            t.step_rows(&x);
        }
        let late = t.update_magnitude();
        assert!(late < early, "EMA did not settle: early {early}, late {late}");
        assert!(late < 0.05, "steady-state update magnitude too large: {late}");
    }

    #[test]
    fn rotation_only_keeps_white_inputs_white() {
        // RotationOnly assumes whitened inputs; after training, outputs
        // should still be (approximately) white — the rotation term is
        // skew-symmetric so it cannot destroy whiteness.
        let mut rng = Pcg64::seed(37);
        let x = Mat::from_fn(6000, 4, |_, _| (rng.next_f32() * 2.0 - 1.0) * 3f32.sqrt());
        let mut t = EasiTrainer::new(EasiConfig {
            input_dim: 4,
            output_dim: 4,
            mu: 1e-3,
            mode: EasiMode::RotationOnly,
            ..Default::default()
        });
        for _ in 0..2 {
            t.step_rows(&x);
        }
        let w = t.output_whiteness(&x);
        assert!(w < 0.15, "rotation destroyed whiteness: {w}");
    }

    #[test]
    fn dimensionality_reduction_shape() {
        let mut t = EasiTrainer::new(EasiConfig {
            input_dim: 32,
            output_dim: 8,
            ..Default::default()
        });
        let x = vec![0.5; 32];
        t.step(&x);
        assert_eq!(t.transform(&x).len(), 8);
    }

    #[test]
    fn divergence_guard_caps_norm() {
        let mut t = EasiTrainer::new(EasiConfig {
            input_dim: 2,
            output_dim: 2,
            mu: 0.5, // absurdly large on purpose
            max_norm: 10.0,
            ..Default::default()
        });
        let mut rng = Pcg64::seed(39);
        for _ in 0..500 {
            let x = [
                rng.next_gaussian() as f32 * 5.0,
                rng.next_gaussian() as f32 * 5.0,
            ];
            t.step(&x);
        }
        assert!(t.separation_matrix().fro_norm() <= 10.0 + 1e-3);
        assert!(t.separation_matrix().as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic_training() {
        let (x, _) = mixed_sources(3, 4, 500, 41);
        let run = || {
            let mut t = EasiTrainer::new(EasiConfig {
                input_dim: 4,
                output_dim: 3,
                ..Default::default()
            });
            t.step_rows(&x);
            t.separation_matrix().clone()
        };
        assert_eq!(run().as_slice(), run().as_slice());
    }
}
