//! The literal (naive) EASI arithmetic — the exact operation sequence of
//! the paper's Fig. 3 datapath and Alg. 1, with the explicit `n×n`
//! relative-gradient matrix `F` and full `F·B` product.
//!
//! The streaming trainer in `mod.rs` uses an algebraically identical
//! factored form that is O(nm) instead of O(n²m); this module is the
//! oracle the property tests compare it against, and its operation
//! counts are what `hwmodel` charges for the FPGA datapath.

use super::{cubic, EasiMode};
use crate::linalg::Mat;

/// Build the relative gradient
/// `F = [yyᵀ − I]·1{whiten} + [g(y)yᵀ − y g(y)ᵀ]·1{rotate}`
/// exactly as the datapath's stage 4 computes it (Alg. 1, step 4).
pub fn relative_gradient(y: &[f32], mode: EasiMode) -> Mat {
    let n = y.len();
    let mut g = vec![0.0f32; n];
    cubic(y, &mut g);
    Mat::from_fn(n, n, |i, j| {
        let mut f = 0.0;
        if mode.has_whitening() {
            f += y[i] * y[j] - if i == j { 1.0 } else { 0.0 };
        }
        if mode.has_rotation() {
            f += g[i] * y[j] - y[i] * g[j];
        }
        f
    })
}

/// One literal Eq. 6 update: `B ← B − μ F B` with `y = Bx` computed
/// first (Alg. 1 steps 2–6). Returns the new matrix.
pub fn naive_step(b: &Mat, x: &[f32], mu: f32, mode: EasiMode) -> Mat {
    let y = b.matvec(x);
    let f = relative_gradient(&y, mode);
    let fb = f.matmul(b);
    let mut out = b.clone();
    out.add_scaled(-mu, &fb);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::easi::{EasiConfig, EasiTrainer};
    use crate::linalg::max_abs_diff;
    use crate::rng::{Pcg64, RngExt};

    fn check_factored_matches_naive(mode: EasiMode, seed: u64) {
        let (n, m, mu) = (4usize, 7usize, 1e-3f32);
        let mut rng = Pcg64::seed(seed);
        let mut trainer = EasiTrainer::new(EasiConfig {
            input_dim: m,
            output_dim: n,
            mu,
            mode,
            normalized: false,
            max_norm: 0.0,
            clip: 0.0,
            random_init: None,
        });
        let mut b = trainer.separation_matrix().clone();
        for _ in 0..200 {
            let x: Vec<f32> = (0..m).map(|_| rng.next_gaussian() as f32).collect();
            trainer.step(&x);
            b = naive_step(&b, &x, mu, mode);
        }
        let d = max_abs_diff(trainer.separation_matrix(), &b);
        assert!(d < 1e-4, "mode {mode:?}: factored vs naive diff {d}");
    }

    #[test]
    fn factored_matches_naive_full() {
        check_factored_matches_naive(EasiMode::Full, 101);
    }

    #[test]
    fn factored_matches_naive_whiten() {
        check_factored_matches_naive(EasiMode::WhitenOnly, 102);
    }

    #[test]
    fn factored_matches_naive_rotation() {
        check_factored_matches_naive(EasiMode::RotationOnly, 103);
    }

    #[test]
    fn hos_term_is_skew_symmetric() {
        let y = [0.3f32, -1.2, 0.7];
        let f = relative_gradient(&y, EasiMode::RotationOnly);
        for i in 0..3 {
            for j in 0..3 {
                assert!(
                    (f.get(i, j) + f.get(j, i)).abs() < 1e-6,
                    "F not skew at ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn whitening_term_is_symmetric() {
        let y = [0.3f32, -1.2, 0.7];
        let f = relative_gradient(&y, EasiMode::WhitenOnly);
        for i in 0..3 {
            for j in 0..3 {
                assert!((f.get(i, j) - f.get(j, i)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn full_is_sum_of_parts() {
        let y = [0.5f32, 1.5, -0.25, 2.0];
        let w = relative_gradient(&y, EasiMode::WhitenOnly);
        let r = relative_gradient(&y, EasiMode::RotationOnly);
        let f = relative_gradient(&y, EasiMode::Full);
        for i in 0..4 {
            for j in 0..4 {
                assert!((f.get(i, j) - w.get(i, j) - r.get(i, j)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn zero_gradient_at_white_uncorrelated_fixpoint() {
        // If y has unit "instantaneous variance" pattern e_i, F for
        // whitening is e_i e_iᵀ − I which is nonzero — fixpoints hold in
        // expectation, not per-sample. Instead verify: μ = 0 ⇒ no change.
        let b = Mat::eye(2, 3);
        let after = naive_step(&b, &[1.0, 2.0, 3.0], 0.0, EasiMode::Full);
        assert_eq!(b.as_slice(), after.as_slice());
    }
}
