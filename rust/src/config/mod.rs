//! Experiment configuration: JSON files + CLI overrides.
//!
//! One [`ExperimentConfig`] fully determines a training run — dataset,
//! pipeline dimensions, datapath mode, backend (native Rust vs PJRT
//! artifacts), optimisation hyper-parameters and seeds. The CLI
//! (`dimred train --config cfg.json --mu 2e-3 ...`) loads the file
//! first, then applies flag overrides, so configs are reproducible and
//! tweakable.

use crate::easi::EasiMode;
use crate::fxp::Precision;
use crate::rp::RpDistribution;
use crate::stage::spec::parse_stage_list;
use crate::stage::{GraphSpec, StageDecl, StageOp};
use crate::util::cli::Args;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Which execution engine drives training.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust reference implementation (baseline / oracle).
    Native,
    /// AOT-compiled XLA executables via PJRT (the production path).
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            other => bail!("unknown backend '{other}' (native|pjrt)"),
        }
    }
}

/// Datapath configuration (mirrors the paper's reconfigurable mux plus
/// the RP front end).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineMode {
    /// Full EASI, m → n.
    Easi,
    /// PCA whitening (HOS term bypassed), m → n.
    PcaWhiten,
    /// RP only, m → n (no trained stage).
    RpOnly,
    /// The paper's proposal: RP m → p, rotation-only EASI p → n.
    RpEasi,
}

impl PipelineMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "easi" => Ok(Self::Easi),
            "pca-whiten" | "whiten" => Ok(Self::PcaWhiten),
            "rp" => Ok(Self::RpOnly),
            "rp-easi" | "proposed" => Ok(Self::RpEasi),
            other => bail!("unknown mode '{other}' (easi|pca-whiten|rp|rp-easi)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Easi => "easi",
            Self::PcaWhiten => "pca-whiten",
            Self::RpOnly => "rp",
            Self::RpEasi => "rp-easi",
        }
    }

    /// The EASI datapath mode used by the trained stage, if any.
    pub fn easi_mode(&self) -> Option<EasiMode> {
        match self {
            Self::Easi => Some(EasiMode::Full),
            Self::PcaWhiten => Some(EasiMode::WhitenOnly),
            Self::RpEasi => Some(EasiMode::RotationOnly),
            Self::RpOnly => None,
        }
    }

    /// Whether the RP front end is active.
    pub fn uses_rp(&self) -> bool {
        matches!(self, Self::RpOnly | Self::RpEasi)
    }
}

/// A full experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Dataset name: waveform | mnist | har | ads | csv:<path>.
    pub dataset: String,
    /// Input dimensionality m (checked against the dataset).
    pub input_dim: usize,
    /// Intermediate dimensionality p (used by RP modes).
    pub intermediate_dim: usize,
    /// Output dimensionality n.
    pub output_dim: usize,
    pub mode: PipelineMode,
    /// Explicit stage-graph override: a comma-separated stage list
    /// (`rp:ternary/16,whiten:gha,rot:easi` — see
    /// [`crate::stage::spec`]) composing an arbitrary DR cascade. When
    /// set it replaces the `mode` → stage mapping (native backend
    /// only); `mode` keeps driving the reconfiguration mux.
    pub stages: Option<String>,
    pub backend: Backend,
    /// Arithmetic of the DR datapath: f32, uniform bit-accurate fixed
    /// point (`"q4.12"`, optionally with `:wrap`/`:trunc` policy
    /// suffixes), or a per-stage mixed-precision plan
    /// (`"rp=q8.16,whiten=q4.12,rot=q1.15[,qat=ste]"` — see
    /// [`Precision::parse`]). Fixed point runs the quantized kernels of
    /// [`crate::fxp`] — native backend only; `qat=ste` selects
    /// straight-through-estimator training.
    pub precision: Precision,
    pub rp_distribution: RpDistribution,
    /// EASI rotation learning rate μ.
    pub mu: f32,
    /// GHA (whitening) learning rate.
    pub mu_w: f32,
    /// Samples of whitener-only warm-up before the rotation engages.
    pub rot_warmup: usize,
    /// Passes over the training set for the DR stage.
    pub epochs: usize,
    /// Minibatch fed to one PJRT step executable.
    pub batch: usize,
    /// Bounded-queue depth between the streaming source and the trainer
    /// (backpressure window, in batches).
    pub queue_depth: usize,
    /// Forward-path lanes for the *fixed-point* engine: its bulk
    /// transforms shard a tile's rows across this many threads
    /// (deterministic merge, bit-identical outputs). The f32 engine's
    /// bulk transform is a single dense matmul, which ignores this
    /// knob. Training parallelism is governed separately by
    /// `train_lanes`. 1 = single-lane.
    pub lanes: usize,
    /// Training-path lanes for the fixed-point engine: shards the
    /// entry quantizer's tile and the EASI STE shadow backward pass
    /// across this many threads (those updates commute on disjoint
    /// row blocks, so training stays bit-identical — see
    /// `StageGraph::set_train_lanes`). Bit-exact integer updates and
    /// the GHA STE prefix recursion remain sequential regardless.
    /// 1 = sequential (never spawns).
    pub train_lanes: usize,
    pub seed: u64,
    pub artifact_dir: PathBuf,
    /// Train the downstream classifier and report accuracy.
    pub train_classifier: bool,
    /// Classifier epochs.
    pub mlp_epochs: usize,
    /// Validate batches at the ingest boundary (reject empty,
    /// wrong-dimension and non-finite payloads before they reach
    /// trainer state). Default on; `--no-validate-ingest` disables the
    /// per-batch scan for callers that already guarantee clean input.
    pub validate_ingest: bool,
    /// Instrument the datapath: per-stage counters, fxp saturation
    /// health, periodic JSONL events and an end-of-run snapshot.
    pub telemetry: bool,
    /// Where `train` writes the schema-validated telemetry snapshot.
    pub telemetry_out: PathBuf,
    /// Where periodic JSONL progress events go. `None` keeps the
    /// historical behaviour (stdout) for a bare `--telemetry`; setting
    /// `--telemetry-out` derives a sibling `.events.jsonl` path so the
    /// events never interleave with report output on stdout.
    pub telemetry_events: Option<PathBuf>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            dataset: "waveform".into(),
            input_dim: 32,
            intermediate_dim: 16,
            output_dim: 8,
            mode: PipelineMode::RpEasi,
            stages: None,
            backend: Backend::Native,
            precision: Precision::F32,
            rp_distribution: RpDistribution::Ternary,
            mu: 1e-3,
            mu_w: 5e-3,
            rot_warmup: 2000,
            epochs: 4,
            batch: 256,
            queue_depth: 4,
            lanes: 1,
            train_lanes: 1,
            seed: 2018,
            artifact_dir: PathBuf::from("artifacts"),
            train_classifier: true,
            mlp_epochs: 30,
            validate_ingest: true,
            telemetry: false,
            telemetry_out: PathBuf::from("TELEMETRY_snapshot.json"),
            telemetry_events: None,
        }
    }
}

impl ExperimentConfig {
    /// Load from a JSON file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    /// Build from parsed JSON (all fields optional; defaults apply).
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut c = Self::default();
        if let Some(x) = v.get("dataset") {
            c.dataset = x.as_str()?.to_string();
        }
        if let Some(x) = v.get("input_dim") {
            c.input_dim = x.as_usize()?;
        }
        if let Some(x) = v.get("intermediate_dim") {
            c.intermediate_dim = x.as_usize()?;
        }
        if let Some(x) = v.get("output_dim") {
            c.output_dim = x.as_usize()?;
        }
        if let Some(x) = v.get("mode") {
            c.mode = PipelineMode::parse(x.as_str()?)?;
        }
        if let Some(x) = v.get("stages") {
            c.stages = Some(x.as_str()?.to_string());
        }
        if let Some(x) = v.get("backend") {
            c.backend = Backend::parse(x.as_str()?)?;
        }
        if let Some(x) = v.get("precision") {
            c.precision = Precision::parse(x.as_str()?)?;
        }
        if let Some(x) = v.get("rp_distribution") {
            c.rp_distribution = match x.as_str()? {
                "ternary" => RpDistribution::Ternary,
                "achlioptas" => RpDistribution::Achlioptas,
                "gaussian" => RpDistribution::Gaussian,
                other => bail!("unknown rp_distribution '{other}'"),
            };
        }
        if let Some(x) = v.get("mu") {
            c.mu = x.as_f64()? as f32;
        }
        if let Some(x) = v.get("mu_w") {
            c.mu_w = x.as_f64()? as f32;
        }
        if let Some(x) = v.get("rot_warmup") {
            c.rot_warmup = x.as_usize()?;
        }
        if let Some(x) = v.get("epochs") {
            c.epochs = x.as_usize()?;
        }
        if let Some(x) = v.get("batch") {
            c.batch = x.as_usize()?;
        }
        if let Some(x) = v.get("queue_depth") {
            c.queue_depth = x.as_usize()?;
        }
        if let Some(x) = v.get("lanes") {
            c.lanes = x.as_usize()?;
        }
        if let Some(x) = v.get("train_lanes") {
            c.train_lanes = x.as_usize()?;
        }
        if let Some(x) = v.get("seed") {
            c.seed = x.as_u64()?;
        }
        if let Some(x) = v.get("artifact_dir") {
            c.artifact_dir = PathBuf::from(x.as_str()?);
        }
        if let Some(x) = v.get("train_classifier") {
            c.train_classifier = x.as_bool()?;
        }
        if let Some(x) = v.get("mlp_epochs") {
            c.mlp_epochs = x.as_usize()?;
        }
        if let Some(x) = v.get("validate_ingest") {
            c.validate_ingest = x.as_bool()?;
        }
        if let Some(x) = v.get("telemetry") {
            c.telemetry = x.as_bool()?;
        }
        if let Some(x) = v.get("telemetry_out") {
            c.telemetry_out = PathBuf::from(x.as_str()?);
        }
        if let Some(x) = v.get("telemetry_events") {
            c.telemetry_events = Some(PathBuf::from(x.as_str()?));
        }
        c.validate()?;
        Ok(c)
    }

    /// Apply CLI overrides on top of the loaded config.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(d) = args.opt_str("dataset") {
            self.dataset = d.to_string();
        }
        if let Some(m) = args.opt_str("mode") {
            self.mode = PipelineMode::parse(m)?;
        }
        if let Some(s) = args.opt_str("stages") {
            self.stages = Some(s.to_string());
        }
        if let Some(b) = args.opt_str("backend") {
            self.backend = Backend::parse(b)?;
        }
        if let Some(p) = args.opt_str("precision") {
            self.precision = Precision::parse(p)?;
        }
        self.input_dim = args.usize_or("input-dim", self.input_dim)?;
        self.intermediate_dim = args.usize_or("intermediate-dim", self.intermediate_dim)?;
        self.output_dim = args.usize_or("output-dim", self.output_dim)?;
        self.mu = args.f32_or("mu", self.mu)?;
        self.mu_w = args.f32_or("mu-w", self.mu_w)?;
        self.rot_warmup = args.usize_or("rot-warmup", self.rot_warmup)?;
        self.epochs = args.usize_or("epochs", self.epochs)?;
        self.batch = args.usize_or("batch", self.batch)?;
        self.queue_depth = args.usize_or("queue-depth", self.queue_depth)?;
        self.lanes = args.usize_or("lanes", self.lanes)?;
        self.train_lanes = args.usize_or("train-lanes", self.train_lanes)?;
        self.seed = args.u64_or("seed", self.seed)?;
        self.mlp_epochs = args.usize_or("mlp-epochs", self.mlp_epochs)?;
        if let Some(dir) = args.opt_str("artifacts") {
            self.artifact_dir = PathBuf::from(dir);
        }
        if args.flag("no-classifier") {
            self.train_classifier = false;
        }
        if args.flag("no-validate-ingest") {
            self.validate_ingest = false;
        }
        if args.flag("telemetry") {
            self.telemetry = true;
        }
        if let Some(p) = args.opt_str("telemetry-out") {
            // An explicit output path implies instrumentation, and the
            // periodic JSONL events move off stdout to a sibling file so
            // they cannot interleave with report output.
            self.telemetry = true;
            self.telemetry_out = PathBuf::from(p);
            if self.telemetry_events.is_none() {
                self.telemetry_events = Some(self.telemetry_out.with_extension("events.jsonl"));
            }
        }
        if let Some(p) = args.opt_str("telemetry-events") {
            self.telemetry = true;
            self.telemetry_events = Some(PathBuf::from(p));
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.output_dim >= 1 && self.output_dim <= self.input_dim,
            "need 1 <= n <= m"
        );
        if self.mode.uses_rp() {
            anyhow::ensure!(
                self.intermediate_dim >= self.output_dim
                    && self.intermediate_dim <= self.input_dim,
                "need n <= p <= m for RP modes"
            );
        }
        anyhow::ensure!(self.mu > 0.0, "mu must be positive");
        anyhow::ensure!(self.batch >= 1, "batch must be >= 1");
        anyhow::ensure!(self.queue_depth >= 1, "queue_depth must be >= 1");
        anyhow::ensure!(self.lanes >= 1, "lanes must be >= 1");
        anyhow::ensure!(self.train_lanes >= 1, "train_lanes must be >= 1");
        anyhow::ensure!(
            !(self.precision.is_fixed() && self.backend == Backend::Pjrt),
            "fixed-point precision runs on the native backend only \
             (the AOT artifacts are compiled for f32)"
        );
        if self.stages.is_some() {
            anyhow::ensure!(
                self.backend == Backend::Native,
                "custom stage lists run on the native backend only \
                 (the AOT artifacts are compiled per pipeline mode)"
            );
            // Surface stage-list errors — unknown/duplicate tokens AND
            // dimension-chain inconsistencies — at config time, not
            // mid-run.
            self.graph_spec()?.resolve()?;
        }
        Ok(())
    }

    /// The stage graph this config trains: the explicit `stages` list
    /// when given, otherwise the legacy mode → stage mapping (the
    /// paper's proposal is `rp:ternary/p,whiten:gha,rot:easi`).
    pub fn graph_spec(&self) -> Result<GraphSpec> {
        let stages = match &self.stages {
            Some(list) => parse_stage_list(list)?,
            None => {
                let mut v = Vec::new();
                match self.mode {
                    PipelineMode::RpOnly => bail!("RP-only mode has no trained stage"),
                    PipelineMode::RpEasi => {
                        v.push(
                            StageDecl::new(StageOp::Rp(self.rp_distribution))
                                .with_dim(self.intermediate_dim),
                        );
                        v.push(StageDecl::new(StageOp::WhitenGha));
                        v.push(StageDecl::new(StageOp::RotEasi));
                    }
                    PipelineMode::Easi | PipelineMode::PcaWhiten => {
                        v.push(StageDecl::new(StageOp::WhitenGha));
                        v.push(StageDecl::new(StageOp::RotEasi));
                    }
                }
                v
            }
        };
        Ok(GraphSpec {
            input_dim: self.input_dim,
            output_dim: self.output_dim,
            stages,
            seed: self.seed,
            precision: self.precision,
            mu_w: self.mu_w,
            mu_rot: self.mu,
            rot_warmup: Some(self.rot_warmup as u64),
            epochs: self.epochs,
        })
    }

    /// Serialise (reports, checkpoints).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("input_dim", Json::num(self.input_dim as f64)),
            ("intermediate_dim", Json::num(self.intermediate_dim as f64)),
            ("output_dim", Json::num(self.output_dim as f64)),
            ("mode", Json::str(self.mode.label())),
            (
                "backend",
                Json::str(match self.backend {
                    Backend::Native => "native",
                    Backend::Pjrt => "pjrt",
                }),
            ),
            ("precision", Json::str(self.precision.label())),
            ("mu", Json::num(self.mu as f64)),
            ("epochs", Json::num(self.epochs as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("lanes", Json::num(self.lanes as f64)),
            ("train_lanes", Json::num(self.train_lanes as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("validate_ingest", Json::Bool(self.validate_ingest)),
            ("telemetry", Json::Bool(self.telemetry)),
        ];
        if let Some(s) = &self.stages {
            fields.push(("stages", Json::str(s.clone())));
        }
        if let Some(p) = &self.telemetry_events {
            fields.push(("telemetry_events", Json::str(p.display().to_string())));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_fields() {
        let c = ExperimentConfig::from_json(
            &Json::parse(
                r#"{"dataset": "waveform", "mode": "easi", "output_dim": 16,
                    "mu": 0.001, "backend": "pjrt", "epochs": 2}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.mode, PipelineMode::Easi);
        assert_eq!(c.backend, Backend::Pjrt);
        assert_eq!(c.output_dim, 16);
        assert!((c.mu - 0.001).abs() < 1e-9);
    }

    #[test]
    fn rejects_bad_dims() {
        let r = ExperimentConfig::from_json(
            &Json::parse(r#"{"output_dim": 64, "input_dim": 32}"#).unwrap(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bad_mode() {
        assert!(PipelineMode::parse("bogus").is_err());
        assert_eq!(PipelineMode::parse("proposed").unwrap(), PipelineMode::RpEasi);
    }

    #[test]
    fn cli_overrides() {
        let mut c = ExperimentConfig::default();
        let args = Args::parse(
            ["--mu", "0.005", "--mode", "easi", "--epochs", "9"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.mode, PipelineMode::Easi);
        assert_eq!(c.epochs, 9);
        assert!((c.mu - 0.005).abs() < 1e-9);
    }

    #[test]
    fn precision_json_and_cli() {
        let c = ExperimentConfig::from_json(
            &Json::parse(r#"{"precision": "q1.15"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.precision.label(), "q1.15");
        let mut c = ExperimentConfig::default();
        let args = Args::parse(
            ["--precision", "q4.12"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.precision.label(), "q4.12");
        assert!(c.precision.is_fixed());
    }

    #[test]
    fn mixed_precision_plan_json_and_cli() {
        // Plan syntax flows through JSON configs…
        let c = ExperimentConfig::from_json(
            &Json::parse(r#"{"precision": "rp=q8.16,whiten=q4.12,rot=q1.15,qat=ste"}"#)
                .unwrap(),
        )
        .unwrap();
        let plan = c.precision.plan().unwrap();
        assert_eq!(plan.rp.format.width(), 24);
        assert_eq!(plan.whiten.format.width(), 16);
        assert_eq!(plan.rot.format.width(), 16);
        assert_eq!(plan.quant, crate::fxp::QuantMode::Ste);
        // …and the label round-trips through to_json/from_json.
        let j = c.to_json();
        let back = ExperimentConfig::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.precision, c.precision);

        // CLI override with wrap/trunc policy suffixes (ROADMAP item:
        // the wrapping/truncating datapath is now reachable end to end).
        let mut c = ExperimentConfig::default();
        let args = Args::parse(
            ["--precision", "q1.15:wrap:trunc"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        let spec = c.precision.spec().unwrap();
        assert_eq!(spec.overflow, crate::fxp::Overflow::Wrap);
        assert_eq!(spec.rounding, crate::fxp::Rounding::Truncate);
        assert_eq!(c.precision.label(), "q1.15:wrap:trunc");
    }

    #[test]
    fn fixed_precision_rejects_pjrt_backend() {
        let r = ExperimentConfig::from_json(
            &Json::parse(r#"{"precision": "q4.12", "backend": "pjrt"}"#).unwrap(),
        );
        assert!(r.is_err());
    }

    #[test]
    fn stages_json_cli_and_validation() {
        let c = ExperimentConfig::from_json(
            &Json::parse(r#"{"stages": "rp:ternary/16,whiten:gha,rot:easi"}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.stages.as_deref(), Some("rp:ternary/16,whiten:gha,rot:easi"));
        let g = c.graph_spec().unwrap();
        assert_eq!(g.stages_label(), "rp:ternary/16,whiten:gha,rot:easi");
        // Round-trips through to_json.
        let back =
            ExperimentConfig::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.stages, c.stages);
        // Unknown stage tokens fail at config time, naming the token.
        let err = ExperimentConfig::from_json(
            &Json::parse(r#"{"stages": "frobnicate"}"#).unwrap(),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("frobnicate"), "{err}");
        // PJRT backend rejects custom stage lists.
        assert!(ExperimentConfig::from_json(
            &Json::parse(r#"{"stages": "whiten:gha", "backend": "pjrt"}"#).unwrap()
        )
        .is_err());
        // CLI override.
        let mut c = ExperimentConfig::default();
        let args = Args::parse(
            ["--stages", "dct/16,whiten:gha,rot:easi"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.stages.as_deref(), Some("dct/16,whiten:gha,rot:easi"));
        // Legacy modes map onto the equivalent stage lists.
        let g = ExperimentConfig::default().graph_spec().unwrap();
        assert_eq!(g.stages_label(), "rp:ternary/16,whiten:gha,rot:easi");
        let g = ExperimentConfig {
            mode: PipelineMode::Easi,
            ..Default::default()
        }
        .graph_spec()
        .unwrap();
        assert_eq!(g.stages_label(), "whiten:gha,rot:easi");
    }

    #[test]
    fn telemetry_out_derives_events_path() {
        // `--telemetry-out` moves periodic JSONL events off stdout to a
        // sibling file (and implies instrumentation)…
        let mut c = ExperimentConfig::default();
        let args = Args::parse(
            ["--telemetry-out", "runs/snap.json"].iter().map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert!(c.telemetry);
        assert_eq!(
            c.telemetry_events.as_deref(),
            Some(Path::new("runs/snap.events.jsonl"))
        );
        // …an explicit `--telemetry-events` wins over the derivation…
        let mut c = ExperimentConfig::default();
        let args = Args::parse(
            ["--telemetry-events", "ev.jsonl", "--telemetry-out", "snap.json"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.telemetry_events.as_deref(), Some(Path::new("ev.jsonl")));
        // …and a bare `--telemetry` keeps the historical stdout route.
        let mut c = ExperimentConfig::default();
        let args = Args::parse(std::iter::once("--telemetry".to_string()), &["telemetry"]).unwrap();
        c.apply_args(&args).unwrap();
        assert!(c.telemetry);
        assert!(c.telemetry_events.is_none());
    }

    #[test]
    fn mode_easi_mapping() {
        assert_eq!(
            PipelineMode::RpEasi.easi_mode(),
            Some(crate::easi::EasiMode::RotationOnly)
        );
        assert_eq!(PipelineMode::RpOnly.easi_mode(), None);
        assert!(PipelineMode::RpEasi.uses_rp());
        assert!(!PipelineMode::Easi.uses_rp());
    }
}
