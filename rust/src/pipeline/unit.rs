//! The composed streaming DR unit: the paper's Fig. 2 decomposition
//! (whitening → rotation) realised so that every stage can actually
//! learn:
//!
//! ```text
//!  x (m) ──[RP, fixed ±1]──► (p) ──[GHA subspace + λ̂ scaling]──► z (n)
//!                                         └──[EASI rotation n×n]──► y
//! ```
//!
//! * the RP front end is the paper's §IV multiplication-free reducer;
//! * the whitening half is Sanger's GHA (see [`crate::gha`] for why
//!   Eq. 3's multiplicative recursion cannot serve as a *rectangular*
//!   whitener — its row space is frozen at init);
//! * the rotation half is the paper's modified EASI datapath
//!   (`yyᵀ − I` muxed out) on the whitened square: exactly Eq. 6's HOS
//!   term, where the multiplicative update is sound because n = n.
//!
//! The datapath mux of the paper maps to [`DrUnit::set_rotation`]:
//! rotation off ⇒ PCA whitening; rotation on ⇒ ICA.

use crate::easi::{EasiConfig, EasiMode, EasiTrainer};

/// Rotation steps between retractions to the orthogonal manifold (also
/// the cadence the PJRT backend applies host-side between batches).
pub const RETRACT_INTERVAL: u64 = 256;
use crate::gha::{GhaConfig, GhaWhitener};
use crate::linalg::Mat;

/// Configuration for one composed unit (excluding any RP front end,
/// which the callers own because it is shared across modes).
#[derive(Debug, Clone)]
pub struct DrUnitConfig {
    /// Stage input dimensionality (the paper's m, or p behind RP).
    pub input_dim: usize,
    /// Output dimensionality n.
    pub output_dim: usize,
    /// GHA (whitening) learning rate.
    pub mu_w: f32,
    /// EASI rotation learning rate.
    pub mu_rot: f32,
    /// Whether the HOS rotation stage is active (the paper's mux).
    pub rotate: bool,
    /// Samples to train the whitener alone before the rotation starts
    /// learning (the rotation's inputs are meaningless until λ̂ has
    /// settled; the paper's own Fig. 2 presents whitening and rotation
    /// as sequential stages).
    pub rot_warmup: u64,
    pub seed: u64,
}

impl Default for DrUnitConfig {
    fn default() -> Self {
        Self {
            input_dim: 32,
            output_dim: 8,
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rotate: true,
            rot_warmup: 2000,
            seed: 2018,
        }
    }
}

/// Streaming whiten(+rotate) unit.
#[derive(Debug, Clone)]
pub struct DrUnit {
    pub config: DrUnitConfig,
    gha: GhaWhitener,
    /// Square rotation on the whitened outputs (always allocated so the
    /// mux can toggle mid-stream; skipped when `rotate` is false).
    rot: EasiTrainer,
    scratch_z: Vec<f32>,
}

impl DrUnit {
    pub fn new(config: DrUnitConfig) -> Self {
        let gha = GhaWhitener::new(GhaConfig {
            input_dim: config.input_dim,
            output_dim: config.output_dim,
            mu: config.mu_w,
            seed: config.seed,
            ..Default::default()
        });
        let rot = EasiTrainer::new(EasiConfig {
            input_dim: config.output_dim,
            output_dim: config.output_dim,
            mu: config.mu_rot,
            mode: EasiMode::RotationOnly,
            normalized: true,
            max_norm: 4.0 * (config.output_dim as f32).sqrt(),
            clip: 0.05,
            random_init: None, // identity: a rotation starts at I
        });
        let n = config.output_dim;
        Self {
            config,
            gha,
            rot,
            scratch_z: vec![0.0; n],
        }
    }

    /// One streaming sample: update the whitener, then (if enabled) the
    /// rotation on the whitened output — the two halves of Fig. 2
    /// training simultaneously, as the paper's pipelined datapath does.
    pub fn step(&mut self, x: &[f32]) {
        self.gha.step(x);
        if self.config.rotate && self.gha.steps() > self.config.rot_warmup {
            // Whiten straight into the scratch buffer (no intermediate
            // vector — the whole step is allocation-free).
            self.gha.whiten_into(x, &mut self.scratch_z);
            // Robustness clamp: a whitened coordinate should be O(1);
            // outliers (heavy tails or a still-settling λ̂) are limited
            // so the cubic nonlinearity cannot blow up the rotation.
            for v in &mut self.scratch_z {
                *v = v.clamp(-4.0, 4.0);
            }
            self.rot.step(&self.scratch_z);
            // Retract U to the rotation manifold periodically: the
            // multiplicative update drifts off it (singular values of
            // I − μF are >= 1) and conditioning would otherwise degrade
            // multiplicatively over long streams.
            if self.rot.steps() % RETRACT_INTERVAL == 0 {
                self.rot.reorthonormalize();
            }
        }
    }

    /// Consume every row of a sample matrix.
    pub fn step_rows(&mut self, x: &Mat) {
        for i in 0..x.rows_count() {
            self.step(x.row(i));
        }
    }

    /// Toggle the rotation stage (the paper's reconfiguration mux).
    /// State of both stages is preserved.
    pub fn set_rotation(&mut self, on: bool) {
        self.config.rotate = on;
    }

    pub fn rotation_enabled(&self) -> bool {
        self.config.rotate
    }

    /// Transform one sample.
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        let z = self.gha.whiten(x);
        if self.config.rotate {
            self.rot.transform(&z)
        } else {
            z
        }
    }

    /// The unit as one dense matrix: `U · diag(λ̂^{-1/2}) · W` (or just
    /// the whitening part with rotation off). Used for bulk transforms,
    /// checkpointing, and as the `B` fed to inference artifacts.
    pub fn effective_matrix(&self) -> Mat {
        let wm = self.gha.whitening_matrix();
        if self.config.rotate {
            self.rot.separation_matrix().matmul(&wm)
        } else {
            wm
        }
    }

    /// Convergence signal: the larger of the two stages' update EMAs
    /// (the whitener dominates early, the rotation late).
    pub fn update_magnitude(&self) -> f64 {
        let gha_like = self.gha_orthonormality();
        if self.config.rotate {
            gha_like.max(self.rot.update_magnitude())
        } else {
            gha_like
        }
    }

    fn gha_orthonormality(&self) -> f64 {
        self.gha.orthonormality_error()
    }

    /// Access the whitener (tests, diagnostics).
    pub fn whitener(&self) -> &GhaWhitener {
        &self.gha
    }

    /// Access the rotation stage.
    pub fn rotation(&self) -> &EasiTrainer {
        &self.rot
    }

    /// Restore state (checkpoint / PJRT round-trip). `steps` restores
    /// the whitener's sample count — without it a restored unit would
    /// re-run the rotation warm-up gate (`gha.steps() > rot_warmup`)
    /// from zero and freeze its rotation stage.
    pub fn set_state(&mut self, w: Mat, var: Vec<f32>, u: Mat, steps: u64) {
        assert_eq!(w.shape(), self.gha.subspace().shape());
        assert_eq!(var.len(), self.config.output_dim);
        assert_eq!(u.shape(), self.rot.separation_matrix().shape());
        self.gha.set_state(w, var, steps);
        self.rot.set_separation_matrix(u);
    }

    /// Manually retract the rotation to the orthogonal manifold (the
    /// PJRT backend calls this between batches at [`RETRACT_INTERVAL`]).
    pub fn retract(&mut self) {
        self.rot.reorthonormalize();
    }

    /// Expose state tensors (W, λ̂, U) for the PJRT backend.
    pub fn state(&self) -> (&Mat, &[f32], &Mat) {
        (
            self.gha.subspace(),
            self.gha.variances(),
            self.rot.separation_matrix(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::whiteness_error;
    use crate::rng::{Pcg64, RngExt};

    fn correlated(samples: usize, dim: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed(seed);
        // Low-rank structure + noise.
        let mut data = Vec::with_capacity(samples * dim);
        for _ in 0..samples {
            let a = rng.next_gaussian() as f32 * 2.0;
            let b = (rng.next_f32() * 2.0 - 1.0) * 3.0; // sub-Gaussian
            for j in 0..dim {
                let s = a * ((j as f32 * 0.7).sin()) + b * ((j as f32 * 0.3).cos());
                data.push(s + 0.2 * rng.next_gaussian() as f32);
            }
        }
        Mat::from_vec(samples, dim, data)
    }

    #[test]
    fn outputs_whiten_and_rotate() {
        let x = correlated(5000, 10, 81);
        let mut unit = DrUnit::new(DrUnitConfig {
            input_dim: 10,
            output_dim: 3,
            ..Default::default()
        });
        for _ in 0..6 {
            unit.step_rows(&x);
        }
        let y = Mat::from_fn(x.rows_count(), 3, |i, j| unit.transform(x.row(i))[j]);
        let w = whiteness_error(&y);
        assert!(w < 0.25, "whiteness after whiten+rotate: {w}");
    }

    #[test]
    fn effective_matrix_matches_transform() {
        let x = correlated(2000, 8, 82);
        let mut unit = DrUnit::new(DrUnitConfig {
            input_dim: 8,
            output_dim: 4,
            ..Default::default()
        });
        unit.step_rows(&x);
        let eff = unit.effective_matrix();
        for i in 0..10 {
            let direct = unit.transform(x.row(i));
            let via = eff.matvec(x.row(i));
            for (a, b) in direct.iter().zip(&via) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn mux_toggle_preserves_state() {
        let x = correlated(1000, 8, 83);
        let mut unit = DrUnit::new(DrUnitConfig {
            input_dim: 8,
            output_dim: 4,
            ..Default::default()
        });
        unit.step_rows(&x);
        let w_before = unit.whitener().subspace().clone();
        unit.set_rotation(false);
        assert!(!unit.rotation_enabled());
        // Whitening-only transform now ignores U but W is untouched.
        assert_eq!(unit.whitener().subspace().as_slice(), w_before.as_slice());
        let z = unit.transform(x.row(0));
        assert_eq!(z.len(), 4);
        unit.set_rotation(true);
        assert!(unit.rotation_enabled());
    }

    #[test]
    fn whiten_only_mode_skips_rotation_updates() {
        let x = correlated(1000, 8, 84);
        let mut unit = DrUnit::new(DrUnitConfig {
            input_dim: 8,
            output_dim: 4,
            rotate: false,
            ..Default::default()
        });
        let u_before = unit.rotation().separation_matrix().clone();
        unit.step_rows(&x);
        assert_eq!(
            unit.rotation().separation_matrix().as_slice(),
            u_before.as_slice(),
            "rotation must stay frozen with the mux off"
        );
    }

    #[test]
    fn restored_unit_does_not_rerun_rotation_warmup() {
        // Regression for the set_state steps bug: a unit restored from
        // a post-warm-up checkpoint must keep training its rotation
        // immediately, not sit behind the warm-up gate again.
        let x = correlated(3000, 8, 86);
        let cfg = DrUnitConfig {
            input_dim: 8,
            output_dim: 4,
            rot_warmup: 2000,
            ..Default::default()
        };
        let mut unit = DrUnit::new(cfg.clone());
        unit.step_rows(&x); // 3000 samples: warm-up done, rotation live
        let (w, var, u) = unit.state();
        let (w, var, u) = (w.clone(), var.to_vec(), u.clone());
        let steps = unit.whitener().steps();
        assert!(steps > cfg.rot_warmup);

        let mut restored = DrUnit::new(cfg);
        restored.set_state(w, var, u, steps);
        assert_eq!(restored.whitener().steps(), steps);
        let u_before = restored.rotation().separation_matrix().clone();
        let probe = correlated(300, 8, 87);
        restored.step_rows(&probe);
        let moved: f32 = restored
            .rotation()
            .separation_matrix()
            .as_slice()
            .iter()
            .zip(u_before.as_slice())
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(
            moved > 0.0,
            "restored rotation stayed frozen — warm-up gate re-ran"
        );
    }

    #[test]
    fn deterministic() {
        let x = correlated(500, 8, 85);
        let run = || {
            let mut u = DrUnit::new(DrUnitConfig {
                input_dim: 8,
                output_dim: 4,
                ..Default::default()
            });
            u.step_rows(&x);
            u.effective_matrix()
        };
        assert_eq!(run().as_slice(), run().as_slice());
    }
}
