//! Composed dimensionality-reduction pipelines — the paper's §IV
//! proposal as a first-class API.
//!
//! A [`DrPipeline`] is a fitted [`crate::stage::StageGraph`]: the
//! legacy declarative surface ([`PipelineSpec`] — an optional RP front
//! end plus one [`StageSpec`]) maps onto a stage list
//! ([`PipelineSpec::to_graph_spec`]) and both numeric domains run the
//! same graph — f32 and bit-accurate fixed point are two *backends* of
//! one pipeline, not two pipelines. The paper's proposed configuration
//! is the graph `rp:ternary/p → whiten:gha → rot:easi`; the baselines
//! of Table I and Fig. 1 are other graphs in the same space, which is
//! exactly the reconfigurability story of §IV.
//!
//! Arbitrary cascades beyond the legacy forms (e.g. `rp → pca`,
//! `dct → whiten → rot`, a whiten-only fixed-point datapath) are built
//! directly from a [`crate::stage::GraphSpec`] / the `--stages` CLI
//! syntax — see [`crate::stage::spec`].

pub mod unit;

pub use unit::{DrUnit, DrUnitConfig};

use crate::datasets::Dataset;
use crate::easi::EasiMode;
use crate::fxp::Precision;
use crate::linalg::Mat;
use crate::rp::{RandomProjection, RpDistribution};
use crate::stage::{GraphSpec, StageDecl, StageGraph, StageOp};

/// Declarative pipeline specification (maps 1:1 onto the CLI / TOML
/// config and onto AOT artifact variants). The legacy two-slot form;
/// [`PipelineSpec::to_graph_spec`] is the bridge to the composable
/// stage-graph representation.
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    /// Input dimensionality `m`.
    pub input_dim: usize,
    /// Optional RP front end: `(intermediate_dim, distribution)`.
    pub rp: Option<RpStage>,
    /// The trained / fixed second stage.
    pub stage: StageSpec,
    /// Output dimensionality `n`.
    pub output_dim: usize,
    /// Seed for all randomness (R matrix, init).
    pub seed: u64,
    /// Arithmetic the fitted pipeline computes in. [`Precision::Fixed`]
    /// runs the bit-accurate quantized kernels ([`crate::fxp`]) for the
    /// streaming stages (RP, rotation-only EASI, the composed ICA
    /// unit); batch stages (PCA) have no streaming datapath and reject
    /// fixed precision.
    pub precision: Precision,
}

/// RP front-end declaration.
#[derive(Debug, Clone, Copy)]
pub struct RpStage {
    pub intermediate_dim: usize,
    pub distribution: RpDistribution,
}

/// Second-stage declaration.
#[derive(Debug, Clone, Copy)]
pub enum StageSpec {
    /// Adaptive EASI with the given mode and learning rate — the
    /// paper-literal rectangular Eq. 6 datapath. NOTE: its row space is
    /// frozen at init (see crate::gha docs); prefer [`StageSpec::Ica`]
    /// for an actually-learning reduction stage.
    Easi { mode: EasiMode, mu: f32, epochs: usize },
    /// The composed GHA-whitening + EASI-rotation unit (production
    /// pipeline; the graph stages `whiten:gha → rot:easi`).
    Ica { mu_w: f32, mu_rot: f32, epochs: usize },
    /// Batch PCA projection (no whitening).
    Pca,
    /// Batch PCA whitening.
    PcaWhiten,
    /// Fixed 1-D DCT truncation ("bilinear transform" baseline).
    Dct,
    /// No second stage: RP only (requires `rp` so dims still reduce).
    Identity,
}

impl PipelineSpec {
    /// The paper's proposed configuration: ternary RP to `p`, then
    /// rotation-only EASI to `n`.
    pub fn proposed(m: usize, p: usize, n: usize, mu: f32, epochs: usize, seed: u64) -> Self {
        Self {
            input_dim: m,
            rp: Some(RpStage {
                intermediate_dim: p,
                distribution: RpDistribution::Ternary,
            }),
            stage: StageSpec::Easi {
                mode: EasiMode::RotationOnly,
                mu,
                epochs,
            },
            output_dim: n,
            seed,
            precision: Precision::F32,
        }
    }

    /// Baseline: full EASI straight from `m` to `n` (Table I rows 1, 3).
    pub fn easi_only(m: usize, n: usize, mu: f32, epochs: usize, seed: u64) -> Self {
        Self {
            input_dim: m,
            rp: None,
            stage: StageSpec::Easi {
                mode: EasiMode::Full,
                mu,
                epochs,
            },
            output_dim: n,
            seed,
            precision: Precision::F32,
        }
    }

    /// The dimensionality the trained stage consumes.
    pub fn stage_input_dim(&self) -> usize {
        self.rp.map_or(self.input_dim, |r| r.intermediate_dim)
    }

    /// The same pipeline at another precision.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// The golden mapping: every legacy `StageSpec` form as a stage
    /// list (so one graph builder serves both numeric domains). The
    /// resulting graph is bit-identical to the pre-graph fused datapath
    /// — enforced by `tests/stage_graph_identity.rs`.
    pub fn to_graph_spec(&self) -> GraphSpec {
        let mut stages = Vec::new();
        if let Some(r) = self.rp {
            stages.push(StageDecl::new(StageOp::Rp(r.distribution)).with_dim(r.intermediate_dim));
        }
        let (mu_w, mu_rot, epochs) = match self.stage {
            StageSpec::Easi { mu, epochs, .. } => (5e-3, mu, epochs),
            StageSpec::Ica { mu_w, mu_rot, epochs } => (mu_w, mu_rot, epochs),
            _ => (5e-3, 1e-3, 1),
        };
        match self.stage {
            StageSpec::Easi { mode, .. } => stages.push(StageDecl::new(StageOp::Easi(mode))),
            StageSpec::Ica { .. } => {
                stages.push(StageDecl::new(StageOp::WhitenGha));
                stages.push(StageDecl::new(StageOp::RotEasi));
            }
            StageSpec::Pca => stages.push(StageDecl::new(StageOp::Pca { whiten: false })),
            StageSpec::PcaWhiten => stages.push(StageDecl::new(StageOp::Pca { whiten: true })),
            StageSpec::Dct => stages.push(StageDecl::new(StageOp::Dct)),
            StageSpec::Identity => stages.push(StageDecl::new(StageOp::Identity)),
        }
        GraphSpec {
            input_dim: self.input_dim,
            output_dim: self.output_dim,
            stages,
            seed: self.seed,
            precision: self.precision,
            mu_w,
            mu_rot,
            rot_warmup: None,
            epochs,
        }
    }
}

/// A fitted pipeline, ready to transform samples — a thin façade over
/// the fitted [`StageGraph`].
pub struct DrPipeline {
    pub spec: PipelineSpec,
    graph: StageGraph,
}

impl DrPipeline {
    /// Fit the pipeline on training data (rows are samples). The DR
    /// model trains unsupervised, as in the paper's §V.B protocol.
    ///
    /// With [`Precision::Fixed`], the streaming stages train and run
    /// bit-accurately in fixed point (quantized RP network, quantized
    /// update kernels); panics for batch stages (PCA), which have no
    /// streaming datapath to quantize.
    pub fn fit(spec: PipelineSpec, train_x: &Mat) -> Self {
        assert_eq!(train_x.cols_count(), spec.input_dim, "input dim mismatch");
        let gspec = spec.to_graph_spec();
        let mut graph = match gspec.build(Some(train_x.rows_count())) {
            Ok(g) => g,
            Err(e) => panic!("{e}"),
        };
        graph.fit(train_x, gspec.epochs);
        Self { spec, graph }
    }

    /// Transform one sample `m → n`.
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        self.graph.transform(x)
    }

    /// Transform every row of a sample matrix. Fixed-precision
    /// pipelines run the whole matrix as one tile through the quantized
    /// datapath (bit-identical to per-sample [`DrPipeline::transform`],
    /// without the per-sample staging vectors).
    pub fn transform_rows(&self, x: &Mat) -> Mat {
        self.graph.transform_rows(x)
    }

    /// Map an entire dataset through the pipeline (used before training
    /// the downstream classifier).
    pub fn transform_dataset(&self, d: &Dataset) -> Dataset {
        Dataset {
            name: format!("{}+dr{}", d.name, self.spec.output_dim),
            train_x: self.transform_rows(&d.train_x),
            train_y: d.train_y.clone(),
            test_x: self.transform_rows(&d.test_x),
            test_y: d.test_y.clone(),
            num_classes: d.num_classes,
        }
    }

    /// The fitted stage graph (per-stage access, checkpointing).
    pub fn graph(&self) -> &StageGraph {
        &self.graph
    }

    /// The RP front end, if any.
    pub fn rp(&self) -> Option<&RandomProjection> {
        self.graph.random_projection()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pca::BatchPca;
    use crate::rng::{Pcg64, RngExt};

    fn gaussian_data(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed(seed);
        Mat::from_fn(n, d, |_, _| rng.next_gaussian() as f32)
    }

    #[test]
    fn proposed_pipeline_shapes() {
        let x = gaussian_data(500, 32, 71);
        let spec = PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7);
        let p = DrPipeline::fit(spec, &x);
        assert_eq!(p.transform(x.row(0)).len(), 8);
        assert_eq!(p.transform_rows(&x).shape(), (500, 8));
    }

    #[test]
    fn easi_only_pipeline_shapes() {
        let x = gaussian_data(500, 32, 72);
        let p = DrPipeline::fit(PipelineSpec::easi_only(32, 16, 1e-3, 1, 7), &x);
        assert_eq!(p.transform_rows(&x).shape(), (500, 16));
    }

    #[test]
    fn pca_stage_matches_direct_batch_pca() {
        let x = gaussian_data(300, 10, 73);
        let spec = PipelineSpec {
            input_dim: 10,
            rp: None,
            stage: StageSpec::Pca,
            output_dim: 3,
            seed: 1,
            precision: Precision::F32,
        };
        let p = DrPipeline::fit(spec, &x);
        let direct = BatchPca::fit(&x, 3);
        let a = p.transform(x.row(0));
        let b = direct.transform(x.row(0));
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn identity_requires_matching_dims() {
        let x = gaussian_data(50, 16, 74);
        let spec = PipelineSpec {
            input_dim: 16,
            rp: Some(RpStage {
                intermediate_dim: 8,
                distribution: RpDistribution::Ternary,
            }),
            stage: StageSpec::Identity,
            output_dim: 8,
            seed: 1,
            precision: Precision::F32,
        };
        let p = DrPipeline::fit(spec, &x);
        assert_eq!(p.transform_rows(&x).shape(), (50, 8));
    }

    #[test]
    fn transform_dataset_preserves_labels() {
        use crate::datasets::waveform::WaveformConfig;
        let d = WaveformConfig {
            samples: 300,
            train: 200,
            ..WaveformConfig::paper()
        }
        .generate();
        let p = DrPipeline::fit(PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7), &d.train_x);
        let t = p.transform_dataset(&d);
        assert_eq!(t.train_y, d.train_y);
        assert_eq!(t.input_dim(), 8);
        t.validate().unwrap();
    }

    #[test]
    fn deterministic_fit() {
        let x = gaussian_data(200, 32, 75);
        let run = || {
            let p = DrPipeline::fit(PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7), &x);
            p.transform(x.row(0))
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn legacy_specs_map_onto_stage_lists() {
        // The golden mapping, shape level: every legacy StageSpec
        // variant produces the expected stage list (bit-identity of the
        // built graphs is enforced in tests/stage_graph_identity.rs).
        let base = PipelineSpec::proposed(32, 16, 8, 1e-3, 2, 7);
        assert_eq!(base.to_graph_spec().stages_label(), "rp:ternary/16,easi:rot");
        let ica = PipelineSpec {
            stage: StageSpec::Ica {
                mu_w: 5e-3,
                mu_rot: 1e-3,
                epochs: 2,
            },
            ..base.clone()
        };
        assert_eq!(
            ica.to_graph_spec().stages_label(),
            "rp:ternary/16,whiten:gha,rot:easi"
        );
        let easi = PipelineSpec::easi_only(32, 16, 1e-3, 1, 7);
        assert_eq!(easi.to_graph_spec().stages_label(), "easi:full");
        for (stage, want) in [
            (StageSpec::Pca, "rp:ternary/16,pca"),
            (StageSpec::PcaWhiten, "rp:ternary/16,pca:whiten"),
            (StageSpec::Dct, "rp:ternary/16,dct"),
            (StageSpec::Identity, "rp:ternary/16,identity"),
        ] {
            let spec = PipelineSpec {
                stage,
                ..base.clone()
            };
            assert_eq!(spec.to_graph_spec().stages_label(), want);
        }
    }

    #[test]
    fn fixed_precision_proposed_pipeline_tracks_f32() {
        // The paper's proposed RP→rotation-only-EASI configuration at
        // 16-bit Q4.12: shapes right, outputs finite, and close to the
        // f32 pipeline (same seed, same data). Documented tolerance:
        // 0.15 absolute on ~unit-scale outputs after one epoch.
        let x = gaussian_data(600, 32, 76);
        let f32_p = DrPipeline::fit(PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7), &x);
        let fx_p = DrPipeline::fit(
            PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7)
                .with_precision(Precision::parse("q4.12").unwrap()),
            &x,
        );
        let y_fx = fx_p.transform_rows(&x);
        assert_eq!(y_fx.shape(), (600, 8));
        assert!(y_fx.as_slice().iter().all(|v| v.is_finite()));
        let y_f32 = f32_p.transform_rows(&x);
        let mut worst = 0.0f32;
        let mut mean = 0.0f64;
        for (a, b) in y_fx.as_slice().iter().zip(y_f32.as_slice()) {
            worst = worst.max((a - b).abs());
            mean += (a - b).abs() as f64;
        }
        mean /= y_fx.as_slice().len() as f64;
        // The f32 trainer additionally normalises/clips (guards the
        // hardware datapath doesn't have) and skips the periodic
        // retraction, so the trajectories drift — the fitted maps must
        // still largely agree on ~unit-scale outputs.
        assert!(mean < 0.25, "fixed vs f32 outputs diverged: mean {mean}");
        assert!(worst < 1.5, "fixed vs f32 outputs diverged: worst {worst}");
    }

    #[test]
    fn fixed_precision_identity_rp_pipeline() {
        let x = gaussian_data(50, 16, 77);
        let spec = PipelineSpec {
            input_dim: 16,
            rp: Some(RpStage {
                intermediate_dim: 8,
                distribution: RpDistribution::Ternary,
            }),
            stage: StageSpec::Identity,
            output_dim: 8,
            seed: 1,
            precision: Precision::parse("q8.16").unwrap(),
        };
        let p = DrPipeline::fit(spec.clone(), &x);
        let y = p.transform_rows(&x);
        assert_eq!(y.shape(), (50, 8));
        // Ternary RP (scale 1, ≥4 integer bits so no prescale): the
        // quantized network agrees with f32 to input-quantization error.
        let f32_p = DrPipeline::fit(spec.with_precision(Precision::F32), &x);
        let y32 = f32_p.transform_rows(&x);
        for (a, b) in y.as_slice().iter().zip(y32.as_slice()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn mixed_precision_ste_pipeline_tracks_f32() {
        // The acceptance plan: wide RP accumulator, 16-bit whiten and
        // rotation, STE-trained. Must produce finite outputs close to
        // the f32 pipeline, like the uniform q4.12 test above.
        let x = gaussian_data(600, 32, 79);
        let f32_p = DrPipeline::fit(PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7), &x);
        let plan = Precision::parse("rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste").unwrap();
        let fx_p = DrPipeline::fit(
            PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7).with_precision(plan),
            &x,
        );
        let y_fx = fx_p.transform_rows(&x);
        assert_eq!(y_fx.shape(), (600, 8));
        assert!(y_fx.as_slice().iter().all(|v| v.is_finite()));
        let y_f32 = f32_p.transform_rows(&x);
        let mut mean = 0.0f64;
        for (a, b) in y_fx.as_slice().iter().zip(y_f32.as_slice()) {
            mean += (a - b).abs() as f64;
        }
        mean /= y_fx.as_slice().len() as f64;
        assert!(mean < 0.25, "mixed STE vs f32 outputs diverged: mean {mean}");
    }

    #[test]
    fn mixed_precision_narrow_rotation_stays_finite() {
        // Narrow rotation behind a wide whitener: the σ target drops to
        // fit q1.15 and every boundary requantizes; outputs must stay
        // finite and on the rotation format's grid.
        let x = gaussian_data(500, 32, 80);
        let plan = Precision::parse("rp=q8.16,whiten=q8.16,rot=q1.15,qat=ste").unwrap();
        let p = DrPipeline::fit(
            PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7).with_precision(plan),
            &x,
        );
        let y = p.transform_rows(&x);
        assert_eq!(y.shape(), (500, 8));
        let rot = plan.plan().unwrap().rot;
        for &v in y.as_slice() {
            assert!(v.is_finite());
            let q = rot.dequantize(rot.quantize(v));
            assert!((v - q).abs() < 1e-9, "output off the rot grid: {v}");
        }
    }

    #[test]
    fn fixed_transform_rows_matches_per_sample_transform() {
        // The tiled bulk path must be bit-identical to per-sample
        // transform (same raw words, so exactly equal f32 outputs) —
        // for both uniform and mixed plans.
        let x = gaussian_data(300, 32, 91);
        for plan in ["q4.12", "rp=q8.16,whiten=q4.12,rot=q1.15"] {
            let p = DrPipeline::fit(
                PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7)
                    .with_precision(Precision::parse(plan).unwrap()),
                &x,
            );
            let tiled = p.transform_rows(&x);
            for i in 0..x.rows_count() {
                assert_eq!(
                    tiled.row(i),
                    p.transform(x.row(i)).as_slice(),
                    "row {i} diverged under plan {plan}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "fixed-point precision supports the streaming stages")]
    fn fixed_precision_rejects_batch_stages() {
        let x = gaussian_data(50, 8, 78);
        let spec = PipelineSpec {
            input_dim: 8,
            rp: None,
            stage: StageSpec::Pca,
            output_dim: 4,
            seed: 1,
            precision: Precision::parse("q4.12").unwrap(),
        };
        DrPipeline::fit(spec, &x);
    }
}
