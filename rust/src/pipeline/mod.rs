//! Composed dimensionality-reduction pipelines — the paper's §IV
//! proposal as a first-class API.
//!
//! A [`DrPipeline`] is an optional random-projection front end followed
//! by an optional trained stage (EASI in one of its modes, or batch
//! PCA, or a fixed DCT). The paper's proposed configuration is
//! `Rp → Easi(RotationOnly)`; the baselines of Table I and Fig. 1 are
//! other points in the same space, which is exactly the
//! reconfigurability story of §IV.

pub mod unit;

pub use unit::{DrUnit, DrUnitConfig};

use crate::datasets::Dataset;
use crate::easi::{EasiConfig, EasiMode, EasiTrainer};
use crate::linalg::Mat;
use crate::pca::dct::Dct1d;
use crate::pca::BatchPca;
use crate::rp::{RandomProjection, RpDistribution};

/// Declarative pipeline specification (maps 1:1 onto the CLI / TOML
/// config and onto AOT artifact variants).
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    /// Input dimensionality `m`.
    pub input_dim: usize,
    /// Optional RP front end: `(intermediate_dim, distribution)`.
    pub rp: Option<RpStage>,
    /// The trained / fixed second stage.
    pub stage: StageSpec,
    /// Output dimensionality `n`.
    pub output_dim: usize,
    /// Seed for all randomness (R matrix, init).
    pub seed: u64,
}

/// RP front-end declaration.
#[derive(Debug, Clone, Copy)]
pub struct RpStage {
    pub intermediate_dim: usize,
    pub distribution: RpDistribution,
}

/// Second-stage declaration.
#[derive(Debug, Clone, Copy)]
pub enum StageSpec {
    /// Adaptive EASI with the given mode and learning rate — the
    /// paper-literal rectangular Eq. 6 datapath. NOTE: its row space is
    /// frozen at init (see crate::gha docs); prefer [`StageSpec::Ica`]
    /// for an actually-learning reduction stage.
    Easi { mode: EasiMode, mu: f32, epochs: usize },
    /// The composed GHA-whitening + EASI-rotation unit (production
    /// pipeline; see pipeline::unit).
    Ica { mu_w: f32, mu_rot: f32, epochs: usize },
    /// Batch PCA projection (no whitening).
    Pca,
    /// Batch PCA whitening.
    PcaWhiten,
    /// Fixed 1-D DCT truncation ("bilinear transform" baseline).
    Dct,
    /// No second stage: RP only (requires `rp` so dims still reduce).
    Identity,
}

impl PipelineSpec {
    /// The paper's proposed configuration: ternary RP to `p`, then
    /// rotation-only EASI to `n`.
    pub fn proposed(m: usize, p: usize, n: usize, mu: f32, epochs: usize, seed: u64) -> Self {
        Self {
            input_dim: m,
            rp: Some(RpStage {
                intermediate_dim: p,
                distribution: RpDistribution::Ternary,
            }),
            stage: StageSpec::Easi {
                mode: EasiMode::RotationOnly,
                mu,
                epochs,
            },
            output_dim: n,
            seed,
        }
    }

    /// Baseline: full EASI straight from `m` to `n` (Table I rows 1, 3).
    pub fn easi_only(m: usize, n: usize, mu: f32, epochs: usize, seed: u64) -> Self {
        Self {
            input_dim: m,
            rp: None,
            stage: StageSpec::Easi {
                mode: EasiMode::Full,
                mu,
                epochs,
            },
            output_dim: n,
            seed,
        }
    }

    /// The dimensionality the trained stage consumes.
    pub fn stage_input_dim(&self) -> usize {
        self.rp.map_or(self.input_dim, |r| r.intermediate_dim)
    }
}

/// A fitted pipeline, ready to transform samples.
pub struct DrPipeline {
    pub spec: PipelineSpec,
    rp: Option<RandomProjection>,
    stage: FittedStage,
}

enum FittedStage {
    Easi(EasiTrainer),
    Unit(unit::DrUnit),
    Pca(BatchPca, /*whiten=*/ bool),
    Dct(Dct1d),
    Identity,
}

impl DrPipeline {
    /// Fit the pipeline on training data (rows are samples). The DR
    /// model trains unsupervised, as in the paper's §V.B protocol.
    pub fn fit(spec: PipelineSpec, train_x: &Mat) -> Self {
        assert_eq!(train_x.cols_count(), spec.input_dim, "input dim mismatch");
        let rp = spec.rp.map(|r| {
            let proj = RandomProjection::new(
                spec.input_dim,
                r.intermediate_dim,
                r.distribution,
                spec.seed,
            );
            // Adaptive stages assume unit-variance inputs; fixed stages
            // get the raw distance-preserving projection.
            if matches!(spec.stage, StageSpec::Easi { .. } | StageSpec::Ica { .. }) {
                proj.unit_variance()
            } else {
                proj
            }
        });
        // Materialise the (possibly projected) training view for the
        // second stage.
        let staged: Mat = match &rp {
            Some(proj) => proj.apply_rows(train_x),
            None => train_x.clone(),
        };
        let stage = match spec.stage {
            StageSpec::Easi { mode, mu, epochs } => {
                let mut t = EasiTrainer::new(EasiConfig {
                    input_dim: spec.stage_input_dim(),
                    output_dim: spec.output_dim,
                    mu,
                    mode,
                    normalized: true,
                    max_norm: if mode == EasiMode::RotationOnly {
                        4.0 * (spec.output_dim as f32).sqrt()
                    } else {
                        1e4
                    },
                    clip: 0.05,
                    random_init: Some(spec.seed),
                });
                for _ in 0..epochs.max(1) {
                    t.step_rows(&staged);
                }
                FittedStage::Easi(t)
            }
            StageSpec::Ica { mu_w, mu_rot, epochs } => {
                let mut u = unit::DrUnit::new(unit::DrUnitConfig {
                    input_dim: spec.stage_input_dim(),
                    output_dim: spec.output_dim,
                    mu_w,
                    mu_rot,
                    rotate: true,
                    rot_warmup: (staged.rows_count() / 2).min(2000) as u64,
                    seed: spec.seed,
                });
                for _ in 0..epochs.max(1) {
                    u.step_rows(&staged);
                }
                FittedStage::Unit(u)
            }
            StageSpec::Pca => FittedStage::Pca(BatchPca::fit(&staged, spec.output_dim), false),
            StageSpec::PcaWhiten => {
                FittedStage::Pca(BatchPca::fit(&staged, spec.output_dim), true)
            }
            StageSpec::Dct => FittedStage::Dct(Dct1d::new(spec.stage_input_dim(), spec.output_dim)),
            StageSpec::Identity => {
                assert_eq!(
                    spec.stage_input_dim(),
                    spec.output_dim,
                    "Identity stage requires RP to land on output_dim"
                );
                FittedStage::Identity
            }
        };
        Self { spec, rp, stage }
    }

    /// Transform one sample `m → n`.
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        let staged: Vec<f32> = match &self.rp {
            Some(proj) => proj.apply(x),
            None => x.to_vec(),
        };
        match &self.stage {
            FittedStage::Easi(t) => t.transform(&staged),
            FittedStage::Unit(u) => u.transform(&staged),
            FittedStage::Pca(p, false) => p.transform(&staged),
            FittedStage::Pca(p, true) => p.whiten(&staged),
            FittedStage::Dct(d) => d.transform(&staged),
            FittedStage::Identity => staged,
        }
    }

    /// Transform every row of a sample matrix.
    pub fn transform_rows(&self, x: &Mat) -> Mat {
        let rows = x.rows_count();
        let mut out = Vec::with_capacity(rows * self.spec.output_dim);
        for r in x.rows() {
            out.extend(self.transform(r));
        }
        Mat::from_vec(rows, self.spec.output_dim, out)
    }

    /// Map an entire dataset through the pipeline (used before training
    /// the downstream classifier).
    pub fn transform_dataset(&self, d: &Dataset) -> Dataset {
        Dataset {
            name: format!("{}+dr{}", d.name, self.spec.output_dim),
            train_x: self.transform_rows(&d.train_x),
            train_y: d.train_y.clone(),
            test_x: self.transform_rows(&d.test_x),
            test_y: d.test_y.clone(),
            num_classes: d.num_classes,
        }
    }

    /// Access the fitted EASI trainer (None for non-EASI stages) — used
    /// by the coordinator for checkpointing and by tests.
    pub fn easi(&self) -> Option<&EasiTrainer> {
        match &self.stage {
            FittedStage::Easi(t) => Some(t),
            _ => None,
        }
    }

    /// The RP front end, if any.
    pub fn rp(&self) -> Option<&RandomProjection> {
        self.rp.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, RngExt};

    fn gaussian_data(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed(seed);
        Mat::from_fn(n, d, |_, _| rng.next_gaussian() as f32)
    }

    #[test]
    fn proposed_pipeline_shapes() {
        let x = gaussian_data(500, 32, 71);
        let spec = PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7);
        let p = DrPipeline::fit(spec, &x);
        assert_eq!(p.transform(x.row(0)).len(), 8);
        assert_eq!(p.transform_rows(&x).shape(), (500, 8));
    }

    #[test]
    fn easi_only_pipeline_shapes() {
        let x = gaussian_data(500, 32, 72);
        let p = DrPipeline::fit(PipelineSpec::easi_only(32, 16, 1e-3, 1, 7), &x);
        assert_eq!(p.transform_rows(&x).shape(), (500, 16));
    }

    #[test]
    fn pca_stage_matches_direct_batch_pca() {
        let x = gaussian_data(300, 10, 73);
        let spec = PipelineSpec {
            input_dim: 10,
            rp: None,
            stage: StageSpec::Pca,
            output_dim: 3,
            seed: 1,
        };
        let p = DrPipeline::fit(spec, &x);
        let direct = BatchPca::fit(&x, 3);
        let a = p.transform(x.row(0));
        let b = direct.transform(x.row(0));
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn identity_requires_matching_dims() {
        let x = gaussian_data(50, 16, 74);
        let spec = PipelineSpec {
            input_dim: 16,
            rp: Some(RpStage {
                intermediate_dim: 8,
                distribution: RpDistribution::Ternary,
            }),
            stage: StageSpec::Identity,
            output_dim: 8,
            seed: 1,
        };
        let p = DrPipeline::fit(spec, &x);
        assert_eq!(p.transform_rows(&x).shape(), (50, 8));
    }

    #[test]
    fn transform_dataset_preserves_labels() {
        use crate::datasets::waveform::WaveformConfig;
        let d = WaveformConfig {
            samples: 300,
            train: 200,
            ..WaveformConfig::paper()
        }
        .generate();
        let p = DrPipeline::fit(PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7), &d.train_x);
        let t = p.transform_dataset(&d);
        assert_eq!(t.train_y, d.train_y);
        assert_eq!(t.input_dim(), 8);
        t.validate().unwrap();
    }

    #[test]
    fn deterministic_fit() {
        let x = gaussian_data(200, 32, 75);
        let run = || {
            let p = DrPipeline::fit(PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7), &x);
            p.transform(x.row(0))
        };
        assert_eq!(run(), run());
    }
}
