//! Composed dimensionality-reduction pipelines — the paper's §IV
//! proposal as a first-class API.
//!
//! A [`DrPipeline`] is an optional random-projection front end followed
//! by an optional trained stage (EASI in one of its modes, or batch
//! PCA, or a fixed DCT). The paper's proposed configuration is
//! `Rp → Easi(RotationOnly)`; the baselines of Table I and Fig. 1 are
//! other points in the same space, which is exactly the
//! reconfigurability story of §IV.

pub mod unit;

pub use unit::{DrUnit, DrUnitConfig};

use crate::datasets::Dataset;
use crate::easi::{EasiConfig, EasiMode, EasiTrainer};
use crate::fxp::{self, FxpEasiRot, FxpRp, FxpSpec, Precision, PrecisionPlan, Scratch};
use crate::linalg::Mat;
use crate::pca::dct::Dct1d;
use crate::pca::BatchPca;
use crate::rp::{RandomProjection, RpDistribution};

/// Declarative pipeline specification (maps 1:1 onto the CLI / TOML
/// config and onto AOT artifact variants).
#[derive(Debug, Clone)]
pub struct PipelineSpec {
    /// Input dimensionality `m`.
    pub input_dim: usize,
    /// Optional RP front end: `(intermediate_dim, distribution)`.
    pub rp: Option<RpStage>,
    /// The trained / fixed second stage.
    pub stage: StageSpec,
    /// Output dimensionality `n`.
    pub output_dim: usize,
    /// Seed for all randomness (R matrix, init).
    pub seed: u64,
    /// Arithmetic the fitted pipeline computes in. [`Precision::Fixed`]
    /// runs the bit-accurate quantized kernels ([`crate::fxp`]) for the
    /// streaming stages (RP, rotation-only EASI, the composed ICA
    /// unit); batch/fixed stages (PCA, DCT) have no streaming datapath
    /// and reject fixed precision.
    pub precision: Precision,
}

/// RP front-end declaration.
#[derive(Debug, Clone, Copy)]
pub struct RpStage {
    pub intermediate_dim: usize,
    pub distribution: RpDistribution,
}

/// Second-stage declaration.
#[derive(Debug, Clone, Copy)]
pub enum StageSpec {
    /// Adaptive EASI with the given mode and learning rate — the
    /// paper-literal rectangular Eq. 6 datapath. NOTE: its row space is
    /// frozen at init (see crate::gha docs); prefer [`StageSpec::Ica`]
    /// for an actually-learning reduction stage.
    Easi { mode: EasiMode, mu: f32, epochs: usize },
    /// The composed GHA-whitening + EASI-rotation unit (production
    /// pipeline; see pipeline::unit).
    Ica { mu_w: f32, mu_rot: f32, epochs: usize },
    /// Batch PCA projection (no whitening).
    Pca,
    /// Batch PCA whitening.
    PcaWhiten,
    /// Fixed 1-D DCT truncation ("bilinear transform" baseline).
    Dct,
    /// No second stage: RP only (requires `rp` so dims still reduce).
    Identity,
}

impl PipelineSpec {
    /// The paper's proposed configuration: ternary RP to `p`, then
    /// rotation-only EASI to `n`.
    pub fn proposed(m: usize, p: usize, n: usize, mu: f32, epochs: usize, seed: u64) -> Self {
        Self {
            input_dim: m,
            rp: Some(RpStage {
                intermediate_dim: p,
                distribution: RpDistribution::Ternary,
            }),
            stage: StageSpec::Easi {
                mode: EasiMode::RotationOnly,
                mu,
                epochs,
            },
            output_dim: n,
            seed,
            precision: Precision::F32,
        }
    }

    /// Baseline: full EASI straight from `m` to `n` (Table I rows 1, 3).
    pub fn easi_only(m: usize, n: usize, mu: f32, epochs: usize, seed: u64) -> Self {
        Self {
            input_dim: m,
            rp: None,
            stage: StageSpec::Easi {
                mode: EasiMode::Full,
                mu,
                epochs,
            },
            output_dim: n,
            seed,
            precision: Precision::F32,
        }
    }

    /// The dimensionality the trained stage consumes.
    pub fn stage_input_dim(&self) -> usize {
        self.rp.map_or(self.input_dim, |r| r.intermediate_dim)
    }

    /// The same pipeline at another precision.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Build the RP front end this spec declares (None without one).
    /// Single source of the unit-variance policy: adaptive stages
    /// assume unit-variance inputs, fixed stages get the raw
    /// distance-preserving projection. Shared by the f32 and
    /// fixed-precision fit paths so they always project identically.
    fn build_front_end(&self) -> Option<RandomProjection> {
        self.rp.map(|r| {
            let proj = RandomProjection::new(
                self.input_dim,
                r.intermediate_dim,
                r.distribution,
                self.seed,
            );
            if matches!(self.stage, StageSpec::Easi { .. } | StageSpec::Ica { .. }) {
                proj.unit_variance()
            } else {
                proj
            }
        })
    }
}

/// Entry/exit arithmetic of a fitted fixed-point pipeline — which
/// format samples are quantized into, the power-of-two prescale applied
/// first, the trained stage's input format (the RP→stage boundary
/// requantizes), and the output format to dequantize from. For uniform
/// plans all four specs coincide and every boundary is a no-op.
#[derive(Debug, Clone, Copy)]
struct FxpIo {
    entry: FxpSpec,
    prescale: f32,
    stage_in: FxpSpec,
    output: FxpSpec,
}

/// Prescale + quantize one sample into a fixed-point pipeline's input
/// domain (the entry-point arithmetic shared by fit and transform).
fn quantize_prescaled(fspec: &FxpSpec, prescale: f32, x: &[f32]) -> Vec<i32> {
    x.iter().map(|&v| fspec.quantize(v * prescale)).collect()
}

/// A fitted pipeline, ready to transform samples.
pub struct DrPipeline {
    pub spec: PipelineSpec,
    rp: Option<RandomProjection>,
    /// Quantized image of `rp` for fixed-precision pipelines.
    fxp_rp: Option<FxpRp>,
    /// Boundary arithmetic for fixed-precision pipelines.
    fxp_io: Option<FxpIo>,
    stage: FittedStage,
}

enum FittedStage {
    Easi(EasiTrainer),
    Unit(unit::DrUnit),
    /// Quantized rotation-only EASI (fixed precision).
    FxpEasi(FxpEasiRot),
    /// Quantized composed whiten+rotate unit (fixed precision).
    FxpUnit(fxp::FxpDrUnit),
    Pca(BatchPca, /*whiten=*/ bool),
    Dct(Dct1d),
    Identity,
}

impl DrPipeline {
    /// Fit the pipeline on training data (rows are samples). The DR
    /// model trains unsupervised, as in the paper's §V.B protocol.
    ///
    /// With [`Precision::Fixed`], the streaming stages train and run
    /// bit-accurately in fixed point (quantized RP network, quantized
    /// update kernels); panics for batch stages (PCA/DCT), which have
    /// no streaming datapath to quantize.
    pub fn fit(spec: PipelineSpec, train_x: &Mat) -> Self {
        assert_eq!(train_x.cols_count(), spec.input_dim, "input dim mismatch");
        if let Precision::Fixed(plan) = spec.precision {
            return Self::fit_fixed(spec, plan, train_x);
        }
        let rp = spec.build_front_end();
        // Materialise the (possibly projected) training view for the
        // second stage.
        let staged: Mat = match &rp {
            Some(proj) => proj.apply_rows(train_x),
            None => train_x.clone(),
        };
        let stage = match spec.stage {
            StageSpec::Easi { mode, mu, epochs } => {
                let mut t = EasiTrainer::new(EasiConfig {
                    input_dim: spec.stage_input_dim(),
                    output_dim: spec.output_dim,
                    mu,
                    mode,
                    normalized: true,
                    max_norm: if mode == EasiMode::RotationOnly {
                        4.0 * (spec.output_dim as f32).sqrt()
                    } else {
                        1e4
                    },
                    clip: 0.05,
                    random_init: Some(spec.seed),
                });
                for _ in 0..epochs.max(1) {
                    t.step_rows(&staged);
                }
                FittedStage::Easi(t)
            }
            StageSpec::Ica { mu_w, mu_rot, epochs } => {
                let mut u = unit::DrUnit::new(unit::DrUnitConfig {
                    input_dim: spec.stage_input_dim(),
                    output_dim: spec.output_dim,
                    mu_w,
                    mu_rot,
                    rotate: true,
                    rot_warmup: (staged.rows_count() / 2).min(2000) as u64,
                    seed: spec.seed,
                });
                for _ in 0..epochs.max(1) {
                    u.step_rows(&staged);
                }
                FittedStage::Unit(u)
            }
            StageSpec::Pca => FittedStage::Pca(BatchPca::fit(&staged, spec.output_dim), false),
            StageSpec::PcaWhiten => {
                FittedStage::Pca(BatchPca::fit(&staged, spec.output_dim), true)
            }
            StageSpec::Dct => FittedStage::Dct(Dct1d::new(spec.stage_input_dim(), spec.output_dim)),
            StageSpec::Identity => {
                assert_eq!(
                    spec.stage_input_dim(),
                    spec.output_dim,
                    "Identity stage requires RP to land on output_dim"
                );
                FittedStage::Identity
            }
        };
        Self {
            spec,
            rp,
            fxp_rp: None,
            fxp_io: None,
            stage,
        }
    }

    /// Fixed-precision fit: quantized RP network (at the plan's RP
    /// format) feeding quantized streaming kernels (whitener/rotation
    /// at theirs), trained on the quantized view of the data. Stage
    /// boundaries requantize; uniform plans reduce exactly to the
    /// single-format datapath.
    fn fit_fixed(spec: PipelineSpec, plan: PrecisionPlan, train_x: &Mat) -> Self {
        let rp = spec.build_front_end();
        let fxp_rp = rp.as_ref().map(|p| FxpRp::from_rp(p, plan.rp));
        let stage_in = spec.stage_input_dim();
        // Per-stage boundary arithmetic. The trained stage's input
        // format decides the σ machinery; the entry format is the RP
        // accumulator when an RP front end exists.
        let stage_in_spec = match spec.stage {
            StageSpec::Easi { .. } => plan.rot,
            StageSpec::Ica { .. } => plan.whiten,
            _ => plan.rp,
        };
        let entry = if fxp_rp.is_some() { plan.rp } else { stage_in_spec };
        let prescale = plan.entry_prescale(fxp_rp.is_some(), &stage_in_spec);
        // Quantized training view, built once as one flat row-major
        // tile through the crate-wide shared ingress (the same
        // definition the coordinator and the bench run): prescale +
        // quantize the whole sample matrix, push the tile through the
        // quantized RP network, and cross the RP→stage boundary —
        // row-for-row identical to per-sample ingress, with no
        // per-sample vectors.
        let rows = train_x.rows_count();
        let mut ingress = Scratch::new();
        fxp::kernels::ingress_tile(
            fxp_rp.as_ref(),
            &entry,
            &stage_in_spec,
            prescale,
            train_x.as_slice(),
            rows,
            &mut ingress,
        );
        let staged_raw: &[i32] = if fxp_rp.is_some() {
            &ingress.stage
        } else {
            &ingress.xq
        };
        let mut output = stage_in_spec;
        let stage = match spec.stage {
            StageSpec::Easi { mode, mu, epochs } => {
                assert!(
                    mode == EasiMode::RotationOnly,
                    "fixed-point EASI implements the paper's rotation-only \
                     datapath; got {mode:?}"
                );
                // Update terms scale as σ⁴ under the input prescale —
                // fold the compensation into μ (exact power of two).
                let mu_eff = mu / prescale.powi(4);
                let mut t = FxpEasiRot::new(
                    stage_in,
                    spec.output_dim,
                    mu_eff,
                    Some(spec.seed),
                    plan.rot,
                    plan.quant,
                );
                for _ in 0..epochs.max(1) {
                    t.step_tile_raw(staged_raw, rows);
                }
                output = plan.rot;
                FittedStage::FxpEasi(t)
            }
            StageSpec::Ica { mu_w, mu_rot, epochs } => {
                let mut u = fxp::FxpDrUnit::new(fxp::FxpUnitConfig {
                    input_dim: stage_in,
                    output_dim: spec.output_dim,
                    mu_w,
                    mu_rot,
                    rotate: true,
                    rot_warmup: (train_x.rows_count() / 2).min(2000) as u64,
                    seed: spec.seed,
                    whiten_spec: plan.whiten,
                    rot_spec: plan.rot,
                    quant: plan.quant,
                });
                for _ in 0..epochs.max(1) {
                    u.step_tile_raw(staged_raw, rows);
                }
                output = u.output_spec();
                FittedStage::FxpUnit(u)
            }
            StageSpec::Identity => {
                assert_eq!(
                    stage_in, spec.output_dim,
                    "Identity stage requires RP to land on output_dim"
                );
                FittedStage::Identity
            }
            other => panic!(
                "fixed-point precision supports the streaming stages \
                 (easi rotation-only, ica, identity), not {other:?}"
            ),
        };
        Self {
            spec,
            rp,
            fxp_rp,
            fxp_io: Some(FxpIo {
                entry,
                prescale,
                stage_in: stage_in_spec,
                output,
            }),
            stage,
        }
    }

    /// Transform one sample `m → n`.
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        if let Some(io) = &self.fxp_io {
            let xq = quantize_prescaled(&io.entry, io.prescale, x);
            let staged = match &self.fxp_rp {
                Some(f) => io.stage_in.requantize_vec_from(&f.apply_raw(&xq), &io.entry),
                None => xq,
            };
            let out = match &self.stage {
                FittedStage::FxpEasi(t) => t.transform_raw(&staged),
                FittedStage::FxpUnit(u) => u.transform_raw(&staged),
                FittedStage::Identity => staged,
                _ => unreachable!("fixed pipelines hold quantized stages"),
            };
            return io.output.dequantize_vec(&out);
        }
        let staged: Vec<f32> = match &self.rp {
            Some(proj) => proj.apply(x),
            None => x.to_vec(),
        };
        match &self.stage {
            FittedStage::Easi(t) => t.transform(&staged),
            FittedStage::Unit(u) => u.transform(&staged),
            FittedStage::Pca(p, false) => p.transform(&staged),
            FittedStage::Pca(p, true) => p.whiten(&staged),
            FittedStage::Dct(d) => d.transform(&staged),
            FittedStage::Identity => staged,
            FittedStage::FxpEasi(_) | FittedStage::FxpUnit(_) => {
                unreachable!("f32 pipelines hold f32 stages")
            }
        }
    }

    /// Transform every row of a sample matrix. Fixed-precision
    /// pipelines run the whole matrix as one tile through the quantized
    /// datapath (bit-identical to per-sample [`DrPipeline::transform`],
    /// without the per-sample staging vectors).
    pub fn transform_rows(&self, x: &Mat) -> Mat {
        if let Some(io) = self.fxp_io {
            return self.transform_rows_fixed(&io, x);
        }
        let rows = x.rows_count();
        let mut out = Vec::with_capacity(rows * self.spec.output_dim);
        for r in x.rows() {
            out.extend(self.transform(r));
        }
        Mat::from_vec(rows, self.spec.output_dim, out)
    }

    /// The tiled fixed-point bulk transform: the shared ingress
    /// (quantize at the entry format, project through the quantized RP
    /// network, cross the stage boundary), then the quantized stage
    /// tile-at-a-time.
    fn transform_rows_fixed(&self, io: &FxpIo, x: &Mat) -> Mat {
        let rows = x.rows_count();
        let mut ingress = Scratch::new();
        fxp::kernels::ingress_tile(
            self.fxp_rp.as_ref(),
            &io.entry,
            &io.stage_in,
            io.prescale,
            x.as_slice(),
            rows,
            &mut ingress,
        );
        let staged: &[i32] = if self.fxp_rp.is_some() {
            &ingress.stage
        } else {
            &ingress.xq
        };
        let mut raw = Vec::new();
        match &self.stage {
            FittedStage::FxpEasi(t) => t.transform_tile_raw(staged, rows, &mut raw),
            FittedStage::FxpUnit(u) => {
                let mut scratch = Scratch::new();
                u.transform_tile_raw(staged, rows, &mut scratch, &mut raw);
            }
            FittedStage::Identity => raw.extend_from_slice(staged),
            _ => unreachable!("fixed pipelines hold quantized stages"),
        }
        Mat::from_vec(
            rows,
            self.spec.output_dim,
            raw.iter().map(|&w| io.output.dequantize(w)).collect(),
        )
    }

    /// Map an entire dataset through the pipeline (used before training
    /// the downstream classifier).
    pub fn transform_dataset(&self, d: &Dataset) -> Dataset {
        Dataset {
            name: format!("{}+dr{}", d.name, self.spec.output_dim),
            train_x: self.transform_rows(&d.train_x),
            train_y: d.train_y.clone(),
            test_x: self.transform_rows(&d.test_x),
            test_y: d.test_y.clone(),
            num_classes: d.num_classes,
        }
    }

    /// Access the fitted EASI trainer (None for non-EASI stages) — used
    /// by the coordinator for checkpointing and by tests.
    pub fn easi(&self) -> Option<&EasiTrainer> {
        match &self.stage {
            FittedStage::Easi(t) => Some(t),
            _ => None,
        }
    }

    /// The RP front end, if any.
    pub fn rp(&self) -> Option<&RandomProjection> {
        self.rp.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Pcg64, RngExt};

    fn gaussian_data(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed(seed);
        Mat::from_fn(n, d, |_, _| rng.next_gaussian() as f32)
    }

    #[test]
    fn proposed_pipeline_shapes() {
        let x = gaussian_data(500, 32, 71);
        let spec = PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7);
        let p = DrPipeline::fit(spec, &x);
        assert_eq!(p.transform(x.row(0)).len(), 8);
        assert_eq!(p.transform_rows(&x).shape(), (500, 8));
    }

    #[test]
    fn easi_only_pipeline_shapes() {
        let x = gaussian_data(500, 32, 72);
        let p = DrPipeline::fit(PipelineSpec::easi_only(32, 16, 1e-3, 1, 7), &x);
        assert_eq!(p.transform_rows(&x).shape(), (500, 16));
    }

    #[test]
    fn pca_stage_matches_direct_batch_pca() {
        let x = gaussian_data(300, 10, 73);
        let spec = PipelineSpec {
            input_dim: 10,
            rp: None,
            stage: StageSpec::Pca,
            output_dim: 3,
            seed: 1,
            precision: Precision::F32,
        };
        let p = DrPipeline::fit(spec, &x);
        let direct = BatchPca::fit(&x, 3);
        let a = p.transform(x.row(0));
        let b = direct.transform(x.row(0));
        for (u, v) in a.iter().zip(&b) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn identity_requires_matching_dims() {
        let x = gaussian_data(50, 16, 74);
        let spec = PipelineSpec {
            input_dim: 16,
            rp: Some(RpStage {
                intermediate_dim: 8,
                distribution: RpDistribution::Ternary,
            }),
            stage: StageSpec::Identity,
            output_dim: 8,
            seed: 1,
            precision: Precision::F32,
        };
        let p = DrPipeline::fit(spec, &x);
        assert_eq!(p.transform_rows(&x).shape(), (50, 8));
    }

    #[test]
    fn transform_dataset_preserves_labels() {
        use crate::datasets::waveform::WaveformConfig;
        let d = WaveformConfig {
            samples: 300,
            train: 200,
            ..WaveformConfig::paper()
        }
        .generate();
        let p = DrPipeline::fit(PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7), &d.train_x);
        let t = p.transform_dataset(&d);
        assert_eq!(t.train_y, d.train_y);
        assert_eq!(t.input_dim(), 8);
        t.validate().unwrap();
    }

    #[test]
    fn deterministic_fit() {
        let x = gaussian_data(200, 32, 75);
        let run = || {
            let p = DrPipeline::fit(PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7), &x);
            p.transform(x.row(0))
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn fixed_precision_proposed_pipeline_tracks_f32() {
        // The paper's proposed RP→rotation-only-EASI configuration at
        // 16-bit Q4.12: shapes right, outputs finite, and close to the
        // f32 pipeline (same seed, same data). Documented tolerance:
        // 0.15 absolute on ~unit-scale outputs after one epoch.
        let x = gaussian_data(600, 32, 76);
        let f32_p = DrPipeline::fit(PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7), &x);
        let fx_p = DrPipeline::fit(
            PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7)
                .with_precision(Precision::parse("q4.12").unwrap()),
            &x,
        );
        let y_fx = fx_p.transform_rows(&x);
        assert_eq!(y_fx.shape(), (600, 8));
        assert!(y_fx.as_slice().iter().all(|v| v.is_finite()));
        let y_f32 = f32_p.transform_rows(&x);
        let mut worst = 0.0f32;
        let mut mean = 0.0f64;
        for (a, b) in y_fx.as_slice().iter().zip(y_f32.as_slice()) {
            worst = worst.max((a - b).abs());
            mean += (a - b).abs() as f64;
        }
        mean /= y_fx.as_slice().len() as f64;
        // The f32 trainer additionally normalises/clips (guards the
        // hardware datapath doesn't have) and skips the periodic
        // retraction, so the trajectories drift — the fitted maps must
        // still largely agree on ~unit-scale outputs.
        assert!(mean < 0.25, "fixed vs f32 outputs diverged: mean {mean}");
        assert!(worst < 1.5, "fixed vs f32 outputs diverged: worst {worst}");
    }

    #[test]
    fn fixed_precision_identity_rp_pipeline() {
        let x = gaussian_data(50, 16, 77);
        let spec = PipelineSpec {
            input_dim: 16,
            rp: Some(RpStage {
                intermediate_dim: 8,
                distribution: RpDistribution::Ternary,
            }),
            stage: StageSpec::Identity,
            output_dim: 8,
            seed: 1,
            precision: Precision::parse("q8.16").unwrap(),
        };
        let p = DrPipeline::fit(spec.clone(), &x);
        let y = p.transform_rows(&x);
        assert_eq!(y.shape(), (50, 8));
        // Ternary RP (scale 1, ≥4 integer bits so no prescale): the
        // quantized network agrees with f32 to input-quantization error.
        let f32_p = DrPipeline::fit(spec.with_precision(Precision::F32), &x);
        let y32 = f32_p.transform_rows(&x);
        for (a, b) in y.as_slice().iter().zip(y32.as_slice()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn mixed_precision_ste_pipeline_tracks_f32() {
        // The acceptance plan: wide RP accumulator, 16-bit whiten and
        // rotation, STE-trained. Must produce finite outputs close to
        // the f32 pipeline, like the uniform q4.12 test above.
        let x = gaussian_data(600, 32, 79);
        let f32_p = DrPipeline::fit(PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7), &x);
        let plan = Precision::parse("rp=q8.16,whiten=q4.12,rot=q4.12,qat=ste").unwrap();
        let fx_p = DrPipeline::fit(
            PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7).with_precision(plan),
            &x,
        );
        let y_fx = fx_p.transform_rows(&x);
        assert_eq!(y_fx.shape(), (600, 8));
        assert!(y_fx.as_slice().iter().all(|v| v.is_finite()));
        let y_f32 = f32_p.transform_rows(&x);
        let mut mean = 0.0f64;
        for (a, b) in y_fx.as_slice().iter().zip(y_f32.as_slice()) {
            mean += (a - b).abs() as f64;
        }
        mean /= y_fx.as_slice().len() as f64;
        assert!(mean < 0.25, "mixed STE vs f32 outputs diverged: mean {mean}");
    }

    #[test]
    fn mixed_precision_narrow_rotation_stays_finite() {
        // Narrow rotation behind a wide whitener: the σ target drops to
        // fit q1.15 and every boundary requantizes; outputs must stay
        // finite and on the rotation format's grid.
        let x = gaussian_data(500, 32, 80);
        let plan = Precision::parse("rp=q8.16,whiten=q8.16,rot=q1.15,qat=ste").unwrap();
        let p = DrPipeline::fit(
            PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7).with_precision(plan),
            &x,
        );
        let y = p.transform_rows(&x);
        assert_eq!(y.shape(), (500, 8));
        let rot = plan.plan().unwrap().rot;
        for &v in y.as_slice() {
            assert!(v.is_finite());
            let q = rot.dequantize(rot.quantize(v));
            assert!((v - q).abs() < 1e-9, "output off the rot grid: {v}");
        }
    }

    #[test]
    fn fixed_transform_rows_matches_per_sample_transform() {
        // The tiled bulk path must be bit-identical to per-sample
        // transform (same raw words, so exactly equal f32 outputs) —
        // for both uniform and mixed plans.
        let x = gaussian_data(300, 32, 91);
        for plan in ["q4.12", "rp=q8.16,whiten=q4.12,rot=q1.15"] {
            let p = DrPipeline::fit(
                PipelineSpec::proposed(32, 16, 8, 1e-3, 1, 7)
                    .with_precision(Precision::parse(plan).unwrap()),
                &x,
            );
            let tiled = p.transform_rows(&x);
            for i in 0..x.rows_count() {
                assert_eq!(
                    tiled.row(i),
                    p.transform(x.row(i)).as_slice(),
                    "row {i} diverged under plan {plan}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "fixed-point precision supports the streaming stages")]
    fn fixed_precision_rejects_batch_stages() {
        let x = gaussian_data(50, 8, 78);
        let spec = PipelineSpec {
            input_dim: 8,
            rp: None,
            stage: StageSpec::Pca,
            output_dim: 4,
            seed: 1,
            precision: Precision::parse("q4.12").unwrap(),
        };
        DrPipeline::fit(spec, &x);
    }
}
