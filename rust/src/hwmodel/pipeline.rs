//! Pipelined timing model.
//!
//! The paper's implementation (following Nazemi et al., ASAP'17) is
//! fully pipelined: one new sample enters the datapath per clock, and
//! the post-place-and-route clock frequency on the Arria 10 target is
//! **106.64 MHz**, *independent of dimensionality* (that independence is
//! the ASAP'17 contribution the paper inherits; Meyer-Baese et al.'s
//! earlier design lost frequency as dimensions grew).
//!
//! Consequently:
//! * throughput = f_clk samples/s for every configuration;
//! * adding the RP front end does not change f_clk, it only adds
//!   pipeline *latency* — the paper's §V.C remark — because whitening
//!   and rotation now happen sequentially instead of in one fused
//!   update.

use super::HwConfig;

/// Pipeline depth (cycles) of each fp32 operator class at f_clk ≈ 107
/// MHz on Arria 10 hard-FP DSPs (typical latencies for the hardened
/// single-precision blocks).
const FP_MULT_LATENCY: u64 = 3;
const FP_ADD_LATENCY: u64 = 3;
/// Soft-logic add/sub latency (deeper: carry chains in ALMs).
const SOFT_ADD_LATENCY: u64 = 4;

/// Timing summary for one configuration.
#[derive(Debug, Clone, Copy)]
pub struct TimingReport {
    /// Clock frequency (Hz) — dimension-independent by design.
    pub f_clk_hz: f64,
    /// Steady-state training throughput (samples/s) = f_clk.
    pub throughput_samples_per_s: f64,
    /// End-to-end latency of one sample through the datapath, cycles.
    pub latency_cycles: u64,
    /// Latency in nanoseconds.
    pub latency_ns: f64,
}

/// The timing model.
#[derive(Debug, Clone, Copy)]
pub struct PipelineModel {
    /// Post-P&R clock, Hz. Paper: 106.64 MHz.
    pub f_clk_hz: f64,
}

impl Default for PipelineModel {
    fn default() -> Self {
        Self {
            f_clk_hz: 106.64e6,
        }
    }
}

impl PipelineModel {
    /// Latency in cycles of the EASI datapath for output dim `n`:
    /// stage 1 (dot-product tree: 1 mult + ⌈log₂ m⌉ add levels),
    /// stage 2 (two mult levels for y³), stage 3 (mult + combine),
    /// stage 4 (mult + ⌈log₂ n⌉ add levels), stage 5 (mult + add).
    pub fn easi_latency_cycles(&self, m: usize, n: usize) -> u64 {
        let log2 = |x: usize| (usize::BITS - x.next_power_of_two().leading_zeros() - 1) as u64;
        let s1 = FP_MULT_LATENCY + log2(m.max(2)) * FP_ADD_LATENCY;
        let s2 = 2 * FP_MULT_LATENCY;
        let s3 = FP_MULT_LATENCY + 2 * FP_ADD_LATENCY;
        let s4 = FP_MULT_LATENCY + log2(n.max(2)) * FP_ADD_LATENCY;
        let s5 = FP_MULT_LATENCY + FP_ADD_LATENCY;
        s1 + s2 + s3 + s4 + s5
    }

    /// Latency in cycles of the RP module: a conditional add/sub
    /// reduction tree over `m` inputs.
    pub fn rp_latency_cycles(&self, m: usize) -> u64 {
        let log2 = |x: usize| (usize::BITS - x.next_power_of_two().leading_zeros() - 1) as u64;
        log2(m.max(2)) * SOFT_ADD_LATENCY
    }

    /// Full timing report for a configuration.
    pub fn timing(&self, cfg: &HwConfig) -> TimingReport {
        let mut latency = self.easi_latency_cycles(cfg.easi_input(), cfg.output_dim);
        if cfg.intermediate_dim.is_some() {
            latency += self.rp_latency_cycles(cfg.input_dim);
        }
        TimingReport {
            f_clk_hz: self.f_clk_hz,
            throughput_samples_per_s: self.f_clk_hz,
            latency_cycles: latency,
            latency_ns: latency as f64 / self.f_clk_hz * 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frequency_is_dimension_independent() {
        let model = PipelineModel::default();
        let a = model.timing(&HwConfig::easi(32, 8));
        let b = model.timing(&HwConfig::easi(1024, 64));
        assert_eq!(a.f_clk_hz, b.f_clk_hz);
        assert_eq!(a.throughput_samples_per_s, b.throughput_samples_per_s);
    }

    #[test]
    fn rp_adds_latency_not_throughput() {
        // §V.C: same clock, slightly higher latency.
        let model = PipelineModel::default();
        let plain = model.timing(&HwConfig::easi(32, 8));
        let cascade = model.timing(&HwConfig::rp_easi(32, 16, 8));
        assert_eq!(
            plain.throughput_samples_per_s,
            cascade.throughput_samples_per_s
        );
        assert!(cascade.latency_cycles > plain.latency_cycles);
        // "asymptotic latency of random projection is negligible" — the
        // added cycles are a small fraction.
        let added = cascade.latency_cycles - plain.latency_cycles;
        assert!(
            (added as f64) < 0.75 * plain.latency_cycles as f64,
            "RP latency {added} vs EASI {}",
            plain.latency_cycles
        );
    }

    #[test]
    fn latency_grows_logarithmically_with_m() {
        let model = PipelineModel::default();
        let l32 = model.easi_latency_cycles(32, 8);
        let l64 = model.easi_latency_cycles(64, 8);
        let l128 = model.easi_latency_cycles(128, 8);
        // Constant increments in log2(m).
        assert_eq!(l64 - l32, l128 - l64);
        assert!(l64 > l32);
    }

    #[test]
    fn paper_clock_frequency() {
        let t = PipelineModel::default().timing(&HwConfig::easi(32, 8));
        assert!((t.f_clk_hz - 106.64e6).abs() < 1.0);
        // ~9.4 ns per cycle; latency tens of cycles → hundreds of ns.
        assert!(t.latency_ns > 100.0 && t.latency_ns < 1000.0);
    }
}
