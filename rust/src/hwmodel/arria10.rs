//! Arria-10 resource mapping, calibrated against the paper's Table II.
//!
//! # Calibration (documented per DESIGN.md §7)
//!
//! The mapping has four constants, fixed once against the paper's
//! anchor row (EASI 32→8: 4052 DSPs / 38122 ALMs / 138368 register
//! bits) and the decomposition of its second row:
//!
//! * `dsp_per_mult` — Table II row 1 has 4052 DSPs for 2704 datapath
//!   multipliers ⇒ **1.4985 DSPs per multiplier** (the hard-FP DSPs
//!   also absorb roughly half of the adders' accumulation work).
//! * `alm_per_hard_op` — 38122 ALMs / 5128 hard fp ops ⇒ **7.43 ALMs
//!   per op** (routing + control around each pipelined unit).
//! * `alm_per_soft_addsub` — row 2 minus the EASI(16→8) share leaves
//!   ≈ 49,990 ALMs for the RP module's 512 conditional add/sub units ⇒
//!   **97.6 ALMs per soft fp32 add/sub**, consistent with a soft-logic
//!   single-precision adder on Arria 10.
//! * `pipeline_regs_per_op` — register bits beyond the architectural
//!   storage (624 words in row 1) imply **0.7215 pipeline words per
//!   hard fp op** (each DSP operator is internally pipelined; ~¾ of a
//!   32-bit stage register ends up charged per op after retiming). The
//!   RP module's pipeline registers are part of its storage inventory
//!   (sign store + accumulators), so soft ops are not double-charged.
//!
//! Row 1 is matched by construction; row 2 is then a genuine
//! *prediction* of the model (within ~4% on every column — see
//! EXPERIMENTS.md). All four constants are plain struct fields, so
//! alternative technologies (Stratix, UltraScale) can be modelled by
//! substitution.

use super::ops::{NumericFormat, OpCounts};
use super::HwConfig;

/// Arria 10 GX 1150 device capacity (paper §V.C).
pub const ARRIA10_CAPACITY: DeviceCapacity = DeviceCapacity {
    alms: 427_200,
    dsps: 1518,
    bram_bits: 55_562_240,
};

/// FPGA device capacity for utilisation reporting.
#[derive(Debug, Clone, Copy)]
pub struct DeviceCapacity {
    pub alms: u64,
    pub dsps: u64,
    pub bram_bits: u64,
}

/// Resource consumption of one configuration.
#[derive(Debug, Clone, Copy)]
pub struct ResourceReport {
    pub dsps: u64,
    pub alms: u64,
    pub register_bits: u64,
    /// Utilisation fractions against [`ARRIA10_CAPACITY`] (may exceed
    /// 1.0 — the paper notes Table II itself exceeds the target board).
    pub dsp_utilisation: f64,
    pub alm_utilisation: f64,
}

impl ResourceReport {
    /// Sum two module reports (cascaded datapaths), recomputing
    /// utilisation against the given capacity.
    pub fn merge(&self, other: &ResourceReport, capacity: &DeviceCapacity) -> ResourceReport {
        let dsps = self.dsps + other.dsps;
        let alms = self.alms + other.alms;
        ResourceReport {
            dsps,
            alms,
            register_bits: self.register_bits + other.register_bits,
            dsp_utilisation: dsps as f64 / capacity.dsps as f64,
            alm_utilisation: alms as f64 / capacity.alms as f64,
        }
    }
}

/// The calibrated cost model.
///
/// The fp32 constants are Table-II-calibrated (module docs). The
/// fixed-point constants model the *mechanism* behind the precision
/// lever:
///
/// * **DSPs** — an Arria-10 DSP block natively packs two independent
///   18×19 multiplies or one 27×27: ½ DSP per multiplier at ≤ 18 bits,
///   1 at ≤ 27, 2 above (the block pairs up for wide products).
/// * **ALMs** — a w-bit two's-complement add/sub is a bare carry chain:
///   each ALM provides two bits of arithmetic plus shared routing,
///   modelled at `alm_per_bit_addsub = 0.35` ALMs/bit (an 18-bit adder
///   ≈ 6 ALMs, vs ~100 for a soft fp32 adder), plus a small per-mult
///   routing overhead.
/// * **Registers** — the same pipeline/storage *word counts* as fp32,
///   at the operand width: an 18-bit datapath stores 18-bit words.
#[derive(Debug, Clone, Copy)]
pub struct Arria10Model {
    pub dsp_per_mult: f64,
    pub alm_per_hard_op: f64,
    pub alm_per_soft_addsub: f64,
    pub pipeline_regs_per_op: f64,
    /// ALMs per bit of a fixed-point add/sub carry chain.
    pub alm_per_bit_addsub: f64,
    /// ALM routing overhead charged per fixed-point multiplier.
    pub alm_fixed_mult_overhead: f64,
    pub word_bits: u64,
    pub capacity: DeviceCapacity,
}

impl Arria10Model {
    /// Constants calibrated against the paper's Table II (see module
    /// docs for the derivation).
    pub fn paper_calibrated() -> Self {
        Self {
            dsp_per_mult: 4052.0 / 2704.0,             // 1.4985
            alm_per_hard_op: 38122.0 / 5128.0,         // 7.4340
            alm_per_soft_addsub: 97.6,
            pipeline_regs_per_op: (4324.0 - 624.0) / 5128.0, // 0.7215
            alm_per_bit_addsub: 0.35,
            alm_fixed_mult_overhead: 2.0,
            word_bits: 32,
            capacity: ARRIA10_CAPACITY,
        }
    }

    /// DSP blocks per multiplier at a given operand width (the native
    /// 18×19 / 27×27 packing of the Arria-10 DSP).
    pub fn fixed_dsp_per_mult(width_bits: u8) -> f64 {
        if width_bits <= 18 {
            0.5
        } else if width_bits <= 27 {
            1.0
        } else {
            2.0
        }
    }

    /// Cost a configuration (uses its [`NumericFormat`]).
    pub fn cost(&self, cfg: &HwConfig) -> ResourceReport {
        self.cost_fmt(&cfg.op_counts(), cfg.format)
    }

    /// Cost raw operation counts at fp32 (the paper's Table II mapping).
    pub fn cost_ops(&self, ops: &OpCounts) -> ResourceReport {
        self.cost_fmt(ops, NumericFormat::Fp32)
    }

    /// Cost the RP → trained-stage pipeline under a [`Precision`] —
    /// the precision axis of the Pareto sweep. f32 and *uniform* fixed
    /// plans delegate to the single-format path (bit-identical to the
    /// PR-1 pricing); mixed plans price each precision domain at its
    /// own width: the RP module at `plan.rp`, the trained stage split
    /// per [`crate::hwmodel::ops::easi_split_ops`] — its projection
    /// matvec + state at `plan.whiten`, the HOS/update machinery at
    /// `plan.rot` — and sum the module reports.
    pub fn cost_precision(
        &self,
        m: usize,
        p: Option<usize>,
        n: usize,
        precision: &crate::fxp::Precision,
    ) -> ResourceReport {
        use crate::fxp::Precision;
        let base = match p {
            Some(p) => HwConfig::rp_easi(m, p, n),
            None => HwConfig::easi(m, n),
        };
        let plan = match precision {
            Precision::F32 => return self.cost(&base),
            Precision::Fixed(plan) if plan.is_uniform() => {
                return self.cost(&base.with_format(NumericFormat::Fixed {
                    width_bits: plan.whiten.format.width(),
                }));
            }
            Precision::Fixed(plan) => plan,
        };
        let stage_in = base.easi_input();
        let (whiten_ops, rot_ops) = crate::hwmodel::ops::easi_split_ops(stage_in, n);
        let at = |w: u8| NumericFormat::Fixed { width_bits: w };
        let mut report = self
            .cost_fmt(&whiten_ops, at(plan.whiten.format.width()))
            .merge(
                &self.cost_fmt(&rot_ops, at(plan.rot.format.width())),
                &self.capacity,
            );
        if let Some(p) = base.intermediate_dim {
            report = report.merge(
                &self.cost_fmt(
                    &crate::hwmodel::ops::rp_ops(m, p),
                    at(plan.rp.format.width()),
                ),
                &self.capacity,
            );
        }
        report
    }

    /// Price an arbitrary stage cascade by folding per-stage
    /// inventories, each at its own operand format — the stage-graph
    /// pricing path ([`crate::stage::GraphSpec::hw_cost`]). Summing the
    /// module reports mirrors how cascaded datapaths compose on the
    /// fabric (each stage is its own pipelined region).
    pub fn cost_stages(&self, stages: &[(OpCounts, NumericFormat)]) -> ResourceReport {
        let mut report: Option<ResourceReport> = None;
        for (ops, fmt) in stages {
            let part = self.cost_fmt(ops, *fmt);
            report = Some(match report {
                None => part,
                Some(acc) => acc.merge(&part, &self.capacity),
            });
        }
        report.unwrap_or_else(|| self.cost_fmt(&OpCounts::default(), NumericFormat::Fp32))
    }

    /// Cost raw operation counts at a given operand format.
    pub fn cost_fmt(&self, ops: &OpCounts, fmt: NumericFormat) -> ResourceReport {
        let hard_ops = ops.mults + ops.adds;
        let (dsps, alms, word_bits) = match fmt {
            NumericFormat::Fp32 => {
                let dsps = (ops.mults as f64 * self.dsp_per_mult).round() as u64;
                let alms = (hard_ops as f64 * self.alm_per_hard_op
                    + ops.soft_addsubs as f64 * self.alm_per_soft_addsub)
                    .round() as u64;
                (dsps, alms, self.word_bits)
            }
            NumericFormat::Fixed { width_bits } => {
                let dsps = (ops.mults as f64 * Self::fixed_dsp_per_mult(width_bits))
                    .ceil() as u64;
                let alm_per_addsub = width_bits as f64 * self.alm_per_bit_addsub;
                let alms = ((ops.adds + ops.soft_addsubs) as f64 * alm_per_addsub
                    + ops.mults as f64 * self.alm_fixed_mult_overhead)
                    .round() as u64;
                (dsps, alms, width_bits as u64)
            }
        };
        let pipeline_words =
            (hard_ops as f64 * self.pipeline_regs_per_op).round() as u64;
        let register_bits = (ops.storage_words + pipeline_words) * word_bits;
        ResourceReport {
            dsps,
            alms,
            register_bits,
            dsp_utilisation: dsps as f64 / self.capacity.dsps as f64,
            alm_utilisation: alms as f64 / self.capacity.alms as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwmodel::ops::{easi_ops, rp_ops};

    #[test]
    fn anchor_row_matches_paper_tightly() {
        // Calibrated on this row — must land within 2%.
        let model = Arria10Model::paper_calibrated();
        let r = model.cost_ops(&easi_ops(32, 8));
        assert!((r.dsps as f64 - 4052.0).abs() / 4052.0 < 0.02, "DSPs {}", r.dsps);
        assert!((r.alms as f64 - 38122.0).abs() / 38122.0 < 0.02, "ALMs {}", r.alms);
        assert!(
            (r.register_bits as f64 - 138368.0).abs() / 138368.0 < 0.05,
            "regs {}",
            r.register_bits
        );
    }

    #[test]
    fn prediction_row_within_ten_percent() {
        // Row 2 is a genuine prediction (only the ALM split used row-2
        // information).
        let model = Arria10Model::paper_calibrated();
        let ops = easi_ops(16, 8).merge(&rp_ops(32, 16));
        let r = model.cost_ops(&ops);
        assert!((r.dsps as f64 - 2212.0).abs() / 2212.0 < 0.10, "DSPs {}", r.dsps);
        assert!((r.alms as f64 - 70031.0).abs() / 70031.0 < 0.10, "ALMs {}", r.alms);
        assert!(
            (r.register_bits as f64 - 75392.0).abs() / 75392.0 < 0.10,
            "regs {}",
            r.register_bits
        );
    }

    #[test]
    fn rp_consumes_no_dsps() {
        let model = Arria10Model::paper_calibrated();
        let r = model.cost_ops(&rp_ops(128, 32));
        assert_eq!(r.dsps, 0);
        assert!(r.alms > 0);
    }

    #[test]
    fn utilisation_fractions() {
        let model = Arria10Model::paper_calibrated();
        let r = model.cost_ops(&easi_ops(32, 8));
        // The paper notes these projections exceed the target board's
        // 1518 DSPs.
        assert!(r.dsp_utilisation > 1.0);
        assert!(r.alm_utilisation < 1.0);
    }

    #[test]
    fn fixed_point_strictly_cheaper_than_fp32() {
        // The mechanism behind the paper's savings claim: the same
        // operator inventory priced at 16/18-bit fixed point must be
        // strictly cheaper than fp32 on every column, for both Table II
        // configurations.
        let model = Arria10Model::paper_calibrated();
        for ops in [easi_ops(32, 8), easi_ops(16, 8).merge(&rp_ops(32, 16))] {
            let fp = model.cost_fmt(&ops, NumericFormat::Fp32);
            for w in [16u8, 18] {
                let fx = model.cost_fmt(&ops, NumericFormat::Fixed { width_bits: w });
                assert!(fx.dsps < fp.dsps, "{w}-bit DSPs {} vs {}", fx.dsps, fp.dsps);
                assert!(fx.alms < fp.alms, "{w}-bit ALMs {} vs {}", fx.alms, fp.alms);
                assert!(
                    fx.register_bits < fp.register_bits,
                    "{w}-bit regs {} vs {}",
                    fx.register_bits,
                    fp.register_bits
                );
            }
        }
    }

    #[test]
    fn eighteen_bit_multiplier_is_half_a_dsp() {
        let model = Arria10Model::paper_calibrated();
        let ops = easi_ops(32, 8);
        let r = model.cost_fmt(&ops, NumericFormat::Fixed { width_bits: 18 });
        assert_eq!(r.dsps, (ops.mults as f64 * 0.5).ceil() as u64);
        // 27-bit: one DSP per multiplier; 32-bit: two.
        let r27 = model.cost_fmt(&ops, NumericFormat::Fixed { width_bits: 27 });
        assert_eq!(r27.dsps, ops.mults);
        let r32 = model.cost_fmt(&ops, NumericFormat::Fixed { width_bits: 32 });
        assert_eq!(r32.dsps, 2 * ops.mults);
    }

    #[test]
    fn fixed_cost_monotone_in_width() {
        let model = Arria10Model::paper_calibrated();
        let ops = easi_ops(32, 8).merge(&rp_ops(64, 32));
        let mut last = (0u64, 0u64, 0u64);
        for w in [8u8, 12, 16, 18, 20, 27, 32] {
            let r = model.cost_fmt(&ops, NumericFormat::Fixed { width_bits: w });
            assert!(
                r.dsps >= last.0 && r.alms >= last.1 && r.register_bits >= last.2,
                "width {w} not monotone"
            );
            last = (r.dsps, r.alms, r.register_bits);
        }
    }

    #[test]
    fn hwconfig_format_flows_through_cost() {
        use crate::hwmodel::HwConfig;
        let model = Arria10Model::paper_calibrated();
        let fp = model.cost(&HwConfig::rp_easi(32, 16, 8));
        let fx = model.cost(
            &HwConfig::rp_easi(32, 16, 8)
                .with_format(NumericFormat::Fixed { width_bits: 16 }),
        );
        assert!(fx.dsps < fp.dsps && fx.alms < fp.alms);
        // register bits exactly halve: same word count, half the width.
        assert_eq!(fx.register_bits * 2, fp.register_bits);
    }

    #[test]
    fn cost_precision_uniform_matches_single_format_path() {
        use crate::fxp::Precision;
        let model = Arria10Model::paper_calibrated();
        for s in ["f32", "q4.12", "q8.16"] {
            let prec = Precision::parse(s).unwrap();
            let via_plan = model.cost_precision(32, Some(16), 8, &prec);
            let via_cfg = model.cost(
                &crate::hwmodel::HwConfig::rp_easi(32, 16, 8)
                    .with_format(NumericFormat::from_precision(&prec)),
            );
            assert_eq!(via_plan.dsps, via_cfg.dsps, "{s} DSPs");
            assert_eq!(via_plan.alms, via_cfg.alms, "{s} ALMs");
            assert_eq!(via_plan.register_bits, via_cfg.register_bits, "{s} regs");
        }
    }

    #[test]
    fn mixed_plan_undercuts_its_widest_uniform_format() {
        use crate::fxp::Precision;
        let model = Arria10Model::paper_calibrated();
        // Wide RP accumulator + 16-bit trained stage vs uniform 24-bit.
        let mixed = Precision::parse("rp=q8.16,whiten=q4.12,rot=q4.12").unwrap();
        let uniform = Precision::parse("q8.16").unwrap();
        let mx = model.cost_precision(32, Some(16), 8, &mixed);
        let un = model.cost_precision(32, Some(16), 8, &uniform);
        // The trained stage holds every multiplier: 16-bit packs two
        // per DSP where 24-bit needs a whole one.
        assert!(mx.dsps < un.dsps, "mixed {} vs uniform {}", mx.dsps, un.dsps);
        assert!(mx.alms < un.alms);
        assert!(mx.register_bits < un.register_bits);
        // And narrowing only the rotation still saves versus pricing
        // everything at the whitener's width.
        let rot_narrow = Precision::parse("rp=q4.12,whiten=q4.12,rot=q1.7").unwrap();
        let rn = model.cost_precision(32, Some(16), 8, &rot_narrow);
        let at16 = model.cost_precision(32, Some(16), 8, &Precision::parse("q4.12").unwrap());
        assert!(rn.alms < at16.alms);
        assert!(rn.register_bits < at16.register_bits);
    }

    #[test]
    fn dsp_cost_monotone_in_dims() {
        let model = Arria10Model::paper_calibrated();
        let small = model.cost_ops(&easi_ops(16, 8)).dsps;
        let big = model.cost_ops(&easi_ops(32, 8)).dsps;
        let bigger = model.cost_ops(&easi_ops(32, 16)).dsps;
        assert!(small < big && big < bigger);
    }
}
