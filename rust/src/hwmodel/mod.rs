//! FPGA hardware cost model — the substitution for the paper's Quartus
//! synthesis flow (DESIGN.md §7).
//!
//! The paper's Table II reports post-synthesis resource consumption on
//! an Arria 10 (427,200 ALMs / 1518 DSPs / 55,562,240 BRAM bits) for the
//! EASI datapath of Nazemi et al. (ASAP'17) with and without the
//! random-projection front end. We cannot run Quartus, but the paper's
//! *claim* is about operation-count scaling — hardware complexity
//! O(m·n²) in adders and multipliers, hence cost ∝ m/p once RP shrinks
//! m to p. An inventory-based model preserves exactly that structure:
//!
//! 1. [`ops`] counts every fp32 multiplier, adder and register in the
//!    five-stage datapath of the paper's Fig. 3 / Alg. 1 (and the
//!    add/sub network of the RP module);
//! 2. [`arria10`] maps operation counts to Arria-10 DSPs / ALMs /
//!    register bits with constants calibrated once against the paper's
//!    own Table II anchor row (documented there);
//! 3. [`pipeline`] models the pipelined timing: one new sample per
//!    clock at the paper's post-place-and-route 106.64 MHz, plus
//!    latency in cycles for each configuration.

pub mod arria10;
pub mod ops;
pub mod pipeline;

pub use arria10::{Arria10Model, ResourceReport, ARRIA10_CAPACITY};
pub use ops::{easi_ops, rp_ops, NumericFormat, OpCounts};
pub use pipeline::{PipelineModel, TimingReport};


/// One hardware configuration to cost — either plain EASI or the
/// paper's RP → EASI cascade, at a given operand format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HwConfig {
    /// Input dimensionality `m`.
    pub input_dim: usize,
    /// Intermediate dimensionality `p` (None ⇒ no RP front end).
    pub intermediate_dim: Option<usize>,
    /// Output dimensionality `n`.
    pub output_dim: usize,
    /// Operand numeric format (fp32 = the paper's Table II datapath).
    pub format: NumericFormat,
}

impl HwConfig {
    /// Plain EASI, `m → n` (Table II row 1), fp32.
    pub fn easi(m: usize, n: usize) -> Self {
        Self {
            input_dim: m,
            intermediate_dim: None,
            output_dim: n,
            format: NumericFormat::Fp32,
        }
    }

    /// RP front end then EASI, `m → p → n` (Table II row 2), fp32.
    pub fn rp_easi(m: usize, p: usize, n: usize) -> Self {
        assert!(m >= p && p >= n, "need m >= p >= n");
        Self {
            input_dim: m,
            intermediate_dim: Some(p),
            output_dim: n,
            format: NumericFormat::Fp32,
        }
    }

    /// Re-price the same datapath at another operand format.
    pub fn with_format(mut self, format: NumericFormat) -> Self {
        self.format = format;
        self
    }

    /// The EASI stage's effective input dimensionality.
    pub fn easi_input(&self) -> usize {
        self.intermediate_dim.unwrap_or(self.input_dim)
    }

    /// Total operation counts (EASI stage + optional RP stage).
    pub fn op_counts(&self) -> OpCounts {
        let mut total = easi_ops(self.easi_input(), self.output_dim);
        if let Some(p) = self.intermediate_dim {
            total = total.merge(&rp_ops(self.input_dim, p));
        }
        total
    }

    /// Human-readable label used in reports (format suffixed when not
    /// the fp32 baseline).
    pub fn label(&self) -> String {
        let base = match self.intermediate_dim {
            Some(p) => format!("RP({}→{p}) + EASI({p}→{})", self.input_dim, self.output_dim),
            None => format!("EASI({}→{})", self.input_dim, self.output_dim),
        };
        match self.format {
            NumericFormat::Fp32 => base,
            f => format!("{base} @{}", f.label()),
        }
    }
}

/// A row of the regenerated Table II.
#[derive(Debug, Clone)]
pub struct TableIiRow {
    pub input: usize,
    pub intermediate: Option<usize>,
    pub output: usize,
    pub dsps: u64,
    pub alms: u64,
    pub register_bits: u64,
}

/// Regenerate the paper's Table II for a set of configurations.
pub fn table_ii(configs: &[HwConfig]) -> Vec<TableIiRow> {
    let model = Arria10Model::paper_calibrated();
    configs
        .iter()
        .map(|cfg| {
            let r = model.cost(cfg);
            TableIiRow {
                input: cfg.input_dim,
                intermediate: cfg.intermediate_dim,
                output: cfg.output_dim,
                dsps: r.dsps,
                alms: r.alms,
                register_bits: r.register_bits,
            }
        })
        .collect()
}

/// The paper's exact Table II configurations.
pub fn paper_table_ii_configs() -> Vec<HwConfig> {
    vec![HwConfig::easi(32, 8), HwConfig::rp_easi(32, 16, 8)]
}

/// Published Table II reference values, for paper-vs-model reporting.
pub const PAPER_TABLE_II: [(u64, u64, u64); 2] = [
    (4052, 38122, 138368), // EASI 32→8: DSPs, ALMs, register bits
    (2212, 70031, 75392),  // RP 32→16 + EASI 16→8
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_formatting() {
        assert_eq!(HwConfig::easi(32, 8).label(), "EASI(32→8)");
        assert_eq!(
            HwConfig::rp_easi(32, 16, 8).label(),
            "RP(32→16) + EASI(16→8)"
        );
    }

    #[test]
    fn fixed_format_label_and_table_cost() {
        let fx = HwConfig::rp_easi(32, 16, 8)
            .with_format(NumericFormat::Fixed { width_bits: 18 });
        assert_eq!(fx.label(), "RP(32→16) + EASI(16→8) @fixed18");
        let rows = table_ii(&[HwConfig::rp_easi(32, 16, 8), fx]);
        assert!(rows[1].dsps < rows[0].dsps, "fixed18 must undercut fp32");
        assert!(rows[1].alms < rows[0].alms);
        assert!(rows[1].register_bits < rows[0].register_bits);
    }

    #[test]
    fn easi_input_respects_rp() {
        assert_eq!(HwConfig::easi(32, 8).easi_input(), 32);
        assert_eq!(HwConfig::rp_easi(32, 16, 8).easi_input(), 16);
    }

    #[test]
    fn table_ii_reproduces_paper_within_tolerance() {
        // Shape criterion from DESIGN.md §5: every cell within 10% of
        // the paper's value (the model is calibrated on row 1, so row 1
        // is tight; row 2 is a genuine prediction).
        let rows = table_ii(&paper_table_ii_configs());
        for (row, &(dsps, alms, regs)) in rows.iter().zip(&PAPER_TABLE_II) {
            let close = |got: u64, want: u64, tol: f64| {
                (got as f64 - want as f64).abs() <= want as f64 * tol
            };
            assert!(close(row.dsps, dsps, 0.10), "DSPs {} vs {dsps}", row.dsps);
            assert!(close(row.alms, alms, 0.10), "ALMs {} vs {alms}", row.alms);
            assert!(
                close(row.register_bits, regs, 0.10),
                "regs {} vs {regs}",
                row.register_bits
            );
        }
    }

    #[test]
    fn savings_proportional_to_m_over_p() {
        // §V.C: "the amount of savings will be proportional to m/p".
        // DSP ratio between plain EASI(m→n) and RP+EASI(m→p→n) should
        // track m/p across a sweep.
        let n = 8;
        for (m, p) in [(32, 16), (64, 16), (64, 32), (128, 32)] {
            let rows = table_ii(&[HwConfig::easi(m, n), HwConfig::rp_easi(m, p, n)]);
            let ratio = rows[0].dsps as f64 / rows[1].dsps as f64;
            let expect = m as f64 / p as f64;
            assert!(
                (ratio - expect).abs() < expect * 0.25,
                "m={m} p={p}: DSP ratio {ratio:.2} vs m/p {expect:.2}"
            );
        }
    }

    #[test]
    #[should_panic(expected = "need m >= p >= n")]
    fn rp_easi_rejects_bad_dims() {
        HwConfig::rp_easi(16, 32, 8);
    }
}
