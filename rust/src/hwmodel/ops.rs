//! Operation inventory of the EASI datapath (paper Fig. 3 / Alg. 1) and
//! the random-projection module.
//!
//! Counts are *spatial*: each multiplier/adder is a physical pipelined
//! fp32 unit processing one new sample per clock, exactly as in the
//! ASAP'17 implementation the paper builds on. This is where the
//! O(m·n²) complexity the paper fights lives — stage 4's `F·B` product.


/// Operand numeric format of a datapath — the precision axis of the
/// cost model. The operator *counts* are format-independent (the
/// algorithm fixes how many MACs exist); the format decides what each
/// operator costs ([`super::Arria10Model::cost_fmt`]) and how wide the
/// storage words are.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumericFormat {
    /// IEEE single precision in hard-FP DSPs (the paper's Table II).
    Fp32,
    /// Two's-complement fixed point of the given total operand width.
    /// An Arria-10 DSP block natively packs two 18×19 multiplies (half
    /// a DSP per multiplier at ≤ 18 bits) or one 27×27.
    Fixed { width_bits: u8 },
}

impl NumericFormat {
    /// Storage word width in bits.
    pub fn word_bits(&self) -> u64 {
        match self {
            NumericFormat::Fp32 => 32,
            NumericFormat::Fixed { width_bits } => *width_bits as u64,
        }
    }

    /// Human-readable label used in reports.
    pub fn label(&self) -> String {
        match self {
            NumericFormat::Fp32 => "fp32".to_string(),
            NumericFormat::Fixed { width_bits } => format!("fixed{width_bits}"),
        }
    }

    /// The format a pipeline [`crate::fxp::Precision`] implies. For a
    /// mixed-precision plan this is the *widest* stage width (a single
    /// conservative format); per-stage pricing is
    /// [`super::Arria10Model::cost_precision`].
    pub fn from_precision(p: &crate::fxp::Precision) -> Self {
        match p {
            crate::fxp::Precision::F32 => NumericFormat::Fp32,
            crate::fxp::Precision::Fixed(plan) => NumericFormat::Fixed {
                width_bits: plan.widest_width(),
            },
        }
    }
}

/// Operator and storage inventory for one datapath, counted in
/// format-agnostic units (see [`NumericFormat`] for the pricing axis).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// Multipliers (DSP candidates).
    pub mults: u64,
    /// Adders/subtractors realised alongside the multipliers (the
    /// matrix-product accumulations) — hard-FP DSPs at fp32, carry
    /// chains at fixed point.
    pub adds: u64,
    /// Add/sub units realised in soft logic (ALMs) — the RP module's
    /// conditional add/sub network.
    pub soft_addsubs: u64,
    /// Storage words (width set by the [`NumericFormat`] at costing
    /// time): state matrices and inter-stage buffers.
    pub storage_words: u64,
}

impl OpCounts {
    /// Total pipelined fp operator count (hard + soft).
    pub fn total_ops(&self) -> u64 {
        self.mults + self.adds + self.soft_addsubs
    }

    /// Elementwise sum — cascade two modules.
    pub fn merge(&self, other: &OpCounts) -> OpCounts {
        OpCounts {
            mults: self.mults + other.mults,
            adds: self.adds + other.adds,
            soft_addsubs: self.soft_addsubs + other.soft_addsubs,
            storage_words: self.storage_words + other.storage_words,
        }
    }
}

/// Per-stage inventory of the five-stage EASI datapath for input
/// dimensionality `m` and output dimensionality `n` (paper Alg. 1).
///
/// | stage | computation                         | mults | adds        |
/// |-------|-------------------------------------|-------|-------------|
/// | 1     | `y = Bx`                            | nm    | n(m−1)      |
/// | 2     | `g(y) = y³`                         | 2n    | —           |
/// | 3     | `F = yyᵀ − I + gyᵀ − ygᵀ`           | 2n²   | 2n²         |
/// | 4     | `F·B` (relative gradient update)    | n²m   | n(n−1)m     |
/// | 5     | `B ← B − μ(FB)`                     | nm    | nm          |
///
/// Stage 3 computes `yyᵀ` and `g yᵀ` (2n² mults); `y gᵀ` is the
/// transpose of `g yᵀ` and is wired, not recomputed. Combining the three
/// terms and the `−I` costs ≈ 2n² adds. Stage 4 dominates: **O(m·n²)**.
pub fn easi_stage_ops(m: usize, n: usize, stage: usize) -> (u64, u64) {
    let (m, n) = (m as u64, n as u64);
    match stage {
        1 => (n * m, n * (m - 1)),
        2 => (2 * n, 0),
        3 => (2 * n * n, 2 * n * n),
        4 => (n * n * m, n * (n - 1) * m),
        5 => (n * m, n * m),
        _ => panic!("EASI has stages 1..=5"),
    }
}

/// Full EASI datapath inventory: operator totals plus storage —
/// the `B` register file (n·m), the inter-stage buffers (`x`, `y`, `g`,
/// `F`, `F·B`).
pub fn easi_ops(m: usize, n: usize) -> OpCounts {
    assert!(m >= n && n >= 1, "need m >= n >= 1");
    let (mut mults, mut adds) = (0u64, 0u64);
    for stage in 1..=5 {
        let (mu, ad) = easi_stage_ops(m, n, stage);
        mults += mu;
        adds += ad;
    }
    let (m64, n64) = (m as u64, n as u64);
    let storage_words = n64 * m64      // B register file
        + n64 * m64                    // F·B buffer
        + n64 * n64                    // F buffer
        + m64                          // x input regs
        + 2 * n64; // y and g buffers
    OpCounts {
        mults,
        adds,
        soft_addsubs: 0,
        storage_words,
    }
}

/// The EASI datapath inventory split into its two precision domains —
/// the basis of mixed-precision pricing:
///
/// * **whiten share** — stage 1 (`y = Bx`, the projection/whitening
///   matvec) plus the `B` register file and the `x` input taps;
/// * **rotation share** — stages 2–5 (the HOS nonlinearity and the
///   relative-gradient update machinery) plus the `F`, `F·B`, `y`, `g`
///   buffers.
///
/// The two shares sum exactly to [`easi_ops`], so pricing both at one
/// width reproduces the uniform inventory.
pub fn easi_split_ops(m: usize, n: usize) -> (OpCounts, OpCounts) {
    assert!(m >= n && n >= 1, "need m >= n >= 1");
    let (m64, n64) = (m as u64, n as u64);
    let (s1_mults, s1_adds) = easi_stage_ops(m, n, 1);
    let whiten = OpCounts {
        mults: s1_mults,
        adds: s1_adds,
        soft_addsubs: 0,
        storage_words: n64 * m64 + m64, // B register file + x input regs
    };
    let total = easi_ops(m, n);
    let rot = OpCounts {
        mults: total.mults - whiten.mults,
        adds: total.adds - whiten.adds,
        soft_addsubs: 0,
        storage_words: total.storage_words - whiten.storage_words,
    };
    (whiten, rot)
}

/// Dense linear-stage inventory, `m → k`: one pipelined matvec (a DCT
/// truncation or a batch-PCA projection realised as a constant-matrix
/// multiply), plus the coefficient store and input taps. Used by the
/// stage-graph pricing for cascades beyond the paper's RP → EASI shape.
pub fn dense_stage_ops(m: usize, k: usize) -> OpCounts {
    assert!(m >= k && k >= 1, "need m >= k >= 1");
    let (m64, k64) = (m as u64, k as u64);
    OpCounts {
        mults: k64 * m64,
        adds: k64 * (m64 - 1),
        soft_addsubs: 0,
        storage_words: k64 * m64 // coefficient matrix
            + m64, // input taps
    }
}

/// Random-projection module inventory, `m → p`, Fox et al. FPT'16
/// style: a fully-spatial conditional add/subtract network — `p` output
/// accumulation trees, each fed by all `m` inputs gated by the ternary
/// sign of `R` (the generic reconfigurable fabric provisions the full
/// m×p network so any `R` can be loaded at run time). Zero multipliers,
/// zero DSPs.
pub fn rp_ops(m: usize, p: usize) -> OpCounts {
    assert!(m >= p && p >= 1, "need m >= p >= 1");
    let (m64, p64) = (m as u64, p as u64);
    OpCounts {
        mults: 0,
        adds: 0,
        soft_addsubs: m64 * p64,
        storage_words: m64       // input taps
            + p64                // output accumulators
            + (m64 * p64).div_euclid(16), // 2-bit ternary sign store, in words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage4_dominates() {
        let (m, n) = (32, 8);
        let (s4m, s4a) = easi_stage_ops(m, n, 4);
        let total = easi_ops(m, n);
        assert!(s4m * 2 > total.mults, "stage 4 is the mult hot-spot");
        assert!(s4a * 2 > total.adds, "stage 4 is the add hot-spot");
    }

    #[test]
    fn easi_totals_match_formula() {
        let (m, n) = (32u64, 8u64);
        let c = easi_ops(32, 8);
        assert_eq!(c.mults, n * n * m + 2 * n * m + 2 * n * n + 2 * n);
        assert_eq!(c.adds, n * (m - 1) + 2 * n * n + n * (n - 1) * m + n * m);
    }

    #[test]
    fn easi_complexity_is_o_mn2() {
        // Doubling m doubles the dominant term; doubling n quadruples it.
        let base = easi_ops(64, 8).mults as f64;
        let double_m = easi_ops(128, 8).mults as f64;
        let double_n = easi_ops(64, 16).mults as f64;
        assert!((double_m / base - 2.0).abs() < 0.2);
        assert!((double_n / base - 4.0).abs() < 0.6);
    }

    #[test]
    fn easi_split_sums_to_total() {
        for (m, n) in [(32, 8), (16, 8), (64, 16), (8, 8)] {
            let (w, r) = easi_split_ops(m, n);
            let total = easi_ops(m, n);
            assert_eq!(w.merge(&r), total, "split must partition m={m} n={n}");
            // Stage 4 (the O(m·n²) hot spot) belongs to the rotation
            // share; the whiten share is the O(m·n) matvec.
            assert!(r.mults > w.mults);
        }
    }

    #[test]
    fn rp_has_no_multipliers() {
        let c = rp_ops(32, 16);
        assert_eq!(c.mults, 0);
        assert_eq!(c.adds, 0);
        assert_eq!(c.soft_addsubs, 512);
    }

    #[test]
    fn merge_adds_fields() {
        let a = easi_ops(16, 8);
        let b = rp_ops(32, 16);
        let m = a.merge(&b);
        assert_eq!(m.mults, a.mults);
        assert_eq!(m.soft_addsubs, b.soft_addsubs);
        assert_eq!(m.storage_words, a.storage_words + b.storage_words);
    }

    #[test]
    fn linear_saving_in_easi_stage() {
        // The paper's core claim: halving the EASI input dimensionality
        // halves its (dominant) hardware complexity.
        let full = easi_ops(32, 8);
        let half = easi_ops(16, 8);
        let ratio = full.mults as f64 / half.mults as f64;
        assert!((ratio - 1.9).abs() < 0.15, "mult ratio {ratio}");
    }
}
