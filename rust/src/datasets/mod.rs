//! Dataset substrates.
//!
//! The paper evaluates on four datasets. The repo has no network access,
//! so per DESIGN.md §7 each is re-materialised as a generator:
//!
//! * [`waveform`] — *exact*: the UCI "Waveform Database Generator
//!   (Version 2)" dataset **is** a published generator (Breiman et al.,
//!   CART 1984); we implement it and draw the same 5000-sample split the
//!   paper uses.
//! * [`mnist_like`] — structural substitute for MNIST: 10-class 28×28
//!   images from prototype digit strokes + elastic jitter.
//! * [`har_like`] — structural substitute for the UCI HAR smartphone
//!   dataset: 6-class, 561 correlated statistics of class-conditioned
//!   AR(2) processes.
//! * [`ads_like`] — structural substitute for the Internet-Ads dataset:
//!   2-class, 1558 sparse binary features with low-rank discriminative
//!   structure.
//!
//! All generators take a seed and are fully deterministic.

pub mod ads_like;
pub mod csv;
pub mod har_like;
pub mod mnist_like;
pub mod waveform;

use crate::linalg::Mat;

/// A supervised dataset split into train and test partitions.
///
/// Rows of `*_x` are samples; `*_y` are class labels in
/// `0..num_classes`.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub train_x: Mat,
    pub train_y: Vec<usize>,
    pub test_x: Mat,
    pub test_y: Vec<usize>,
    pub num_classes: usize,
}

impl Dataset {
    /// Number of input features (the paper's `m`).
    pub fn input_dim(&self) -> usize {
        self.train_x.cols_count()
    }

    /// Sanity-check invariants; used by tests and by the coordinator on
    /// ingest.
    pub fn validate(&self) -> crate::Result<()> {
        use anyhow::ensure;
        ensure!(
            self.train_x.rows_count() == self.train_y.len(),
            "train rows/labels mismatch"
        );
        ensure!(
            self.test_x.rows_count() == self.test_y.len(),
            "test rows/labels mismatch"
        );
        ensure!(
            self.train_x.cols_count() == self.test_x.cols_count(),
            "train/test feature dims differ"
        );
        ensure!(self.num_classes >= 2, "need at least two classes");
        for &y in self.train_y.iter().chain(&self.test_y) {
            ensure!(y < self.num_classes, "label {y} out of range");
        }
        for &v in self.train_x.as_slice().iter().chain(self.test_x.as_slice()) {
            ensure!(v.is_finite(), "non-finite feature value");
        }
        Ok(())
    }

    /// Standardise features to zero mean / unit variance using statistics
    /// of the *training* partition (applied to both partitions). Returns
    /// the `(means, stds)` used. EASI and PCA whitening both assume
    /// zero-mean inputs, matching the paper's preprocessing.
    pub fn standardize(&mut self) -> (Vec<f32>, Vec<f32>) {
        let d = self.train_x.cols_count();
        let n = self.train_x.rows_count() as f32;
        let means = self.train_x.col_means();
        let mut vars = vec![0.0f32; d];
        for r in self.train_x.rows() {
            for ((v, &x), &m) in vars.iter_mut().zip(r).zip(&means) {
                let c = x - m;
                *v += c * c;
            }
        }
        let stds: Vec<f32> = vars.iter().map(|v| (v / n).sqrt().max(1e-6)).collect();
        for part in [&mut self.train_x, &mut self.test_x] {
            let rows = part.rows_count();
            for i in 0..rows {
                let row = part.row_mut(i);
                for ((x, &m), &s) in row.iter_mut().zip(&means).zip(&stds) {
                    *x = (*x - m) / s;
                }
            }
        }
        (means, stds)
    }

    /// Replace features with their image under a linear map `W` (rows of
    /// the output = `W · x`). Used to chain DR stages before training the
    /// classifier.
    pub fn map_features(&self, w: &Mat) -> Dataset {
        Dataset {
            name: self.name.clone(),
            train_x: w.apply_rows(&self.train_x),
            train_y: self.train_y.clone(),
            test_x: w.apply_rows(&self.test_x),
            test_y: self.test_y.clone(),
            num_classes: self.num_classes,
        }
    }
}

/// Per-class sample counts — used by tests to check class balance.
pub fn class_histogram(labels: &[usize], num_classes: usize) -> Vec<usize> {
    let mut h = vec![0usize; num_classes];
    for &y in labels {
        h[y] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            name: "tiny".into(),
            train_x: Mat::from_vec(4, 2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]),
            train_y: vec![0, 1, 0, 1],
            test_x: Mat::from_vec(2, 2, vec![1.0, 1.0, 2.0, 2.0]),
            test_y: vec![0, 1],
            num_classes: 2,
        }
    }

    #[test]
    fn validate_ok() {
        tiny().validate().unwrap();
    }

    #[test]
    fn validate_catches_bad_label() {
        let mut d = tiny();
        d.train_y[0] = 5;
        assert!(d.validate().is_err());
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut d = tiny();
        d.standardize();
        let means = d.train_x.col_means();
        for m in means {
            assert!(m.abs() < 1e-5);
        }
        let cov = d.train_x.covariance(true, false);
        for i in 0..2 {
            assert!((cov.get(i, i) - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn map_features_shapes() {
        let d = tiny();
        let w = Mat::eye(1, 2);
        let mapped = d.map_features(&w);
        assert_eq!(mapped.input_dim(), 1);
        assert_eq!(mapped.train_x.rows_count(), 4);
        // first feature preserved
        assert_eq!(mapped.train_x.get(2, 0), d.train_x.get(2, 0));
    }

    #[test]
    fn histogram_counts() {
        assert_eq!(class_histogram(&[0, 1, 1, 2], 3), vec![1, 2, 1]);
    }
}
