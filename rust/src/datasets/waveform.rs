//! Breiman's Waveform Database Generator (Version 2) — the paper's
//! Table I dataset, implemented *exactly*.
//!
//! The UCI "waveform-5000" file is a single 5000-sample draw from this
//! generator (Breiman, Friedman, Olshen & Stone, *Classification and
//! Regression Trees*, 1984, §2.6.1). Version 2 has 40 attributes: 21
//! informative + 19 pure `N(0,1)` noise.
//!
//! Each sample combines two of three triangular base waves
//! `h₁, h₂, h₃` (height 6, support width 13, centred at positions 7, 15
//! and 11 on the 1..=21 grid) with a uniform convex weight `u ~ U(0,1)`:
//!
//! ```text
//! class 0:  x_i = u·h₁(i) + (1−u)·h₂(i) + ε_i
//! class 1:  x_i = u·h₁(i) + (1−u)·h₃(i) + ε_i
//! class 2:  x_i = u·h₂(i) + (1−u)·h₃(i) + ε_i     ε_i ~ N(0,1)
//! ```
//!
//! Paper protocol (§V.A): 5000 samples, first 4000 train / last 1000
//! test, **drop the last 8 features** so m = 32. (The paper states the
//! remaining pure-noise count as 13; with the canonical 21+19 layout it
//! is 19−8 = 11 — the informative waves are ≈0 at the support edges,
//! which is presumably how the authors counted 13. The feature count 32
//! is what matters and is preserved.)

use super::Dataset;
use crate::linalg::Mat;
use crate::rng::{Pcg64, RngExt};

/// Number of informative features in the canonical generator.
pub const INFORMATIVE: usize = 21;
/// Total features in Version 2 (before the paper's truncation).
pub const TOTAL_V2: usize = 40;

/// Triangular base wave `h_k(i)` for `k ∈ {0,1,2}` and 1-based grid
/// position `i ∈ 1..=21`.
#[inline]
pub fn base_wave(k: usize, i: usize) -> f32 {
    let center = match k {
        0 => 7.0,
        1 => 15.0,
        2 => 11.0,
        _ => panic!("base wave index out of range"),
    };
    (6.0 - (i as f32 - center).abs()).max(0.0)
}

/// Which pair of base waves each class mixes.
#[inline]
pub fn class_waves(class: usize) -> (usize, usize) {
    match class {
        0 => (0, 1),
        1 => (0, 2),
        2 => (1, 2),
        _ => panic!("class out of range"),
    }
}

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct WaveformConfig {
    /// Total samples to draw.
    pub samples: usize,
    /// Samples used for training (the rest are the test split).
    pub train: usize,
    /// Features kept (from the front); the paper keeps 32 of 40.
    pub keep_features: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for WaveformConfig {
    fn default() -> Self {
        Self {
            samples: 5000,
            train: 4000,
            keep_features: TOTAL_V2,
            seed: 2018,
        }
    }
}

impl WaveformConfig {
    /// The exact configuration of the paper's §V.A: 5000 samples,
    /// 4000/1000 split, last 8 features removed ⇒ m = 32.
    pub fn paper() -> Self {
        Self {
            keep_features: 32,
            ..Self::default()
        }
    }

    /// Draw the dataset.
    pub fn generate(&self) -> Dataset {
        assert!(self.train < self.samples, "train split must leave test data");
        assert!(
            self.keep_features >= 1 && self.keep_features <= TOTAL_V2,
            "keep_features out of range"
        );
        let mut rng = Pcg64::seed_stream(self.seed, STREAM_TAG);
        let mut xs = Vec::with_capacity(self.samples * self.keep_features);
        let mut ys = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let class = rng.next_below(3) as usize;
            let (a, b) = class_waves(class);
            let u = rng.next_f32();
            for i in 1..=TOTAL_V2 {
                // Draw noise for every canonical feature so the stream is
                // identical regardless of truncation, then keep the front.
                let eps = rng.next_gaussian() as f32;
                let v = if i <= INFORMATIVE {
                    u * base_wave(a, i) + (1.0 - u) * base_wave(b, i) + eps
                } else {
                    eps
                };
                if i <= self.keep_features {
                    xs.push(v);
                }
            }
            ys.push(class);
        }
        let split = self.train * self.keep_features;
        let (train_flat, test_flat) = xs.split_at(split);
        Dataset {
            name: format!("waveform-m{}", self.keep_features),
            train_x: Mat::from_vec(self.train, self.keep_features, train_flat.to_vec()),
            train_y: ys[..self.train].to_vec(),
            test_x: Mat::from_vec(
                self.samples - self.train,
                self.keep_features,
                test_flat.to_vec(),
            ),
            test_y: ys[self.train..].to_vec(),
            num_classes: 3,
        }
    }
}

/// Sub-stream tag for the waveform generator ("WAVE" in ASCII).
const STREAM_TAG: u64 = 0x5741_5645;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::class_histogram;

    #[test]
    fn base_waves_shape() {
        // Height 6 at the centre, zero at distance >= 6.
        assert_eq!(base_wave(0, 7), 6.0);
        assert_eq!(base_wave(1, 15), 6.0);
        assert_eq!(base_wave(2, 11), 6.0);
        assert_eq!(base_wave(0, 1), 0.0);
        assert_eq!(base_wave(0, 13), 0.0);
        assert_eq!(base_wave(0, 8), 5.0);
    }

    #[test]
    fn paper_config_shapes() {
        let d = WaveformConfig::paper().generate();
        d.validate().unwrap();
        assert_eq!(d.train_x.shape(), (4000, 32));
        assert_eq!(d.test_x.shape(), (1000, 32));
        assert_eq!(d.num_classes, 3);
    }

    #[test]
    fn classes_roughly_balanced() {
        let d = WaveformConfig::paper().generate();
        let h = class_histogram(&d.train_y, 3);
        for c in h {
            assert!((c as f64 - 4000.0 / 3.0).abs() < 150.0, "class count {c}");
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = WaveformConfig::paper().generate();
        let b = WaveformConfig::paper().generate();
        assert_eq!(a.train_x.as_slice(), b.train_x.as_slice());
        let c = WaveformConfig {
            seed: 7,
            ..WaveformConfig::paper()
        }
        .generate();
        assert_ne!(a.train_x.as_slice(), c.train_x.as_slice());
    }

    #[test]
    fn truncation_preserves_front_features() {
        // Same seed with and without truncation must agree on the kept
        // features (the noise stream is drawn for all 40 either way).
        let full = WaveformConfig::default().generate();
        let trunc = WaveformConfig::paper().generate();
        for i in 0..100 {
            for j in 0..32 {
                assert_eq!(full.train_x.get(i, j), trunc.train_x.get(i, j));
            }
        }
    }

    #[test]
    fn noise_features_are_standard_normal() {
        let d = WaveformConfig::default().generate();
        // Feature 40 (index 39) is pure noise.
        let col: Vec<f32> = d.train_x.col(39).collect();
        let n = col.len() as f64;
        let mean: f64 = col.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var: f64 = col.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn informative_features_depend_on_class() {
        let d = WaveformConfig::default().generate();
        // Feature at grid 7 (index 6) peaks for classes using h1 (0, 1).
        let mut means = [0.0f64; 3];
        let mut counts = [0usize; 3];
        for (i, &y) in d.train_y.iter().enumerate() {
            means[y] += d.train_x.get(i, 6) as f64;
            counts[y] += 1;
        }
        for k in 0..3 {
            means[k] /= counts[k] as f64;
        }
        // classes 0 and 1 mix h1 with weight E[u]=0.5 → mean ≈ 3 at the
        // h1 peak; class 2 has no h1 → mean ≈ h2(7)+h3(7) weighted ≈ 1.
        assert!(means[0] > 2.0 && means[1] > 2.0);
        assert!(means[2] < means[0] && means[2] < means[1]);
    }
}
