//! HAR-like synthetic dataset (substitution for Fig. 1b — see
//! DESIGN.md §7).
//!
//! The UCI HAR dataset contains 561 statistics (means, stds, band
//! energies, correlations, ...) computed from smartphone accelerometer /
//! gyroscope windows, for 6 activity classes. Structurally: a long,
//! highly *redundant* feature vector derived from a few underlying
//! signals — intrinsic dimensionality ≈ tens, which is why Fig. 1b shows
//! ICA/RP holding accuracy down to ~90 features.
//!
//! We reproduce that structure generatively: each class defines the
//! dynamics of six latent AR(2) processes (3-axis accel + 3-axis gyro);
//! a window of the processes is simulated and 561 redundant statistics
//! are extracted (per-signal moments, pairwise correlations, lag
//! autocorrelations, band energies, and many linear recombinations —
//! mirroring HAR's heavily-correlated feature blocks).

use super::Dataset;
use crate::linalg::Mat;
use crate::rng::{Pcg64, RngExt};

/// Feature dimensionality, matching UCI HAR.
pub const DIM: usize = 561;
/// Number of activity classes (walking, upstairs, downstairs, sitting,
/// standing, laying in the original).
pub const CLASSES: usize = 6;
/// Latent signals (3-axis accelerometer + 3-axis gyroscope).
const SIGNALS: usize = 6;
/// Samples per simulated window (2.56 s @ 50 Hz in the original).
const WINDOW: usize = 128;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct HarLikeConfig {
    pub train: usize,
    pub test: usize,
    pub seed: u64,
}

impl Default for HarLikeConfig {
    fn default() -> Self {
        Self {
            train: 4000,
            test: 1000,
            seed: 2018,
        }
    }
}

/// Class-conditioned AR(2) parameters for each latent signal:
/// x_t = a₁ x_{t-1} + a₂ x_{t-2} + σ ε_t, plus a per-class DC offset
/// (gravity orientation differs between postures).
fn class_dynamics(class: usize, signal: usize) -> (f32, f32, f32, f32) {
    // Hand-tuned so that: classes 0-2 (dynamic activities) are
    // oscillatory with class-specific resonance; classes 3-5 (static
    // postures) are near-DC with distinct offsets.
    let cf = class as f32;
    let sf = signal as f32;
    // Position-coded class signatures: the DC offsets ALTERNATE in sign
    // across signals so the global mean carries (almost) no class
    // information — distinguishing classes requires reading *specific*
    // feature positions, which is exactly what a low-frequency DCT
    // truncation cannot do (the property behind Fig. 1b's bilinear
    // collapse; real HAR features likewise have no meaningful "smooth"
    // ordering).
    let alt = if signal % 2 == 0 { 1.0 } else { -1.0 };
    match class {
        0..=2 => {
            // Oscillatory AR(2): poles at r·e^{±iω}, ω class+signal
            // specific (closely spaced — classes overlap).
            let omega = 0.30 + 0.09 * cf + 0.05 * sf;
            let r = 0.94 - 0.015 * cf;
            (2.0 * r * omega.cos(), -r * r, 0.30 + 0.05 * cf, 0.12 * alt * cf)
        }
        _ => {
            // Near-static: strong AR(1)-ish smoothing, moderate noise,
            // class-distinct but sign-alternating DC (gravity
            // projection differs per axis, cancels in aggregate).
            let a1 = 0.97 - 0.01 * (cf - 3.0);
            (a1, 0.0, 0.08, alt * (0.35 * (cf - 3.0) + 0.25) + 0.1 * sf - 0.25)
        }
    }
}

/// Simulate one window of the six latent signals for a class.
fn simulate_window(class: usize, rng: &mut Pcg64) -> Vec<Vec<f32>> {
    (0..SIGNALS)
        .map(|s| {
            let (a1, a2, sigma, dc) = class_dynamics(class, s);
            let mut x = vec![0.0f32; WINDOW];
            let (mut x1, mut x2) = (0.0f32, 0.0f32);
            // Burn-in so the window starts in the stationary regime.
            for t in 0..(WINDOW + 32) {
                let v = a1 * x1 + a2 * x2 + sigma * rng.next_gaussian() as f32;
                x2 = x1;
                x1 = v;
                if t >= 32 {
                    x[t - 32] = v + dc;
                }
            }
            x
        })
        .collect()
}

/// Extract 561 redundant statistics from the window — the HAR feature
/// recipe in miniature, padded with deterministic linear recombinations
/// (HAR's own tail features are similarly derived/correlated).
fn extract_features(window: &[Vec<f32>]) -> Vec<f32> {
    let mut f = Vec::with_capacity(DIM);
    let n = WINDOW as f32;
    let mut stats: Vec<(f32, f32)> = Vec::with_capacity(SIGNALS); // (mean, std)
    // Block 1: per-signal moments + extrema + energy (6 × 8 = 48).
    for x in window {
        let mean = x.iter().sum::<f32>() / n;
        let var = x.iter().map(|v| (v - mean).powi(2)).sum::<f32>() / n;
        let std = var.sqrt();
        let mad = x.iter().map(|v| (v - mean).abs()).sum::<f32>() / n;
        let min = x.iter().cloned().fold(f32::INFINITY, f32::min);
        let max = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let energy = x.iter().map(|v| v * v).sum::<f32>() / n;
        let skewish = x.iter().map(|v| (v - mean).powi(3)).sum::<f32>() / (n * (std.powi(3) + 1e-6));
        f.extend_from_slice(&[mean, std, mad, min, max, energy, skewish, max - min]);
        stats.push((mean, std));
    }
    // Block 2: lagged autocorrelations, lags 1..=8 (6 × 8 = 48).
    for (s, x) in window.iter().enumerate() {
        let (mean, std) = stats[s];
        for lag in 1..=8usize {
            let mut ac = 0.0f32;
            for t in lag..WINDOW {
                ac += (x[t] - mean) * (x[t - lag] - mean);
            }
            f.push(ac / ((n - lag as f32) * (std * std + 1e-6)));
        }
    }
    // Block 3: pairwise correlations (15).
    for i in 0..SIGNALS {
        for j in (i + 1)..SIGNALS {
            let (mi, si) = stats[i];
            let (mj, sj) = stats[j];
            let mut c = 0.0f32;
            for t in 0..WINDOW {
                c += (window[i][t] - mi) * (window[j][t] - mj);
            }
            f.push(c / (n * (si * sj + 1e-6)));
        }
    }
    // Block 4: 8-band energies via Goertzel-style projections (6 × 8 = 48).
    for x in window {
        for band in 0..8usize {
            let omega = std::f32::consts::PI * (band as f32 + 0.5) / 8.0;
            let (mut re, mut im) = (0.0f32, 0.0f32);
            for (t, &v) in x.iter().enumerate() {
                let ph = omega * t as f32;
                re += v * ph.cos();
                im += v * ph.sin();
            }
            f.push((re * re + im * im) / (n * n));
        }
    }
    // Block 5: deterministic redundant recombinations up to 561 —
    // fixed sparse linear mixes of the base features (mirrors HAR's
    // derived angle()/gravityMean-style features and gives the feature
    // vector its characteristic redundancy).
    let base = f.len();
    let mut k = 0usize;
    while f.len() < DIM {
        let i = (k * 7 + 3) % base;
        let j = (k * 13 + 5) % base;
        let l = (k * 29 + 11) % base;
        let v = match k % 3 {
            0 => 0.5 * (f[i] + f[j]),
            1 => f[i] - 0.5 * f[j] + 0.25 * f[l],
            _ => 0.75 * f[i] + 0.25 * f[l],
        };
        f.push(v);
        k += 1;
    }
    debug_assert_eq!(f.len(), DIM);
    // Scatter the features with a fixed pseudo-random permutation: the
    // real HAR vector has no meaningful serial ordering (means, stds,
    // band energies and correlations are interleaved by the feature
    // recipe), so methods that exploit positional smoothness (the
    // bilinear/DCT baseline) find none — the property behind Fig. 1b's
    // bilinear collapse. PCA/ICA/RP are permutation-equivariant and
    // unaffected.
    let mut out = vec![0.0f32; DIM];
    for (i, v) in f.into_iter().enumerate() {
        out[feature_permutation(i)] = v;
    }
    out
}

/// Deterministic feature permutation (multiplicative shuffle; 350 and
/// 561 are coprime so this is a bijection).
#[inline]
fn feature_permutation(i: usize) -> usize {
    (i * 350 + 97) % DIM
}

impl HarLikeConfig {
    pub fn generate(&self) -> Dataset {
        let mut rng = Pcg64::seed_stream(self.seed, 0x4841_5253); // "HARS"
        let total = self.train + self.test;
        let mut xs = Vec::with_capacity(total * DIM);
        let mut ys = Vec::with_capacity(total);
        for _ in 0..total {
            let class = rng.next_below(CLASSES as u64) as usize;
            let w = simulate_window(class, &mut rng);
            xs.extend(extract_features(&w));
            ys.push(class);
        }
        let (tr, te) = xs.split_at(self.train * DIM);
        Dataset {
            name: "har-like".into(),
            train_x: Mat::from_vec(self.train, DIM, tr.to_vec()),
            train_y: ys[..self.train].to_vec(),
            test_x: Mat::from_vec(self.test, DIM, te.to_vec()),
            test_y: ys[self.train..].to_vec(),
            num_classes: CLASSES,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::class_histogram;

    fn small() -> Dataset {
        HarLikeConfig {
            train: 240,
            test: 60,
            ..Default::default()
        }
        .generate()
    }

    #[test]
    fn shapes_and_validity() {
        let d = small();
        d.validate().unwrap();
        assert_eq!(d.input_dim(), 561);
        assert_eq!(d.num_classes, 6);
    }

    #[test]
    fn all_classes_present() {
        let d = small();
        let h = class_histogram(&d.train_y, 6);
        assert!(h.iter().all(|&c| c > 0), "{h:?}");
    }

    #[test]
    fn deterministic() {
        let a = small();
        let b = small();
        assert_eq!(a.train_x.as_slice(), b.train_x.as_slice());
    }

    #[test]
    fn static_classes_have_distinct_dc() {
        // The signal-0 mean feature (original index 0, scattered to
        // feature_permutation(0)) must separate the static postures
        // (classes 3..5).
        let d = HarLikeConfig {
            train: 600,
            test: 60,
            ..Default::default()
        }
        .generate();
        let col = super::feature_permutation(0);
        let mut means = [0.0f64; 6];
        let mut counts = [0usize; 6];
        for (i, &y) in d.train_y.iter().enumerate() {
            means[y] += d.train_x.get(i, col) as f64;
            counts[y] += 1;
        }
        for k in 0..6 {
            means[k] /= counts[k].max(1) as f64;
        }
        assert!((means[3] - means[4]).abs() > 0.2 || (means[4] - means[5]).abs() > 0.2,
                "static class means: {:?}", &means[3..]);
    }

    #[test]
    fn feature_permutation_is_bijection() {
        let mut seen = vec![false; DIM];
        for i in 0..DIM {
            let j = super::feature_permutation(i);
            assert!(!seen[j], "collision at {i} -> {j}");
            seen[j] = true;
        }
    }

    #[test]
    fn features_are_redundant() {
        // The recombination block guarantees exact linear dependence —
        // the property that makes aggressive DR possible on this dataset.
        let d = small();
        // Feature `base + 0` is 0.5*(f[3] + f[5]) by construction.
        // Verify via correlation instead of exact indices: the tail block
        // must be highly correlated with the head block.
        let cov = d.train_x.covariance(true, false);
        let mut max_corr = 0.0f64;
        for tail in 400..561 {
            for head in 0..200 {
                let c = cov.get(tail, head) as f64
                    / ((cov.get(tail, tail) as f64).sqrt() * (cov.get(head, head) as f64).sqrt()
                        + 1e-12);
                max_corr = max_corr.max(c.abs());
            }
        }
        assert!(max_corr > 0.8, "tail/head max correlation {max_corr}");
    }
}
