//! Internet-Ads-like synthetic dataset (substitution for Fig. 1c — see
//! DESIGN.md §7).
//!
//! The UCI Internet Advertisements dataset: 2 classes (ad / not-ad),
//! 1558 features — 3 continuous geometry features plus ~1555 sparse
//! binary bag-of-words indicators from the URL / anchor / alt text.
//! Fig. 1c's striking result — accuracy flat down to **five** features —
//! works because the class signal lives in a very low-rank subspace of
//! the sparse binary features (a few keyword clusters decide "ad").
//!
//! The generator reproduces exactly that: a handful of latent topics,
//! each activating a block of correlated binary features, with class
//! determined by two "ad-ish" topics; plus 3 geometry features
//! (width/height/aspect) whose distribution is class-conditional.

use super::Dataset;
use crate::linalg::Mat;
use crate::rng::{Pcg64, RngExt};

/// Feature dimensionality, matching UCI Internet Ads.
pub const DIM: usize = 1558;
/// Latent topics generating the binary block.
const TOPICS: usize = 12;
/// Continuous geometry features at the front (height, width, aspect).
const GEOM: usize = 3;

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct AdsLikeConfig {
    pub train: usize,
    pub test: usize,
    pub seed: u64,
    /// Fraction of positive (ad) samples; the real dataset is ~14% ads.
    pub pos_rate: f64,
}

impl Default for AdsLikeConfig {
    fn default() -> Self {
        Self {
            train: 2000,
            test: 500,
            seed: 2018,
            pos_rate: 0.5, // balanced by default so accuracy is informative
        }
    }
}

/// Deterministic topic → feature-block assignment. Each binary feature
/// belongs to exactly one topic; topic blocks tile the 1555 binary dims.
#[inline]
fn topic_of(feature: usize) -> usize {
    // feature index within the binary block
    (feature * TOPICS) / (DIM - GEOM)
}

impl AdsLikeConfig {
    pub fn generate(&self) -> Dataset {
        let mut rng = Pcg64::seed_stream(self.seed, 0x4144_5321); // "ADS!"
        let total = self.train + self.test;
        let mut xs = Vec::with_capacity(total * DIM);
        let mut ys = Vec::with_capacity(total);
        for _ in 0..total {
            let is_ad = rng.next_f64() < self.pos_rate;
            // Topic intensities: ads strongly activate topics 0-1
            // ("banner words"), weakly 2-3; non-ads the reverse, with
            // shared background topics 4..12.
            let mut intensity = [0.0f64; TOPICS];
            for (t, it) in intensity.iter_mut().enumerate() {
                // Topics 0-3 are "ad vocabularies", 4-7 "content
                // vocabularies", 8-11 class-independent background. The
                // wide firing-rate contrast concentrates the class signal
                // in a strong low-rank direction — the property that lets
                // Fig. 1c hold accuracy down to ~5 features.
                let base = match (is_ad, t) {
                    (true, 0..=3) => 2.0,
                    (true, 4..=7) => 0.04,
                    (false, 0..=3) => 0.03,
                    (false, 4..=7) => 1.9,
                    _ => 0.15, // background topics, class-independent
                };
                // Mild per-sample topic jitter creates within-class
                // variation without drowning the class signal.
                *it = (base * (0.85 + 0.3 * rng.next_f64())).clamp(0.0, 2.4);
            }
            // Geometry features: ads are wide and short (banners).
            let (h, w) = if is_ad {
                (
                    rng.next_gaussian_with(60.0, 15.0).max(1.0),
                    rng.next_gaussian_with(440.0, 80.0).max(1.0),
                )
            } else {
                (
                    rng.next_gaussian_with(140.0, 60.0).max(1.0),
                    rng.next_gaussian_with(160.0, 70.0).max(1.0),
                )
            };
            xs.push(h as f32);
            xs.push(w as f32);
            xs.push((w / h) as f32);
            // Sparse binary block: feature j fires w.p. its topic
            // intensity (plus a small floor so no column is constant).
            for j in 0..(DIM - GEOM) {
                let p = intensity[topic_of(j)] * 0.25 + 0.003;
                xs.push(if rng.next_f64() < p { 1.0 } else { 0.0 });
            }
            ys.push(if is_ad { 1 } else { 0 });
        }
        let (tr, te) = xs.split_at(self.train * DIM);
        Dataset {
            name: "ads-like".into(),
            train_x: Mat::from_vec(self.train, DIM, tr.to_vec()),
            train_y: ys[..self.train].to_vec(),
            test_x: Mat::from_vec(self.test, DIM, te.to_vec()),
            test_y: ys[self.train..].to_vec(),
            num_classes: 2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Dataset {
        AdsLikeConfig {
            train: 400,
            test: 100,
            ..Default::default()
        }
        .generate()
    }

    #[test]
    fn shapes_and_validity() {
        let d = small();
        d.validate().unwrap();
        assert_eq!(d.input_dim(), 1558);
        assert_eq!(d.num_classes, 2);
    }

    #[test]
    fn binary_block_is_sparse() {
        let d = small();
        let total = (d.train_x.rows_count() * (DIM - GEOM)) as f64;
        let ones: f64 = d
            .train_x
            .rows()
            .map(|r| r[GEOM..].iter().filter(|&&v| v == 1.0).count() as f64)
            .sum();
        let density = ones / total;
        assert!(density < 0.25, "density {density}");
        assert!(density > 0.001, "density {density}");
    }

    #[test]
    fn binary_features_are_binary() {
        let d = small();
        for r in d.train_x.rows().take(20) {
            for &v in &r[GEOM..] {
                assert!(v == 0.0 || v == 1.0);
            }
        }
    }

    #[test]
    fn ad_topics_separate_classes() {
        let d = small();
        // Mean activation of topic-0 block must be much higher for ads.
        let block_end = (DIM - GEOM) / TOPICS;
        let mut m = [0.0f64; 2];
        let mut c = [0usize; 2];
        for (i, &y) in d.train_y.iter().enumerate() {
            let r = d.train_x.row(i);
            m[y] += r[GEOM..GEOM + block_end].iter().map(|&v| v as f64).sum::<f64>();
            c[y] += 1;
        }
        let (neg, pos) = (m[0] / c[0] as f64, m[1] / c[1] as f64);
        assert!(pos > 3.0 * neg, "pos {pos} vs neg {neg}");
    }

    #[test]
    fn geometry_separates_classes() {
        let d = small();
        // Aspect ratio (feature 2) is larger for ads.
        let mut m = [0.0f64; 2];
        let mut c = [0usize; 2];
        for (i, &y) in d.train_y.iter().enumerate() {
            m[y] += d.train_x.get(i, 2) as f64;
            c[y] += 1;
        }
        assert!(m[1] / c[1] as f64 > m[0] / c[0] as f64);
    }

    #[test]
    fn deterministic() {
        let a = small();
        let b = small();
        assert_eq!(a.train_x.as_slice(), b.train_x.as_slice());
    }
}
