//! MNIST-like synthetic digits (substitution for Fig. 1a — see
//! DESIGN.md §7).
//!
//! No network access means no real MNIST. Fig. 1a's message is about the
//! *structure* of natural images — strong local pixel correlation and an
//! intrinsic dimensionality far below 784 — which is what lets
//! PCA/ICA/RP compress 784 → ~50–100 features without hurting a
//! classifier. This generator reproduces those structural properties:
//!
//! * 10 classes, each a 28×28 prototype digit drawn with line strokes;
//! * per-sample elastic deformation (random affine: shift, scale,
//!   shear) — creates a low-dimensional class manifold;
//! * per-sample stroke-thickness / intensity variation;
//! * smoothing kernel — produces the local correlation PCA exploits;
//! * pixel noise.

use super::Dataset;
use crate::linalg::Mat;
use crate::rng::{Pcg64, RngExt};

/// Image side; features = SIDE².
pub const SIDE: usize = 28;
/// Feature dimensionality (28×28 = 784, as MNIST).
pub const DIM: usize = SIDE * SIDE;

/// Stroke segments (in a nominal 20×20 box, origin top-left) per digit.
/// Crude 7-segment-ish renderings are enough: classes only need to be
/// mutually distinguishable, not beautiful.
fn digit_strokes(d: usize) -> &'static [((f32, f32), (f32, f32))] {
    // Segment endpoints (x, y) in [0, 20]².
    const S: [&[((f32, f32), (f32, f32))]; 10] = [
        // 0: rounded box
        &[
            ((5.0, 2.0), (15.0, 2.0)),
            ((15.0, 2.0), (15.0, 18.0)),
            ((15.0, 18.0), (5.0, 18.0)),
            ((5.0, 18.0), (5.0, 2.0)),
        ],
        // 1: vertical bar + flag
        &[((10.0, 2.0), (10.0, 18.0)), ((7.0, 5.0), (10.0, 2.0))],
        // 2
        &[
            ((5.0, 4.0), (15.0, 2.0)),
            ((15.0, 2.0), (15.0, 9.0)),
            ((15.0, 9.0), (5.0, 18.0)),
            ((5.0, 18.0), (15.0, 18.0)),
        ],
        // 3
        &[
            ((5.0, 2.0), (15.0, 2.0)),
            ((15.0, 2.0), (8.0, 10.0)),
            ((8.0, 10.0), (15.0, 14.0)),
            ((15.0, 14.0), (5.0, 18.0)),
        ],
        // 4
        &[
            ((13.0, 2.0), (5.0, 12.0)),
            ((5.0, 12.0), (16.0, 12.0)),
            ((13.0, 2.0), (13.0, 18.0)),
        ],
        // 5
        &[
            ((15.0, 2.0), (5.0, 2.0)),
            ((5.0, 2.0), (5.0, 10.0)),
            ((5.0, 10.0), (15.0, 12.0)),
            ((15.0, 12.0), (13.0, 18.0)),
            ((13.0, 18.0), (5.0, 17.0)),
        ],
        // 6
        &[
            ((14.0, 2.0), (6.0, 8.0)),
            ((6.0, 8.0), (5.0, 15.0)),
            ((5.0, 15.0), (10.0, 18.0)),
            ((10.0, 18.0), (15.0, 14.0)),
            ((15.0, 14.0), (6.0, 11.0)),
        ],
        // 7
        &[((5.0, 2.0), (15.0, 2.0)), ((15.0, 2.0), (8.0, 18.0))],
        // 8
        &[
            ((10.0, 2.0), (5.0, 6.0)),
            ((5.0, 6.0), (15.0, 13.0)),
            ((15.0, 13.0), (10.0, 18.0)),
            ((10.0, 18.0), (5.0, 13.0)),
            ((5.0, 13.0), (15.0, 6.0)),
            ((15.0, 6.0), (10.0, 2.0)),
        ],
        // 9
        &[
            ((14.0, 9.0), (6.0, 7.0)),
            ((6.0, 7.0), (8.0, 2.0)),
            ((8.0, 2.0), (14.0, 4.0)),
            ((14.0, 4.0), (14.0, 9.0)),
            ((14.0, 9.0), (12.0, 18.0)),
        ],
    ];
    S[d]
}

/// Generator configuration.
#[derive(Debug, Clone)]
pub struct MnistLikeConfig {
    pub train: usize,
    pub test: usize,
    pub seed: u64,
    /// Gaussian pixel-noise standard deviation (on [0,1] intensities).
    pub noise: f32,
}

impl Default for MnistLikeConfig {
    fn default() -> Self {
        Self {
            train: 4000,
            test: 1000,
            seed: 2018,
            noise: 0.08,
        }
    }
}

impl MnistLikeConfig {
    pub fn generate(&self) -> Dataset {
        let mut rng = Pcg64::seed_stream(self.seed, 0x4D4E_4953); // "MNIS"
        let total = self.train + self.test;
        let mut xs = Vec::with_capacity(total * DIM);
        let mut ys = Vec::with_capacity(total);
        for _ in 0..total {
            let class = rng.next_below(10) as usize;
            let img = render_digit(class, &mut rng, self.noise);
            xs.extend_from_slice(&img);
            ys.push(class);
        }
        let split = self.train * DIM;
        let (tr, te) = xs.split_at(split);
        Dataset {
            name: "mnist-like".into(),
            train_x: Mat::from_vec(self.train, DIM, tr.to_vec()),
            train_y: ys[..self.train].to_vec(),
            test_x: Mat::from_vec(self.test, DIM, te.to_vec()),
            test_y: ys[self.train..].to_vec(),
            num_classes: 10,
        }
    }
}

/// Render one jittered digit into a 784-vector of [0,1] intensities.
fn render_digit(class: usize, rng: &mut Pcg64, noise: f32) -> Vec<f32> {
    // Random affine jitter: shift ±2px, scale 0.85–1.15, shear ±0.15.
    let dx = rng.next_gaussian_with(4.0, 1.0) as f32; // nominal offset into 28 box
    let dy = rng.next_gaussian_with(4.0, 1.0) as f32;
    let scale = 0.85 + 0.3 * rng.next_f32();
    let shear = (rng.next_f32() - 0.5) * 0.3;
    let thickness = 1.0 + 0.6 * rng.next_f32();
    let intensity = 0.75 + 0.25 * rng.next_f32();

    let mut img = vec![0.0f32; DIM];
    for &((x0, y0), (x1, y1)) in digit_strokes(class) {
        // Transform endpoints.
        let tx = |x: f32, y: f32| scale * (x + shear * y) + dx;
        let ty = |y: f32| scale * y + dy;
        let (ax, ay) = (tx(x0, y0), ty(y0));
        let (bx, by) = (tx(x1, y1), ty(y1));
        // Rasterise the segment with a soft (Gaussian-profile) pen.
        let len = ((bx - ax).powi(2) + (by - ay).powi(2)).sqrt().max(1e-3);
        let steps = (len * 2.0).ceil() as usize + 1;
        for s in 0..=steps {
            let t = s as f32 / steps as f32;
            let px = ax + t * (bx - ax);
            let py = ay + t * (by - ay);
            let r = thickness.ceil() as i32 + 1;
            let (cx, cy) = (px.round() as i32, py.round() as i32);
            for oy in -r..=r {
                for ox in -r..=r {
                    let (ix, iy) = (cx + ox, cy + oy);
                    if ix < 0 || iy < 0 || ix >= SIDE as i32 || iy >= SIDE as i32 {
                        continue;
                    }
                    let d2 = (ix as f32 - px).powi(2) + (iy as f32 - py).powi(2);
                    let v = intensity * (-d2 / (thickness * thickness)).exp();
                    let idx = iy as usize * SIDE + ix as usize;
                    img[idx] = img[idx].max(v);
                }
            }
        }
    }
    // Pixel noise, clipped to [0,1].
    for p in &mut img {
        *p = (*p + noise * rng.next_gaussian() as f32).clamp(0.0, 1.0);
    }
    img
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::class_histogram;

    fn small() -> Dataset {
        MnistLikeConfig {
            train: 300,
            test: 100,
            ..Default::default()
        }
        .generate()
    }

    #[test]
    fn shapes_and_validity() {
        let d = small();
        d.validate().unwrap();
        assert_eq!(d.input_dim(), 784);
        assert_eq!(d.num_classes, 10);
    }

    #[test]
    fn intensities_in_unit_interval() {
        let d = small();
        for &v in d.train_x.as_slice() {
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn all_classes_present() {
        let d = small();
        let h = class_histogram(&d.train_y, 10);
        assert!(h.iter().all(|&c| c > 0), "histogram {h:?}");
    }

    #[test]
    fn images_have_ink() {
        let d = small();
        for r in d.train_x.rows().take(50) {
            let ink: f32 = r.iter().sum();
            assert!(ink > 5.0, "blank image (ink {ink})");
        }
    }

    #[test]
    fn classes_are_distinguishable_by_mean_image() {
        // Mean images of different classes should differ substantially.
        let d = MnistLikeConfig {
            train: 1000,
            test: 10,
            ..Default::default()
        }
        .generate();
        let mut means = vec![vec![0.0f32; DIM]; 10];
        let mut counts = [0usize; 10];
        for (i, &y) in d.train_y.iter().enumerate() {
            for (m, &x) in means[y].iter_mut().zip(d.train_x.row(i)) {
                *m += x;
            }
            counts[y] += 1;
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f32;
            }
        }
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt()
        };
        for i in 0..10 {
            for j in (i + 1)..10 {
                assert!(
                    dist(&means[i], &means[j]) > 1.0,
                    "classes {i}/{j} too similar"
                );
            }
        }
    }

    #[test]
    fn neighbouring_pixels_correlated() {
        // The property Fig. 1a exploits: local pixel correlation.
        let d = small();
        let a: Vec<f32> = d.train_x.col(14 * SIDE + 13).collect();
        let b: Vec<f32> = d.train_x.col(14 * SIDE + 14).collect();
        let n = a.len() as f64;
        let (ma, mb) = (
            a.iter().map(|&x| x as f64).sum::<f64>() / n,
            b.iter().map(|&x| x as f64).sum::<f64>() / n,
        );
        let mut cov = 0.0;
        let mut va = 0.0;
        let mut vb = 0.0;
        for (&x, &y) in a.iter().zip(&b) {
            cov += (x as f64 - ma) * (y as f64 - mb);
            va += (x as f64 - ma).powi(2);
            vb += (y as f64 - mb).powi(2);
        }
        let corr = cov / (va.sqrt() * vb.sqrt() + 1e-12);
        assert!(corr > 0.5, "neighbour correlation {corr}");
    }
}
