//! Minimal CSV loader so users can feed *real* MNIST/HAR/Ads exports (or
//! any numeric dataset) through the same pipelines the synthetic
//! generators drive. Format: one sample per line, comma-separated
//! features, label as the **last** column (integer). Lines starting with
//! `#` and blank lines are skipped.

use super::Dataset;
use crate::linalg::Mat;
use crate::Result;
use anyhow::{ensure, anyhow, Context};
use std::path::Path;

/// Parse CSV text into `(features, labels)` rows.
pub fn parse_csv(text: &str) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
    let mut rows = Vec::new();
    let mut labels = Vec::new();
    let mut width: Option<usize> = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        ensure!(fields.len() >= 2, "line {}: need >=2 columns", lineno + 1);
        let label: usize = fields
            .last()
            .unwrap()
            .parse()
            .map_err(|e| anyhow!("line {}: bad label: {e}", lineno + 1))?;
        let feats: Vec<f32> = fields[..fields.len() - 1]
            .iter()
            .map(|f| {
                f.parse::<f32>()
                    .map_err(|e| anyhow!("line {}: bad feature '{f}': {e}", lineno + 1))
            })
            .collect::<Result<_>>()?;
        if let Some(w) = width {
            ensure!(feats.len() == w, "line {}: ragged row", lineno + 1);
        } else {
            width = Some(feats.len());
        }
        rows.push(feats);
        labels.push(label);
    }
    ensure!(!rows.is_empty(), "empty CSV");
    Ok((rows, labels))
}

/// Load a dataset from a CSV file, splitting the first `train_fraction`
/// of rows into the training partition (file order is preserved — shuffle
/// upstream if needed).
pub fn load_csv(path: &Path, name: &str, train_fraction: f64) -> Result<Dataset> {
    ensure!(
        (0.0..1.0).contains(&train_fraction) && train_fraction > 0.0,
        "train_fraction must be in (0,1)"
    );
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let (rows, labels) = parse_csv(&text)?;
    let dim = rows[0].len();
    let n_train = ((rows.len() as f64) * train_fraction).round() as usize;
    ensure!(
        n_train >= 1 && n_train < rows.len(),
        "split leaves an empty partition"
    );
    let num_classes = labels.iter().copied().max().unwrap() + 1;
    let flat = |rs: &[Vec<f32>]| -> Vec<f32> { rs.iter().flatten().copied().collect() };
    let ds = Dataset {
        name: name.to_string(),
        train_x: Mat::from_vec(n_train, dim, flat(&rows[..n_train])),
        train_y: labels[..n_train].to_vec(),
        test_x: Mat::from_vec(rows.len() - n_train, dim, flat(&rows[n_train..])),
        test_y: labels[n_train..].to_vec(),
        num_classes,
    };
    ds.validate()?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# toy data
1.0, 2.0, 0
3.0, 4.0, 1

5.0, 6.0, 0
7.0, 8.0, 1
";

    #[test]
    fn parse_basic() {
        let (rows, labels) = parse_csv(SAMPLE).unwrap();
        assert_eq!(rows.len(), 4);
        assert_eq!(labels, vec![0, 1, 0, 1]);
        assert_eq!(rows[1], vec![3.0, 4.0]);
    }

    #[test]
    fn parse_rejects_ragged() {
        assert!(parse_csv("1,2,0\n1,0\n").is_err());
    }

    #[test]
    fn parse_rejects_bad_label() {
        assert!(parse_csv("1,2,zebra\n").is_err());
    }

    #[test]
    fn parse_rejects_empty() {
        assert!(parse_csv("# nothing\n").is_err());
    }

    #[test]
    fn load_roundtrip() {
        let path = std::env::temp_dir().join(format!("dimred-csv-test-{}.csv", std::process::id()));
        std::fs::write(&path, SAMPLE).unwrap();
        let ds = load_csv(&path, "toy", 0.5).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(ds.train_x.shape(), (2, 2));
        assert_eq!(ds.test_x.shape(), (2, 2));
        assert_eq!(ds.num_classes, 2);
    }
}
