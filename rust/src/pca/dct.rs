//! The "bilinear transform" baseline of Fig. 1: a fixed (data-free)
//! orthogonal transform followed by coefficient truncation. For images
//! we use the separable 2-D DCT-II and keep the top-left (low-frequency)
//! zig-zag block; for generic vectors, the 1-D DCT-II truncated to the
//! first `k` coefficients.
//!
//! This is the classic "transform coding" baseline: excellent when the
//! signal energy is concentrated in low frequencies (natural images —
//! Fig. 1a), poor when class information lives elsewhere (HAR — the
//! paper's Fig. 1b shows it below 60%).

use crate::linalg::Mat;

/// Orthonormal DCT-II basis matrix of size `n×n` (rows are basis
/// functions).
pub fn dct_matrix(n: usize) -> Mat {
    assert!(n >= 1);
    let scale0 = (1.0 / n as f64).sqrt();
    let scale = (2.0 / n as f64).sqrt();
    Mat::from_fn(n, n, |k, i| {
        let s = if k == 0 { scale0 } else { scale };
        (s * ((std::f64::consts::PI / n as f64) * (i as f64 + 0.5) * k as f64).cos()) as f32
    })
}

/// 1-D DCT-II truncation: keep the first `k` coefficients of each row.
#[derive(Debug, Clone)]
pub struct Dct1d {
    basis: Mat, // k×n
}

impl Dct1d {
    pub fn new(input_dim: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= input_dim);
        let full = dct_matrix(input_dim);
        let basis = Mat::from_fn(k, input_dim, |i, j| full.get(i, j));
        Self { basis }
    }

    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        self.basis.matvec(x)
    }

    pub fn transform_rows(&self, x: &Mat) -> Mat {
        self.basis.apply_rows(x)
    }

    /// The transform as a dense matrix (for cost accounting / export).
    pub fn matrix(&self) -> &Mat {
        &self.basis
    }
}

/// 2-D separable DCT-II truncation for `side×side` images flattened
/// row-major: keeps coefficients in zig-zag (low-frequency-first) order.
#[derive(Debug, Clone)]
pub struct Dct2d {
    side: usize,
    k: usize,
    basis: Mat, // side×side 1-D basis
    /// Zig-zag order of (u, v) coefficient indices.
    order: Vec<(usize, usize)>,
}

impl Dct2d {
    pub fn new(side: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= side * side);
        let basis = dct_matrix(side);
        let order = zigzag(side);
        Self {
            side,
            k,
            basis,
            order,
        }
    }

    /// Transform one flattened image → `k` low-frequency coefficients.
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        let s = self.side;
        assert_eq!(x.len(), s * s, "dct2d input size");
        // C · X · Cᵀ via two passes of the 1-D basis.
        // tmp[u][j] = Σ_i basis[u][i] x[i][j]
        let mut tmp = vec![0.0f32; s * s];
        for u in 0..s {
            let brow = self.basis.row(u);
            for j in 0..s {
                let mut acc = 0.0;
                for i in 0..s {
                    acc += brow[i] * x[i * s + j];
                }
                tmp[u * s + j] = acc;
            }
        }
        // coef[u][v] = Σ_j tmp[u][j] basis[v][j]
        self.order
            .iter()
            .take(self.k)
            .map(|&(u, v)| {
                let brow = self.basis.row(v);
                let trow = &tmp[u * s..(u + 1) * s];
                crate::linalg::dot(trow, brow)
            })
            .collect()
    }

    pub fn transform_rows(&self, x: &Mat) -> Mat {
        let rows = x.rows_count();
        let mut out = Vec::with_capacity(rows * self.k);
        for r in x.rows() {
            out.extend(self.transform(r));
        }
        Mat::from_vec(rows, self.k, out)
    }
}

/// Zig-zag traversal order of an `n×n` coefficient grid (JPEG-style):
/// anti-diagonals of increasing `u+v`.
fn zigzag(n: usize) -> Vec<(usize, usize)> {
    let mut order = Vec::with_capacity(n * n);
    for s in 0..(2 * n - 1) {
        let mut diag: Vec<(usize, usize)> = (0..n)
            .filter_map(|u| {
                let v = s.checked_sub(u)?;
                (v < n).then_some((u, v))
            })
            .collect();
        if s % 2 == 1 {
            diag.reverse();
        }
        order.extend(diag);
    }
    order
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dot;

    #[test]
    fn dct_matrix_is_orthonormal() {
        let c = dct_matrix(8);
        for i in 0..8 {
            for j in 0..8 {
                let d = dot(c.row(i), c.row(j));
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((d - expect).abs() < 1e-5, "({i},{j}) = {d}");
            }
        }
    }

    #[test]
    fn dc_coefficient_is_mean_scaled() {
        let d = Dct1d::new(4, 1);
        let y = d.transform(&[1.0, 1.0, 1.0, 1.0]);
        // DC basis = 1/√4 each ⇒ coefficient = 4·(1/2) = 2.
        assert!((y[0] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn constant_image_energy_in_dc_only() {
        let d = Dct2d::new(4, 16);
        let y = d.transform(&[3.0; 16]);
        assert!(y[0].abs() > 1.0, "DC coefficient holds the energy");
        for &c in &y[1..] {
            assert!(c.abs() < 1e-4, "AC leak: {c}");
        }
    }

    #[test]
    fn zigzag_covers_grid() {
        let z = zigzag(5);
        assert_eq!(z.len(), 25);
        let mut seen = vec![false; 25];
        for (u, v) in z {
            seen[u * 5 + v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn zigzag_low_freq_first() {
        let z = zigzag(8);
        assert_eq!(z[0], (0, 0));
        // The first few entries all have small u+v.
        assert!(z[1..3].iter().all(|&(u, v)| u + v == 1));
        assert!(z[3..6].iter().all(|&(u, v)| u + v == 2));
    }

    #[test]
    fn energy_preserved_full_transform() {
        // Full DCT (k = n) is orthonormal ⇒ ‖y‖ = ‖x‖.
        let d = Dct1d::new(16, 16);
        let x: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let y = d.transform(&x);
        let ex: f32 = x.iter().map(|v| v * v).sum();
        let ey: f32 = y.iter().map(|v| v * v).sum();
        assert!((ex - ey).abs() < 1e-3);
    }

    #[test]
    fn smooth_signal_compacts_into_few_coeffs() {
        // Low-frequency signal: truncation to 4 coefficients keeps most
        // of the energy — the property that makes this baseline strong
        // on images.
        let x: Vec<f32> = (0..32)
            .map(|i| (std::f32::consts::PI * i as f32 / 32.0).sin())
            .collect();
        let full = Dct1d::new(32, 32).transform(&x);
        let trunc = Dct1d::new(32, 4).transform(&x);
        let e_full: f32 = full.iter().map(|v| v * v).sum();
        let e_trunc: f32 = trunc.iter().map(|v| v * v).sum();
        assert!(e_trunc / e_full > 0.95, "ratio {}", e_trunc / e_full);
    }
}
