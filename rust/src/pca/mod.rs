//! PCA whitening (adaptive and batch) and the bilinear-transform
//! baseline.
//!
//! * [`AdaptiveWhitener`] — Eq. 3 of the paper, i.e. the EASI datapath
//!   with the HOS term muxed out ([`crate::easi::EasiMode::WhitenOnly`]).
//! * [`BatchPca`] — covariance + Jacobi eigendecomposition oracle; also
//!   the "PCA" series of Fig. 1.
//! * [`dct`] — the separable DCT-II "bilinear transform" baseline of
//!   Fig. 1.

pub mod dct;

use crate::easi::{EasiConfig, EasiMode, EasiTrainer};
use crate::linalg::{symmetric_eigen, Mat};

/// Streaming PCA whitening via the Kullback–Leibler gradient recursion
/// `W ← W − μ[zzᵀ − I]W` (paper Eq. 3) — a thin configuration of the
/// EASI trainer, mirroring how the paper reuses one datapath for both
/// algorithms.
#[derive(Debug, Clone)]
pub struct AdaptiveWhitener {
    inner: EasiTrainer,
}

impl AdaptiveWhitener {
    pub fn new(input_dim: usize, output_dim: usize, mu: f32) -> Self {
        Self {
            inner: EasiTrainer::new(EasiConfig {
                input_dim,
                output_dim,
                mu,
                mode: EasiMode::WhitenOnly,
                normalized: false,
                max_norm: 1e4,
                clip: 0.0,
                random_init: None,
            }),
        }
    }

    /// One streaming update.
    pub fn step(&mut self, x: &[f32]) {
        self.inner.step(x);
    }

    /// Consume all rows.
    pub fn step_rows(&mut self, x: &Mat) {
        self.inner.step_rows(x);
    }

    /// The whitening matrix `W (n×m)`.
    pub fn whitening_matrix(&self) -> &Mat {
        self.inner.separation_matrix()
    }

    /// `z = Wx`.
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        self.inner.transform(x)
    }

    /// Whiteness of outputs on given samples (→ 0 at convergence).
    pub fn output_whiteness(&self, x: &Mat) -> f64 {
        self.inner.output_whiteness(x)
    }
}

/// Batch PCA fitted by eigendecomposition of the sample covariance.
#[derive(Debug, Clone)]
pub struct BatchPca {
    /// Column means of the training data (subtracted before projecting).
    pub means: Vec<f32>,
    /// Eigenvalues of the covariance, descending.
    pub eigenvalues: Vec<f64>,
    /// Principal axes as rows (descending eigenvalue order), `k×m`.
    pub components: Mat,
    /// Whitening rows `λ_i^{-1/2} v_iᵀ`, `k×m`.
    pub whitening: Mat,
}

impl BatchPca {
    /// Fit from data rows, keeping `k` components.
    ///
    /// Small covariances use cyclic Jacobi (all pairs, exact); beyond
    /// 96 dimensions Jacobi's O(m³)-per-sweep cost dominates and we
    /// switch to subspace iteration for the leading k pairs — PCA only
    /// needs those.
    pub fn fit(x: &Mat, k: usize) -> Self {
        let m = x.cols_count();
        assert!(k >= 1 && k <= m, "component count out of range");
        let means = x.col_means();
        let cov = x.covariance(true, false);
        let eig = if m <= 96 {
            symmetric_eigen(&cov)
        } else {
            crate::linalg::subspace_eigen(&cov, k, 60, 17)
        };
        let components = Mat::from_fn(k, m, |i, j| eig.vectors.get(i, j));
        let whitening = Mat::from_fn(k, m, |i, j| {
            let lam = eig.values[i].max(1e-12);
            (eig.vectors.get(i, j) as f64 / lam.sqrt()) as f32
        });
        Self {
            means,
            eigenvalues: eig.values[..k].to_vec(),
            components,
            whitening,
        }
    }

    /// Project (no variance normalisation): `y = V(x − μ)`.
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        let centered: Vec<f32> = x.iter().zip(&self.means).map(|(a, m)| a - m).collect();
        self.components.matvec(&centered)
    }

    /// Whiten: `z = Λ^{-1/2} V (x − μ)`.
    pub fn whiten(&self, x: &[f32]) -> Vec<f32> {
        let centered: Vec<f32> = x.iter().zip(&self.means).map(|(a, m)| a - m).collect();
        self.whitening.matvec(&centered)
    }

    /// Apply [`Self::transform`] to all rows.
    pub fn transform_rows(&self, x: &Mat) -> Mat {
        let rows = x.rows_count();
        let mut out = Vec::with_capacity(rows * self.components.rows_count());
        for r in x.rows() {
            out.extend(self.transform(r));
        }
        Mat::from_vec(rows, self.components.rows_count(), out)
    }

    /// Apply [`Self::whiten`] to all rows.
    pub fn whiten_rows(&self, x: &Mat) -> Mat {
        let rows = x.rows_count();
        let mut out = Vec::with_capacity(rows * self.whitening.rows_count());
        for r in x.rows() {
            out.extend(self.whiten(r));
        }
        Mat::from_vec(rows, self.whitening.rows_count(), out)
    }

    /// Fraction of total variance captured by the kept components.
    pub fn explained_variance_ratio(&self, x: &Mat) -> f64 {
        let cov = x.covariance(true, false);
        let total: f64 = (0..cov.rows_count()).map(|i| cov.get(i, i) as f64).sum();
        self.eigenvalues.iter().sum::<f64>() / total.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::whiteness_error;
    use crate::rng::{Pcg64, RngExt};

    /// Correlated 2-D Gaussian data with known principal axis (1,1)/√2.
    fn correlated(samples: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed(seed);
        let mut data = Vec::with_capacity(samples * 2);
        for _ in 0..samples {
            let a = rng.next_gaussian() as f32 * 3.0;
            let b = rng.next_gaussian() as f32 * 0.5;
            data.push((a + b) * std::f32::consts::FRAC_1_SQRT_2);
            data.push((a - b) * std::f32::consts::FRAC_1_SQRT_2);
        }
        Mat::from_vec(samples, 2, data)
    }

    #[test]
    fn batch_pca_finds_principal_axis() {
        let x = correlated(5000, 51);
        let pca = BatchPca::fit(&x, 2);
        // First component ≈ ±(1,1)/√2.
        let c = pca.components.row(0);
        let alignment = (c[0] * std::f32::consts::FRAC_1_SQRT_2
            + c[1] * std::f32::consts::FRAC_1_SQRT_2)
            .abs();
        assert!(alignment > 0.99, "alignment {alignment}");
        // Eigenvalues ≈ 9 and 0.25.
        assert!((pca.eigenvalues[0] - 9.0).abs() < 0.5);
        assert!((pca.eigenvalues[1] - 0.25).abs() < 0.1);
    }

    #[test]
    fn batch_whitening_whitens() {
        let x = correlated(5000, 52);
        let pca = BatchPca::fit(&x, 2);
        let z = pca.whiten_rows(&x);
        let w = whiteness_error(&z);
        assert!(w < 0.05, "whiteness {w}");
    }

    #[test]
    fn adaptive_matches_batch_asymptotically() {
        let x = correlated(8000, 53);
        let mut aw = AdaptiveWhitener::new(2, 2, 2e-3);
        for _ in 0..4 {
            aw.step_rows(&x);
        }
        let w = aw.output_whiteness(&x);
        assert!(w < 0.1, "adaptive whiteness {w}");
    }

    #[test]
    fn explained_variance_monotone() {
        let x = correlated(2000, 54);
        let r1 = BatchPca::fit(&x, 1).explained_variance_ratio(&x);
        let r2 = BatchPca::fit(&x, 2).explained_variance_ratio(&x);
        assert!(r1 <= r2 + 1e-9);
        assert!((r2 - 1.0).abs() < 1e-6, "full rank must explain all: {r2}");
        assert!(r1 > 0.9, "dominant axis explains most: {r1}");
    }

    #[test]
    fn transform_reduces_dim() {
        let x = correlated(100, 55);
        let pca = BatchPca::fit(&x, 1);
        assert_eq!(pca.transform_rows(&x).shape(), (100, 1));
    }
}
