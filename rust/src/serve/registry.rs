//! Tenant-keyed session store with checkpoint-based evict/restore.
//!
//! Each tenant owns one [`Session`]. A session is either *live* (trainer
//! resident in memory) or *evicted* (collapsed to a
//! [`SessionCheckpoint`]: stage-graph raw words, metrics, remaining
//! reconfig schedule). Eviction is how a serving host caps resident
//! state under many tenants — and because fixed-point stage state is
//! saved as raw words, a restored session continues **bit-exactly**
//! where it left off (proven in `tests/serve.rs`).

use crate::config::{Backend, ExperimentConfig};
use crate::coordinator::{Session, SessionCheckpoint, TelemetrySink};
use crate::telemetry::Metrics;
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

enum TenantSlot {
    Live(Box<Session<'static>>),
    Evicted(SessionCheckpoint),
}

/// Session store keyed by tenant id.
#[derive(Default)]
pub struct SessionRegistry {
    slots: HashMap<String, TenantSlot>,
    restores: HashMap<String, u64>,
}

impl SessionRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a tenant with a fresh session. Serving is native-only
    /// (checkpoints need the stage graph; PJRT state is opaque), and the
    /// session's JSONL event sink is disabled — interleaved progress
    /// lines from many tenants would be noise; the serving layer reports
    /// through its own surface.
    pub fn create(&mut self, tenant: &str, cfg: &ExperimentConfig) -> Result<()> {
        ensure!(
            cfg.backend == Backend::Native,
            "serving sessions run on the native backend only"
        );
        ensure!(
            !self.slots.contains_key(tenant),
            "tenant '{tenant}' already registered"
        );
        let mut s = Session::new(cfg, None)?;
        s.set_event_sink(TelemetrySink::Disabled);
        self.slots
            .insert(tenant.to_string(), TenantSlot::Live(Box::new(s)));
        Ok(())
    }

    /// The tenant's live session, transparently restoring it from its
    /// checkpoint if it was evicted.
    pub fn session_mut(&mut self, tenant: &str) -> Result<&mut Session<'static>> {
        if matches!(self.slots.get(tenant), Some(TenantSlot::Evicted(_))) {
            let Some(TenantSlot::Evicted(ck)) = self.slots.remove(tenant) else {
                unreachable!("checked evicted above");
            };
            // Keep the checkpoint if the rebuild fails, so a transient
            // error does not lose the tenant's state.
            match Session::restore(ck.clone(), None) {
                Ok(mut s) => {
                    s.set_event_sink(TelemetrySink::Disabled);
                    self.slots
                        .insert(tenant.to_string(), TenantSlot::Live(Box::new(s)));
                    *self.restores.entry(tenant.to_string()).or_insert(0) += 1;
                }
                Err(e) => {
                    self.slots
                        .insert(tenant.to_string(), TenantSlot::Evicted(ck));
                    return Err(e);
                }
            }
        }
        match self.slots.get_mut(tenant) {
            Some(TenantSlot::Live(s)) => Ok(s),
            Some(TenantSlot::Evicted(_)) => unreachable!("restored above"),
            None => bail!("unknown tenant '{tenant}'"),
        }
    }

    /// Collapse a live session to its checkpoint. Idempotent: evicting
    /// an already-evicted tenant is a no-op.
    pub fn evict(&mut self, tenant: &str) -> Result<()> {
        match self.slots.get_mut(tenant) {
            Some(slot) => {
                if let TenantSlot::Live(s) = slot {
                    let ck = s.checkpoint()?;
                    *slot = TenantSlot::Evicted(ck);
                }
                Ok(())
            }
            None => bail!("unknown tenant '{tenant}'"),
        }
    }

    pub fn is_live(&self, tenant: &str) -> bool {
        matches!(self.slots.get(tenant), Some(TenantSlot::Live(_)))
    }

    /// How many times this tenant has been restored from a checkpoint.
    pub fn restores(&self, tenant: &str) -> u64 {
        self.restores.get(tenant).copied().unwrap_or(0)
    }

    /// The tenant's run metrics, live or evicted (checkpoints carry a
    /// full metrics clone, reservoir included).
    pub fn metrics_of(&self, tenant: &str) -> Option<&Metrics> {
        match self.slots.get(tenant)? {
            TenantSlot::Live(s) => Some(s.metrics()),
            TenantSlot::Evicted(ck) => Some(ck.metrics()),
        }
    }

    /// The tenant's datapath telemetry, live or evicted. Checkpoints
    /// carry the snapshot taken at eviction time, so reading an evicted
    /// tenant's telemetry never rebuilds a trainer — and a tenant whose
    /// restore would fail still reports.
    pub fn telemetry_of(&self, tenant: &str) -> Option<crate::telemetry::TelemetrySnapshot> {
        match self.slots.get(tenant)? {
            TenantSlot::Live(s) => s.trainer().telemetry_snapshot(),
            TenantSlot::Evicted(ck) => ck.telemetry().cloned(),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn tenants(&self) -> impl Iterator<Item = &str> {
        self.slots.keys().map(String::as_str)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Batch;
    use crate::linalg::Mat;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig {
            train_classifier: false,
            rot_warmup: 32,
            ..Default::default()
        }
    }

    fn batch(dim: usize, salt: usize) -> Batch {
        Batch::Full(Mat::from_fn(64, dim, |i, j| {
            ((i * 31 + j * 7 + salt * 13) % 17) as f32 / 17.0 - 0.5
        }))
    }

    #[test]
    fn create_evict_restore_roundtrip() {
        let mut reg = SessionRegistry::new();
        let c = cfg();
        reg.create("t0", &c).unwrap();
        assert!(reg.is_live("t0"));
        for salt in 0..4 {
            let s = reg.session_mut("t0").unwrap();
            s.ingest(&batch(c.input_dim, salt)).unwrap();
        }
        reg.evict("t0").unwrap();
        assert!(!reg.is_live("t0"));
        // Metrics survive eviction.
        assert_eq!(reg.metrics_of("t0").unwrap().samples_in, 256);
        // Idempotent evict.
        reg.evict("t0").unwrap();
        // Touching the session transparently restores it.
        let s = reg.session_mut("t0").unwrap();
        s.ingest(&batch(c.input_dim, 4)).unwrap();
        assert!(reg.is_live("t0"));
        assert_eq!(reg.restores("t0"), 1);
        assert_eq!(reg.metrics_of("t0").unwrap().samples_in, 320);
    }

    #[test]
    fn duplicate_and_unknown_tenants_rejected() {
        let mut reg = SessionRegistry::new();
        reg.create("t0", &cfg()).unwrap();
        assert!(reg.create("t0", &cfg()).is_err());
        assert!(reg.session_mut("nope").is_err());
        assert!(reg.evict("nope").is_err());
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn rejected_batch_leaves_session_state_untouched() {
        // The ingest-boundary guarantee at registry level: a poisoned
        // batch errors out *before* any value reaches trainer state, so
        // the forward transform and every counter except the rejection
        // tally are exactly what they were.
        let mut reg = SessionRegistry::new();
        let c = ExperimentConfig {
            precision: crate::fxp::Precision::parse("q4.12").unwrap(),
            ..cfg()
        };
        reg.create("t0", &c).unwrap();
        for salt in 0..3 {
            let s = reg.session_mut("t0").unwrap();
            s.ingest(&batch(c.input_dim, salt)).unwrap();
        }
        let probe = Mat::from_fn(16, c.input_dim, |i, j| ((i * 5 + j) % 11) as f32 / 11.0);
        let before = reg.session_mut("t0").unwrap().trainer().transform_rows(&probe);
        let samples_before = reg.metrics_of("t0").unwrap().samples_in;

        let mut poisoned = Mat::from_fn(64, c.input_dim, |_, _| 0.1);
        poisoned.set(7, 3, f32::NAN);
        let err = reg
            .session_mut("t0")
            .unwrap()
            .ingest(&Batch::Full(poisoned))
            .unwrap_err();
        let rejected = err.downcast_ref::<crate::coordinator::BatchRejected>();
        assert!(rejected.is_some(), "expected a typed rejection, got {err:#}");

        let s = reg.session_mut("t0").unwrap();
        assert_eq!(s.metrics().samples_in, samples_before);
        assert_eq!(s.metrics().rejected_batches, 1);
        assert_eq!(
            s.trainer().transform_rows(&probe).as_slice(),
            before.as_slice(),
            "trainer state moved on a rejected batch"
        );
        // The session still accepts clean traffic afterwards.
        s.ingest(&batch(c.input_dim, 9)).unwrap();
        assert_eq!(reg.metrics_of("t0").unwrap().samples_in, samples_before + 64);
    }

    #[test]
    fn pjrt_backend_rejected() {
        let mut reg = SessionRegistry::new();
        let c = ExperimentConfig {
            backend: Backend::Pjrt,
            ..cfg()
        };
        assert!(reg.create("t0", &c).is_err());
    }
}
