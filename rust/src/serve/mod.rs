//! L4 serving layer: many concurrent training sessions, sharded across
//! worker threads.
//!
//! The paper's pitch is a *scalable* DR engine serving heavy traffic;
//! the coordinator trains exactly one stream. This module multiplexes
//! [`crate::coordinator::Session`]s across tenants:
//!
//! ```text
//!   tenant producers ──► per-tenant bounded queues ──► shard workers
//!        (ingress)            (backpressure)          (round-robin +
//!                                                      shape-coalesced)
//!                                   │
//!              SessionRegistry ◄────┘  evict ⇄ restore (checkpoints)
//! ```
//!
//! * [`registry`] — tenant-keyed session store with checkpoint-based
//!   evict/restore (PR 5's stage-state save/restore; restored
//!   fixed-point sessions continue bit-exactly).
//! * [`shard`] — a worker owning a set of tenants: bounded ingress
//!   queues generalizing the single-stream batcher, a round-robin
//!   quantum so no tenant starves under skewed arrival, and per-round
//!   coalescing of pending batches by graph shape so same-shape tiles
//!   run back to back. With `--pipeline` each shard runs a bounded
//!   two-slot stage/commit pipeline: round N+1's validation + entry
//!   quantization overlaps round N's trainer commits on a staging
//!   thread, and consecutive same-plan batches fuse into mega-tile
//!   commits — bit-identical to the serial schedule.
//! * [`workload`] — synthetic multi-tenant drivers for `dimred serve`
//!   and the bench `multi_tenant` scenario family (tenant count,
//!   arrival pattern, per-tenant cascade/precision).
//! * [`report`] — schema-validated JSON + text rendering of a serve
//!   run, with per-tenant latency percentiles, telemetry health and
//!   fault-containment counters.
//! * [`faults`] — deterministic, seeded fault injection (poisoned
//!   batches, producer stalls, synthetic ingest/restore failures) that
//!   the shard's per-tenant circuit breaker is tested against: a
//!   faulting tenant is retried with bounded backoff, then quarantined
//!   on its last-good checkpoint while every other tenant keeps its
//!   bit-exact stream (proven in `tests/chaos.rs`).

pub mod faults;
pub mod registry;
pub mod report;
pub mod shard;
pub mod workload;

pub use faults::{FaultKind, FaultPlan, TenantInjector};
pub use registry::SessionRegistry;
pub use shard::{
    PipelineStats, RoundStats, Shard, ShardOptions, TenantHealth, TenantIngress, TenantOutcome,
};
pub use workload::{
    pipeline_identity_check, ArrivalPattern, ServeOptions, ServeReport, ShardPipeline,
    TenantReport,
};
