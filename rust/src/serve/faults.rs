//! Deterministic fault injection for the serving layer.
//!
//! The paper's pitch is *always-on* training in hardware; a serving
//! host only honours that claim if one tenant's garbage cannot take
//! the others down — and that property is untestable without a way to
//! produce the garbage on demand. This module is that way: a
//! [`FaultPlan`] parsed from a compact spec string
//! (`t1:nan@0.5,t3:ingest@0.25,t5:restore`) drives per-tenant
//! [`TenantInjector`]s that poison batches (NaN / Inf /
//! dimension-mismatch / empty), stall producers, and force synthetic
//! ingest and restore failures at configurable rates.
//!
//! Everything is seeded through [`crate::rng::derive_seed`]: each
//! `(tenant, kind)` pair owns an independent [`Pcg64`] stream, so a
//! given spec + seed produces the same fault sequence per tenant on
//! every run regardless of how the scheduler interleaves tenants. The
//! chaos suite (`tests/chaos.rs`) leans on that determinism to prove
//! that tenants *outside* the blast radius stay bit-identical to a
//! fault-free oracle run.

use crate::coordinator::Batch;
use crate::linalg::Mat;
use crate::rng::{derive_seed, Pcg64, RngExt};
use anyhow::{bail, Context, Result};

/// One kind of injected misbehaviour.
///
/// The first four corrupt a batch on the producer side (exercising the
/// ingest validator); `Stall` delays a producer (exercising scheduler
/// fairness); `Ingest` and `Restore` fail shard-side operations
/// (exercising the retry / quarantine circuit breaker).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Overwrite a few batch entries with NaN.
    Nan,
    /// Overwrite a few batch entries with +/-Inf.
    Inf,
    /// Widen the batch to the wrong feature dimension.
    DimMismatch,
    /// Replace the batch with a zero-row one.
    Empty,
    /// Producer sleeps before sending (slow-tenant simulation).
    Stall,
    /// Shard-side synthetic ingest error (before the session is touched).
    Ingest,
    /// Shard-side synthetic `Session::restore` failure for an evicted
    /// tenant.
    Restore,
}

impl FaultKind {
    /// Every kind, in spec order (also the poison precedence order).
    pub const ALL: [FaultKind; 7] = [
        FaultKind::Nan,
        FaultKind::Inf,
        FaultKind::DimMismatch,
        FaultKind::Empty,
        FaultKind::Stall,
        FaultKind::Ingest,
        FaultKind::Restore,
    ];

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "nan" => Ok(Self::Nan),
            "inf" => Ok(Self::Inf),
            "dim" => Ok(Self::DimMismatch),
            "empty" => Ok(Self::Empty),
            "stall" => Ok(Self::Stall),
            "ingest" => Ok(Self::Ingest),
            "restore" => Ok(Self::Restore),
            other => bail!("unknown fault kind '{other}' (nan|inf|dim|empty|stall|ingest|restore)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Self::Nan => "nan",
            Self::Inf => "inf",
            Self::DimMismatch => "dim",
            Self::Empty => "empty",
            Self::Stall => "stall",
            Self::Ingest => "ingest",
            Self::Restore => "restore",
        }
    }

    /// Corrupts the batch payload on the producer side (vs failing a
    /// shard-side operation).
    pub fn poisons_batch(&self) -> bool {
        matches!(self, Self::Nan | Self::Inf | Self::DimMismatch | Self::Empty)
    }

    /// Seed-stream tag: each kind draws from its own decorrelated RNG.
    fn tag(&self) -> u64 {
        match self {
            Self::Nan => 1,
            Self::Inf => 2,
            Self::DimMismatch => 3,
            Self::Empty => 4,
            Self::Stall => 5,
            Self::Ingest => 6,
            Self::Restore => 7,
        }
    }
}

/// One spec entry: inject `kind` faults into `tenant`'s traffic at
/// `rate` (probability per opportunity). `tenant == "*"` matches every
/// tenant.
#[derive(Debug, Clone)]
pub struct FaultEntry {
    pub tenant: String,
    pub kind: FaultKind,
    pub rate: f64,
}

/// A parsed `--inject-faults` spec: which tenants get which faults at
/// which rates.
///
/// Spec grammar: comma-separated `tenant:kind[@rate]` items, e.g.
/// `t1:nan@0.5,t3:ingest@0.25,t5:restore` (rate defaults to 1.0).
/// Duplicate `(tenant, kind)` pairs are rejected naming the offending
/// token, following the stage-list parser's convention.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    pub entries: Vec<FaultEntry>,
}

impl FaultPlan {
    pub fn parse(spec: &str) -> Result<Self> {
        let mut entries: Vec<FaultEntry> = Vec::new();
        for item in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (target, rate) = match item.split_once('@') {
                Some((t, r)) => {
                    let rate: f64 = r
                        .parse()
                        .ok()
                        .filter(|&x: &f64| (0.0..=1.0).contains(&x))
                        .with_context(|| format!("bad fault rate in '{item}' (want 0..=1)"))?;
                    (t, rate)
                }
                None => (item, 1.0),
            };
            let (tenant, kind) = target
                .split_once(':')
                .with_context(|| format!("bad fault item '{item}' (want tenant:kind[@rate])"))?;
            anyhow::ensure!(!tenant.is_empty(), "empty tenant in fault item '{item}'");
            let kind = FaultKind::parse(kind)?;
            if entries.iter().any(|e| e.tenant == tenant && e.kind == kind) {
                bail!("duplicate fault entry '{item}'");
            }
            entries.push(FaultEntry {
                tenant: tenant.to_string(),
                kind,
                rate,
            });
        }
        anyhow::ensure!(!entries.is_empty(), "empty fault spec");
        Ok(Self { entries })
    }

    /// Canonical spec string (round-trips through [`FaultPlan::parse`]).
    pub fn label(&self) -> String {
        self.entries
            .iter()
            .map(|e| format!("{}:{}@{}", e.tenant, e.kind.label(), e.rate))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Build the injector for one tenant, or `None` if no entry matches
    /// it. Each `(tenant, kind)` gate draws from its own RNG stream
    /// derived from `seed`, so fault sequences are per-tenant
    /// deterministic no matter how tenants interleave.
    pub fn injector_for(&self, tenant: &str, seed: u64) -> Option<TenantInjector> {
        let gates: Vec<(FaultKind, RateGate)> = self
            .entries
            .iter()
            .filter(|e| e.tenant == "*" || e.tenant == tenant)
            .map(|e| {
                let stream = derive_seed(derive_seed(seed, tenant_tag(tenant)), e.kind.tag());
                (e.kind, RateGate::new(e.rate, stream))
            })
            .collect();
        (!gates.is_empty()).then_some(TenantInjector { gates })
    }
}

/// FNV-1a over the tenant name: a stable per-tenant seed tag.
fn tenant_tag(tenant: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in tenant.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// A seeded Bernoulli gate: fires with probability `rate` per draw.
#[derive(Debug)]
struct RateGate {
    rate: f64,
    rng: Pcg64,
}

impl RateGate {
    fn new(rate: f64, seed: u64) -> Self {
        Self {
            rate,
            rng: Pcg64::seed(seed),
        }
    }

    fn fire(&mut self) -> bool {
        // rate 1.0 always fires (next_f64 < 1.0 by construction).
        self.rng.next_f64() < self.rate
    }
}

/// One tenant's fault source. The producer side calls
/// [`TenantInjector::poison`] / [`TenantInjector::stall_fault`]; the
/// shard side calls [`TenantInjector::ingest_fault`] /
/// [`TenantInjector::restore_fault`]. The two sides draw from disjoint
/// kind streams, so a plan can safely be instantiated on both.
#[derive(Debug)]
pub struct TenantInjector {
    gates: Vec<(FaultKind, RateGate)>,
}

impl TenantInjector {
    fn fire(&mut self, kind: FaultKind) -> bool {
        self.gates
            .iter_mut()
            .find(|(k, _)| *k == kind)
            .map(|(_, g)| g.fire())
            .unwrap_or(false)
    }

    /// Maybe corrupt an outgoing batch. At most one poison kind applies
    /// per batch, in [`FaultKind::ALL`] precedence order; returns the
    /// (possibly corrupted) batch and which kind fired.
    pub fn poison(&mut self, batch: Batch) -> (Batch, Option<FaultKind>) {
        for kind in FaultKind::ALL {
            if kind.poisons_batch() && self.fire(kind) {
                return (corrupt(batch, kind), Some(kind));
            }
        }
        (batch, None)
    }

    /// Should the producer stall before this send?
    pub fn stall_fault(&mut self) -> bool {
        self.fire(FaultKind::Stall)
    }

    /// Should this shard-side ingest attempt fail synthetically?
    pub fn ingest_fault(&mut self) -> bool {
        self.fire(FaultKind::Ingest)
    }

    /// Should this restore of an evicted session fail synthetically?
    pub fn restore_fault(&mut self) -> bool {
        self.fire(FaultKind::Restore)
    }
}

/// Apply one poison kind to a batch. Public so the chaos suite can
/// craft the exact corrupted payloads the workload driver would send.
pub fn corrupt(batch: Batch, kind: FaultKind) -> Batch {
    let m = batch.into_mat();
    let (rows, cols) = m.shape();
    match kind {
        FaultKind::Nan | FaultKind::Inf => {
            let v = if kind == FaultKind::Nan {
                f32::NAN
            } else {
                f32::INFINITY
            };
            let mut m = m;
            if rows > 0 && cols > 0 {
                // First and middle entries: corruption a validator that
                // only samples the batch head would still catch.
                m.set(0, 0, v);
                m.set(rows / 2, cols / 2, -v);
            }
            Batch::Full(m)
        }
        FaultKind::DimMismatch => Batch::Full(Mat::from_fn(rows.max(1), cols + 1, |i, j| {
            if j < cols && i < rows {
                m.get(i, j)
            } else {
                0.0
            }
        })),
        FaultKind::Empty => Batch::Full(Mat::from_vec(0, cols, Vec::new())),
        // Non-poison kinds never reach here (see `poison`).
        FaultKind::Stall | FaultKind::Ingest | FaultKind::Restore => Batch::Full(m),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batch(rows: usize, dim: usize) -> Batch {
        Batch::Full(Mat::from_fn(rows, dim, |i, j| (i * dim + j) as f32 * 0.01))
    }

    #[test]
    fn spec_parses_and_round_trips() {
        let p = FaultPlan::parse("t1:nan@0.5, t3:ingest@0.25 ,t5:restore").unwrap();
        assert_eq!(p.entries.len(), 3);
        assert_eq!(p.entries[0].kind, FaultKind::Nan);
        assert_eq!(p.entries[2].rate, 1.0);
        let back = FaultPlan::parse(&p.label()).unwrap();
        assert_eq!(back.label(), p.label());
    }

    #[test]
    fn spec_rejects_bad_items() {
        for bad in [
            "",
            "t1",               // no kind
            "t1:frobnicate",    // unknown kind
            "t1:nan@1.5",       // rate out of range
            "t1:nan@x",         // non-numeric rate
            ":nan",             // empty tenant
            "t1:nan,t1:nan@0.5", // duplicate (tenant, kind)
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn wildcard_matches_every_tenant_and_misses_none() {
        let p = FaultPlan::parse("*:stall@0.5").unwrap();
        assert!(p.injector_for("t0", 1).is_some());
        assert!(p.injector_for("anything", 1).is_some());
        let p = FaultPlan::parse("t0:nan").unwrap();
        assert!(p.injector_for("t1", 1).is_none());
    }

    #[test]
    fn injection_is_deterministic_per_seed_and_tenant() {
        let p = FaultPlan::parse("t0:ingest@0.5,t0:nan@0.3").unwrap();
        let fire = |seed: u64| -> (Vec<bool>, Vec<bool>) {
            let mut inj = p.injector_for("t0", seed).unwrap();
            let ing: Vec<bool> = (0..32).map(|_| inj.ingest_fault()).collect();
            let poi: Vec<bool> = (0..32).map(|_| inj.poison(batch(4, 3)).1.is_some()).collect();
            (ing, poi)
        };
        assert_eq!(fire(2018), fire(2018));
        assert_ne!(fire(2018), fire(2019), "seeds must decorrelate");
        // The two kinds draw from independent streams: consuming one
        // does not shift the other.
        let mut a = p.injector_for("t0", 2018).unwrap();
        let mut b = p.injector_for("t0", 2018).unwrap();
        for _ in 0..16 {
            b.ingest_fault();
        }
        let pa: Vec<bool> = (0..16).map(|_| a.poison(batch(4, 3)).1.is_some()).collect();
        let pb: Vec<bool> = (0..16).map(|_| b.poison(batch(4, 3)).1.is_some()).collect();
        assert_eq!(pa, pb);
    }

    #[test]
    fn rate_one_always_fires_rate_zero_never() {
        let p = FaultPlan::parse("t0:ingest@1,t0:restore@0").unwrap();
        let mut inj = p.injector_for("t0", 7).unwrap();
        for _ in 0..64 {
            assert!(inj.ingest_fault());
            assert!(!inj.restore_fault());
        }
    }

    #[test]
    fn corrupt_produces_each_poison_shape() {
        let b = batch(8, 4);
        let (rows, cols) = (8, 4);
        let nan = corrupt(b.clone(), FaultKind::Nan);
        assert!(nan.rows().get(0, 0).is_nan());
        assert_eq!(nan.rows().shape(), (rows, cols));
        let inf = corrupt(b.clone(), FaultKind::Inf);
        assert!(inf.rows().get(0, 0).is_infinite());
        let dim = corrupt(b.clone(), FaultKind::DimMismatch);
        assert_eq!(dim.rows().cols_count(), cols + 1);
        let empty = corrupt(b, FaultKind::Empty);
        assert!(empty.is_empty());
    }
}
