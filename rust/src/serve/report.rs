//! Serve-run reporting: schema-validated JSON (`SERVE_report.json`) and
//! an aligned text table, following the bench/telemetry golden-schema
//! discipline — the CLI validates its own output before writing, and CI
//! validates the uploaded artifact.
//!
//! Schema v2 adds the fault-containment surface: a top-level `faults`
//! object (injection totals, producer hang-ups, quarantine count) and a
//! per-session `faults` block mirroring
//! [`crate::serve::TenantHealth`]. Clean runs carry the same shape with
//! all counters at zero, so consumers never branch on schema presence.
//!
//! Schema v3 adds the `pipeline` section: whether the shards ran the
//! two-slot stage/commit pipeline and, per shard, the staging/fusion
//! counters ([`crate::serve::PipelineStats`]) plus the overlap ratio
//! (fraction of staging cost hidden behind commits). Serial runs carry
//! the section with `enabled: false` and all-zero rows — same
//! no-branching contract as `faults`.

use super::workload::{ServeOptions, ServeReport};
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};

/// Serialise one serve run under the golden schema (see [`validate`]).
pub fn to_json(opts: &ServeOptions, r: &ServeReport) -> Json {
    let quarantined = r.tenants.iter().filter(|t| t.health.quarantined).count();
    Json::obj(vec![
        ("experiment", Json::str("serve_report")),
        ("schema_version", Json::num(3.0)),
        ("tenants", Json::num(r.tenants.len() as f64)),
        ("shards", Json::num(r.shards as f64)),
        ("arrival", Json::str(r.arrival.clone())),
        ("batch", Json::num(opts.batch as f64)),
        ("batches_per_tenant", Json::num(opts.batches_per_tenant as f64)),
        ("queue_depth", Json::num(opts.queue_depth as f64)),
        ("quantum", Json::num(opts.quantum as f64)),
        ("evict_idle", Json::Bool(opts.evict_idle)),
        ("seed", Json::num(opts.seed as f64)),
        ("elapsed_s", Json::num(r.elapsed_s)),
        ("total_samples", Json::num(r.total_samples as f64)),
        ("aggregate_samples_per_s", Json::num(r.aggregate_samples_per_s)),
        (
            "fairness_spread",
            r.fairness_spread.map(Json::num).unwrap_or(Json::Null),
        ),
        (
            "faults",
            Json::obj(vec![
                (
                    "spec",
                    r.faults_spec
                        .clone()
                        .map(Json::str)
                        .unwrap_or(Json::Null),
                ),
                ("injected_batches", Json::num(r.injected_batches as f64)),
                ("injected_stalls", Json::num(r.injected_stalls as f64)),
                ("producer_hangups", Json::num(r.producer_hangups as f64)),
                (
                    "total_faults",
                    Json::num(r.tenants.iter().map(|t| t.health.faults).sum::<u64>() as f64),
                ),
                (
                    "retries",
                    Json::num(r.tenants.iter().map(|t| t.health.retries).sum::<u64>() as f64),
                ),
                (
                    "rejected_batches",
                    Json::num(
                        r.tenants.iter().map(|t| t.health.rejected_batches).sum::<u64>() as f64,
                    ),
                ),
                (
                    "dropped_batches",
                    Json::num(
                        r.tenants.iter().map(|t| t.health.dropped_batches).sum::<u64>() as f64,
                    ),
                ),
                ("quarantined", Json::num(quarantined as f64)),
            ]),
        ),
        (
            "pipeline",
            Json::obj(vec![
                ("enabled", Json::Bool(r.pipeline)),
                (
                    "shards",
                    Json::Arr(
                        r.pipeline_shards
                            .iter()
                            .map(|p| {
                                let st = &p.stats;
                                Json::obj(vec![
                                    ("shard", Json::num(p.shard as f64)),
                                    ("staged_rounds", Json::num(st.staged_rounds as f64)),
                                    ("staged_batches", Json::num(st.staged_batches as f64)),
                                    ("fused_tiles", Json::num(st.fused_tiles as f64)),
                                    ("fused_batches", Json::num(st.fused_batches as f64)),
                                    ("max_fused_rows", Json::num(st.max_fused_rows as f64)),
                                    ("stage_ns", Json::num(st.stage_ns as f64)),
                                    ("commit_ns", Json::num(st.commit_ns as f64)),
                                    ("stage_wait_ns", Json::num(st.stage_wait_ns as f64)),
                                    ("overlap_ratio", Json::num(st.overlap_ratio())),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "sessions",
            Json::Arr(
                r.tenants
                    .iter()
                    .map(|t| {
                        let mut fields = vec![
                            ("tenant", Json::str(t.tenant.clone())),
                            ("shard", Json::num(t.shard as f64)),
                            ("stages", Json::str(t.stages.clone())),
                            ("precision", Json::str(t.precision.clone())),
                            ("batches", Json::num(t.batches as f64)),
                            ("samples", Json::num(t.samples as f64)),
                            ("p50_ns", t.p50_ns.map(Json::num).unwrap_or(Json::Null)),
                            ("p99_ns", t.p99_ns.map(Json::num).unwrap_or(Json::Null)),
                            ("restores", Json::num(t.restores as f64)),
                            (
                                "completed_at_s",
                                t.completed_at_s.map(Json::num).unwrap_or(Json::Null),
                            ),
                            (
                                "faults",
                                Json::obj(vec![
                                    ("total", Json::num(t.health.faults as f64)),
                                    ("retries", Json::num(t.health.retries as f64)),
                                    (
                                        "rejected_batches",
                                        Json::num(t.health.rejected_batches as f64),
                                    ),
                                    (
                                        "dropped_batches",
                                        Json::num(t.health.dropped_batches as f64),
                                    ),
                                    ("quarantined", Json::Bool(t.health.quarantined)),
                                    (
                                        "last_error",
                                        t.health
                                            .last_error
                                            .clone()
                                            .map(Json::str)
                                            .unwrap_or(Json::Null),
                                    ),
                                ]),
                            ),
                        ];
                        if let Some(snap) = &t.telemetry {
                            fields.push((
                                "health",
                                Json::Arr(
                                    snap.all()
                                        .map(|s| {
                                            Json::obj(vec![
                                                ("stage", Json::str(s.name.clone())),
                                                (
                                                    "sat_per_sample",
                                                    Json::num(s.sat_per_sample()),
                                                ),
                                                ("max_bits", Json::num(s.max_bits() as f64)),
                                                (
                                                    "headroom_bits",
                                                    s.headroom_bits()
                                                        .map(|b| Json::num(b as f64))
                                                        .unwrap_or(Json::Null),
                                                ),
                                                ("samples", Json::num(s.samples as f64)),
                                            ])
                                        })
                                        .collect(),
                                ),
                            ));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Golden-schema check for `SERVE_report.json`. With `expect_telemetry`
/// every non-quarantined session must carry a non-empty per-tenant
/// `health` block with sane counters — the CI smoke's validation of the
/// per-tenant telemetry snapshot. Quarantined sessions are held to a
/// weaker contract (their numbers are a frozen last-good checkpoint,
/// which may legitimately be empty).
pub fn validate(v: &Json, expect_telemetry: bool) -> Result<()> {
    ensure!(
        v.field("experiment")?.as_str()? == "serve_report",
        "wrong experiment tag"
    );
    ensure!(
        v.field("schema_version")?.as_usize()? == 3,
        "unknown schema version"
    );
    let tenants = v.field("tenants")?.as_usize()?;
    ensure!(tenants >= 1, "tenants must be >= 1");
    ensure!(v.field("shards")?.as_usize()? >= 1, "shards must be >= 1");
    v.field("arrival")?.as_str()?;
    let total = v.field("total_samples")?.as_u64()?;
    ensure!(total > 0, "total_samples must be positive");
    let agg = v.field("aggregate_samples_per_s")?.as_f64()?;
    ensure!(
        agg.is_finite() && agg > 0.0,
        "aggregate_samples_per_s must be positive, got {agg}"
    );
    match v.field("fairness_spread")? {
        Json::Null => {}
        other => {
            let s = other.as_f64()?;
            ensure!(s >= 1.0, "fairness spread is slowest/fastest, got {s}");
        }
    }
    let faults = v.field("faults").context("missing faults section")?;
    match faults.field("spec")? {
        Json::Null => {}
        other => {
            other.as_str()?;
        }
    }
    for key in [
        "injected_batches",
        "injected_stalls",
        "producer_hangups",
        "total_faults",
        "retries",
        "rejected_batches",
        "dropped_batches",
    ] {
        faults.field(key)?.as_u64()?;
    }
    let quarantined_total = faults.field("quarantined")?.as_u64()?;

    let pipeline = v.field("pipeline").context("missing pipeline section")?;
    let pipelined = pipeline.field("enabled")?.as_bool()?;
    let shard_rows = pipeline.field("shards")?.as_arr()?;
    ensure!(
        shard_rows.len() == v.field("shards")?.as_usize()?,
        "pipeline shard rows {} != shards",
        shard_rows.len()
    );
    let mut staged_total = 0u64;
    for row in shard_rows {
        row.field("shard")?.as_usize()?;
        let staged = row.field("staged_batches")?.as_u64()?;
        staged_total += staged;
        let fused_tiles = row.field("fused_tiles")?.as_u64()?;
        let fused_batches = row.field("fused_batches")?.as_u64()?;
        ensure!(
            fused_batches >= 2 * fused_tiles,
            "a mega-tile fuses at least two batches"
        );
        ensure!(
            fused_batches <= staged,
            "fused batches exceed staged batches"
        );
        ensure!(
            row.field("staged_rounds")?.as_u64()? <= staged,
            "every staged round carries at least one batch"
        );
        row.field("max_fused_rows")?.as_u64()?;
        row.field("stage_ns")?.as_u64()?;
        row.field("commit_ns")?.as_u64()?;
        row.field("stage_wait_ns")?.as_u64()?;
        let overlap = row.field("overlap_ratio")?.as_f64()?;
        ensure!(
            (0.0..=1.0).contains(&overlap),
            "overlap_ratio must be in [0, 1], got {overlap}"
        );
        if !pipelined {
            ensure!(staged == 0, "serial run reports staged batches");
        }
    }
    if pipelined {
        ensure!(
            staged_total > 0,
            "pipelined run staged no batches"
        );
    }

    let sessions = v.field("sessions")?.as_arr()?;
    ensure!(
        sessions.len() == tenants,
        "sessions count {} != tenants {}",
        sessions.len(),
        tenants
    );
    let mut quarantined_seen = 0u64;
    for s in sessions {
        let tenant = s.field("tenant")?.as_str()?;
        s.field("shard")?.as_usize()?;
        s.field("stages")?.as_str()?;
        s.field("precision")?.as_str()?;
        let batches = s.field("batches")?.as_u64()?;
        let samples = s.field("samples")?.as_u64()?;
        let f = s
            .field("faults")
            .with_context(|| format!("tenant '{tenant}' missing faults block"))?;
        let fault_total = f.field("total")?.as_u64()?;
        let retries = f.field("retries")?.as_u64()?;
        let rejected = f.field("rejected_batches")?.as_u64()?;
        f.field("dropped_batches")?.as_u64()?;
        ensure!(
            retries + rejected <= fault_total,
            "tenant '{tenant}' fault counters inconsistent"
        );
        let quarantined = f.field("quarantined")?.as_bool()?;
        quarantined_seen += u64::from(quarantined);
        if quarantined {
            ensure!(
                !matches!(f.field("last_error")?, Json::Null),
                "tenant '{tenant}' quarantined without a last_error"
            );
            ensure!(
                matches!(s.field("completed_at_s")?, Json::Null),
                "tenant '{tenant}' both quarantined and completed"
            );
        } else {
            ensure!(samples > 0, "tenant '{tenant}' processed no samples");
        }
        if batches > 0 {
            s.field("p50_ns")?
                .as_f64()
                .with_context(|| format!("tenant '{tenant}' p50"))?;
            s.field("p99_ns")?
                .as_f64()
                .with_context(|| format!("tenant '{tenant}' p99"))?;
        }
        s.field("restores")?.as_u64()?;
        if expect_telemetry && !quarantined {
            let health = s
                .field("health")
                .with_context(|| format!("tenant '{tenant}' missing telemetry health"))?
                .as_arr()?;
            ensure!(
                !health.is_empty(),
                "tenant '{tenant}' telemetry health is empty"
            );
            let mut seen_samples = 0u64;
            for h in health {
                h.field("stage")?.as_str()?;
                let rate = h.field("sat_per_sample")?.as_f64()?;
                ensure!(
                    rate.is_finite() && rate >= 0.0,
                    "sat_per_sample must be non-negative, got {rate}"
                );
                ensure!(
                    h.field("max_bits")?.as_usize()? <= 32,
                    "max_bits exceeds a raw word"
                );
                seen_samples += h.field("samples")?.as_u64()?;
            }
            ensure!(
                seen_samples > 0,
                "tenant '{tenant}' telemetry recorded no samples"
            );
        }
    }
    ensure!(
        quarantined_seen == quarantined_total,
        "faults.quarantined {quarantined_total} != {quarantined_seen} quarantined sessions"
    );
    Ok(())
}

/// Aligned text report.
pub fn render(r: &ServeReport) -> String {
    let mut s = format!(
        "dimred serve — {} tenants on {} shards ({} arrival)\n",
        r.tenants.len(),
        r.shards,
        r.arrival
    );
    s.push_str(&format!(
        "aggregate: {:.0} samples/s over {:.3}s ({} samples)",
        r.aggregate_samples_per_s, r.elapsed_s, r.total_samples
    ));
    if let Some(spread) = r.fairness_spread {
        s.push_str(&format!("  fairness spread: {spread:.2}x"));
    }
    s.push('\n');
    if let Some(spec) = &r.faults_spec {
        let quarantined = r.tenants.iter().filter(|t| t.health.quarantined).count();
        s.push_str(&format!(
            "faults: spec={spec} injected={} stalls={} hangups={} quarantined={quarantined}\n",
            r.injected_batches, r.injected_stalls, r.producer_hangups
        ));
    }
    if r.pipeline {
        for p in &r.pipeline_shards {
            let st = &p.stats;
            s.push_str(&format!(
                "pipeline shard {}: staged={} fused={}x{} (max {} rows) overlap={:.0}%\n",
                p.shard,
                st.staged_batches,
                st.fused_tiles,
                st.fused_batches,
                st.max_fused_rows,
                st.overlap_ratio() * 100.0
            ));
        }
    }
    s.push_str(&format!(
        "{:<6} {:>5} {:<34} {:<10} {:>7} {:>8} {:>10} {:>10} {:>8}\n",
        "tenant", "shard", "stages", "precision", "batches", "samples", "p50", "p99", "restores"
    ));
    for t in &r.tenants {
        let fmt_ns = |v: Option<f64>| {
            v.map(|ns| crate::util::bench::fmt_duration(std::time::Duration::from_nanos(ns as u64)))
                .unwrap_or_else(|| "-".into())
        };
        s.push_str(&format!(
            "{:<6} {:>5} {:<34} {:<10} {:>7} {:>8} {:>10} {:>10} {:>8}\n",
            t.tenant,
            t.shard,
            t.stages,
            t.precision,
            t.batches,
            t.samples,
            fmt_ns(t.p50_ns),
            fmt_ns(t.p99_ns),
            t.restores
        ));
        let h = &t.health;
        if h.faults > 0 || h.quarantined {
            s.push_str(&format!(
                "       faults {:<3} retries={} rejected={} dropped={}{}{}\n",
                h.faults,
                h.retries,
                h.rejected_batches,
                h.dropped_batches,
                if h.quarantined { "  QUARANTINED" } else { "" },
                h.last_error
                    .as_deref()
                    .map(|e| format!("  last: {e}"))
                    .unwrap_or_default(),
            ));
        }
        if let Some(snap) = &t.telemetry {
            for h in snap.all() {
                let headroom = h
                    .headroom_bits()
                    .map(|b| format!("{b}b"))
                    .unwrap_or_else(|| "-".into());
                s.push_str(&format!(
                    "       health {:<14} sat/smp={:<8.3} max_bits={:<3} headroom={}\n",
                    h.name,
                    h.sat_per_sample(),
                    h.max_bits(),
                    headroom
                ));
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::workload::{self, ArrivalPattern, ServeOptions};

    fn tiny_opts(telemetry: bool) -> ServeOptions {
        ServeOptions {
            tenants: 2,
            shards: 2,
            batch: 16,
            batches_per_tenant: 3,
            arrival: ArrivalPattern::Uniform,
            telemetry,
            ..ServeOptions::default()
        }
    }

    #[test]
    fn report_roundtrips_and_validates() {
        let opts = tiny_opts(true);
        let r = workload::run(&opts).unwrap();
        let json = to_json(&opts, &r);
        let parsed = Json::parse(&json.to_string_pretty()).unwrap();
        validate(&parsed, true).unwrap();
        let table = render(&r);
        assert!(table.contains("tenant"), "{table}");
        assert!(table.contains("health"), "{table}");
        // A clean run still carries the (all-zero) faults section.
        let faults = parsed.field("faults").unwrap();
        assert_eq!(faults.field("quarantined").unwrap().as_u64().unwrap(), 0);
    }

    #[test]
    fn validate_rejects_drift_and_missing_telemetry() {
        let opts = tiny_opts(false);
        let r = workload::run(&opts).unwrap();
        let good = to_json(&opts, &r);
        // Without telemetry the relaxed check passes…
        validate(&good, false).unwrap();
        // …but the telemetry-expecting check fails (no health blocks).
        assert!(validate(&good, true).is_err());
        // Wrong tag / stale version / dropped sections all fail.
        let mut map = good.as_obj().unwrap().clone();
        map.insert("experiment".into(), Json::str("something_else"));
        assert!(validate(&Json::Obj(map), false).is_err());
        let mut map = good.as_obj().unwrap().clone();
        map.insert("schema_version".into(), Json::num(2.0));
        assert!(validate(&Json::Obj(map), false).is_err());
        let mut map = good.as_obj().unwrap().clone();
        map.remove("sessions");
        assert!(validate(&Json::Obj(map), false).is_err());
        let mut map = good.as_obj().unwrap().clone();
        map.remove("faults");
        assert!(validate(&Json::Obj(map), false).is_err());
        let mut map = good.as_obj().unwrap().clone();
        map.remove("pipeline");
        assert!(validate(&Json::Obj(map), false).is_err());
    }

    #[test]
    fn pipelined_report_roundtrips_and_validates() {
        let opts = ServeOptions {
            pipeline: true,
            batches_per_tenant: 6,
            ..tiny_opts(true)
        };
        let r = workload::run(&opts).unwrap();
        let json = to_json(&opts, &r);
        let parsed = Json::parse(&json.to_string_pretty()).unwrap();
        validate(&parsed, true).unwrap();
        let pipeline = parsed.field("pipeline").unwrap();
        assert!(pipeline.field("enabled").unwrap().as_bool().unwrap());
        let staged: u64 = pipeline
            .field("shards")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|row| row.field("staged_batches").unwrap().as_u64().unwrap())
            .sum();
        assert_eq!(staged, 2 * 6, "every batch staged exactly once");
        let table = render(&r);
        assert!(table.contains("pipeline shard"), "{table}");
    }

    #[test]
    fn faulted_run_reports_quarantine_and_validates() {
        // t1 sends pure NaN traffic → quarantined; everyone else clean.
        // Enough batches that the breaker (max_retries consecutive
        // failures) trips before the stream runs dry.
        let opts = ServeOptions {
            faults: Some("t1:nan".into()),
            batches_per_tenant: 8,
            ..tiny_opts(true)
        };
        let r = workload::run(&opts).unwrap();
        let json = to_json(&opts, &r);
        let parsed = Json::parse(&json.to_string_pretty()).unwrap();
        validate(&parsed, true).unwrap();
        let faults = parsed.field("faults").unwrap();
        assert_eq!(faults.field("quarantined").unwrap().as_u64().unwrap(), 1);
        assert!(faults.field("injected_batches").unwrap().as_u64().unwrap() >= 1);
        let table = render(&r);
        assert!(table.contains("QUARANTINED"), "{table}");
    }
}
