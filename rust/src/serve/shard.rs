//! A shard: one worker owning a set of tenants.
//!
//! Generalizes the single-stream batcher queue to N tenants: each
//! tenant gets a bounded ingress queue (same backpressure contract —
//! a full queue blocks that tenant's producer, nobody else's), and the
//! shard drains them with a round-robin *quantum* so a tenant blasting
//! batches cannot starve a trickling one. Within a round, pending
//! batches are coalesced by graph shape (stage cascade + precision):
//! same-shape tiles run back to back, which keeps the datapath's
//! instruction/data locality under mixed-tenant traffic. The sort is
//! stable, so each tenant's batches stay in FIFO order.

use super::registry::SessionRegistry;
use crate::config::ExperimentConfig;
use crate::coordinator::Batch;
use crate::telemetry::TelemetrySnapshot;
use anyhow::{Context, Result};
use std::sync::mpsc::{Receiver, SyncSender, TryRecvError};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct ShardOptions {
    /// Depth of each tenant's bounded ingress queue (batches).
    pub queue_depth: usize,
    /// Max batches drained per tenant per round-robin round — the
    /// fairness knob: a backlogged tenant gets at most this much of the
    /// shard per pass over the other tenants.
    pub quantum: usize,
    /// Evict live sessions that had no work this round (aggressive
    /// memory cap; restores are transparent and bit-exact).
    pub evict_idle: bool,
}

impl Default for ShardOptions {
    fn default() -> Self {
        Self {
            queue_depth: 8,
            quantum: 4,
            evict_idle: false,
        }
    }
}

/// A tenant's ingress handle: producers push batches through it.
/// Blocking send — a full queue is backpressure on that tenant only.
pub struct TenantIngress {
    pub tenant: String,
    tx: SyncSender<Batch>,
}

impl TenantIngress {
    pub fn send(&self, b: Batch) -> Result<()> {
        self.tx
            .send(b)
            .map_err(|_| anyhow::anyhow!("shard hung up on tenant '{}'", self.tenant))
    }
}

struct TenantQueue {
    tenant: String,
    /// Graph-shape key (stage cascade + precision label) — the
    /// coalescing class.
    shape: String,
    rx: Receiver<Batch>,
    /// Set when the producer hung up and the queue fully drained.
    completed_at: Option<Duration>,
}

/// Per-round work summary.
#[derive(Debug, Clone, Copy)]
pub struct RoundStats {
    pub batches: usize,
    pub samples: u64,
    /// Every tenant's producer has hung up and every queue is drained.
    pub all_done: bool,
}

/// Final per-tenant summary a shard hands back to the workload driver.
#[derive(Debug, Clone)]
pub struct TenantOutcome {
    pub tenant: String,
    pub shard: usize,
    pub shape: String,
    pub batches: u64,
    pub samples: u64,
    pub p50_ns: Option<f64>,
    pub p99_ns: Option<f64>,
    pub restores: u64,
    pub completed_at_s: Option<f64>,
    pub telemetry: Option<TelemetrySnapshot>,
}

/// One worker: a registry of sessions plus their ingress queues.
pub struct Shard {
    pub id: usize,
    registry: SessionRegistry,
    queues: Vec<TenantQueue>,
    opts: ShardOptions,
    started: Instant,
}

impl Shard {
    pub fn new(id: usize, opts: ShardOptions) -> Self {
        Self {
            id,
            registry: SessionRegistry::new(),
            queues: Vec::new(),
            opts,
            started: Instant::now(),
        }
    }

    /// Register a tenant and hand back its ingress. The shape key
    /// groups tenants whose batches can be coalesced.
    pub fn add_tenant(&mut self, tenant: &str, cfg: &ExperimentConfig) -> Result<TenantIngress> {
        let (tx, rx) = std::sync::mpsc::sync_channel(self.opts.queue_depth);
        self.attach(tenant, cfg, rx)?;
        Ok(TenantIngress {
            tenant: tenant.to_string(),
            tx,
        })
    }

    /// Register a tenant draining an externally created queue (the
    /// workload driver creates channels before moving the shard into
    /// its worker thread).
    pub fn attach(
        &mut self,
        tenant: &str,
        cfg: &ExperimentConfig,
        rx: Receiver<Batch>,
    ) -> Result<()> {
        let shape = format!(
            "{}@{}",
            cfg.graph_spec()
                .with_context(|| format!("tenant '{tenant}' graph"))?
                .stages_label(),
            cfg.precision.label()
        );
        self.registry.create(tenant, cfg)?;
        self.queues.push(TenantQueue {
            tenant: tenant.to_string(),
            shape,
            rx,
            completed_at: None,
        });
        Ok(())
    }

    pub fn registry(&self) -> &SessionRegistry {
        &self.registry
    }

    pub fn registry_mut(&mut self) -> &mut SessionRegistry {
        &mut self.registry
    }

    /// One scheduler round: drain up to `quantum` batches per tenant,
    /// coalesce the round's worklist by graph shape (stable — per-tenant
    /// FIFO preserved), ingest everything, then optionally evict
    /// sessions that saw no traffic.
    pub fn poll_round(&mut self) -> Result<RoundStats> {
        let mut work: Vec<(usize, Batch)> = Vec::new();
        for (qi, q) in self.queues.iter_mut().enumerate() {
            if q.completed_at.is_some() {
                continue;
            }
            for _ in 0..self.opts.quantum {
                match q.rx.try_recv() {
                    Ok(b) => work.push((qi, b)),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        // Disconnected means drained AND hung up (mpsc
                        // yields buffered messages first).
                        q.completed_at = Some(self.started.elapsed());
                        break;
                    }
                }
            }
        }
        let mut had_work = vec![false; self.queues.len()];
        for (qi, _) in &work {
            had_work[*qi] = true;
        }
        // Coalesce: same-shape batches run back to back. Stable sort →
        // each tenant's own batches keep their arrival order.
        work.sort_by(|a, b| self.queues[a.0].shape.cmp(&self.queues[b.0].shape));

        let batches = work.len();
        let mut samples = 0u64;
        for (qi, batch) in work {
            let tenant = self.queues[qi].tenant.clone();
            let session = self.registry.session_mut(&tenant)?;
            session.ingest(&batch)?;
            samples += batch.len() as u64;
        }
        if self.opts.evict_idle {
            for qi in 0..self.queues.len() {
                let q = &self.queues[qi];
                if q.completed_at.is_none() && !had_work[qi] && self.registry.is_live(&q.tenant) {
                    let tenant = q.tenant.clone();
                    self.registry.evict(&tenant)?;
                }
            }
        }
        Ok(RoundStats {
            batches,
            samples,
            all_done: self.queues.iter().all(|q| q.completed_at.is_some()),
        })
    }

    /// Drive rounds until every tenant's stream completes. Sleeps
    /// briefly on idle rounds so a waiting shard doesn't spin a core.
    pub fn run_to_completion(&mut self) -> Result<()> {
        loop {
            let stats = self.poll_round()?;
            if stats.all_done {
                return Ok(());
            }
            if stats.batches == 0 {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
    }

    /// Final per-tenant summaries (restores evicted sessions to read
    /// their telemetry snapshot).
    pub fn tenant_outcomes(&mut self) -> Result<Vec<TenantOutcome>> {
        let mut out = Vec::with_capacity(self.queues.len());
        for qi in 0..self.queues.len() {
            let (tenant, shape, completed_at) = {
                let q = &self.queues[qi];
                (q.tenant.clone(), q.shape.clone(), q.completed_at)
            };
            let shard = self.id;
            let restores = self.registry.restores(&tenant);
            let session = self.registry.session_mut(&tenant)?;
            let m = session.metrics();
            out.push(TenantOutcome {
                tenant,
                shard,
                shape,
                batches: m.batches,
                samples: m.samples_in,
                p50_ns: m.step_latency.percentile(50.0).map(|d| d.as_nanos() as f64),
                p99_ns: m.step_latency.percentile(99.0).map(|d| d.as_nanos() as f64),
                restores,
                completed_at_s: completed_at.map(|d| d.as_secs_f64()),
                telemetry: session.trainer().telemetry_snapshot(),
            });
        }
        Ok(out)
    }
}
