//! A shard: one worker owning a set of tenants.
//!
//! Generalizes the single-stream batcher queue to N tenants: each
//! tenant gets a bounded ingress queue (same backpressure contract —
//! a full queue blocks that tenant's producer, nobody else's), and the
//! shard drains them with a round-robin *quantum* so a tenant blasting
//! batches cannot starve a trickling one. Within a round, pending
//! batches are coalesced by graph shape (stage cascade + precision):
//! same-shape tiles run back to back, which keeps the datapath's
//! instruction/data locality under mixed-tenant traffic. The sort is
//! stable, so each tenant's batches stay in FIFO order.
//!
//! Failures are contained per tenant by a circuit breaker: an erroring
//! ingest halts only that tenant's round, the failed batch is requeued
//! (transient errors) or dropped (typed [`BatchRejected`] payload
//! errors), and the tenant backs off for exponentially growing round
//! counts. After `max_retries` consecutive failures the tenant is
//! *quarantined*: its last-good checkpoint stays in the registry for
//! reporting, its queue is torn down so the producer observes the
//! hang-up, and every other tenant keeps draining untouched.

use super::faults::{FaultPlan, TenantInjector};
use super::registry::SessionRegistry;
use crate::config::ExperimentConfig;
use crate::coordinator::{Batch, BatchRejected};
use crate::telemetry::TelemetrySnapshot;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{Receiver, SyncSender, TryRecvError};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct ShardOptions {
    /// Depth of each tenant's bounded ingress queue (batches).
    pub queue_depth: usize,
    /// Max batches drained per tenant per round-robin round — the
    /// fairness knob: a backlogged tenant gets at most this much of the
    /// shard per pass over the other tenants.
    pub quantum: usize,
    /// Evict live sessions that had no work this round (aggressive
    /// memory cap; restores are transparent and bit-exact).
    pub evict_idle: bool,
    /// Consecutive ingest failures a tenant may accumulate before it is
    /// quarantined (its last-good checkpoint is preserved).
    pub max_retries: u32,
    /// Cap on the exponential retry backoff, in scheduler rounds.
    pub backoff_cap_rounds: u64,
}

impl Default for ShardOptions {
    fn default() -> Self {
        Self {
            queue_depth: 8,
            quantum: 4,
            evict_idle: false,
            max_retries: 3,
            backoff_cap_rounds: 8,
        }
    }
}

/// A tenant's ingress handle: producers push batches through it.
/// Blocking send — a full queue is backpressure on that tenant only.
pub struct TenantIngress {
    pub tenant: String,
    tx: SyncSender<Batch>,
}

impl TenantIngress {
    pub fn send(&self, b: Batch) -> Result<()> {
        self.tx
            .send(b)
            .map_err(|_| anyhow::anyhow!("shard hung up on tenant '{}'", self.tenant))
    }
}

/// Per-tenant fault-containment state, reported through
/// [`TenantOutcome`] into the serve report's `faults` section.
#[derive(Debug, Clone, Default)]
pub struct TenantHealth {
    /// Ingest attempts that failed (any cause).
    pub faults: u64,
    /// Failed batches requeued for another attempt.
    pub retries: u64,
    /// Batches refused by ingest validation (poisoned payloads; never
    /// retried — garbage stays garbage).
    pub rejected_batches: u64,
    /// Batches discarded at quarantine (in-flight + queued backlog).
    pub dropped_batches: u64,
    /// Circuit breaker open: the tenant is out of the scheduler and its
    /// last-good checkpoint is frozen in the registry.
    pub quarantined: bool,
    /// Most recent failure, for the report.
    pub last_error: Option<String>,
    /// Consecutive failures so far (resets on success).
    consecutive: u32,
    /// Scheduler round before which this tenant is skipped (backoff).
    backoff_until: u64,
}

struct TenantQueue {
    tenant: String,
    /// Graph-shape key (stage cascade + precision label) — the
    /// coalescing class.
    shape: String,
    /// `None` once the producer side hung up (or the tenant was
    /// quarantined and the shard dropped its end).
    rx: Option<Receiver<Batch>>,
    /// Drained-but-unprocessed batches: retry requeues land at the
    /// front so per-tenant FIFO order survives a failure.
    backlog: VecDeque<Batch>,
    health: TenantHealth,
    /// Set when the producer hung up and the queue fully drained.
    completed_at: Option<Duration>,
}

/// Per-round work summary.
#[derive(Debug, Clone, Copy)]
pub struct RoundStats {
    /// Batches ingested successfully this round.
    pub batches: usize,
    pub samples: u64,
    /// Ingest attempts that failed this round (contained per tenant).
    pub faults: usize,
    /// Every tenant either completed its stream or is quarantined.
    pub all_done: bool,
}

/// Final per-tenant summary a shard hands back to the workload driver.
#[derive(Debug, Clone)]
pub struct TenantOutcome {
    pub tenant: String,
    pub shard: usize,
    pub shape: String,
    pub batches: u64,
    pub samples: u64,
    pub p50_ns: Option<f64>,
    pub p99_ns: Option<f64>,
    pub restores: u64,
    pub completed_at_s: Option<f64>,
    pub telemetry: Option<TelemetrySnapshot>,
    pub health: TenantHealth,
}

/// One worker: a registry of sessions plus their ingress queues.
pub struct Shard {
    pub id: usize,
    registry: SessionRegistry,
    queues: Vec<TenantQueue>,
    opts: ShardOptions,
    started: Instant,
    round: u64,
    plan: Option<FaultPlan>,
    fault_seed: u64,
    injectors: HashMap<String, TenantInjector>,
}

impl Shard {
    pub fn new(id: usize, opts: ShardOptions) -> Self {
        Self {
            id,
            registry: SessionRegistry::new(),
            queues: Vec::new(),
            opts,
            started: Instant::now(),
            round: 0,
            plan: None,
            fault_seed: 0,
            injectors: HashMap::new(),
        }
    }

    /// Arm shard-side fault injection (synthetic ingest / restore
    /// failures) for current and future tenants. Injector streams are
    /// derived from `seed` per `(tenant, kind)`, so the fault sequence
    /// each tenant sees is deterministic.
    pub fn set_fault_plan(&mut self, plan: FaultPlan, seed: u64) {
        for q in &self.queues {
            if let Some(inj) = plan.injector_for(&q.tenant, seed) {
                self.injectors.insert(q.tenant.clone(), inj);
            }
        }
        self.plan = Some(plan);
        self.fault_seed = seed;
    }

    /// Register a tenant and hand back its ingress. The shape key
    /// groups tenants whose batches can be coalesced.
    pub fn add_tenant(&mut self, tenant: &str, cfg: &ExperimentConfig) -> Result<TenantIngress> {
        let (tx, rx) = std::sync::mpsc::sync_channel(self.opts.queue_depth);
        self.attach(tenant, cfg, rx)?;
        Ok(TenantIngress {
            tenant: tenant.to_string(),
            tx,
        })
    }

    /// Register a tenant draining an externally created queue (the
    /// workload driver creates channels before moving the shard into
    /// its worker thread).
    pub fn attach(
        &mut self,
        tenant: &str,
        cfg: &ExperimentConfig,
        rx: Receiver<Batch>,
    ) -> Result<()> {
        let shape = format!(
            "{}@{}",
            cfg.graph_spec()
                .with_context(|| format!("tenant '{tenant}' graph"))?
                .stages_label(),
            cfg.precision.label()
        );
        self.registry.create(tenant, cfg)?;
        if let Some(plan) = &self.plan {
            if let Some(inj) = plan.injector_for(tenant, self.fault_seed) {
                self.injectors.insert(tenant.to_string(), inj);
            }
        }
        self.queues.push(TenantQueue {
            tenant: tenant.to_string(),
            shape,
            rx: Some(rx),
            backlog: VecDeque::new(),
            health: TenantHealth::default(),
            completed_at: None,
        });
        Ok(())
    }

    pub fn registry(&self) -> &SessionRegistry {
        &self.registry
    }

    pub fn registry_mut(&mut self) -> &mut SessionRegistry {
        &mut self.registry
    }

    /// One ingest attempt for one tenant, with shard-side fault
    /// injection applied before the session is touched.
    fn try_ingest(&mut self, tenant: &str, batch: &Batch) -> Result<u64> {
        if let Some(inj) = self.injectors.get_mut(tenant) {
            if !self.registry.is_live(tenant) && inj.restore_fault() {
                anyhow::bail!("injected fault: restore failed for tenant '{tenant}'");
            }
            if inj.ingest_fault() {
                anyhow::bail!("injected fault: ingest error for tenant '{tenant}'");
            }
        }
        let session = self
            .registry
            .session_mut(tenant)
            .with_context(|| format!("session lookup for tenant '{tenant}'"))?;
        session
            .ingest(batch)
            .with_context(|| format!("ingest for tenant '{tenant}'"))?;
        Ok(batch.len() as u64)
    }

    /// One scheduler round: drain up to `quantum` batches per tenant
    /// (skipping quarantined and backing-off tenants), coalesce the
    /// round's worklist by graph shape (stable — per-tenant FIFO
    /// preserved), ingest everything with per-tenant error containment,
    /// then optionally evict sessions that saw no traffic.
    ///
    /// An ingest failure never propagates out of the round: the tenant
    /// is halted for the rest of the round (its remaining batches go
    /// back to the front of its backlog in order), charged a fault, and
    /// either backed off for retry or quarantined once it exceeds
    /// `max_retries` consecutive failures.
    pub fn poll_round(&mut self) -> Result<RoundStats> {
        self.round += 1;
        let mut work: Vec<(usize, Batch)> = Vec::new();
        for (qi, q) in self.queues.iter_mut().enumerate() {
            if q.completed_at.is_some() || q.health.quarantined {
                continue;
            }
            if self.round < q.health.backoff_until {
                continue;
            }
            // Top the backlog up from the wire, then take this round's
            // quantum from the backlog front (retries sit ahead of
            // newer traffic there).
            if let Some(rx) = &q.rx {
                while q.backlog.len() < self.opts.quantum {
                    match rx.try_recv() {
                        Ok(b) => q.backlog.push_back(b),
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            // Disconnected means drained AND hung up
                            // (mpsc yields buffered messages first).
                            q.rx = None;
                            break;
                        }
                    }
                }
            }
            for _ in 0..self.opts.quantum {
                match q.backlog.pop_front() {
                    Some(b) => work.push((qi, b)),
                    None => break,
                }
            }
        }
        let mut had_work = vec![false; self.queues.len()];
        for (qi, _) in &work {
            had_work[*qi] = true;
        }
        // Coalesce: same-shape batches run back to back. Stable sort →
        // each tenant's own batches keep their arrival order.
        work.sort_by(|a, b| self.queues[a.0].shape.cmp(&self.queues[b.0].shape));

        let mut batches = 0usize;
        let mut faults = 0usize;
        let mut samples = 0u64;
        let mut halted = vec![false; self.queues.len()];
        let mut requeue: Vec<Vec<Batch>> = (0..self.queues.len()).map(|_| Vec::new()).collect();
        for (qi, batch) in work {
            if self.queues[qi].health.quarantined {
                self.queues[qi].health.dropped_batches += 1;
                continue;
            }
            if halted[qi] {
                requeue[qi].push(batch);
                continue;
            }
            let tenant = self.queues[qi].tenant.clone();
            match self.try_ingest(&tenant, &batch) {
                Ok(n) => {
                    batches += 1;
                    samples += n;
                    let h = &mut self.queues[qi].health;
                    h.consecutive = 0;
                    h.backoff_until = 0;
                }
                Err(err) => {
                    faults += 1;
                    halted[qi] = true;
                    // A typed rejection means the payload itself is
                    // garbage: never retried (garbage stays garbage);
                    // anything else is treated as transient.
                    let rejected = err.downcast_ref::<BatchRejected>().is_some();
                    let (quarantine, retry) = {
                        let h = &mut self.queues[qi].health;
                        h.faults += 1;
                        h.consecutive += 1;
                        h.last_error = Some(format!("{err:#}"));
                        if rejected {
                            h.rejected_batches += 1;
                        }
                        if h.consecutive > self.opts.max_retries {
                            h.quarantined = true;
                            if !rejected {
                                h.dropped_batches += 1;
                            }
                            (true, false)
                        } else {
                            let delay =
                                (1u64 << (h.consecutive - 1)).min(self.opts.backoff_cap_rounds);
                            h.backoff_until = self.round + delay;
                            if !rejected {
                                h.retries += 1;
                            }
                            (false, !rejected)
                        }
                    };
                    if quarantine {
                        // Freeze the last-good checkpoint for
                        // reporting. May fail or be a no-op (already
                        // evicted on the restore-fault path) — either
                        // way the tenant is out of the scheduler.
                        let _ = self.registry.evict(&tenant);
                    }
                    if retry {
                        requeue[qi].push(batch);
                    }
                }
            }
        }
        // Settle each queue: quarantined tenants shed everything and
        // drop their receiver (the producer's next send observes the
        // hang-up); healthy tenants get their halted remainder back in
        // FIFO order and complete once wire + backlog are empty.
        let elapsed = self.started.elapsed();
        for (qi, rq) in requeue.into_iter().enumerate() {
            let q = &mut self.queues[qi];
            if q.health.quarantined {
                let mut dropped = (rq.len() + q.backlog.len()) as u64;
                q.backlog.clear();
                if let Some(rx) = q.rx.take() {
                    while rx.try_recv().is_ok() {
                        dropped += 1;
                    }
                }
                q.health.dropped_batches += dropped;
            } else {
                for b in rq.into_iter().rev() {
                    q.backlog.push_front(b);
                }
                if q.rx.is_none() && q.backlog.is_empty() && q.completed_at.is_none() {
                    q.completed_at = Some(elapsed);
                }
            }
        }
        if self.opts.evict_idle {
            for qi in 0..self.queues.len() {
                let q = &self.queues[qi];
                if q.completed_at.is_none()
                    && !q.health.quarantined
                    && !had_work[qi]
                    && self.registry.is_live(&q.tenant)
                {
                    let tenant = q.tenant.clone();
                    self.registry.evict(&tenant)?;
                }
            }
        }
        Ok(RoundStats {
            batches,
            samples,
            faults,
            all_done: self
                .queues
                .iter()
                .all(|q| q.completed_at.is_some() || q.health.quarantined),
        })
    }

    /// Drive rounds until every tenant's stream completes (or is
    /// quarantined). Sleeps briefly on idle rounds so a waiting shard
    /// doesn't spin a core.
    pub fn run_to_completion(&mut self) -> Result<()> {
        loop {
            let stats = self.poll_round()?;
            if stats.all_done {
                return Ok(());
            }
            if stats.batches == 0 {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
    }

    /// Final per-tenant summaries. Reads metrics and telemetry straight
    /// from the registry slot — checkpoints carry both, so no evicted
    /// session is rebuilt and a tenant whose restore would fail still
    /// reports (its numbers are the last-good checkpoint's).
    pub fn tenant_outcomes(&self) -> Vec<TenantOutcome> {
        self.queues
            .iter()
            .map(|q| {
                let m = self.registry.metrics_of(&q.tenant);
                TenantOutcome {
                    tenant: q.tenant.clone(),
                    shard: self.id,
                    shape: q.shape.clone(),
                    batches: m.map_or(0, |m| m.batches),
                    samples: m.map_or(0, |m| m.samples_in),
                    p50_ns: m
                        .and_then(|m| m.step_latency.percentile(50.0))
                        .map(|d| d.as_nanos() as f64),
                    p99_ns: m
                        .and_then(|m| m.step_latency.percentile(99.0))
                        .map(|d| d.as_nanos() as f64),
                    restores: self.registry.restores(&q.tenant),
                    completed_at_s: q.completed_at.map(|d| d.as_secs_f64()),
                    telemetry: self.registry.telemetry_of(&q.tenant),
                    health: q.health.clone(),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig {
            train_classifier: false,
            rot_warmup: 32,
            telemetry: true,
            ..Default::default()
        }
    }

    fn batch(dim: usize, salt: usize) -> Batch {
        Batch::Full(Mat::from_fn(64, dim, |i, j| {
            ((i * 31 + j * 7 + salt * 13) % 17) as f32 / 17.0 - 0.5
        }))
    }

    #[test]
    fn synthetic_ingest_faults_trip_quarantine_without_aborting_the_shard() {
        let c = cfg();
        let opts = ShardOptions {
            queue_depth: 32,
            quantum: 2,
            max_retries: 2,
            ..Default::default()
        };
        let mut shard = Shard::new(0, opts);
        let bad = shard.add_tenant("t_bad", &c).unwrap();
        let good = shard.add_tenant("t_good", &c).unwrap();
        shard.set_fault_plan(FaultPlan::parse("t_bad:ingest@1").unwrap(), 2018);
        for salt in 0..6 {
            bad.send(batch(c.input_dim, salt)).unwrap();
            good.send(batch(c.input_dim, salt)).unwrap();
        }
        drop(bad);
        drop(good);
        shard.run_to_completion().unwrap();

        let by_tenant: HashMap<String, TenantOutcome> = shard
            .tenant_outcomes()
            .into_iter()
            .map(|o| (o.tenant.clone(), o))
            .collect();
        let bad = &by_tenant["t_bad"];
        assert!(bad.health.quarantined);
        // max_retries failed attempts were retried, the breaker opened
        // on attempt max_retries + 1.
        assert_eq!(bad.health.faults, u64::from(opts.max_retries) + 1);
        assert_eq!(bad.health.retries, u64::from(opts.max_retries));
        // Everything the tenant ever sent was shed (the retried batch
        // plus the rest of the stream), nothing ingested.
        assert_eq!(bad.health.dropped_batches, 6);
        assert_eq!(bad.samples, 0);
        assert!(bad.completed_at_s.is_none(), "quarantine is not completion");
        let good = &by_tenant["t_good"];
        assert!(!good.health.quarantined);
        assert_eq!(good.health.faults, 0);
        assert_eq!(good.samples, 6 * 64);
        assert!(good.completed_at_s.is_some());
    }

    #[test]
    fn backoff_skips_rounds_between_retries() {
        let c = cfg();
        let mut shard = Shard::new(
            0,
            ShardOptions {
                queue_depth: 8,
                quantum: 1,
                max_retries: 3,
                ..Default::default()
            },
        );
        let ing = shard.add_tenant("t0", &c).unwrap();
        shard.set_fault_plan(FaultPlan::parse("t0:ingest@1").unwrap(), 7);
        ing.send(batch(c.input_dim, 0)).unwrap();
        drop(ing);
        // After the failure on round r, backoff_until = r + delay and the
        // tenant is skipped while round < backoff_until, so with delays
        // 1, 2, 4 the attempts land on rounds 1, 2, 4, 8 — the fourth
        // attempt exceeds max_retries = 3 and trips the breaker.
        let mut attempt_rounds = Vec::new();
        for round in 1..=20u64 {
            let stats = shard.poll_round().unwrap();
            if stats.faults > 0 {
                attempt_rounds.push(round);
            }
            if stats.all_done {
                break;
            }
        }
        assert_eq!(attempt_rounds, vec![1, 2, 4, 8]);
        let out = &shard.tenant_outcomes()[0];
        assert!(out.health.quarantined);
        assert_eq!(out.health.faults, 4);
    }

    #[test]
    fn poisoned_batches_are_rejected_not_retried_and_state_is_preserved() {
        let c = cfg();
        let mut shard = Shard::new(
            0,
            ShardOptions {
                queue_depth: 32,
                quantum: 4,
                max_retries: 2,
                ..Default::default()
            },
        );
        let ing = shard.add_tenant("t0", &c).unwrap();
        // Two clean batches first, so the last-good checkpoint has real
        // samples, then a stream of NaN batches.
        ing.send(batch(c.input_dim, 0)).unwrap();
        ing.send(batch(c.input_dim, 1)).unwrap();
        for salt in 2..8 {
            ing.send(super::super::faults::corrupt(
                batch(c.input_dim, salt),
                super::super::faults::FaultKind::Nan,
            ))
            .unwrap();
        }
        drop(ing);
        shard.run_to_completion().unwrap();
        let out = &shard.tenant_outcomes()[0];
        assert!(out.health.quarantined);
        // Rejections are counted as rejections, not retries.
        assert_eq!(out.health.rejected_batches, 3);
        assert_eq!(out.health.retries, 0);
        // The clean samples survive in the frozen checkpoint.
        assert_eq!(out.samples, 2 * 64);
        assert!(!shard.registry().is_live("t0"), "quarantine evicts");
        assert!(out.telemetry.is_some(), "checkpoint still reports telemetry");
    }

    #[test]
    fn restore_faults_on_evicted_tenant_quarantine_but_keep_the_checkpoint() {
        let c = cfg();
        let mut shard = Shard::new(
            0,
            ShardOptions {
                queue_depth: 32,
                quantum: 4,
                evict_idle: true,
                max_retries: 1,
                ..Default::default()
            },
        );
        let ing = shard.add_tenant("t0", &c).unwrap();
        shard.set_fault_plan(FaultPlan::parse("t0:restore@1").unwrap(), 11);
        ing.send(batch(c.input_dim, 0)).unwrap();
        shard.poll_round().unwrap();
        assert_eq!(shard.registry().metrics_of("t0").unwrap().samples_in, 64);
        // Idle round → evicted.
        shard.poll_round().unwrap();
        assert!(!shard.registry().is_live("t0"));
        // Every later batch needs a restore, which is forced to fail.
        ing.send(batch(c.input_dim, 1)).unwrap();
        drop(ing);
        shard.run_to_completion().unwrap();
        let out = &shard.tenant_outcomes()[0];
        assert!(out.health.quarantined);
        let last = out.health.last_error.as_deref().unwrap();
        assert!(last.contains("restore failed"), "got: {last}");
        // The checkpoint (and its 64 pre-fault samples) still reports.
        assert_eq!(out.samples, 64);
    }
}
