//! A shard: one worker owning a set of tenants.
//!
//! Generalizes the single-stream batcher queue to N tenants: each
//! tenant gets a bounded ingress queue (same backpressure contract —
//! a full queue blocks that tenant's producer, nobody else's), and the
//! shard drains them with a round-robin *quantum* so a tenant blasting
//! batches cannot starve a trickling one. Within a round, pending
//! batches are coalesced by graph shape (stage cascade + precision):
//! same-shape tiles run back to back, which keeps the datapath's
//! instruction/data locality under mixed-tenant traffic. The sort key
//! includes the drain sequence, so each tenant's batches stay in FIFO
//! order (an allocation-free equivalent of the old stable sort).
//!
//! With [`ShardOptions::pipeline`] on, each round runs a bounded
//! two-slot pipeline instead of the serial loop: round N's batches are
//! dispatched to a long-lived *stager* thread (validation + entry
//! quantization — the ingress work [`Session::ingest`] would do before
//! touching the trainer) while the shard thread *commits* round N−1's
//! already-staged tiles through the stage graphs, hiding ingress cost
//! behind compute. The stager also fuses consecutive same-plan batches
//! into one contiguous raw buffer, and the commit path turns maximal
//! clean same-tenant runs into **mega-tile** commits — one trainer call
//! per run, attributed per batch through the row-range map. Both are
//! bit-identical to the serial path: entry quantization is per-sample
//! deterministic, commit order is unchanged, and stage warm-up gates
//! count global rows, not tile boundaries (fusion is additionally
//! gated on [`Session::fusion_ready`] and never applied to tenants
//! with fault injectors, whose streams must draw once per batch).
//!
//! Failures are contained per tenant by a circuit breaker: an erroring
//! ingest halts only that tenant's round, the failed batch is requeued
//! (transient errors) or dropped (typed [`BatchRejected`] payload
//! errors), and the tenant backs off for exponentially growing round
//! counts. After `max_retries` consecutive failures the tenant is
//! *quarantined*: its last-good checkpoint stays in the registry for
//! reporting, its queue is torn down so the producer observes the
//! hang-up, and every other tenant keeps draining untouched. In the
//! pipelined engine a staging-time rejection is charged through
//! [`Session::commit_rejected`] — the same typed path — and a commit
//! failure strips the tenant's in-flight staged batches back to the
//! backlog front *behind* the retried remainder, so per-tenant FIFO
//! survives the pipeline.
//!
//! [`Session::ingest`]: crate::coordinator::Session::ingest
//! [`Session::fusion_ready`]: crate::coordinator::Session::fusion_ready
//! [`Session::commit_rejected`]: crate::coordinator::Session::commit_rejected

use super::faults::{FaultPlan, TenantInjector};
use super::registry::SessionRegistry;
use crate::config::ExperimentConfig;
use crate::coordinator::{stage_batch, Batch, BatchRejected, StagePlan, StagedMark};
use crate::telemetry::TelemetrySnapshot;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::ops::Range;
use std::sync::mpsc::{Receiver, Sender, SyncSender, TryRecvError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct ShardOptions {
    /// Depth of each tenant's bounded ingress queue (batches).
    pub queue_depth: usize,
    /// Max batches drained per tenant per round-robin round — the
    /// fairness knob: a backlogged tenant gets at most this much of the
    /// shard per pass over the other tenants.
    pub quantum: usize,
    /// Evict live sessions that had no work this round (aggressive
    /// memory cap; restores are transparent and bit-exact).
    pub evict_idle: bool,
    /// Consecutive ingest failures a tenant may accumulate before it is
    /// quarantined (its last-good checkpoint is preserved).
    pub max_retries: u32,
    /// Cap on the exponential retry backoff, in scheduler rounds.
    pub backoff_cap_rounds: u64,
    /// Run the two-slot stage/commit pipeline with mega-tile fusion
    /// (see module docs) instead of the serial round loop.
    pub pipeline: bool,
}

impl Default for ShardOptions {
    fn default() -> Self {
        Self {
            queue_depth: 8,
            quantum: 4,
            evict_idle: false,
            max_retries: 3,
            backoff_cap_rounds: 8,
            pipeline: false,
        }
    }
}

/// A tenant's ingress handle: producers push batches through it.
/// Blocking send — a full queue is backpressure on that tenant only.
pub struct TenantIngress {
    pub tenant: String,
    tx: SyncSender<Batch>,
}

impl TenantIngress {
    pub fn send(&self, b: Batch) -> Result<()> {
        self.tx
            .send(b)
            .map_err(|_| anyhow::anyhow!("shard hung up on tenant '{}'", self.tenant))
    }
}

/// Per-tenant fault-containment state, reported through
/// [`TenantOutcome`] into the serve report's `faults` section.
#[derive(Debug, Clone, Default)]
pub struct TenantHealth {
    /// Ingest attempts that failed (any cause).
    pub faults: u64,
    /// Failed batches requeued for another attempt.
    pub retries: u64,
    /// Batches refused by ingest validation (poisoned payloads; never
    /// retried — garbage stays garbage).
    pub rejected_batches: u64,
    /// Batches discarded at quarantine (in-flight + queued backlog).
    pub dropped_batches: u64,
    /// Circuit breaker open: the tenant is out of the scheduler and its
    /// last-good checkpoint is frozen in the registry.
    pub quarantined: bool,
    /// Most recent failure, for the report.
    pub last_error: Option<String>,
    /// Consecutive failures so far (resets on success).
    consecutive: u32,
    /// Scheduler round before which this tenant is skipped (backoff).
    backoff_until: u64,
}

struct TenantQueue {
    tenant: String,
    /// Graph-shape key (stage cascade + precision label) — the
    /// coalescing class.
    shape: String,
    /// The `Send + Copy` staging recipe for this tenant's session
    /// (static over the session's lifetime — captured at attach so the
    /// pipelined path never restores an evicted session just to read
    /// its plan).
    plan: StagePlan,
    /// `None` once the producer side hung up (or the tenant was
    /// quarantined and the shard dropped its end).
    rx: Option<Receiver<Batch>>,
    /// Drained-but-unprocessed batches: retry requeues land at the
    /// front so per-tenant FIFO order survives a failure.
    backlog: VecDeque<Batch>,
    health: TenantHealth,
    /// Set when the producer hung up and the queue fully drained.
    completed_at: Option<Duration>,
}

/// Per-round work summary.
#[derive(Debug, Clone, Copy)]
pub struct RoundStats {
    /// Batches ingested successfully this round.
    pub batches: usize,
    pub samples: u64,
    /// Ingest attempts that failed this round (contained per tenant).
    pub faults: usize,
    /// Every tenant either completed its stream or is quarantined (and
    /// no staged work is still in flight).
    pub all_done: bool,
}

/// Final per-tenant summary a shard hands back to the workload driver.
#[derive(Debug, Clone)]
pub struct TenantOutcome {
    pub tenant: String,
    pub shard: usize,
    pub shape: String,
    pub batches: u64,
    pub samples: u64,
    pub p50_ns: Option<f64>,
    pub p99_ns: Option<f64>,
    pub restores: u64,
    pub completed_at_s: Option<f64>,
    pub telemetry: Option<TelemetrySnapshot>,
    pub health: TenantHealth,
}

/// Per-shard pipeline counters (all zeros while the shard runs the
/// serial scheduler).
#[derive(Debug, Clone, Copy, Default)]
pub struct PipelineStats {
    /// Rounds that dispatched work to the stager.
    pub staged_rounds: u64,
    /// Batches staged off the compute path.
    pub staged_batches: u64,
    /// Mega-tile commits (fused runs of ≥ 2 batches).
    pub fused_tiles: u64,
    /// Batches committed through mega-tiles.
    pub fused_batches: u64,
    /// Largest mega-tile committed, in rows.
    pub max_fused_rows: u64,
    /// Stager-thread busy time (validate + entry-quantize), ns.
    pub stage_ns: u64,
    /// Shard-thread commit time (trainer calls), ns.
    pub commit_ns: u64,
    /// Shard-thread time blocked waiting on the stager — the staging
    /// tail the commits could not hide, ns.
    pub stage_wait_ns: u64,
}

impl PipelineStats {
    /// Fraction of staging cost hidden behind commits: 1.0 = fully
    /// overlapped, 0.0 = every staged nanosecond stalled the shard.
    pub fn overlap_ratio(&self) -> f64 {
        if self.stage_ns == 0 {
            return 1.0;
        }
        (self.stage_ns.saturating_sub(self.stage_wait_ns) as f64 / self.stage_ns as f64)
            .clamp(0.0, 1.0)
    }
}

/// One staging work item: queue index, plan, batch.
type StageItem = (usize, StagePlan, Batch);

/// A round's staging job — the drained, shape-sorted worklist.
struct StageJob {
    items: Vec<StageItem>,
}

/// One staged batch: validated and (for raw plans) entry-quantized into
/// its group's fused buffer, or failed validation (`err`).
struct StagedItem {
    qi: usize,
    batch: Batch,
    /// This item's words inside the group buffer (empty for f32 plans
    /// and for rejected items) — the fused tile's row-range map.
    seg: Range<usize>,
    err: Option<BatchRejected>,
    mark: StagedMark,
}

/// Consecutive same-plan items staged into one contiguous buffer.
struct StagedGroup {
    plan: StagePlan,
    buf: Vec<i32>,
    items: Vec<StagedItem>,
}

/// One fully staged round, ready to commit next round.
struct StagedRound {
    groups: Vec<StagedGroup>,
    /// Stager busy time for this round, ns.
    ns: u64,
}

/// Run one staging job: group consecutive same-plan items, validate
/// and entry-quantize each batch into its group's fused buffer. Runs
/// on the stager thread; [`stage_batch`] is pure and session-free.
fn stage_job(job: StageJob) -> StagedRound {
    let mut groups: Vec<StagedGroup> = Vec::new();
    for (qi, plan, batch) in job.items {
        let need_new = match groups.last() {
            Some(g) => g.plan != plan,
            None => true,
        };
        if need_new {
            groups.push(StagedGroup {
                plan,
                buf: Vec::new(),
                items: Vec::new(),
            });
        }
        let g = groups.last_mut().expect("group pushed above");
        let start = g.buf.len();
        let (seg, err, mark) = match stage_batch(&plan, &batch, &mut g.buf) {
            Ok(mark) => (start..g.buf.len(), None, mark),
            Err(e) => {
                g.buf.truncate(start);
                (start..start, Some(e), StagedMark::default())
            }
        };
        g.items.push(StagedItem {
            qi,
            batch,
            seg,
            err,
            mark,
        });
    }
    StagedRound { groups, ns: 0 }
}

/// The shard's staging worker: one long-lived thread receiving round
/// jobs and sending back staged rounds. Dropping the job sender ends
/// the thread; [`Stager`]'s `Drop` joins it.
struct Stager {
    jobs: Option<Sender<StageJob>>,
    done: Receiver<StagedRound>,
    handle: Option<JoinHandle<()>>,
}

impl Stager {
    fn spawn() -> Self {
        let (jobs_tx, jobs_rx) = std::sync::mpsc::channel::<StageJob>();
        let (done_tx, done_rx) = std::sync::mpsc::channel::<StagedRound>();
        let handle = std::thread::Builder::new()
            .name("dimred-stager".into())
            .spawn(move || {
                for job in jobs_rx.iter() {
                    let t0 = Instant::now();
                    let mut round = stage_job(job);
                    round.ns = t0.elapsed().as_nanos() as u64;
                    if done_tx.send(round).is_err() {
                        return;
                    }
                }
            })
            .expect("spawning shard stager thread");
        Self {
            jobs: Some(jobs_tx),
            done: done_rx,
            handle: Some(handle),
        }
    }

    fn submit(&self, job: StageJob) -> Result<()> {
        match &self.jobs {
            Some(tx) => tx
                .send(job)
                .map_err(|_| anyhow::anyhow!("shard stager thread died")),
            None => anyhow::bail!("shard stager already shut down"),
        }
    }

    fn recv(&self) -> Result<StagedRound> {
        self.done
            .recv()
            .map_err(|_| anyhow::anyhow!("shard stager thread died"))
    }
}

impl Drop for Stager {
    fn drop(&mut self) {
        self.jobs.take();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One worker: a registry of sessions plus their ingress queues.
pub struct Shard {
    pub id: usize,
    registry: SessionRegistry,
    queues: Vec<TenantQueue>,
    opts: ShardOptions,
    started: Instant,
    round: u64,
    plan: Option<FaultPlan>,
    fault_seed: u64,
    injectors: HashMap<String, TenantInjector>,
    /// Lazily spawned staging thread (pipelined scheduler only).
    stager: Option<Stager>,
    /// The in-flight staged round: its staging overlapped the previous
    /// round's commits; it commits next round.
    slot: Option<StagedRound>,
    pstats: PipelineStats,
    // Round-scoped scratch hoisted out of `poll_round` so steady-state
    // rounds allocate nothing (proven in `tests/alloc_free.rs`).
    /// This round's worklist: (drain seq, queue index, batch). The seq
    /// breaks shape ties in the unstable sort (stable-equivalent), and
    /// `None` marks a consumed item; leftovers requeue in order.
    work: Vec<(usize, usize, Option<Batch>)>,
    had_work: Vec<bool>,
    halted: Vec<bool>,
    /// Queues with batches in the staged slot (blocks completion).
    staged_pending: Vec<bool>,
}

impl Shard {
    pub fn new(id: usize, opts: ShardOptions) -> Self {
        Self {
            id,
            registry: SessionRegistry::new(),
            queues: Vec::new(),
            opts,
            started: Instant::now(),
            round: 0,
            plan: None,
            fault_seed: 0,
            injectors: HashMap::new(),
            stager: None,
            slot: None,
            pstats: PipelineStats::default(),
            work: Vec::new(),
            had_work: Vec::new(),
            halted: Vec::new(),
            staged_pending: Vec::new(),
        }
    }

    /// Arm shard-side fault injection (synthetic ingest / restore
    /// failures) for current and future tenants. Injector streams are
    /// derived from `seed` per `(tenant, kind)`, so the fault sequence
    /// each tenant sees is deterministic.
    pub fn set_fault_plan(&mut self, plan: FaultPlan, seed: u64) {
        for q in &self.queues {
            if let Some(inj) = plan.injector_for(&q.tenant, seed) {
                self.injectors.insert(q.tenant.clone(), inj);
            }
        }
        self.plan = Some(plan);
        self.fault_seed = seed;
    }

    /// Register a tenant and hand back its ingress. The shape key
    /// groups tenants whose batches can be coalesced.
    pub fn add_tenant(&mut self, tenant: &str, cfg: &ExperimentConfig) -> Result<TenantIngress> {
        let (tx, rx) = std::sync::mpsc::sync_channel(self.opts.queue_depth);
        self.attach(tenant, cfg, rx)?;
        Ok(TenantIngress {
            tenant: tenant.to_string(),
            tx,
        })
    }

    /// Register a tenant draining an externally created queue (the
    /// workload driver creates channels before moving the shard into
    /// its worker thread).
    pub fn attach(
        &mut self,
        tenant: &str,
        cfg: &ExperimentConfig,
        rx: Receiver<Batch>,
    ) -> Result<()> {
        let shape = format!(
            "{}@{}",
            cfg.graph_spec()
                .with_context(|| format!("tenant '{tenant}' graph"))?
                .stages_label(),
            cfg.precision.label()
        );
        self.registry.create(tenant, cfg)?;
        let plan = self
            .registry
            .session_mut(tenant)
            .with_context(|| format!("stage plan for tenant '{tenant}'"))?
            .stage_plan();
        if let Some(fp) = &self.plan {
            if let Some(inj) = fp.injector_for(tenant, self.fault_seed) {
                self.injectors.insert(tenant.to_string(), inj);
            }
        }
        self.queues.push(TenantQueue {
            tenant: tenant.to_string(),
            shape,
            plan,
            rx: Some(rx),
            backlog: VecDeque::new(),
            health: TenantHealth::default(),
            completed_at: None,
        });
        Ok(())
    }

    pub fn registry(&self) -> &SessionRegistry {
        &self.registry
    }

    pub fn registry_mut(&mut self) -> &mut SessionRegistry {
        &mut self.registry
    }

    /// Pipeline counters (zeros unless [`ShardOptions::pipeline`]).
    pub fn pipeline_stats(&self) -> PipelineStats {
        self.pstats
    }

    #[cfg(test)]
    fn backlog_len(&self, tenant: &str) -> usize {
        self.queues
            .iter()
            .find(|q| q.tenant == tenant)
            .map_or(0, |q| q.backlog.len())
    }

    /// One scheduler round: drain up to `quantum` batches per tenant
    /// (skipping quarantined and backing-off tenants), coalesce the
    /// round's worklist by graph shape (per-tenant FIFO preserved),
    /// ingest everything with per-tenant error containment, then
    /// optionally evict sessions that saw no traffic. With
    /// [`ShardOptions::pipeline`] the ingest half runs the two-slot
    /// stage/commit pipeline instead (see module docs).
    ///
    /// An ingest failure never propagates out of the round: the tenant
    /// is halted for the rest of the round (its remaining batches go
    /// back to the front of its backlog in order), charged a fault, and
    /// either backed off for retry or quarantined once it exceeds
    /// `max_retries` consecutive failures.
    pub fn poll_round(&mut self) -> Result<RoundStats> {
        self.round += 1;
        self.drain_round();
        self.sort_work();
        let (batches, samples, faults) = if self.opts.pipeline {
            self.pipeline_round()?
        } else {
            self.commit_serial()
        };
        self.requeue_work();
        self.note_staged_pending();
        self.settle_round()?;
        Ok(RoundStats {
            batches,
            samples,
            faults,
            all_done: self.slot.is_none()
                && self
                    .queues
                    .iter()
                    .all(|q| q.completed_at.is_some() || q.health.quarantined),
        })
    }

    /// Fill the round worklist: top each eligible tenant's backlog up
    /// from the wire, then take this round's quantum from the backlog
    /// front (retries sit ahead of newer traffic there).
    fn drain_round(&mut self) {
        let Self {
            queues,
            work,
            had_work,
            halted,
            staged_pending,
            opts,
            round,
            ..
        } = self;
        if had_work.len() != queues.len() {
            had_work.resize(queues.len(), false);
            halted.resize(queues.len(), false);
            staged_pending.resize(queues.len(), false);
        }
        had_work.fill(false);
        halted.fill(false);
        debug_assert!(work.is_empty(), "worklist not drained last round");
        for (qi, q) in queues.iter_mut().enumerate() {
            if q.completed_at.is_some() || q.health.quarantined {
                continue;
            }
            if *round < q.health.backoff_until {
                continue;
            }
            // Don't read the wire while a failure streak is live with
            // retried batches parked in the backlog: the retries must
            // run first, and pulling fresh traffic now would bury them
            // behind reads this round cannot use yet (it also hides
            // backpressure from the producer).
            let retrying = q.health.consecutive > 0 && !q.backlog.is_empty();
            if !retrying {
                if let Some(rx) = &q.rx {
                    while q.backlog.len() < opts.quantum {
                        match rx.try_recv() {
                            Ok(b) => q.backlog.push_back(b),
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => {
                                // Disconnected means drained AND hung up
                                // (mpsc yields buffered messages first).
                                q.rx = None;
                                break;
                            }
                        }
                    }
                }
            }
            for _ in 0..opts.quantum {
                match q.backlog.pop_front() {
                    Some(b) => {
                        work.push((work.len(), qi, Some(b)));
                        had_work[qi] = true;
                    }
                    None => break,
                }
            }
        }
    }

    /// Coalesce: same-shape batches run back to back. The key includes
    /// the drain sequence, so the in-place unstable sort reproduces the
    /// stable order without allocating.
    fn sort_work(&mut self) {
        let Self { queues, work, .. } = self;
        work.sort_unstable_by(|a, b| {
            queues[a.1]
                .shape
                .cmp(&queues[b.1].shape)
                .then(a.0.cmp(&b.0))
        });
    }

    /// The serial ingest half of a round: one attempt per work item,
    /// consuming successes and leaving halted remainders in place for
    /// the requeue pass. Returns (batches, samples, faults).
    fn commit_serial(&mut self) -> (usize, u64, usize) {
        let Self {
            queues,
            registry,
            injectors,
            work,
            halted,
            opts,
            round,
            ..
        } = self;
        let mut batches = 0usize;
        let mut samples = 0u64;
        let mut faults = 0usize;
        for i in 0..work.len() {
            let qi = work[i].1;
            if queues[qi].health.quarantined {
                queues[qi].health.dropped_batches += 1;
                work[i].2 = None;
                continue;
            }
            if halted[qi] {
                continue; // stays parked for the requeue pass
            }
            let batch = work[i].2.take().expect("unprocessed work item");
            match try_ingest(registry, injectors, &queues[qi].tenant, &batch) {
                Ok(n) => {
                    batches += 1;
                    samples += n;
                    let h = &mut queues[qi].health;
                    h.consecutive = 0;
                    h.backoff_until = 0;
                }
                Err(err) => {
                    faults += 1;
                    halted[qi] = true;
                    if charge_failure(queues, registry, opts, *round, qi, &err) {
                        work[i].2 = Some(batch);
                    }
                }
            }
        }
        (batches, samples, faults)
    }

    /// The pipelined ingest half of a round: dispatch this round's
    /// worklist to the stager, commit the *previous* round's staged
    /// tiles while it runs (the overlap), then receive this round's
    /// staging into the slot, stripping batches whose tenants failed
    /// during the commit so retries keep FIFO order.
    fn pipeline_round(&mut self) -> Result<(usize, u64, usize)> {
        let dispatched = !self.work.is_empty();
        if dispatched {
            let items: Vec<StageItem> = {
                let Self { queues, work, .. } = self;
                work.drain(..)
                    .map(|(_, qi, b)| (qi, queues[qi].plan, b.expect("drained work item")))
                    .collect()
            };
            self.pstats.staged_rounds += 1;
            self.pstats.staged_batches += items.len() as u64;
            if self.stager.is_none() {
                self.stager = Some(Stager::spawn());
            }
            self.stager
                .as_ref()
                .expect("stager spawned above")
                .submit(StageJob { items })?;
        }
        let mut totals = (0usize, 0u64, 0usize);
        if let Some(prev) = self.slot.take() {
            let t0 = Instant::now();
            totals = self.commit_staged_round(prev);
            self.pstats.commit_ns += t0.elapsed().as_nanos() as u64;
        }
        if dispatched {
            let t0 = Instant::now();
            let staged = self.stager.as_ref().expect("stager running").recv()?;
            self.pstats.stage_wait_ns += t0.elapsed().as_nanos() as u64;
            self.pstats.stage_ns += staged.ns;
            self.slot = self.strip_round(staged);
        }
        Ok(totals)
    }

    /// Commit one staged round: walk its groups in order, turning
    /// maximal clean same-tenant runs of seg-contiguous items into one
    /// mega-tile commit each when the session allows it. Failures feed
    /// the same per-tenant circuit breaker as the serial path;
    /// uncommitted batches park on the worklist (in round order) for
    /// the shared requeue pass.
    fn commit_staged_round(&mut self, staged: StagedRound) -> (usize, u64, usize) {
        let Self {
            queues,
            registry,
            injectors,
            work,
            halted,
            opts,
            round,
            pstats,
            ..
        } = self;
        let mut batches = 0usize;
        let mut samples = 0u64;
        let mut faults = 0usize;
        for group in staged.groups {
            let raw_group = group.plan.entry.is_some();
            let buf = group.buf;
            let mut items: Vec<Option<StagedItem>> = group.items.into_iter().map(Some).collect();
            let n = items.len();
            let mut i = 0;
            while i < n {
                let qi = items[i].as_ref().expect("unprocessed staged item").qi;
                if queues[qi].health.quarantined {
                    queues[qi].health.dropped_batches += 1;
                    items[i] = None;
                    i += 1;
                    continue;
                }
                if halted[qi] {
                    i += 1; // parked below
                    continue;
                }
                let has_err = items[i].as_ref().expect("staged item").err.is_some();
                // Maximal fusable run: same tenant, clean, contiguous
                // buffer segments, session fusion-ready, and no fault
                // injector (injector streams draw once per *batch*,
                // exactly like the serial path).
                let mut j = i + 1;
                if !has_err && fusable(registry, injectors, &queues[qi].tenant) {
                    while j < n {
                        let prev_end = items[j - 1].as_ref().expect("staged item").seg.end;
                        let it = items[j].as_ref().expect("staged item");
                        if it.qi != qi
                            || it.err.is_some()
                            || (raw_group && it.seg.start != prev_end)
                        {
                            break;
                        }
                        j += 1;
                    }
                }
                let (res, rows) = {
                    let run: Vec<&StagedItem> = items[i..j]
                        .iter()
                        .map(|o| o.as_ref().expect("staged item"))
                        .collect();
                    let batch_refs: Vec<&Batch> = run.iter().map(|it| &it.batch).collect();
                    let rows: u64 = run.iter().map(|it| it.batch.len() as u64).sum();
                    let raw = if raw_group && !has_err {
                        let mut mark = StagedMark::default();
                        for it in &run {
                            mark.merge(&it.mark);
                        }
                        let first = run.first().expect("non-empty run");
                        let last = run.last().expect("non-empty run");
                        Some((&buf[first.seg.start..last.seg.end], mark))
                    } else {
                        None
                    };
                    let staged_err = run.first().expect("non-empty run").err.as_ref();
                    let res = try_commit(
                        registry,
                        injectors,
                        &queues[qi].tenant,
                        &batch_refs,
                        raw,
                        staged_err,
                    );
                    (res, rows)
                };
                match res {
                    Ok(_) => {
                        batches += j - i;
                        samples += rows;
                        let h = &mut queues[qi].health;
                        h.consecutive = 0;
                        h.backoff_until = 0;
                        if j - i > 1 {
                            pstats.fused_tiles += 1;
                            pstats.fused_batches += (j - i) as u64;
                            pstats.max_fused_rows = pstats.max_fused_rows.max(rows);
                        }
                        for it in &mut items[i..j] {
                            *it = None;
                        }
                    }
                    Err(err) => {
                        faults += 1;
                        halted[qi] = true;
                        if !charge_failure(queues, registry, opts, *round, qi, &err) {
                            // Rejected or quarantining: the failed
                            // batch is consumed; any fused remainder
                            // parks for requeue-or-shed below.
                            items[i] = None;
                        }
                    }
                }
                i = j;
            }
            // Park leftovers (halted remainders) on the worklist in
            // round order for the shared requeue pass.
            for it in items.into_iter().flatten() {
                work.push((work.len(), it.qi, Some(it.batch)));
            }
        }
        (batches, samples, faults)
    }

    /// Drop a freshly staged round's dead weight: quarantined tenants'
    /// items are shed (dropped-batch accounting), and items of tenants
    /// that failed during this round's commit park on the worklist —
    /// *after* the commit's own leftovers, so the requeue pass puts
    /// them behind the retried remainder and per-tenant FIFO holds.
    fn strip_round(&mut self, staged: StagedRound) -> Option<StagedRound> {
        let Self {
            queues,
            work,
            halted,
            ..
        } = self;
        let mut groups = Vec::with_capacity(staged.groups.len());
        for mut g in staged.groups {
            let mut kept = Vec::with_capacity(g.items.len());
            for it in g.items {
                if queues[it.qi].health.quarantined {
                    queues[it.qi].health.dropped_batches += 1;
                } else if halted[it.qi] {
                    work.push((work.len(), it.qi, Some(it.batch)));
                } else {
                    kept.push(it);
                }
            }
            if !kept.is_empty() {
                g.items = kept;
                groups.push(g);
            }
        }
        if groups.is_empty() {
            None
        } else {
            Some(StagedRound {
                groups,
                ns: staged.ns,
            })
        }
    }

    /// Requeue every still-parked work item at the front of its
    /// tenant's backlog, preserving order (reverse iteration +
    /// push_front).
    fn requeue_work(&mut self) {
        let Self { queues, work, .. } = self;
        for (_, qi, b) in work.drain(..).rev() {
            if let Some(b) = b {
                queues[qi].backlog.push_front(b);
            }
        }
    }

    /// Record which queues still have batches in the staged slot —
    /// those streams are not complete even if wire + backlog are empty.
    fn note_staged_pending(&mut self) {
        self.staged_pending.fill(false);
        if let Some(slot) = &self.slot {
            for g in &slot.groups {
                for it in &g.items {
                    self.staged_pending[it.qi] = true;
                }
            }
        }
    }

    /// Settle each queue: quarantined tenants shed everything and drop
    /// their receiver (the producer's next send observes the hang-up);
    /// healthy tenants complete once wire, backlog and staged slot are
    /// all empty. Optionally evicts idle sessions.
    fn settle_round(&mut self) -> Result<()> {
        let elapsed = self.started.elapsed();
        for (qi, q) in self.queues.iter_mut().enumerate() {
            if q.health.quarantined {
                let mut dropped = q.backlog.len() as u64;
                q.backlog.clear();
                if let Some(rx) = q.rx.take() {
                    while rx.try_recv().is_ok() {
                        dropped += 1;
                    }
                }
                q.health.dropped_batches += dropped;
            } else if q.rx.is_none()
                && q.backlog.is_empty()
                && !self.staged_pending[qi]
                && q.completed_at.is_none()
            {
                q.completed_at = Some(elapsed);
            }
        }
        if self.opts.evict_idle {
            let Self {
                queues,
                registry,
                had_work,
                ..
            } = self;
            for (qi, q) in queues.iter().enumerate() {
                if q.completed_at.is_none()
                    && !q.health.quarantined
                    && !had_work[qi]
                    && registry.is_live(&q.tenant)
                {
                    registry.evict(&q.tenant)?;
                }
            }
        }
        Ok(())
    }

    /// Drive rounds until every tenant's stream completes (or is
    /// quarantined). Sleeps briefly on idle rounds so a waiting shard
    /// doesn't spin a core (never while staged work is in flight).
    pub fn run_to_completion(&mut self) -> Result<()> {
        loop {
            let stats = self.poll_round()?;
            if stats.all_done {
                return Ok(());
            }
            if stats.batches == 0 && self.slot.is_none() {
                std::thread::sleep(Duration::from_micros(50));
            }
        }
    }

    /// Final per-tenant summaries. Reads metrics and telemetry straight
    /// from the registry slot — checkpoints carry both, so no evicted
    /// session is rebuilt and a tenant whose restore would fail still
    /// reports (its numbers are the last-good checkpoint's).
    pub fn tenant_outcomes(&self) -> Vec<TenantOutcome> {
        self.queues
            .iter()
            .map(|q| {
                let m = self.registry.metrics_of(&q.tenant);
                TenantOutcome {
                    tenant: q.tenant.clone(),
                    shard: self.id,
                    shape: q.shape.clone(),
                    batches: m.map_or(0, |m| m.batches),
                    samples: m.map_or(0, |m| m.samples_in),
                    p50_ns: m
                        .and_then(|m| m.step_latency.percentile(50.0))
                        .map(|d| d.as_nanos() as f64),
                    p99_ns: m
                        .and_then(|m| m.step_latency.percentile(99.0))
                        .map(|d| d.as_nanos() as f64),
                    restores: self.registry.restores(&q.tenant),
                    completed_at_s: q.completed_at.map(|d| d.as_secs_f64()),
                    telemetry: self.registry.telemetry_of(&q.tenant),
                    health: q.health.clone(),
                }
            })
            .collect()
    }
}

/// One ingest attempt for one tenant, with shard-side fault injection
/// applied before the session is touched. Free function so the round
/// loop can borrow the tenant id out of its queue (no per-batch clone).
fn try_ingest(
    registry: &mut SessionRegistry,
    injectors: &mut HashMap<String, TenantInjector>,
    tenant: &str,
    batch: &Batch,
) -> Result<u64> {
    if let Some(inj) = injectors.get_mut(tenant) {
        if !registry.is_live(tenant) && inj.restore_fault() {
            anyhow::bail!("injected fault: restore failed for tenant '{tenant}'");
        }
        if inj.ingest_fault() {
            anyhow::bail!("injected fault: ingest error for tenant '{tenant}'");
        }
    }
    let session = registry
        .session_mut(tenant)
        .with_context(|| format!("session lookup for tenant '{tenant}'"))?;
    session
        .ingest(batch)
        .with_context(|| format!("ingest for tenant '{tenant}'"))?;
    Ok(batch.len() as u64)
}

/// One *commit* attempt for a staged run: same injector order as
/// [`try_ingest`] (restore fault when the session is evicted, then the
/// ingest fault, both before the session is touched), then either the
/// typed rejection replay (`staged_err`) or the staged commit itself.
fn try_commit(
    registry: &mut SessionRegistry,
    injectors: &mut HashMap<String, TenantInjector>,
    tenant: &str,
    batches: &[&Batch],
    raw: Option<(&[i32], StagedMark)>,
    staged_err: Option<&BatchRejected>,
) -> Result<u64> {
    if let Some(inj) = injectors.get_mut(tenant) {
        if !registry.is_live(tenant) && inj.restore_fault() {
            anyhow::bail!("injected fault: restore failed for tenant '{tenant}'");
        }
        if inj.ingest_fault() {
            anyhow::bail!("injected fault: ingest error for tenant '{tenant}'");
        }
    }
    let session = registry
        .session_mut(tenant)
        .with_context(|| format!("session lookup for tenant '{tenant}'"))?;
    if let Some(err) = staged_err {
        session
            .commit_rejected(err.clone())
            .with_context(|| format!("ingest for tenant '{tenant}'"))?;
        return Ok(0);
    }
    session
        .commit_staged(batches, raw)
        .with_context(|| format!("ingest for tenant '{tenant}'"))?;
    Ok(batches.iter().map(|b| b.len() as u64).sum())
}

/// Whether a tenant's consecutive staged batches may fuse into one
/// mega-tile commit: live session (fusing must never force a restore
/// outside the injector-guarded attempt path), fusion-ready, and no
/// fault injector registered.
fn fusable(
    registry: &mut SessionRegistry,
    injectors: &HashMap<String, TenantInjector>,
    tenant: &str,
) -> bool {
    !injectors.contains_key(tenant)
        && registry.is_live(tenant)
        && registry
            .session_mut(tenant)
            .map(|s| s.fusion_ready())
            .unwrap_or(false)
}

/// Charge one failed attempt to `qi`'s circuit breaker: fault tally,
/// last-error, rejection accounting, and either backoff-for-retry or
/// quarantine (evicting to the last-good checkpoint). Returns whether
/// the failed batch should be requeued (transient, not quarantined).
fn charge_failure(
    queues: &mut [TenantQueue],
    registry: &mut SessionRegistry,
    opts: &ShardOptions,
    round: u64,
    qi: usize,
    err: &anyhow::Error,
) -> bool {
    // A typed rejection means the payload itself is garbage: never
    // retried (garbage stays garbage); anything else is transient.
    let rejected = err.downcast_ref::<BatchRejected>().is_some();
    let quarantine;
    let retry;
    {
        let h = &mut queues[qi].health;
        h.faults += 1;
        h.consecutive += 1;
        h.last_error = Some(format!("{err:#}"));
        if rejected {
            h.rejected_batches += 1;
        }
        if h.consecutive > opts.max_retries {
            h.quarantined = true;
            if !rejected {
                h.dropped_batches += 1;
            }
            quarantine = true;
            retry = false;
        } else {
            let delay = (1u64 << (h.consecutive - 1)).min(opts.backoff_cap_rounds);
            h.backoff_until = round + delay;
            if !rejected {
                h.retries += 1;
            }
            quarantine = false;
            retry = !rejected;
        }
    }
    if quarantine {
        // Freeze the last-good checkpoint for reporting. May fail or
        // be a no-op (already evicted on the restore-fault path) —
        // either way the tenant is out of the scheduler.
        let _ = registry.evict(&queues[qi].tenant);
    }
    retry
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig {
            train_classifier: false,
            rot_warmup: 32,
            telemetry: true,
            ..Default::default()
        }
    }

    fn batch(dim: usize, salt: usize) -> Batch {
        Batch::Full(Mat::from_fn(64, dim, |i, j| {
            ((i * 31 + j * 7 + salt * 13) % 17) as f32 / 17.0 - 0.5
        }))
    }

    #[test]
    fn synthetic_ingest_faults_trip_quarantine_without_aborting_the_shard() {
        let c = cfg();
        let opts = ShardOptions {
            queue_depth: 32,
            quantum: 2,
            max_retries: 2,
            ..Default::default()
        };
        let mut shard = Shard::new(0, opts);
        let bad = shard.add_tenant("t_bad", &c).unwrap();
        let good = shard.add_tenant("t_good", &c).unwrap();
        shard.set_fault_plan(FaultPlan::parse("t_bad:ingest@1").unwrap(), 2018);
        for salt in 0..6 {
            bad.send(batch(c.input_dim, salt)).unwrap();
            good.send(batch(c.input_dim, salt)).unwrap();
        }
        drop(bad);
        drop(good);
        shard.run_to_completion().unwrap();

        let by_tenant: HashMap<String, TenantOutcome> = shard
            .tenant_outcomes()
            .into_iter()
            .map(|o| (o.tenant.clone(), o))
            .collect();
        let bad = &by_tenant["t_bad"];
        assert!(bad.health.quarantined);
        // max_retries failed attempts were retried, the breaker opened
        // on attempt max_retries + 1.
        assert_eq!(bad.health.faults, u64::from(opts.max_retries) + 1);
        assert_eq!(bad.health.retries, u64::from(opts.max_retries));
        // Everything the tenant ever sent was shed (the retried batch
        // plus the rest of the stream), nothing ingested.
        assert_eq!(bad.health.dropped_batches, 6);
        assert_eq!(bad.samples, 0);
        assert!(bad.completed_at_s.is_none(), "quarantine is not completion");
        let good = &by_tenant["t_good"];
        assert!(!good.health.quarantined);
        assert_eq!(good.health.faults, 0);
        assert_eq!(good.samples, 6 * 64);
        assert!(good.completed_at_s.is_some());
    }

    #[test]
    fn backoff_skips_rounds_between_retries() {
        let c = cfg();
        let mut shard = Shard::new(
            0,
            ShardOptions {
                queue_depth: 8,
                quantum: 1,
                max_retries: 3,
                ..Default::default()
            },
        );
        let ing = shard.add_tenant("t0", &c).unwrap();
        shard.set_fault_plan(FaultPlan::parse("t0:ingest@1").unwrap(), 7);
        ing.send(batch(c.input_dim, 0)).unwrap();
        drop(ing);
        // After the failure on round r, backoff_until = r + delay and the
        // tenant is skipped while round < backoff_until, so with delays
        // 1, 2, 4 the attempts land on rounds 1, 2, 4, 8 — the fourth
        // attempt exceeds max_retries = 3 and trips the breaker.
        let mut attempt_rounds = Vec::new();
        for round in 1..=20u64 {
            let stats = shard.poll_round().unwrap();
            if stats.faults > 0 {
                attempt_rounds.push(round);
            }
            if stats.all_done {
                break;
            }
        }
        assert_eq!(attempt_rounds, vec![1, 2, 4, 8]);
        let out = &shard.tenant_outcomes()[0];
        assert!(out.health.quarantined);
        assert_eq!(out.health.faults, 4);
    }

    #[test]
    fn poisoned_batches_are_rejected_not_retried_and_state_is_preserved() {
        let c = cfg();
        let mut shard = Shard::new(
            0,
            ShardOptions {
                queue_depth: 32,
                quantum: 4,
                max_retries: 2,
                ..Default::default()
            },
        );
        let ing = shard.add_tenant("t0", &c).unwrap();
        // Two clean batches first, so the last-good checkpoint has real
        // samples, then a stream of NaN batches.
        ing.send(batch(c.input_dim, 0)).unwrap();
        ing.send(batch(c.input_dim, 1)).unwrap();
        for salt in 2..8 {
            ing.send(super::super::faults::corrupt(
                batch(c.input_dim, salt),
                super::super::faults::FaultKind::Nan,
            ))
            .unwrap();
        }
        drop(ing);
        shard.run_to_completion().unwrap();
        let out = &shard.tenant_outcomes()[0];
        assert!(out.health.quarantined);
        // Rejections are counted as rejections, not retries.
        assert_eq!(out.health.rejected_batches, 3);
        assert_eq!(out.health.retries, 0);
        // The clean samples survive in the frozen checkpoint.
        assert_eq!(out.samples, 2 * 64);
        assert!(!shard.registry().is_live("t0"), "quarantine evicts");
        assert!(out.telemetry.is_some(), "checkpoint still reports telemetry");
    }

    #[test]
    fn restore_faults_on_evicted_tenant_quarantine_but_keep_the_checkpoint() {
        let c = cfg();
        let mut shard = Shard::new(
            0,
            ShardOptions {
                queue_depth: 32,
                quantum: 4,
                evict_idle: true,
                max_retries: 1,
                ..Default::default()
            },
        );
        let ing = shard.add_tenant("t0", &c).unwrap();
        shard.set_fault_plan(FaultPlan::parse("t0:restore@1").unwrap(), 11);
        ing.send(batch(c.input_dim, 0)).unwrap();
        shard.poll_round().unwrap();
        assert_eq!(shard.registry().metrics_of("t0").unwrap().samples_in, 64);
        // Idle round → evicted.
        shard.poll_round().unwrap();
        assert!(!shard.registry().is_live("t0"));
        // Every later batch needs a restore, which is forced to fail.
        ing.send(batch(c.input_dim, 1)).unwrap();
        drop(ing);
        shard.run_to_completion().unwrap();
        let out = &shard.tenant_outcomes()[0];
        assert!(out.health.quarantined);
        let last = out.health.last_error.as_deref().unwrap();
        assert!(last.contains("restore failed"), "got: {last}");
        // The checkpoint (and its 64 pre-fault samples) still reports.
        assert_eq!(out.samples, 64);
    }

    #[test]
    fn retry_rounds_leave_fresh_traffic_on_the_wire() {
        // While a failure streak is live and its retried batches sit in
        // the backlog, the scheduler must not top the backlog up from
        // the wire: fresh traffic pulled early would queue behind
        // retries the round cannot use — and it hides backpressure from
        // the producer, who sees queue capacity that isn't real.
        let c = cfg();
        let mut shard = Shard::new(
            0,
            ShardOptions {
                queue_depth: 2,
                quantum: 4,
                max_retries: 10,
                ..Default::default()
            },
        );
        let ing = shard.add_tenant("t0", &c).unwrap();
        shard.set_fault_plan(FaultPlan::parse("t0:ingest@1").unwrap(), 5);
        ing.send(batch(c.input_dim, 0)).unwrap();
        ing.send(batch(c.input_dim, 1)).unwrap();
        // Round 1 drains the wire, fails the first attempt, requeues
        // both drained batches at the backlog front.
        let stats = shard.poll_round().unwrap();
        assert_eq!(stats.faults, 1);
        assert_eq!(shard.backlog_len("t0"), 2);
        // Refill the wire to capacity while the streak is live.
        ing.send(batch(c.input_dim, 2)).unwrap();
        ing.send(batch(c.input_dim, 3)).unwrap();
        // Round 2 retries (backoff delay 1). The backlog holds fewer
        // batches than the quantum, but the wire must stay untouched:
        // only the parked retries are attempted.
        let stats = shard.poll_round().unwrap();
        assert_eq!(stats.faults, 1);
        assert_eq!(shard.backlog_len("t0"), 2, "retries only — no top-up");
        match ing.tx.try_send(batch(c.input_dim, 4)) {
            Err(std::sync::mpsc::TrySendError::Full(_)) => {}
            other => panic!("wire was drained during a retry round: {other:?}"),
        }
    }

    #[test]
    fn pipelined_shard_matches_serial_and_fuses_mega_tiles() {
        // Same deterministic streams through a serial and a pipelined
        // shard: per-tenant trainer state must be word-for-word
        // identical, and the pipelined run must actually fuse
        // same-tenant runs into mega-tiles (quantum > 1, clean
        // sessions, both numeric domains).
        let mk = |pipeline: bool| {
            let mut shard = Shard::new(
                0,
                ShardOptions {
                    queue_depth: 16,
                    quantum: 4,
                    pipeline,
                    ..Default::default()
                },
            );
            let c_fxp = ExperimentConfig {
                precision: crate::fxp::Precision::parse("q4.12").unwrap(),
                ..cfg()
            };
            let c_f32 = cfg();
            let a = shard.add_tenant("t_fxp", &c_fxp).unwrap();
            let b = shard.add_tenant("t_f32", &c_f32).unwrap();
            for salt in 0..8 {
                a.send(batch(c_fxp.input_dim, salt)).unwrap();
                b.send(batch(c_f32.input_dim, 100 + salt)).unwrap();
            }
            drop(a);
            drop(b);
            shard.run_to_completion().unwrap();
            shard
        };
        let mut serial = mk(false);
        let mut piped = mk(true);
        assert!(
            piped.pipeline_stats().fused_tiles > 0,
            "mega-tiles must fuse"
        );
        assert_eq!(serial.pipeline_stats().staged_batches, 0);
        let dim = cfg().input_dim;
        let probe = Mat::from_fn(32, dim, |i, j| ((i * 13 + j * 5) % 23) as f32 / 23.0 - 0.5);
        for tenant in ["t_fxp", "t_f32"] {
            let samples = {
                let s = serial.registry_mut().session_mut(tenant).unwrap();
                (
                    s.metrics().samples_in,
                    s.metrics().batches,
                    s.trainer().transform_rows(&probe),
                    s.trainer().separation_matrix(),
                )
            };
            let p = piped.registry_mut().session_mut(tenant).unwrap();
            assert_eq!(samples.0, p.metrics().samples_in, "{tenant} samples");
            assert_eq!(samples.1, p.metrics().batches, "{tenant} batches");
            assert_eq!(
                samples.2.as_slice(),
                p.trainer().transform_rows(&probe).as_slice(),
                "{tenant} forward transform diverged under pipelining"
            );
            assert_eq!(
                samples.3.as_slice(),
                p.trainer().separation_matrix().as_slice(),
                "{tenant} separation matrix diverged under pipelining"
            );
        }
    }
}
