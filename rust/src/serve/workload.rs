//! Synthetic multi-tenant workload driver: the engine behind
//! `dimred serve` and the bench `multi_tenant` scenario family.
//!
//! Spins up one producer thread per tenant (arrival pattern: uniform,
//! skewed or bursty), shards tenants round-robin across worker threads,
//! and reports aggregate throughput, per-tenant latency percentiles,
//! restore counts and a fairness spread (slowest / fastest tenant
//! completion — 1.0 is perfectly fair).

use super::faults::FaultPlan;
use super::shard::{PipelineStats, Shard, ShardOptions, TenantHealth, TenantOutcome};
use crate::config::{ExperimentConfig, PipelineMode};
use crate::coordinator::Batch;
use crate::fxp::Precision;
use crate::linalg::Mat;
use crate::telemetry::TelemetrySnapshot;
use anyhow::{bail, ensure, Context, Result};
use std::time::{Duration, Instant};

/// How tenant traffic arrives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalPattern {
    /// Every tenant sends the same batch count, as fast as accepted.
    Uniform,
    /// Tenant 0 sends `ratio`× the base batch count (a heavy tenant
    /// leaning on everyone else's scheduler slots).
    Skewed { ratio: usize },
    /// Batches arrive in bursts of `burst` with pauses between.
    Bursty { burst: usize },
}

impl ArrivalPattern {
    pub fn parse(s: &str) -> Result<Self> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        let num = |default: usize| -> Result<usize> {
            match arg {
                None => Ok(default),
                Some(a) => a
                    .parse::<usize>()
                    .ok()
                    .filter(|&n| n >= 1)
                    .with_context(|| format!("bad arrival parameter '{a}'")),
            }
        };
        match head {
            "uniform" => Ok(Self::Uniform),
            "skewed" => Ok(Self::Skewed { ratio: num(10)? }),
            "bursty" => Ok(Self::Bursty { burst: num(8)? }),
            other => bail!("unknown arrival pattern '{other}' (uniform|skewed[:N]|bursty[:B])"),
        }
    }

    pub fn label(&self) -> String {
        match self {
            Self::Uniform => "uniform".into(),
            Self::Skewed { ratio } => format!("skewed:{ratio}"),
            Self::Bursty { burst } => format!("bursty:{burst}"),
        }
    }
}

/// Knobs for one serve run.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub tenants: usize,
    pub shards: usize,
    /// Rows per batch.
    pub batch: usize,
    /// Base batches per tenant (the skewed pattern multiplies tenant
    /// 0's count).
    pub batches_per_tenant: usize,
    pub queue_depth: usize,
    pub quantum: usize,
    pub arrival: ArrivalPattern,
    /// Stage cascade for every tenant; `None` cycles the mixed preset
    /// (f32 rp-easi / q4.12 rp-easi / q4.12 whiten-only).
    pub stages: Option<String>,
    /// Precision for every tenant; `None` cycles the mixed preset.
    pub precision: Option<String>,
    pub telemetry: bool,
    pub evict_idle: bool,
    /// Run each shard's two-slot stage/commit pipeline with mega-tile
    /// fusion (see [`super::shard`] docs) instead of the serial round
    /// loop. Bit-identical results either way.
    pub pipeline: bool,
    pub seed: u64,
    /// Fault-injection spec (`tenant:kind[@rate],...`), `None` for a
    /// clean run. Parsed by [`FaultPlan::parse`]; injector streams are
    /// seeded from `seed`.
    pub faults: Option<String>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            tenants: 16,
            shards: 4,
            batch: 256,
            batches_per_tenant: 32,
            queue_depth: 8,
            quantum: 4,
            arrival: ArrivalPattern::Uniform,
            stages: None,
            precision: None,
            telemetry: false,
            evict_idle: false,
            pipeline: false,
            seed: 2018,
            faults: None,
        }
    }
}

/// One tenant's final row in the report.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub tenant: String,
    pub shard: usize,
    pub stages: String,
    pub precision: String,
    pub batches: u64,
    pub samples: u64,
    pub p50_ns: Option<f64>,
    pub p99_ns: Option<f64>,
    pub restores: u64,
    pub completed_at_s: Option<f64>,
    pub telemetry: Option<TelemetrySnapshot>,
    /// Fault-containment counters (all zero on a clean run).
    pub health: TenantHealth,
}

/// Outcome of a serve run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub tenants: Vec<TenantReport>,
    pub shards: usize,
    pub arrival: String,
    pub elapsed_s: f64,
    pub total_samples: u64,
    pub aggregate_samples_per_s: f64,
    /// Slowest / fastest tenant completion time (1.0 = perfectly fair).
    /// Quarantined tenants never complete and are excluded.
    pub fairness_spread: Option<f64>,
    /// Canonical fault spec this run was driven with, if any.
    pub faults_spec: Option<String>,
    /// Producers that observed a shard hang-up (their tenant was
    /// quarantined mid-stream) and exited cleanly.
    pub producer_hangups: u64,
    /// Batches the producers poisoned before sending.
    pub injected_batches: u64,
    /// Producer-side stalls injected.
    pub injected_stalls: u64,
    /// Whether the shards ran the pipelined scheduler.
    pub pipeline: bool,
    /// Per-shard pipeline counters, in shard-id order (all-zero stats
    /// when the run was serial).
    pub pipeline_shards: Vec<ShardPipeline>,
}

/// One shard's pipeline counters in the report.
#[derive(Debug, Clone, Copy)]
pub struct ShardPipeline {
    pub shard: usize,
    pub stats: PipelineStats,
}

/// What one producer thread reports back: not a `Result` — a shard
/// hanging up on a quarantined tenant is an observation, not an error
/// that should tear the whole run down.
struct ProducerOutcome {
    hung_up: bool,
    injected_batches: u64,
    injected_stalls: u64,
}

/// The per-tenant experiment config. With no stage/precision override
/// the preset cycles three graph shapes so shards always carry mixed
/// f32/fxp traffic: the interesting scheduling case.
pub fn tenant_config(t: usize, opts: &ServeOptions) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig {
        dataset: format!("synthetic-t{t}"),
        mode: PipelineMode::RpEasi,
        rot_warmup: 64,
        batch: opts.batch,
        queue_depth: opts.queue_depth,
        seed: opts.seed + t as u64,
        train_classifier: false,
        telemetry: opts.telemetry,
        ..Default::default()
    };
    if opts.stages.is_some() || opts.precision.is_some() {
        cfg.stages = opts.stages.clone();
        if let Some(p) = &opts.precision {
            cfg.precision = Precision::parse(p)?;
        }
    } else {
        match t % 3 {
            0 => {} // f32 rp-easi
            1 => cfg.precision = Precision::parse("q4.12")?,
            _ => {
                cfg.stages = Some("whiten:gha".into());
                cfg.precision = Precision::parse("q4.12")?;
            }
        }
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Deterministic synthetic batch: varied across tenants and batch
/// indices, bounded to ±0.5 so fixed-point tenants stay in range.
fn synth_batch(tenant: usize, idx: usize, rows: usize, dim: usize) -> Batch {
    Batch::Full(Mat::from_fn(rows, dim, |i, j| {
        ((i * 31 + j * 7 + tenant * 13 + idx * 101) % 17) as f32 / 17.0 - 0.5
    }))
}

/// Drive a full multi-tenant run: producers → shards → joined report.
pub fn run(opts: &ServeOptions) -> Result<ServeReport> {
    ensure!(opts.tenants >= 1, "need at least one tenant");
    ensure!(opts.shards >= 1, "need at least one shard");
    ensure!(opts.batches_per_tenant >= 1, "need at least one batch per tenant");
    let plan = opts.faults.as_deref().map(FaultPlan::parse).transpose()?;
    let shard_opts = ShardOptions {
        queue_depth: opts.queue_depth,
        quantum: opts.quantum,
        evict_idle: opts.evict_idle,
        pipeline: opts.pipeline,
        ..Default::default()
    };
    let started = Instant::now();

    // Tenants round-robin across shards; channels are created here so
    // producer threads get the senders while receivers move into the
    // shard workers (sessions are built inside the worker thread — they
    // are not `Send`).
    let mut per_shard: Vec<Vec<(String, ExperimentConfig, std::sync::mpsc::Receiver<Batch>)>> =
        (0..opts.shards).map(|_| Vec::new()).collect();
    let mut producers = Vec::with_capacity(opts.tenants);
    for t in 0..opts.tenants {
        let cfg = tenant_config(t, opts)?;
        let (tx, rx) = std::sync::mpsc::sync_channel::<Batch>(opts.queue_depth);
        per_shard[t % opts.shards].push((format!("t{t}"), cfg.clone(), rx));
        let n_batches = match opts.arrival {
            ArrivalPattern::Skewed { ratio } if t == 0 => opts.batches_per_tenant * ratio,
            _ => opts.batches_per_tenant,
        };
        let (rows, dim, arrival) = (opts.batch, cfg.input_dim, opts.arrival);
        let mut injector = plan
            .as_ref()
            .and_then(|p| p.injector_for(&format!("t{t}"), opts.seed));
        let handle = std::thread::Builder::new()
            .name(format!("serve-tenant-{t}"))
            .spawn(move || -> ProducerOutcome {
                let mut out = ProducerOutcome {
                    hung_up: false,
                    injected_batches: 0,
                    injected_stalls: 0,
                };
                for i in 0..n_batches {
                    if let ArrivalPattern::Bursty { burst } = arrival {
                        if i > 0 && i % burst == 0 {
                            std::thread::sleep(Duration::from_micros(200));
                        }
                    }
                    let mut b = synth_batch(t, i, rows, dim);
                    if let Some(inj) = injector.as_mut() {
                        if inj.stall_fault() {
                            out.injected_stalls += 1;
                            std::thread::sleep(Duration::from_micros(500));
                        }
                        let (poisoned, kind) = inj.poison(b);
                        b = poisoned;
                        if kind.is_some() {
                            out.injected_batches += 1;
                        }
                    }
                    if tx.send(b).is_err() {
                        // The shard quarantined this tenant and dropped
                        // its queue: record the hang-up, stop producing.
                        out.hung_up = true;
                        break;
                    }
                }
                out
            })
            .context("spawning tenant producer")?;
        producers.push(handle);
    }

    let mut workers = Vec::with_capacity(opts.shards);
    for (sid, tenants) in per_shard.into_iter().enumerate() {
        let shard_plan = plan.clone();
        let seed = opts.seed;
        let handle = std::thread::Builder::new()
            .name(format!("serve-shard-{sid}"))
            .spawn(move || -> Result<(Vec<TenantOutcome>, PipelineStats)> {
                let mut shard = Shard::new(sid, shard_opts);
                if let Some(p) = shard_plan {
                    shard.set_fault_plan(p, seed);
                }
                for (name, cfg, rx) in tenants {
                    shard.attach(&name, &cfg, rx)?;
                }
                shard.run_to_completion()?;
                Ok((shard.tenant_outcomes(), shard.pipeline_stats()))
            })
            .context("spawning shard worker")?;
        workers.push(handle);
    }

    let mut producer_hangups = 0u64;
    let mut injected_batches = 0u64;
    let mut injected_stalls = 0u64;
    for p in producers {
        match p.join() {
            Ok(o) => {
                producer_hangups += u64::from(o.hung_up);
                injected_batches += o.injected_batches;
                injected_stalls += o.injected_stalls;
            }
            Err(panic) => std::panic::resume_unwind(panic),
        }
    }
    let mut outcomes: Vec<TenantOutcome> = Vec::with_capacity(opts.tenants);
    let mut pipeline_shards = Vec::with_capacity(opts.shards);
    for (sid, w) in workers.into_iter().enumerate() {
        match w.join() {
            Ok(r) => {
                let (tenant_outcomes, stats) = r?;
                outcomes.extend(tenant_outcomes);
                pipeline_shards.push(ShardPipeline { shard: sid, stats });
            }
            Err(panic) => std::panic::resume_unwind(panic),
        }
    }
    let elapsed_s = started.elapsed().as_secs_f64().max(1e-9);

    // "t2" before "t10": numeric order via (len, lexicographic).
    outcomes.sort_by_key(|o| (o.tenant.len(), o.tenant.clone()));
    let total_samples: u64 = outcomes.iter().map(|o| o.samples).sum();
    let completions: Vec<f64> = outcomes.iter().filter_map(|o| o.completed_at_s).collect();
    let fairness_spread = match (
        completions.iter().cloned().fold(f64::INFINITY, f64::min),
        completions.iter().cloned().fold(0.0f64, f64::max),
    ) {
        (min, max) if min.is_finite() && min > 0.0 => Some(max / min),
        _ => None,
    };
    let tenants = outcomes
        .into_iter()
        .map(|o| {
            let (stages, precision) = match o.shape.rsplit_once('@') {
                Some((s, p)) => (s.to_string(), p.to_string()),
                None => (o.shape.clone(), "f32".to_string()),
            };
            TenantReport {
                tenant: o.tenant,
                shard: o.shard,
                stages,
                precision,
                batches: o.batches,
                samples: o.samples,
                p50_ns: o.p50_ns,
                p99_ns: o.p99_ns,
                restores: o.restores,
                completed_at_s: o.completed_at_s,
                telemetry: o.telemetry,
                health: o.health,
            }
        })
        .collect();
    Ok(ServeReport {
        tenants,
        shards: opts.shards,
        arrival: opts.arrival.label(),
        elapsed_s,
        total_samples,
        aggregate_samples_per_s: total_samples as f64 / elapsed_s,
        fairness_spread,
        faults_spec: plan.as_ref().map(FaultPlan::label),
        producer_hangups,
        injected_batches,
        injected_stalls,
        pipeline: opts.pipeline,
        pipeline_shards,
    })
}

/// Bit-identity preflight for the pipelined scheduler: run the same
/// deterministic tenant streams through a serial and a pipelined shard
/// (single-threaded, no faults) and compare every tenant's forward
/// transform and separation matrix word for word. The bench gates its
/// `pipelined_over_serial` speedup claim on this returning `true` —
/// a speedup from a scheduler that changes results is not a speedup.
///
/// The check is deliberately small (tenant/batch counts are capped):
/// it exercises both numeric domains and the fusion path, not the full
/// workload size.
pub fn pipeline_identity_check(opts: &ServeOptions) -> Result<bool> {
    let tenants = opts.tenants.clamp(2, 6);
    let batches = opts.batches_per_tenant.clamp(2, 6);
    let rows = opts.batch.clamp(8, 64);
    let build = |pipeline: bool| -> Result<Shard> {
        let mut shard = Shard::new(
            0,
            ShardOptions {
                // Deep enough to buffer each tenant's whole stream, so
                // the single-threaded driver never blocks on the wire.
                queue_depth: batches,
                quantum: opts.quantum.max(1),
                pipeline,
                ..Default::default()
            },
        );
        for t in 0..tenants {
            let cfg = tenant_config(t, opts)?;
            let ing = shard.add_tenant(&format!("t{t}"), &cfg)?;
            for i in 0..batches {
                ing.send(synth_batch(t, i, rows, cfg.input_dim))?;
            }
        }
        shard.run_to_completion()?;
        Ok(shard)
    };
    let mut serial = build(false)?;
    let mut piped = build(true)?;
    for t in 0..tenants {
        let name = format!("t{t}");
        let dim = tenant_config(t, opts)?.input_dim;
        let probe = Mat::from_fn(16, dim, |i, j| {
            ((i * 13 + j * 5 + t) % 23) as f32 / 23.0 - 0.5
        });
        let (fwd, sep) = {
            let s = serial
                .registry_mut()
                .session_mut(&name)
                .context("serial preflight session")?;
            (
                s.trainer().transform_rows(&probe),
                s.trainer().separation_matrix(),
            )
        };
        let p = piped
            .registry_mut()
            .session_mut(&name)
            .context("pipelined preflight session")?;
        if fwd.as_slice() != p.trainer().transform_rows(&probe).as_slice()
            || sep.as_slice() != p.trainer().separation_matrix().as_slice()
        {
            return Ok(false);
        }
    }
    Ok(true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_patterns_parse() {
        assert_eq!(ArrivalPattern::parse("uniform").unwrap(), ArrivalPattern::Uniform);
        assert_eq!(
            ArrivalPattern::parse("skewed").unwrap(),
            ArrivalPattern::Skewed { ratio: 10 }
        );
        assert_eq!(
            ArrivalPattern::parse("skewed:3").unwrap(),
            ArrivalPattern::Skewed { ratio: 3 }
        );
        assert_eq!(
            ArrivalPattern::parse("bursty:4").unwrap(),
            ArrivalPattern::Bursty { burst: 4 }
        );
        assert!(ArrivalPattern::parse("poisson").is_err());
        assert!(ArrivalPattern::parse("skewed:0").is_err());
        assert_eq!(ArrivalPattern::parse("skewed:3").unwrap().label(), "skewed:3");
    }

    #[test]
    fn preset_cycles_mixed_graph_shapes() {
        let opts = ServeOptions::default();
        let c0 = tenant_config(0, &opts).unwrap();
        let c1 = tenant_config(1, &opts).unwrap();
        let c2 = tenant_config(2, &opts).unwrap();
        assert!(!c0.precision.is_fixed());
        assert!(c1.precision.is_fixed());
        assert_eq!(c2.stages.as_deref(), Some("whiten:gha"));
        // Distinct seeds decorrelate tenant initialisation.
        assert_ne!(c0.seed, c1.seed);
        // Overrides pin every tenant to one shape.
        let opts = ServeOptions {
            precision: Some("q8.16".into()),
            ..ServeOptions::default()
        };
        assert_eq!(tenant_config(2, &opts).unwrap().precision.label(), "q8.16");
        assert!(tenant_config(2, &opts).unwrap().stages.is_none());
    }

    #[test]
    fn small_uniform_run_completes() {
        let opts = ServeOptions {
            tenants: 3,
            shards: 2,
            batch: 16,
            batches_per_tenant: 4,
            ..ServeOptions::default()
        };
        let r = run(&opts).unwrap();
        assert_eq!(r.tenants.len(), 3);
        assert_eq!(r.total_samples, 3 * 4 * 16);
        for t in &r.tenants {
            assert_eq!(t.batches, 4);
            assert_eq!(t.samples, 64);
            assert!(t.p50_ns.is_some());
            assert!(t.completed_at_s.is_some());
        }
        assert!(r.aggregate_samples_per_s > 0.0);
        let spread = r.fairness_spread.unwrap();
        assert!(spread >= 1.0, "spread {spread}");
        // Tenants land on both shards (round-robin: t0,t2 → shard 0,
        // t1 → shard 1).
        assert_eq!(r.tenants[0].shard, 0);
        assert_eq!(r.tenants[1].shard, 1);
        assert_eq!(r.tenants[2].shard, 0);
        // Serial run: stats present per shard, but all zero.
        assert!(!r.pipeline);
        assert_eq!(r.pipeline_shards.len(), 2);
        assert_eq!(r.pipeline_shards[1].shard, 1);
        assert_eq!(r.pipeline_shards[0].stats.staged_batches, 0);
    }

    #[test]
    fn pipelined_run_matches_serial_counts_and_reports_stats() {
        let base = ServeOptions {
            tenants: 4,
            shards: 2,
            batch: 16,
            batches_per_tenant: 6,
            ..ServeOptions::default()
        };
        let serial = run(&base).unwrap();
        let piped = run(&ServeOptions {
            pipeline: true,
            ..base.clone()
        })
        .unwrap();
        assert!(piped.pipeline);
        assert_eq!(serial.total_samples, piped.total_samples);
        for (s, p) in serial.tenants.iter().zip(&piped.tenants) {
            assert_eq!(s.tenant, p.tenant);
            assert_eq!(s.batches, p.batches, "{} batches", s.tenant);
            assert_eq!(s.samples, p.samples, "{} samples", s.tenant);
        }
        let staged: u64 = piped
            .pipeline_shards
            .iter()
            .map(|s| s.stats.staged_batches)
            .sum();
        assert_eq!(staged, 4 * 6, "every batch goes through the stager");
    }

    #[test]
    fn pipeline_identity_preflight_passes_on_the_mixed_preset() {
        let opts = ServeOptions {
            tenants: 3,
            batch: 32,
            batches_per_tenant: 4,
            ..ServeOptions::default()
        };
        assert!(pipeline_identity_check(&opts).unwrap());
    }
}
