//! One tenant's training session, extracted from the old monolithic
//! `TrainingService::run` loop.
//!
//! A [`Session`] owns exactly the per-stream state the serving layer
//! multiplexes: one trainer, the pending reconfiguration schedule, the
//! stop rule and the run [`Metrics`]. Instead of a blocking
//! consume-the-channel loop it exposes a non-blocking step API —
//! [`Session::ingest`] consumes one [`Batch`] (firing due
//! reconfigurations, stepping the trainer, recording latency and the
//! convergence trace, evaluating the stop rule) and [`Session::poll`]
//! reads progress without touching the datapath. `TrainingService` is
//! now a thin single-session façade over this type; the multi-tenant
//! registry in [`crate::serve`] owns many of them.
//!
//! Two satellite fixes live here:
//!
//! * The pending-reconfig queue is a `VecDeque` popped from the front,
//!   ordered by `(after_samples, insertion index)` — two commands
//!   scheduled for the same sample count fire in the order they were
//!   scheduled, not in sort-implementation order.
//! * Periodic `--telemetry` JSONL progress events go through a
//!   [`TelemetrySink`]: stdout only when no output file is configured,
//!   otherwise a JSONL file next to the snapshot — report output stays
//!   clean.
//!
//! Sessions checkpoint: [`Session::checkpoint`] captures the stage
//! graph's state (PR 5's `save_state`, bit-exact for fixed point), the
//! run metrics and the remaining schedule; [`Session::restore`]
//! rebuilds the trainer from the config and resumes — a restored
//! fixed-point session continues bit-identically to an uninterrupted
//! one (proven in `tests/serve.rs`).

use super::batcher::{Batch, BatchRejected};
use super::trainer::Trainer;
use super::{ReconfigCommand, StopRule};
use crate::config::{ExperimentConfig, PipelineMode};
use crate::fxp::FxpSpec;
use crate::runtime::Runtime;
use crate::stage::{Domain, StageState, StagedInput};
use crate::telemetry::Metrics;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::io::Write;
use std::time::{Duration, Instant};

/// Where periodic JSONL progress events go. Chosen from the config:
/// disabled without `--telemetry`; a JSONL file when an events path is
/// configured (`--telemetry-out FILE` derives one next to the
/// snapshot); stdout otherwise (the historical behaviour for a bare
/// `--telemetry`).
pub enum TelemetrySink {
    Disabled,
    Stdout,
    File(std::io::BufWriter<std::fs::File>),
}

impl TelemetrySink {
    pub fn for_config(cfg: &ExperimentConfig) -> Result<Self> {
        if !cfg.telemetry {
            return Ok(Self::Disabled);
        }
        match &cfg.telemetry_events {
            Some(path) => {
                let f = std::fs::File::create(path)
                    .with_context(|| format!("creating telemetry events file {}", path.display()))?;
                Ok(Self::File(std::io::BufWriter::new(f)))
            }
            None => Ok(Self::Stdout),
        }
    }

    /// Emit one JSONL line. Flushed per event — events are rare (every
    /// 32 batches) and a tail-loss on crash would defeat their purpose.
    pub fn emit(&mut self, line: &str) -> Result<()> {
        match self {
            Self::Disabled => Ok(()),
            Self::Stdout => {
                println!("{line}");
                Ok(())
            }
            Self::File(w) => {
                writeln!(w, "{line}").context("writing telemetry event")?;
                w.flush().context("flushing telemetry event")
            }
        }
    }
}

/// A scheduled reconfiguration with its insertion index: the queue is
/// ordered by `(after_samples, seq)` so equal-threshold commands fire
/// in the order they were scheduled.
#[derive(Debug, Clone)]
struct Scheduled {
    seq: u64,
    cmd: ReconfigCommand,
}

/// What one [`Session::ingest`] call did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IngestOutcome {
    /// Batch consumed; the session wants more.
    Active,
    /// The stop rule fired (or had already fired): stream can end.
    Stopped,
}

impl IngestOutcome {
    pub fn is_stopped(&self) -> bool {
        matches!(self, Self::Stopped)
    }
}

/// The `Send + Copy` recipe for staging a batch *off* the session
/// thread: everything [`Session::ingest`]'s pre-trainer phase needs
/// (validation shape, entry arithmetic) without touching the session.
/// Static over a session's lifetime — reconfiguration toggles stages
/// but never changes the entry domain.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StagePlan {
    pub input_dim: usize,
    pub validate: bool,
    /// Entry quantizer `(spec, prescale)` for fixed-point graphs;
    /// `None` for f32 graphs (staging is validation only).
    pub entry: Option<(FxpSpec, f32)>,
}

/// Timing and overflow deltas captured around one off-thread staging
/// pass, replayed into the session's ingress telemetry at commit.
#[derive(Debug, Clone, Copy, Default)]
pub struct StagedMark {
    pub ns: u64,
    pub sat: u64,
    pub wrap: u64,
}

impl StagedMark {
    /// Fold another staged batch's deltas in (fused commits attribute
    /// the whole run's staging to one ingress record).
    pub fn merge(&mut self, other: &StagedMark) {
        self.ns += other.ns;
        self.sat += other.sat;
        self.wrap += other.wrap;
    }
}

/// Validate and (for fixed-point plans) entry-quantize one batch,
/// appending the raw words to `out`. Pure and session-free, so it runs
/// on a stager thread while the session commits earlier work. The
/// quantization is per-sample deterministic — committing the staged
/// words is bit-identical to quantizing inline.
pub fn stage_batch(
    plan: &StagePlan,
    batch: &Batch,
    out: &mut Vec<i32>,
) -> std::result::Result<StagedMark, BatchRejected> {
    let t0 = Instant::now();
    let (sat0, wrap0) = crate::telemetry::events::snapshot();
    if plan.validate {
        batch.validate(plan.input_dim)?;
    }
    if let Some((entry, prescale)) = plan.entry {
        let xs = batch.rows().as_slice();
        out.reserve(xs.len());
        for &v in xs {
            out.push(entry.quantize(v * prescale));
        }
    }
    let (sat, wrap) = crate::telemetry::events::snapshot();
    Ok(StagedMark {
        ns: t0.elapsed().as_nanos() as u64,
        sat: sat - sat0,
        wrap: wrap - wrap0,
    })
}

/// Non-blocking progress read.
#[derive(Debug, Clone, Copy)]
pub struct SessionStatus {
    pub samples_in: u64,
    pub batches: u64,
    pub update_magnitude: f64,
    pub stopped: bool,
}

/// Everything needed to resume a session after eviction: the stage
/// graph's saved state (raw words, accumulators, counters, STE shadows
/// — bit-exact for fixed point), the active mode, the run metrics and
/// the remaining reconfiguration schedule. The trainer itself is
/// rebuilt from the config on restore (RP matrices and initial shapes
/// are seed-deterministic), then overwritten with the saved state.
#[derive(Debug, Clone)]
pub struct SessionCheckpoint {
    cfg: ExperimentConfig,
    mode: PipelineMode,
    stages: Vec<StageState>,
    metrics: Metrics,
    /// Telemetry snapshot at checkpoint time, so reporting on an
    /// evicted tenant never has to rebuild a trainer just to read its
    /// counters (and a failed restore cannot take the report down).
    telemetry: Option<crate::telemetry::TelemetrySnapshot>,
    pending: VecDeque<Scheduled>,
    next_seq: u64,
    stop: StopRule,
    stopped: bool,
}

impl SessionCheckpoint {
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn mode(&self) -> PipelineMode {
        self.mode
    }

    /// The datapath telemetry as of checkpoint time.
    pub fn telemetry(&self) -> Option<&crate::telemetry::TelemetrySnapshot> {
        self.telemetry.as_ref()
    }
}

/// One stream's training state with a non-blocking step API.
pub struct Session<'rt> {
    cfg: ExperimentConfig,
    trainer: Trainer<'rt>,
    pending: VecDeque<Scheduled>,
    next_seq: u64,
    stop: StopRule,
    metrics: Metrics,
    events: TelemetrySink,
    stopped: bool,
}

impl<'rt> Session<'rt> {
    pub fn new(cfg: &ExperimentConfig, runtime: Option<&'rt Runtime>) -> Result<Self> {
        let trainer = Trainer::from_config(cfg, runtime)?;
        let mut metrics = Metrics::new();
        metrics.queue_depth = cfg.queue_depth;
        let events = TelemetrySink::for_config(cfg)?;
        Ok(Self {
            cfg: cfg.clone(),
            trainer,
            pending: VecDeque::new(),
            next_seq: 0,
            stop: StopRule::default(),
            metrics,
            events,
            stopped: false,
        })
    }

    /// Replace the progress-event sink (the serving layer disables
    /// per-session JSONL — interleaved events from many tenants on one
    /// stdout would be noise — and reports through its own surface).
    pub fn set_event_sink(&mut self, sink: TelemetrySink) {
        self.events = sink;
    }

    /// Schedule a mid-stream reconfiguration. Stable: commands with
    /// equal `after_samples` fire in scheduling order.
    pub fn schedule_reconfig(&mut self, cmd: ReconfigCommand) {
        self.pending.push_back(Scheduled {
            seq: self.next_seq,
            cmd,
        });
        self.next_seq += 1;
        self.pending
            .make_contiguous()
            .sort_by_key(|s| (s.cmd.after_samples, s.seq));
    }

    pub fn stop_when(&mut self, rule: StopRule) {
        self.stop = rule;
    }

    /// Consume one batch: fire due reconfigurations, step the trainer,
    /// record metrics, emit a periodic progress event, evaluate the
    /// stop rule. Never blocks. On an already-stopped session this is a
    /// no-op returning [`IngestOutcome::Stopped`].
    pub fn ingest(&mut self, batch: &Batch) -> Result<IngestOutcome> {
        if self.stopped {
            return Ok(IngestOutcome::Stopped);
        }
        // Ingest-boundary validation (default on; `--no-validate-ingest`
        // disables): a rejected batch leaves every piece of session
        // state — trainer words, schedule, counters — untouched, except
        // for the rejection tally itself. The typed `BatchRejected`
        // error lets the serving layer's circuit breaker distinguish
        // bad input (drop the batch) from a failing tenant (retry it).
        if self.cfg.validate_ingest {
            if let Err(e) = batch.validate(self.cfg.input_dim) {
                self.metrics.rejected_batches += 1;
                return Err(anyhow::Error::new(e));
            }
        }
        self.fire_due_reconfigs()?;
        let t0 = Instant::now();
        self.trainer.step(batch)?;
        self.absorb_step(batch, t0.elapsed())
    }

    /// Reconfiguration controller: pop every command whose threshold
    /// has been reached, in (after_samples, insertion) order.
    fn fire_due_reconfigs(&mut self) -> Result<()> {
        while let Some(next) = self.pending.front() {
            if self.metrics.samples_in < next.cmd.after_samples {
                break;
            }
            let cmd = self.pending.pop_front().expect("front exists").cmd;
            self.trainer
                .reconfigure(cmd.mode)
                .context("applying scheduled reconfiguration")?;
            self.metrics
                .reconfigurations
                .push((self.metrics.samples_in, cmd.mode.label().to_string()));
        }
        Ok(())
    }

    /// The post-step bookkeeping shared by [`Session::ingest`] and
    /// [`Session::commit_staged`]: latency, sample/batch counters, the
    /// convergence trace, periodic telemetry events and the stop rule.
    fn absorb_step(&mut self, batch: &Batch, dur: Duration) -> Result<IngestOutcome> {
        self.metrics.step_latency.record(dur);
        self.metrics.samples_in += batch.len() as u64;
        self.metrics.batches += 1;
        if matches!(batch, Batch::Tail(_)) {
            self.metrics.tail_samples += batch.len() as u64;
        }
        if self.metrics.batches % 8 == 0 {
            self.metrics
                .convergence_trace
                .push((self.metrics.samples_in, self.trainer.update_magnitude()));
        }
        // Periodic JSONL telemetry events: one compact line every 32
        // batches, cheap enough to leave on for whole runs.
        if self.cfg.telemetry && self.metrics.batches % 32 == 0 {
            let ev = crate::telemetry::snapshot::progress_event(
                &self.metrics,
                self.trainer.update_magnitude(),
            );
            self.events.emit(&ev.to_string())?;
        }
        if self.stop.threshold > 0.0
            && self.metrics.samples_in >= self.stop.min_samples
            && self.trainer.update_magnitude() < self.stop.threshold
        {
            self.stopped = true;
            return Ok(IngestOutcome::Stopped);
        }
        Ok(IngestOutcome::Active)
    }

    /// The staging recipe matching this session (see [`StagePlan`]).
    pub fn stage_plan(&self) -> StagePlan {
        let entry = self.trainer.stage_graph().and_then(|g| match g.domain() {
            Domain::Fxp { entry, prescale } => Some((entry, prescale)),
            Domain::F32 => None,
        });
        StagePlan {
            input_dim: self.cfg.input_dim,
            validate: self.cfg.validate_ingest,
            entry,
        }
    }

    /// Whether fusing *multiple* batches into one trainer call is
    /// currently indistinguishable from committing them one at a time:
    /// no pending reconfiguration may fire at an intra-run batch
    /// boundary, no stop rule can trip mid-run, and the trainer accepts
    /// staged tiles (native backend, batch stages fitted).
    pub fn fusion_ready(&self) -> bool {
        !self.stopped
            && self.pending.is_empty()
            && self.stop.threshold == 0.0
            && self.trainer.staged_ready()
    }

    /// Charge a staging-time rejection to this session exactly as
    /// [`Session::ingest`] would have: the rejection tally moves,
    /// nothing else does (and an already-stopped session stays a
    /// no-op, as in `ingest`).
    pub fn commit_rejected(&mut self, err: BatchRejected) -> Result<IngestOutcome> {
        if self.stopped {
            return Ok(IngestOutcome::Stopped);
        }
        self.metrics.rejected_batches += 1;
        Err(anyhow::Error::new(err))
    }

    /// Commit a staged run of `k ≥ 1` already-validated batches from
    /// one stream, in FIFO order. For fixed-point sessions `raw`
    /// carries the fused entry-quantized tile plus the staging
    /// telemetry deltas; f32 sessions commit from the batches
    /// themselves. With `k = 1` this is bit- and metrics-identical to
    /// [`Session::ingest`] (validation already ran at staging); `k > 1`
    /// fuses the run into one mega-tile trainer call — callers gate
    /// that on [`Session::fusion_ready`]. Per-batch metrics are
    /// attributed through the row map (each batch charged `dur / k`).
    pub fn commit_staged(
        &mut self,
        batches: &[&Batch],
        raw: Option<(&[i32], StagedMark)>,
    ) -> Result<IngestOutcome> {
        assert!(!batches.is_empty(), "staged commit needs at least one batch");
        debug_assert!(batches.len() == 1 || self.fusion_ready());
        if self.stopped {
            return Ok(IngestOutcome::Stopped);
        }
        self.fire_due_reconfigs()?;
        let rows: usize = batches.iter().map(|b| b.len()).sum();
        let t0 = Instant::now();
        match raw {
            Some((words, mark)) => {
                self.trainer.step_staged(
                    StagedInput::Raw {
                        words,
                        ns: mark.ns,
                        sat: mark.sat,
                        wrap: mark.wrap,
                    },
                    rows,
                )?;
            }
            None if batches.len() == 1 => {
                // Single f32 batch: the exact serial trainer path (it
                // also covers the batch-stage streaming bootstrap).
                self.trainer.step(batches[0])?;
            }
            None => {
                let segs: Vec<&[f32]> = batches.iter().map(|b| b.rows().as_slice()).collect();
                self.trainer
                    .step_staged(StagedInput::F32 { segments: &segs }, rows)?;
            }
        }
        let per = t0.elapsed() / batches.len() as u32;
        let mut out = IngestOutcome::Active;
        for b in batches {
            out = self.absorb_step(b, per)?;
            if out.is_stopped() {
                break;
            }
        }
        Ok(out)
    }

    /// Progress without touching the datapath.
    pub fn poll(&self) -> SessionStatus {
        SessionStatus {
            samples_in: self.metrics.samples_in,
            batches: self.metrics.batches,
            update_magnitude: self.trainer.update_magnitude(),
            stopped: self.stopped,
        }
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    pub fn metrics_mut(&mut self) -> &mut Metrics {
        &mut self.metrics
    }

    pub fn trainer(&self) -> &Trainer<'rt> {
        &self.trainer
    }

    pub fn stopped(&self) -> bool {
        self.stopped
    }

    /// Tear down into the trainer and metrics (for the classifier stage
    /// and report assembly).
    pub fn into_parts(self) -> (Trainer<'rt>, Metrics) {
        (self.trainer, self.metrics)
    }

    /// Capture everything needed to resume later (native backend only:
    /// PJRT state lives inside compiled executables). Fixed-point graph
    /// state is saved as raw words — restoring continues bit-exactly.
    pub fn checkpoint(&self) -> Result<SessionCheckpoint> {
        let graph = self
            .trainer
            .stage_graph()
            .context("only native-backend sessions checkpoint (PJRT state is opaque)")?;
        Ok(SessionCheckpoint {
            cfg: self.cfg.clone(),
            mode: self.trainer.mode(),
            stages: graph.save_state(),
            metrics: self.metrics.clone(),
            telemetry: self.trainer.telemetry_snapshot(),
            pending: self.pending.clone(),
            next_seq: self.next_seq,
            stop: self.stop,
            stopped: self.stopped,
        })
    }

    /// Rebuild a session from a checkpoint. The trainer is
    /// reconstructed from the config (seed-deterministic RP and
    /// shapes), switched to the checkpointed mode if a reconfiguration
    /// had fired, then overwritten with the saved stage state.
    pub fn restore(ck: SessionCheckpoint, runtime: Option<&'rt Runtime>) -> Result<Self> {
        let mut s = Session::new(&ck.cfg, runtime)?;
        if s.trainer.mode() != ck.mode {
            s.trainer
                .reconfigure(ck.mode)
                .context("restoring checkpointed datapath mode")?;
        }
        s.trainer
            .stage_graph_mut()
            .context("only native-backend sessions restore")?
            .restore_state(&ck.stages)
            .context("restoring stage-graph state")?;
        s.metrics = ck.metrics;
        s.pending = ck.pending;
        s.next_seq = ck.next_seq;
        s.stop = ck.stop;
        s.stopped = ck.stopped;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn batch(rows: usize, dim: usize, salt: usize) -> Batch {
        Batch::Full(Mat::from_fn(rows, dim, |i, j| {
            ((i * 31 + j * 7 + salt * 13) % 17) as f32 / 17.0 - 0.5
        }))
    }

    #[test]
    fn equal_after_samples_reconfigs_fire_in_insertion_order() {
        // The latent ordering bug: two commands with the same
        // `after_samples` used to fire in sort-implementation order.
        // The queue is now keyed by (after_samples, insertion index).
        let cfg = ExperimentConfig {
            mode: crate::config::PipelineMode::Easi,
            train_classifier: false,
            rot_warmup: 0,
            ..Default::default()
        };
        let mut s = Session::new(&cfg, None).unwrap();
        s.schedule_reconfig(ReconfigCommand {
            after_samples: 150,
            mode: PipelineMode::PcaWhiten,
        });
        s.schedule_reconfig(ReconfigCommand {
            after_samples: 150,
            mode: PipelineMode::Easi,
        });
        // An earlier threshold scheduled later still sorts first.
        s.schedule_reconfig(ReconfigCommand {
            after_samples: 50,
            mode: PipelineMode::Easi,
        });
        for salt in 0..3 {
            s.ingest(&batch(100, cfg.input_dim, salt)).unwrap();
        }
        let fired: Vec<&str> = s
            .metrics()
            .reconfigurations
            .iter()
            .map(|(_, label)| label.as_str())
            .collect();
        assert_eq!(fired, ["easi", "pca-whiten", "easi"]);
        // Both equal-threshold commands fired at the same sample count,
        // in scheduling order.
        assert_eq!(
            s.metrics().reconfigurations[1].0,
            s.metrics().reconfigurations[2].0
        );
    }

    #[test]
    fn ingest_is_noop_after_stop() {
        let cfg = ExperimentConfig {
            train_classifier: false,
            rot_warmup: 0,
            ..Default::default()
        };
        let mut s = Session::new(&cfg, None).unwrap();
        s.stop_when(StopRule {
            threshold: 1e9, // fires immediately
            min_samples: 0,
        });
        let b = batch(64, cfg.input_dim, 0);
        assert!(s.ingest(&b).unwrap().is_stopped());
        let frozen = s.poll();
        assert!(frozen.stopped);
        assert!(s.ingest(&b).unwrap().is_stopped());
        assert_eq!(s.poll().samples_in, frozen.samples_in);
    }

    #[test]
    fn telemetry_events_route_to_configured_file() {
        let path = std::env::temp_dir().join(format!(
            "dimred_events_{}_{:?}.jsonl",
            std::process::id(),
            std::thread::current().id()
        ));
        let cfg = ExperimentConfig {
            train_classifier: false,
            rot_warmup: 0,
            telemetry: true,
            telemetry_events: Some(path.clone()),
            ..Default::default()
        };
        let mut s = Session::new(&cfg, None).unwrap();
        // 64 batches cross the every-32-batches event cadence twice.
        for salt in 0..64 {
            s.ingest(&batch(8, cfg.input_dim, salt)).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "one event per 32 batches: {text}");
        for line in lines {
            let ev = crate::util::json::Json::parse(line).unwrap();
            assert_eq!(ev.field("event").unwrap().as_str().unwrap(), "telemetry");
            ev.field("samples").unwrap().as_u64().unwrap();
        }
        let _ = std::fs::remove_file(&path);
    }
}
