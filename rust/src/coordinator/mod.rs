//! The L3 coordinator: a streaming training service.
//!
//! Topology (one training run):
//!
//! ```text
//!   dataset/stream ──► producer thread ──► bounded queue ──► trainer
//!        (source)        (batcher.rs)      (backpressure)   (PJRT or
//!                                                            native)
//!                                               │
//!                          convergence monitor ◄┘──► metrics
//! ```
//!
//! The service also owns the *reconfiguration controller*: a command
//! queue that can swap the datapath mode mid-stream (the paper's
//! real-time reconfigurability), and the downstream-classifier stage
//! used by the accuracy experiments (paper §V.B protocol: fit DR
//! unsupervised → transform → train MLP → evaluate).

pub mod batcher;
pub mod session;
pub mod trainer;

pub use batcher::{Batch, BatchRejected, EpochSource, SampleSource};
// Run metrics were absorbed into the telemetry layer (one home for
// run- and stage-level instrumentation); re-exported here so
// coordinator callers keep their import paths.
pub use crate::telemetry::{LatencyHistogram, Metrics};
pub use session::{
    stage_batch, IngestOutcome, Session, SessionCheckpoint, SessionStatus, StagePlan, StagedMark,
    TelemetrySink,
};
pub use trainer::{ArtifactNames, Trainer};

use crate::config::ExperimentConfig;
use crate::datasets::Dataset;
use crate::linalg::Mat;
use crate::mlp::{Mlp, MlpConfig};
use crate::runtime::Runtime;
use anyhow::Result;
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// A scheduled reconfiguration: after `after_samples` samples, switch
/// the datapath to `mode`.
#[derive(Debug, Clone)]
pub struct ReconfigCommand {
    pub after_samples: u64,
    pub mode: crate::config::PipelineMode,
}

/// Early-stop rule: stop when the convergence EMA drops below
/// `threshold` (0 disables).
#[derive(Debug, Clone, Copy)]
pub struct StopRule {
    pub threshold: f64,
    /// Check only after this many samples.
    pub min_samples: u64,
}

impl Default for StopRule {
    fn default() -> Self {
        Self {
            threshold: 0.0,
            min_samples: 0,
        }
    }
}

/// Outcome of a training run.
pub struct TrainReport {
    pub metrics: Metrics,
    /// Per-stage datapath telemetry, when the run was instrumented
    /// (`cfg.telemetry` on a native-backend run).
    pub telemetry: Option<crate::telemetry::TelemetrySnapshot>,
    /// Final separation matrix.
    pub separation: Mat,
    /// Dense RP matrix, if the mode used one.
    pub rp: Option<Mat>,
    /// Test-set classification accuracy, if a classifier was trained.
    pub test_accuracy: Option<f64>,
    /// Final convergence EMA.
    pub final_update_magnitude: f64,
}

/// The training service.
pub struct TrainingService<'rt> {
    cfg: ExperimentConfig,
    runtime: Option<&'rt Runtime>,
    reconfigs: Vec<ReconfigCommand>,
    stop: StopRule,
}

impl<'rt> TrainingService<'rt> {
    pub fn new(cfg: ExperimentConfig, runtime: Option<&'rt Runtime>) -> Self {
        Self {
            cfg,
            runtime,
            reconfigs: Vec::new(),
            stop: StopRule::default(),
        }
    }

    /// Schedule a mid-stream datapath reconfiguration.
    pub fn schedule_reconfig(&mut self, cmd: ReconfigCommand) -> &mut Self {
        self.reconfigs.push(cmd);
        self.reconfigs.sort_by_key(|c| c.after_samples);
        self
    }

    /// Set an early-stopping rule on the convergence EMA.
    pub fn stop_when(&mut self, rule: StopRule) -> &mut Self {
        self.stop = rule;
        self
    }

    /// Run the full paper protocol on a dataset: stream-train the DR
    /// stage, then (optionally) train the classifier on transformed
    /// features and evaluate on the transformed test set.
    ///
    /// This is now a thin single-session façade: all per-stream state
    /// and logic (reconfig schedule, stop rule, metrics, telemetry
    /// events) lives in [`Session`]; this method just pumps the
    /// producer queue into it and runs the classifier stage.
    pub fn run(&mut self, data: &Dataset) -> Result<TrainReport> {
        anyhow::ensure!(
            data.input_dim() == self.cfg.input_dim,
            "dataset dim {} != config input_dim {}",
            data.input_dim(),
            self.cfg.input_dim
        );
        let mut session = Session::new(&self.cfg, self.runtime)?;
        for cmd in &self.reconfigs {
            session.schedule_reconfig(cmd.clone());
        }
        session.stop_when(self.stop);

        // Producer: epochs over the training matrix.
        let shared = Arc::new(data.train_x.clone());
        let source = EpochSource::new(shared, self.cfg.epochs);
        let (rx, producer) =
            batcher::spawn_producer(Box::new(source), self.cfg.batch, self.cfg.queue_depth);

        for batch in rx.iter() {
            let outcome = session.ingest(&batch)?;
            // Return the drained buffer to the producer for reuse.
            producer.recycle(batch);
            if outcome.is_stopped() {
                // Drain: drop the receiver so the producer unblocks.
                break;
            }
        }
        drop(rx);
        // The producer errors with "consumer hung up" only on early
        // stop — that is expected; real panics still propagate.
        match producer.handle.join() {
            Ok(_) => {}
            Err(p) => std::panic::resume_unwind(p),
        }
        session.metrics_mut().backpressure_waits =
            producer.backpressure_waits.load(Ordering::Relaxed);
        let (trainer, m) = session.into_parts();

        // Classifier stage (paper §V.B): train on transformed features.
        let test_accuracy = if self.cfg.train_classifier {
            // Standardise the reduced features on training statistics
            // (the paper normalises classifier inputs; also insulates
            // the MLP from the DR stage's output scale).
            let mut reduced = Dataset {
                name: format!("{}-reduced", data.name),
                train_x: trainer.transform_rows(&data.train_x),
                train_y: data.train_y.clone(),
                test_x: trainer.transform_rows(&data.test_x),
                test_y: data.test_y.clone(),
                num_classes: data.num_classes,
            };
            reduced.standardize();
            let (train_t, test_t) = (reduced.train_x, reduced.test_x);
            let mut mlp = Mlp::new(MlpConfig {
                epochs: self.cfg.mlp_epochs,
                seed: self.cfg.seed,
                ..MlpConfig::paper(self.cfg.output_dim, data.num_classes)
            });
            mlp.train(&train_t, &data.train_y);
            Some(mlp.accuracy(&test_t, &data.test_y))
        } else {
            None
        };

        Ok(TrainReport {
            final_update_magnitude: trainer.update_magnitude(),
            separation: trainer.separation_matrix(),
            rp: trainer.rp_matrix().cloned(),
            test_accuracy,
            telemetry: trainer.telemetry_snapshot(),
            metrics: m,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PipelineMode;
    use crate::datasets::waveform::WaveformConfig;

    fn small_waveform() -> Dataset {
        WaveformConfig {
            samples: 600,
            train: 500,
            ..WaveformConfig::paper()
        }
        .generate()
    }

    fn base_cfg() -> ExperimentConfig {
        ExperimentConfig {
            epochs: 2,
            batch: 64,
            mlp_epochs: 5,
            ..Default::default()
        }
    }

    #[test]
    fn native_end_to_end_runs() {
        let data = small_waveform();
        let mut svc = TrainingService::new(base_cfg(), None);
        let report = svc.run(&data).unwrap();
        assert_eq!(report.metrics.samples_in, 1000); // 500 × 2 epochs
        assert_eq!(report.separation.shape(), (8, 16));
        assert!(report.rp.is_some());
        let acc = report.test_accuracy.unwrap();
        assert!(acc > 0.4, "accuracy {acc} should beat chance (1/3)");
    }

    #[test]
    fn tail_batches_processed() {
        let data = small_waveform(); // 500 training rows
        let mut cfg = base_cfg();
        cfg.batch = 64; // 500*2 = 1000 → 15 full + tail of 40
        let mut svc = TrainingService::new(cfg, None);
        let report = svc.run(&data).unwrap();
        assert_eq!(report.metrics.samples_in, 1000);
        assert!(report.metrics.tail_samples > 0);
    }

    #[test]
    fn reconfiguration_fires_mid_stream() {
        let data = small_waveform();
        let mut cfg = base_cfg();
        cfg.mode = PipelineMode::Easi;
        cfg.train_classifier = false;
        let mut svc = TrainingService::new(cfg, None);
        svc.schedule_reconfig(ReconfigCommand {
            after_samples: 300,
            mode: PipelineMode::PcaWhiten,
        });
        let report = svc.run(&data).unwrap();
        assert_eq!(report.metrics.reconfigurations.len(), 1);
        assert_eq!(report.metrics.reconfigurations[0].1, "pca-whiten");
        assert!(report.metrics.reconfigurations[0].0 >= 300);
    }

    #[test]
    fn early_stop_cuts_stream_short() {
        let data = small_waveform();
        let mut cfg = base_cfg();
        cfg.epochs = 50; // would be 25k samples without the stop rule
        cfg.train_classifier = false;
        let mut svc = TrainingService::new(cfg, None);
        svc.stop_when(StopRule {
            threshold: 0.5, // generous: fires quickly
            min_samples: 200,
        });
        let report = svc.run(&data).unwrap();
        assert!(
            report.metrics.samples_in < 25_000,
            "stopped early at {}",
            report.metrics.samples_in
        );
    }

    #[test]
    fn convergence_trace_recorded() {
        let data = small_waveform();
        let mut cfg = base_cfg();
        cfg.train_classifier = false;
        let report = TrainingService::new(cfg, None).run(&data).unwrap();
        assert!(!report.metrics.convergence_trace.is_empty());
        // Signal decreases over the run.
        let first = report.metrics.convergence_trace.first().unwrap().1;
        let last = report.metrics.convergence_trace.last().unwrap().1;
        assert!(last <= first);
    }

    #[test]
    fn dim_mismatch_rejected() {
        let data = small_waveform();
        let mut cfg = base_cfg();
        cfg.input_dim = 40;
        let mut svc = TrainingService::new(cfg, None);
        assert!(svc.run(&data).is_err());
    }
}
