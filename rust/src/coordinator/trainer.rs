//! Training backends: the same streaming-trainer interface served
//! either by the native Rust implementation (baseline/oracle) or by the
//! AOT-compiled XLA executables through PJRT (the production path).
//!
//! The native backend drives a [`crate::stage::StageGraph`] built from
//! the experiment config — the legacy pipeline modes map onto stage
//! lists (`rp:ternary/p → whiten:gha → rot:easi` for the paper's
//! proposal) and `--stages` composes arbitrary cascades — so training
//! is one tile loop over the graph, whatever the stage mix or numeric
//! domain (f32 and bit-accurate fixed point are the graph's two
//! backends). The PJRT backend realises the paper's reconfigurability
//! story: each datapath mode is a separate compiled executable
//! (bitstream analogue) and [`Trainer::reconfigure`] swaps executables
//! at run time while carrying all state across — the mux of §IV,
//! without re-synthesis. On the native graph the same mux toggles the
//! rotation stage in place.
//!
//! The rotation warm-up is itself expressed through the mux: the first
//! `rot_warmup` samples run the whiten-only datapath, then the rotation
//! stage starts learning.

use crate::config::{Backend, ExperimentConfig, PipelineMode};
use crate::linalg::Mat;
use crate::pipeline::unit::RETRACT_INTERVAL;
use crate::rp::RandomProjection;
use crate::runtime::{Runtime, Tensor};
use crate::stage::{StageGraph, StageRole};
use anyhow::{bail, ensure, Context, Result};

use super::batcher::Batch;

/// Artifact names for one (mode, dims, batch) configuration.
#[derive(Debug, Clone)]
pub struct ArtifactNames {
    /// Full-batch training step (whiten + rotate).
    pub step: String,
    /// Whiten-only variant (rotation muxed out) — used for PCA mode and
    /// for the rotation warm-up phase.
    pub step_whiten: String,
    /// batch=1 variants for stream tails.
    pub step_tail: String,
    pub step_whiten_tail: String,
}

impl ArtifactNames {
    /// Derive the artifact naming scheme used by `python/compile/aot.py`.
    pub fn derive(uses_rp: bool, m: usize, p: usize, n: usize, batch: usize) -> Self {
        if uses_rp {
            Self {
                step: format!("rp_dr_full_m{m}_p{p}_n{n}_b{batch}"),
                step_whiten: format!("rp_dr_whiten_m{m}_p{p}_n{n}_b{batch}"),
                step_tail: format!("rp_dr_full_m{m}_p{p}_n{n}_b1"),
                step_whiten_tail: format!("rp_dr_whiten_m{m}_p{p}_n{n}_b1"),
            }
        } else {
            Self {
                step: format!("dr_full_m{m}_n{n}_b{batch}"),
                step_whiten: format!("dr_whiten_m{m}_n{n}_b{batch}"),
                step_tail: format!("dr_full_m{m}_n{n}_b1"),
                step_whiten_tail: format!("dr_whiten_m{m}_n{n}_b1"),
            }
        }
    }

    fn all(&self) -> [&str; 4] {
        [
            &self.step,
            &self.step_whiten,
            &self.step_tail,
            &self.step_whiten_tail,
        ]
    }
}

/// The unified streaming trainer.
pub enum Trainer<'rt> {
    Native(NativeTrainer),
    Pjrt(PjrtTrainer<'rt>),
}

impl<'rt> Trainer<'rt> {
    /// Build from an experiment config. For the PJRT backend, `runtime`
    /// must outlive the trainer and contain the required artifacts.
    pub fn from_config(cfg: &ExperimentConfig, runtime: Option<&'rt Runtime>) -> Result<Self> {
        match cfg.backend {
            Backend::Native => Ok(Trainer::Native(NativeTrainer::new(cfg)?)),
            Backend::Pjrt => {
                // Guard here too (not just in config validation, which
                // struct-literal construction bypasses): the AOT
                // artifacts compute in f32, so silently accepting a
                // fixed-precision config would mislabel the run.
                ensure!(
                    !cfg.precision.is_fixed(),
                    "fixed-point precision ({}) runs on the native backend only",
                    cfg.precision.label()
                );
                ensure!(
                    cfg.stages.is_none(),
                    "custom stage lists run on the native backend only \
                     (the AOT artifacts are compiled per pipeline mode)"
                );
                let rt = runtime.context("PJRT backend needs a loaded Runtime")?;
                Ok(Trainer::Pjrt(PjrtTrainer::new(cfg, rt)?))
            }
        }
    }

    /// Consume one minibatch (Full → fused batch executable; Tail →
    /// per-sample executable).
    pub fn step(&mut self, batch: &Batch) -> Result<()> {
        match self {
            Trainer::Native(t) => t.step(batch),
            Trainer::Pjrt(t) => t.step(batch),
        }
    }

    /// Consume one *pre-staged* tile (native backend only — the
    /// serving layer's pipelined shard stages validation and entry
    /// quantization off the compute path, then commits here).
    pub fn step_staged(&mut self, input: crate::stage::StagedInput<'_>, rows: usize) -> Result<()> {
        match self {
            Trainer::Native(t) => {
                t.graph.step_staged(input, rows);
                Ok(())
            }
            Trainer::Pjrt(_) => bail!("staged commits run on the native backend only"),
        }
    }

    /// Whether pre-staged (and fused multi-batch) commits are safe for
    /// this trainer: native backend with every batch stage fitted.
    pub fn staged_ready(&self) -> bool {
        match self {
            Trainer::Native(t) => t.graph.staged_ready(),
            Trainer::Pjrt(_) => false,
        }
    }

    /// The fitted DR stage as one dense matrix (n × stage_input_dim):
    /// the fold of every trained stage behind the RP front end. For
    /// fixed-point precision this is the dequantized composition.
    pub fn separation_matrix(&self) -> Mat {
        match self {
            Trainer::Native(t) => t.separation_matrix(),
            Trainer::Pjrt(t) => t.effective_matrix(),
        }
    }

    /// The RP front-end matrix (dense, scaled), if the mode uses one.
    pub fn rp_matrix(&self) -> Option<&Mat> {
        match self {
            Trainer::Native(t) => t.rp_dense.as_ref(),
            Trainer::Pjrt(t) => t.r.as_ref(),
        }
    }

    /// Convergence signal (whitener orthonormality ∨ rotation EMA).
    pub fn update_magnitude(&self) -> f64 {
        match self {
            Trainer::Native(t) => t.update_magnitude(),
            Trainer::Pjrt(t) => t.update_ema,
        }
    }

    /// Transform a sample matrix through the fitted pipeline. Native:
    /// the graph's bulk forward — dense matvec for f32, the
    /// bit-accurate multi-lane integer forward for fixed precision;
    /// artifact-based inference is exercised by examples/benches.
    pub fn transform_rows(&self, x: &Mat) -> Mat {
        match self {
            Trainer::Native(t) => t.transform_rows(x),
            Trainer::Pjrt(_) => {
                let eff = self.separation_matrix();
                let staged = match self.rp_matrix() {
                    Some(r) => r.apply_rows(x),
                    None => x.clone(),
                };
                eff.apply_rows(&staged)
            }
        }
    }

    /// Swap the datapath mode at run time (the paper's reconfigurable
    /// mux): EASI ↔ PCA-whitening toggles the rotation stage; changing
    /// the RP front end is rejected (state shapes would change).
    pub fn reconfigure(&mut self, mode: PipelineMode) -> Result<()> {
        match self {
            Trainer::Native(t) => t.reconfigure(mode),
            Trainer::Pjrt(t) => t.reconfigure(mode),
        }
    }

    pub fn mode(&self) -> PipelineMode {
        match self {
            Trainer::Native(t) => t.mode,
            Trainer::Pjrt(t) => t.mode,
        }
    }

    pub fn backend_label(&self) -> &'static str {
        match self {
            Trainer::Native(_) => "native",
            Trainer::Pjrt(_) => "pjrt",
        }
    }

    /// Point-in-time per-stage telemetry, when the backend is
    /// instrumented (native with `cfg.telemetry` on; the PJRT datapath
    /// runs inside compiled executables and exposes none).
    pub fn telemetry_snapshot(&self) -> Option<crate::telemetry::TelemetrySnapshot> {
        match self {
            Trainer::Native(t) => t.graph.telemetry_snapshot(),
            Trainer::Pjrt(_) => None,
        }
    }

    /// The underlying stage graph (native backend only) — the
    /// checkpointing surface sessions evict/restore through. PJRT
    /// state lives inside compiled executables and is not
    /// checkpointable.
    pub fn stage_graph(&self) -> Option<&StageGraph> {
        match self {
            Trainer::Native(t) => Some(t.graph()),
            Trainer::Pjrt(_) => None,
        }
    }

    /// Mutable stage-graph access (native backend only); see
    /// [`Trainer::stage_graph`].
    pub fn stage_graph_mut(&mut self) -> Option<&mut StageGraph> {
        match self {
            Trainer::Native(t) => Some(t.graph_mut()),
            Trainer::Pjrt(_) => None,
        }
    }
}

fn rotation_active(mode: PipelineMode) -> Result<bool> {
    match mode {
        PipelineMode::Easi | PipelineMode::RpEasi => Ok(true),
        PipelineMode::PcaWhiten => Ok(false),
        PipelineMode::RpOnly => bail!("RP-only mode has no trained stage"),
    }
}

fn build_rp(cfg: &ExperimentConfig) -> Option<RandomProjection> {
    cfg.mode.uses_rp().then(|| {
        RandomProjection::new(
            cfg.input_dim,
            cfg.intermediate_dim,
            cfg.rp_distribution,
            cfg.seed,
        )
        // The adaptive stage assumes unit-variance inputs.
        .unit_variance()
    })
}

// ------------------------------------------------------------- native

/// Pure-Rust backend: one [`StageGraph`] built from the config — the
/// f32 reference stages or their bit-accurate fixed-point images, per
/// `ExperimentConfig::precision`, behind one generic tile loop.
pub struct NativeTrainer {
    mode: PipelineMode,
    graph: StageGraph,
    /// Dense scaled RP matrix for reports, whatever the backend.
    rp_dense: Option<Mat>,
    /// Forward-path lanes for bulk transforms. Training-path sharding
    /// is configured separately on the graph via `train_lanes` (the
    /// commuting STE shadow pass shards; order-dependent recursions
    /// stay sequential).
    lanes: usize,
}

impl NativeTrainer {
    pub fn new(cfg: &ExperimentConfig) -> Result<Self> {
        let gspec = cfg.graph_spec()?;
        let mut graph = gspec.build(None)?;
        if cfg.telemetry {
            graph.enable_telemetry();
        }
        graph.set_train_lanes(cfg.train_lanes.max(1));
        if cfg.stages.is_none() {
            // Legacy modes select the rotation mux (custom stage lists
            // start with every declared stage live).
            let rotate = rotation_active(cfg.mode)?;
            if !rotate {
                graph.set_role_active(StageRole::Rot, false);
            }
        }
        let rp_dense = graph.random_projection().map(RandomProjection::to_dense);
        Ok(Self {
            mode: cfg.mode,
            graph,
            rp_dense,
            lanes: cfg.lanes.max(1),
        })
    }

    /// The trainer's stage graph (checkpointing, per-stage access).
    pub fn graph(&self) -> &StageGraph {
        &self.graph
    }

    /// Mutable graph access (checkpoint restore).
    pub fn graph_mut(&mut self) -> &mut StageGraph {
        &mut self.graph
    }

    /// Consume one minibatch as a whole tile: one pass over the stage
    /// list, every stage before the last trainable one emitting its
    /// per-row training outputs into reusable graph workspaces —
    /// bit-identical to the legacy fused per-sample stepping, with no
    /// per-stage match arms and zero steady-state allocations.
    fn step(&mut self, batch: &Batch) -> Result<()> {
        self.graph.step_rows(batch.rows());
        Ok(())
    }

    fn separation_matrix(&self) -> Mat {
        // The fixed-point graph folds its input prescale in. The
        // trainer applies that same prescale *before* the (linear) RP
        // stage instead, and the two placements commute, so the folded
        // matrix composes correctly with `rp_matrix` as-is.
        self.graph.separation_matrix()
    }

    fn update_magnitude(&self) -> f64 {
        self.graph.update_magnitude()
    }

    /// Bulk transform through the graph: dense matvec for f32, the
    /// bit-accurate integer forward path for fixed point (so reported
    /// accuracies reflect the quantized pipeline). Fixed-point tiles
    /// are sharded across `lanes` scoped threads — the merge is
    /// deterministic (each lane owns a disjoint output range), so the
    /// raw words are identical to the single-lane / per-sample path.
    fn transform_rows(&self, x: &Mat) -> Mat {
        self.graph.forward_rows(x, self.lanes)
    }

    fn reconfigure(&mut self, mode: PipelineMode) -> Result<()> {
        let rotate = rotation_active(mode)?;
        ensure!(
            mode.uses_rp() == self.mode.uses_rp(),
            "reconfigure cannot change the RP front end (state shapes would change)"
        );
        ensure!(
            self.graph.has_role(StageRole::Rot),
            "this stage graph has no rotation stage to reconfigure"
        );
        self.graph.set_role_active(StageRole::Rot, rotate);
        self.mode = mode;
        Ok(())
    }
}

// -------------------------------------------------------------- PJRT

/// PJRT backend: state lives in Rust, steps execute compiled artifacts.
pub struct PjrtTrainer<'rt> {
    runtime: &'rt Runtime,
    mode: PipelineMode,
    names: ArtifactNames,
    batch: usize,
    /// (μ_w, var β, μ_rot) fed as a 3-vector input.
    mus: [f32; 3],
    rot_warmup: u64,
    samples_seen: u64,
    /// GHA subspace W (n × stage_in).
    w: Mat,
    /// λ̂ variance estimates (n).
    var: Vec<f32>,
    /// Rotation U (n × n).
    u: Mat,
    /// Dense scaled RP matrix (p × m), if the mode uses one.
    r: Option<Mat>,
    update_ema: f64,
    last_retract: u64,
}

impl<'rt> PjrtTrainer<'rt> {
    pub fn new(cfg: &ExperimentConfig, runtime: &'rt Runtime) -> Result<Self> {
        rotation_active(cfg.mode)?; // validate the mode
        let names = ArtifactNames::derive(
            cfg.mode.uses_rp(),
            cfg.input_dim,
            cfg.intermediate_dim,
            cfg.output_dim,
            cfg.batch,
        );
        for n in names.all() {
            runtime.manifest().get(n)?;
        }
        runtime.warm(&names.all())?;

        let stage_in = if cfg.mode.uses_rp() {
            cfg.intermediate_dim
        } else {
            cfg.input_dim
        };
        let n = cfg.output_dim;
        Ok(Self {
            runtime,
            mode: cfg.mode,
            names,
            batch: cfg.batch,
            mus: [cfg.mu_w, 5e-3, cfg.mu],
            rot_warmup: cfg.rot_warmup as u64,
            samples_seen: 0,
            w: crate::easi::random_orthonormal(n, stage_in, cfg.seed),
            var: vec![1.0; n],
            u: Mat::eye(n, n),
            r: build_rp(cfg).map(|p| p.to_dense()),
            update_ema: 1.0,
            last_retract: 0,
        })
    }

    /// Whether the rotation stage should be updating right now (mode mux
    /// + warm-up schedule).
    fn rotation_live(&self) -> bool {
        matches!(self.mode, PipelineMode::Easi | PipelineMode::RpEasi)
            && self.samples_seen >= self.rot_warmup
    }

    fn artifact_for(&self, tail: bool) -> &str {
        match (self.rotation_live(), tail) {
            (true, false) => &self.names.step,
            (true, true) => &self.names.step_tail,
            (false, false) => &self.names.step_whiten,
            (false, true) => &self.names.step_whiten_tail,
        }
    }

    fn exec_step(&mut self, artifact: &str, rows: &Mat) -> Result<()> {
        let mut inputs = vec![
            Tensor::from_mat(&self.w),
            Tensor::new(vec![self.var.len()], self.var.clone()),
            Tensor::from_mat(&self.u),
        ];
        if let Some(r) = &self.r {
            inputs.push(Tensor::from_mat(r));
        }
        inputs.push(Tensor::from_mat(rows));
        inputs.push(Tensor::new(vec![3], self.mus.to_vec()));
        let outs = self.runtime.execute(artifact, &inputs)?;
        ensure!(outs.len() == 3, "{artifact}: expected 3 state outputs");
        let mut it = outs.into_iter();
        let new_w = it.next().unwrap().into_mat()?;
        let new_var = it.next().unwrap().data;
        let new_u = it.next().unwrap().into_mat()?;

        // Convergence signal from consecutive W's.
        let mut delta2 = 0.0f64;
        let mut norm2 = 0.0f64;
        for (a, b) in new_w.as_slice().iter().zip(self.w.as_slice()) {
            delta2 += ((a - b) as f64).powi(2);
            norm2 += (*a as f64).powi(2);
        }
        let rel = delta2.sqrt() / (norm2.sqrt() + 1e-30);
        self.update_ema = 0.9 * self.update_ema + 0.1 * rel;

        self.w = new_w;
        self.var = new_var;
        self.u = new_u;
        self.samples_seen += rows.rows_count() as u64;

        // Host-side retraction of U at the same cadence the native unit
        // uses (between executable calls — cheap: O(n³)).
        if self.rotation_live() && self.samples_seen - self.last_retract >= RETRACT_INTERVAL {
            crate::linalg::orthonormalize_rows(&mut self.u);
            self.last_retract = self.samples_seen;
        }
        Ok(())
    }

    fn step(&mut self, batch: &Batch) -> Result<()> {
        match batch {
            Batch::Full(m) => {
                ensure!(
                    m.rows_count() == self.batch,
                    "full batch size {} != configured {}",
                    m.rows_count(),
                    self.batch
                );
                let name = self.artifact_for(false).to_string();
                self.exec_step(&name, m)
            }
            Batch::Tail(m) => {
                for i in 0..m.rows_count() {
                    let row = Mat::from_vec(1, m.cols_count(), m.row(i).to_vec());
                    let name = self.artifact_for(true).to_string();
                    self.exec_step(&name, &row)?;
                }
                Ok(())
            }
        }
    }

    /// `U·diag(λ̂^{-1/2})·W`, with U skipped in whiten-only mode.
    fn effective_matrix(&self) -> Mat {
        let (n, m) = self.w.shape();
        let wm = Mat::from_fn(n, m, |i, j| {
            self.w.get(i, j) / self.var[i].max(1e-9).sqrt()
        });
        if matches!(self.mode, PipelineMode::Easi | PipelineMode::RpEasi) {
            self.u.matmul(&wm)
        } else {
            wm
        }
    }

    fn reconfigure(&mut self, mode: PipelineMode) -> Result<()> {
        rotation_active(mode)?;
        ensure!(
            mode.uses_rp() == self.mode.uses_rp(),
            "reconfigure cannot change the RP front end (state shapes would change)"
        );
        // Same state tensors, different executable — nothing else moves.
        self.mode = mode;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fxp::Precision;

    #[test]
    fn artifact_name_derivation() {
        let n = ArtifactNames::derive(true, 32, 16, 8, 256);
        assert_eq!(n.step, "rp_dr_full_m32_p16_n8_b256");
        assert_eq!(n.step_whiten, "rp_dr_whiten_m32_p16_n8_b256");
        assert_eq!(n.step_tail, "rp_dr_full_m32_p16_n8_b1");
        let n = ArtifactNames::derive(false, 32, 0, 16, 256);
        assert_eq!(n.step, "dr_full_m32_n16_b256");
        assert_eq!(n.step_whiten_tail, "dr_whiten_m32_n16_b1");
    }

    #[test]
    fn native_trainer_trains_and_transforms() {
        let cfg = ExperimentConfig {
            mode: PipelineMode::RpEasi,
            ..Default::default()
        };
        let mut t = Trainer::from_config(&cfg, None).unwrap();
        let data = Mat::from_fn(256, 32, |i, j| ((i * 31 + j * 7) % 17) as f32 / 17.0 - 0.5);
        t.step(&Batch::Full(data.clone())).unwrap();
        let y = t.transform_rows(&data);
        assert_eq!(y.shape(), (256, 8));
        assert!(t.rp_matrix().is_some());
    }

    #[test]
    fn native_trainer_fixed_precision_trains_and_transforms() {
        let cfg = ExperimentConfig {
            mode: PipelineMode::RpEasi,
            precision: Precision::parse("q4.12").unwrap(),
            ..Default::default()
        };
        let mut t = Trainer::from_config(&cfg, None).unwrap();
        let data = Mat::from_fn(256, 32, |i, j| ((i * 31 + j * 7) % 17) as f32 / 17.0 - 0.5);
        t.step(&Batch::Full(data.clone())).unwrap();
        let y = t.transform_rows(&data);
        assert_eq!(y.shape(), (256, 8));
        assert!(y.as_slice().iter().all(|v| v.is_finite()));
        assert!(t.rp_matrix().is_some());
        assert_eq!(t.separation_matrix().shape(), (8, 16));
        // The mux still reconfigures on the quantized engine.
        t.reconfigure(PipelineMode::PcaWhiten)
            .expect_err("rp-easi -> pca-whiten changes the RP front end");
    }

    #[test]
    fn fxp_transform_rows_bit_identical_across_lane_counts() {
        // The multi-lane forward merge is deterministic: any lane count
        // must reproduce the single-lane outputs exactly.
        let data = Mat::from_fn(200, 32, |i, j| ((i * 13 + j * 5) % 23) as f32 / 23.0 - 0.5);
        let run = |lanes: usize| {
            let cfg = ExperimentConfig {
                mode: PipelineMode::RpEasi,
                precision: Precision::parse("q4.12").unwrap(),
                lanes,
                train_classifier: false,
                ..Default::default()
            };
            let mut t = Trainer::from_config(&cfg, None).unwrap();
            t.step(&Batch::Full(data.clone())).unwrap();
            t.transform_rows(&data)
        };
        let one = run(1);
        for lanes in [2usize, 5, 64] {
            assert_eq!(one.as_slice(), run(lanes).as_slice(), "lanes={lanes}");
        }
    }

    #[test]
    fn fxp_training_bit_identical_across_train_lane_counts() {
        // The sharded training paths (entry quantization, STE shadow
        // backward) commute on disjoint row blocks: any train-lane
        // count must reproduce the sequential fit exactly.
        let data = Mat::from_fn(200, 32, |i, j| ((i * 17 + j * 3) % 29) as f32 / 29.0 - 0.5);
        let run = |train_lanes: usize| {
            let cfg = ExperimentConfig {
                mode: PipelineMode::RpEasi,
                precision: Precision::parse("q4.12").unwrap(),
                train_lanes,
                train_classifier: false,
                ..Default::default()
            };
            let mut t = Trainer::from_config(&cfg, None).unwrap();
            t.step(&Batch::Full(data.clone())).unwrap();
            (t.separation_matrix(), t.transform_rows(&data))
        };
        let (sep1, y1) = run(1);
        for lanes in [2usize, 7, 64] {
            let (sep, y) = run(lanes);
            assert_eq!(sep1.as_slice(), sep.as_slice(), "train_lanes={lanes}");
            assert_eq!(y1.as_slice(), y.as_slice(), "train_lanes={lanes}");
        }
    }

    #[test]
    fn native_reconfigure_mode_swap() {
        let cfg = ExperimentConfig {
            mode: PipelineMode::Easi,
            ..Default::default()
        };
        let mut t = Trainer::from_config(&cfg, None).unwrap();
        t.reconfigure(PipelineMode::PcaWhiten).unwrap();
        assert_eq!(t.mode(), PipelineMode::PcaWhiten);
        // Changing the RP front end is rejected.
        assert!(t.reconfigure(PipelineMode::RpEasi).is_err());
    }

    #[test]
    fn native_trainer_runs_custom_stage_lists() {
        // A non-paper cascade straight from the stage-list syntax:
        // dct → whiten → rot, fitted and transformed with zero
        // trainer-side plumbing.
        let cfg = ExperimentConfig {
            stages: Some("dct/16,whiten:gha,rot:easi".into()),
            train_classifier: false,
            ..Default::default()
        };
        let mut t = Trainer::from_config(&cfg, None).unwrap();
        let data = Mat::from_fn(128, 32, |i, j| ((i * 29 + j * 11) % 19) as f32 / 19.0 - 0.5);
        t.step(&Batch::Full(data.clone())).unwrap();
        let y = t.transform_rows(&data);
        assert_eq!(y.shape(), (128, 8));
        assert!(y.as_slice().iter().all(|v| v.is_finite()));
        // No RP stage → no RP matrix reported.
        assert!(t.rp_matrix().is_none());
        // rp → batch PCA: the batch stage bootstraps on the first tile.
        let cfg = ExperimentConfig {
            stages: Some("rp:ternary/16,pca".into()),
            train_classifier: false,
            ..Default::default()
        };
        let mut t = Trainer::from_config(&cfg, None).unwrap();
        t.step(&Batch::Full(data.clone())).unwrap();
        let y = t.transform_rows(&data);
        assert_eq!(y.shape(), (128, 8));
        assert!(y.as_slice().iter().all(|v| v.is_finite()));
        assert!(t.rp_matrix().is_some());
        assert_eq!(t.separation_matrix().shape(), (8, 16));
        // Whiten-only fixed point, also from the stage list.
        let cfg = ExperimentConfig {
            stages: Some("whiten:gha".into()),
            precision: Precision::parse("q4.12").unwrap(),
            train_classifier: false,
            ..Default::default()
        };
        let mut t = Trainer::from_config(&cfg, None).unwrap();
        t.step(&Batch::Full(data.clone())).unwrap();
        let y = t.transform_rows(&data);
        assert_eq!(y.shape(), (128, 8));
        assert!(y.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn pjrt_backend_requires_runtime() {
        let cfg = ExperimentConfig {
            backend: Backend::Pjrt,
            ..Default::default()
        };
        assert!(Trainer::from_config(&cfg, None).is_err());
    }
}
