//! Training backends: the same streaming-trainer interface served
//! either by the native Rust implementation (baseline/oracle) or by the
//! AOT-compiled XLA executables through PJRT (the production path).
//!
//! Both backends drive the composed DR unit of
//! [`crate::pipeline::unit`]: optional ternary RP front end → GHA
//! whitening (+λ̂ scaling) → EASI rotation, with the rotation stage
//! muxed per the paper's §IV. The PJRT backend realises the paper's
//! reconfigurability story: each datapath mode is a separate compiled
//! executable (bitstream analogue) and [`Trainer::reconfigure`] swaps
//! executables at run time while carrying all state across — the mux of
//! §IV, without re-synthesis.
//!
//! The rotation warm-up is itself expressed through the mux: the first
//! `rot_warmup` samples run the whiten-only executable, then the
//! trainer hot-swaps to the full one.

use crate::config::{Backend, ExperimentConfig, PipelineMode};
use crate::fxp::{FxpDrUnit, FxpRp, FxpSpec, FxpUnitConfig, Precision, Scratch};
use crate::linalg::Mat;
use crate::pipeline::unit::{DrUnit, DrUnitConfig, RETRACT_INTERVAL};
use crate::rp::RandomProjection;
use crate::runtime::{Runtime, Tensor};
use anyhow::{bail, ensure, Context, Result};

use super::batcher::Batch;

/// Artifact names for one (mode, dims, batch) configuration.
#[derive(Debug, Clone)]
pub struct ArtifactNames {
    /// Full-batch training step (whiten + rotate).
    pub step: String,
    /// Whiten-only variant (rotation muxed out) — used for PCA mode and
    /// for the rotation warm-up phase.
    pub step_whiten: String,
    /// batch=1 variants for stream tails.
    pub step_tail: String,
    pub step_whiten_tail: String,
}

impl ArtifactNames {
    /// Derive the artifact naming scheme used by `python/compile/aot.py`.
    pub fn derive(uses_rp: bool, m: usize, p: usize, n: usize, batch: usize) -> Self {
        if uses_rp {
            Self {
                step: format!("rp_dr_full_m{m}_p{p}_n{n}_b{batch}"),
                step_whiten: format!("rp_dr_whiten_m{m}_p{p}_n{n}_b{batch}"),
                step_tail: format!("rp_dr_full_m{m}_p{p}_n{n}_b1"),
                step_whiten_tail: format!("rp_dr_whiten_m{m}_p{p}_n{n}_b1"),
            }
        } else {
            Self {
                step: format!("dr_full_m{m}_n{n}_b{batch}"),
                step_whiten: format!("dr_whiten_m{m}_n{n}_b{batch}"),
                step_tail: format!("dr_full_m{m}_n{n}_b1"),
                step_whiten_tail: format!("dr_whiten_m{m}_n{n}_b1"),
            }
        }
    }

    fn all(&self) -> [&str; 4] {
        [
            &self.step,
            &self.step_whiten,
            &self.step_tail,
            &self.step_whiten_tail,
        ]
    }
}

/// The unified streaming trainer.
pub enum Trainer<'rt> {
    Native(NativeTrainer),
    Pjrt(PjrtTrainer<'rt>),
}

impl<'rt> Trainer<'rt> {
    /// Build from an experiment config. For the PJRT backend, `runtime`
    /// must outlive the trainer and contain the required artifacts.
    pub fn from_config(cfg: &ExperimentConfig, runtime: Option<&'rt Runtime>) -> Result<Self> {
        match cfg.backend {
            Backend::Native => Ok(Trainer::Native(NativeTrainer::new(cfg)?)),
            Backend::Pjrt => {
                // Guard here too (not just in config validation, which
                // struct-literal construction bypasses): the AOT
                // artifacts compute in f32, so silently accepting a
                // fixed-precision config would mislabel the run.
                ensure!(
                    !cfg.precision.is_fixed(),
                    "fixed-point precision ({}) runs on the native backend only",
                    cfg.precision.label()
                );
                let rt = runtime.context("PJRT backend needs a loaded Runtime")?;
                Ok(Trainer::Pjrt(PjrtTrainer::new(cfg, rt)?))
            }
        }
    }

    /// Consume one minibatch (Full → fused batch executable; Tail →
    /// per-sample executable).
    pub fn step(&mut self, batch: &Batch) -> Result<()> {
        match self {
            Trainer::Native(t) => t.step(batch),
            Trainer::Pjrt(t) => t.step(batch),
        }
    }

    /// The fitted DR stage as one dense matrix (n × stage_input_dim):
    /// `U·diag(λ̂^{-1/2})·W` (U omitted in whiten-only modes). For
    /// fixed-point precision this is the dequantized composition.
    pub fn separation_matrix(&self) -> Mat {
        match self {
            Trainer::Native(t) => t.separation_matrix(),
            Trainer::Pjrt(t) => t.effective_matrix(),
        }
    }

    /// The RP front-end matrix (dense, scaled), if the mode uses one.
    pub fn rp_matrix(&self) -> Option<&Mat> {
        match self {
            Trainer::Native(t) => t.rp_dense.as_ref(),
            Trainer::Pjrt(t) => t.r.as_ref(),
        }
    }

    /// Convergence signal (whitener orthonormality ∨ rotation EMA).
    pub fn update_magnitude(&self) -> f64 {
        match self {
            Trainer::Native(t) => t.update_magnitude(),
            Trainer::Pjrt(t) => t.update_ema,
        }
    }

    /// Transform a sample matrix through the fitted pipeline (RP then
    /// the DR unit). Native matvec — bit-accurate integer forward for
    /// fixed precision; artifact-based inference is exercised by
    /// examples/benches.
    pub fn transform_rows(&self, x: &Mat) -> Mat {
        match self {
            Trainer::Native(t) => t.transform_rows(x),
            Trainer::Pjrt(_) => {
                let eff = self.separation_matrix();
                let staged = match self.rp_matrix() {
                    Some(r) => r.apply_rows(x),
                    None => x.clone(),
                };
                eff.apply_rows(&staged)
            }
        }
    }

    /// Swap the datapath mode at run time (the paper's reconfigurable
    /// mux): EASI ↔ PCA-whitening toggles the rotation stage; changing
    /// the RP front end is rejected (state shapes would change).
    pub fn reconfigure(&mut self, mode: PipelineMode) -> Result<()> {
        match self {
            Trainer::Native(t) => t.reconfigure(mode),
            Trainer::Pjrt(t) => t.reconfigure(mode),
        }
    }

    pub fn mode(&self) -> PipelineMode {
        match self {
            Trainer::Native(t) => t.mode,
            Trainer::Pjrt(t) => t.mode,
        }
    }

    pub fn backend_label(&self) -> &'static str {
        match self {
            Trainer::Native(_) => "native",
            Trainer::Pjrt(_) => "pjrt",
        }
    }
}

fn rotation_active(mode: PipelineMode) -> Result<bool> {
    match mode {
        PipelineMode::Easi | PipelineMode::RpEasi => Ok(true),
        PipelineMode::PcaWhiten => Ok(false),
        PipelineMode::RpOnly => bail!("RP-only mode has no trained stage"),
    }
}

fn build_rp(cfg: &ExperimentConfig) -> Option<RandomProjection> {
    cfg.mode.uses_rp().then(|| {
        RandomProjection::new(
            cfg.input_dim,
            cfg.intermediate_dim,
            cfg.rp_distribution,
            cfg.seed,
        )
        // The adaptive stage assumes unit-variance inputs.
        .unit_variance()
    })
}

// ------------------------------------------------------------- native

/// Pure-Rust backend: either the f32 reference unit or the bit-accurate
/// fixed-point unit, per `ExperimentConfig::precision`.
pub struct NativeTrainer {
    mode: PipelineMode,
    engine: NativeEngine,
    /// Dense scaled RP matrix for reports, whatever the engine.
    rp_dense: Option<Mat>,
    /// Forward-path lanes for bulk transforms (training updates stay
    /// sequential — the Sanger/EASI recursions are order-dependent).
    lanes: usize,
}

enum NativeEngine {
    F32 {
        unit: DrUnit,
        rp: Option<RandomProjection>,
        /// Reusable projected-tile buffer (batch × p), rebuilt only
        /// when the batch shape changes — the training loop stops
        /// allocating a projected matrix per minibatch.
        staged: Mat,
    },
    // The per-stage arithmetic lives on the unit
    // (`unit.config.{whiten_spec,rot_spec}`, `unit.output_spec`);
    // `entry_spec`/`entry_prescale` describe the pipeline's ingress
    // boundary (the RP accumulator format when an RP front end exists).
    Fxp {
        unit: FxpDrUnit,
        rp: Option<FxpRp>,
        entry_spec: FxpSpec,
        entry_prescale: f32,
        /// Reusable ingress workspaces (quantized tile + RP stage tile)
        /// — zero allocations per sample in steady state.
        scratch: Scratch,
    },
}

/// Tile ingress for the fixed-point engine: delegates to the crate-wide
/// shared definition ([`crate::fxp::kernels::ingress_tile`]) with the
/// whitener's format as the stage boundary, so the trainer, the
/// pipeline and the bench harness can never quantize inputs
/// differently.
fn fxp_ingress_tile(
    unit: &FxpDrUnit,
    rp: &Option<FxpRp>,
    entry_spec: &FxpSpec,
    entry_prescale: f32,
    rows: &Mat,
    scratch: &mut Scratch,
) {
    crate::fxp::kernels::ingress_tile(
        rp.as_ref(),
        entry_spec,
        &unit.config.whiten_spec,
        entry_prescale,
        rows.as_slice(),
        rows.rows_count(),
        scratch,
    );
}

impl NativeTrainer {
    pub fn new(cfg: &ExperimentConfig) -> Result<Self> {
        let rotate = rotation_active(cfg.mode)?;
        let stage_in = if cfg.mode.uses_rp() {
            cfg.intermediate_dim
        } else {
            cfg.input_dim
        };
        let rp = build_rp(cfg);
        let rp_dense = rp.as_ref().map(RandomProjection::to_dense);
        let engine = match cfg.precision {
            Precision::F32 => NativeEngine::F32 {
                unit: DrUnit::new(DrUnitConfig {
                    input_dim: stage_in,
                    output_dim: cfg.output_dim,
                    mu_w: cfg.mu_w,
                    mu_rot: cfg.mu,
                    rotate,
                    rot_warmup: cfg.rot_warmup as u64,
                    seed: cfg.seed,
                }),
                rp,
                staged: Mat::zeros(0, 0),
            },
            Precision::Fixed(plan) => {
                let entry_spec = if rp.is_some() { plan.rp } else { plan.whiten };
                NativeEngine::Fxp {
                    unit: FxpDrUnit::new(FxpUnitConfig {
                        input_dim: stage_in,
                        output_dim: cfg.output_dim,
                        mu_w: cfg.mu_w,
                        mu_rot: cfg.mu,
                        rotate,
                        rot_warmup: cfg.rot_warmup as u64,
                        seed: cfg.seed,
                        whiten_spec: plan.whiten,
                        rot_spec: plan.rot,
                        quant: plan.quant,
                    }),
                    rp: rp.as_ref().map(|p| FxpRp::from_rp(p, plan.rp)),
                    entry_spec,
                    entry_prescale: plan.entry_prescale(rp.is_some(), &plan.whiten),
                    scratch: Scratch::new(),
                }
            }
        };
        Ok(Self {
            mode: cfg.mode,
            engine,
            rp_dense,
            lanes: cfg.lanes.max(1),
        })
    }

    /// Consume one minibatch as a whole tile: the ingress quantizes the
    /// full batch into reusable workspaces, then the unit walks the
    /// tile row by row (bit-identical to per-sample stepping — only the
    /// per-sample staging vectors are gone).
    fn step(&mut self, batch: &Batch) -> Result<()> {
        let rows = batch.rows();
        match &mut self.engine {
            NativeEngine::F32 { unit, rp, staged } => match rp {
                Some(rp) => {
                    let shape = (rows.rows_count(), rp.out_dim);
                    if staged.shape() != shape {
                        *staged = Mat::zeros(shape.0, shape.1);
                    }
                    rp.apply_rows_into(rows, staged);
                    unit.step_rows(staged);
                }
                None => unit.step_rows(rows),
            },
            NativeEngine::Fxp {
                unit,
                rp,
                entry_spec,
                entry_prescale,
                scratch,
            } => {
                let r = rows.rows_count();
                fxp_ingress_tile(unit, rp, entry_spec, *entry_prescale, rows, scratch);
                if rp.is_some() {
                    unit.step_tile_raw(&scratch.stage, r);
                } else {
                    unit.step_tile_raw(&scratch.xq, r);
                }
            }
        }
        Ok(())
    }

    fn separation_matrix(&self) -> Mat {
        match &self.engine {
            NativeEngine::F32 { unit, .. } => unit.effective_matrix(),
            // The fxp unit folds its input prescale in. The trainer
            // applies that same prescale *before* the (linear) RP stage
            // instead, and the two placements commute, so the folded
            // matrix composes correctly with `rp_matrix` as-is.
            NativeEngine::Fxp { unit, .. } => unit.effective_matrix(),
        }
    }

    fn update_magnitude(&self) -> f64 {
        match &self.engine {
            NativeEngine::F32 { unit, .. } => unit.update_magnitude(),
            NativeEngine::Fxp { unit, .. } => unit.update_magnitude(),
        }
    }

    /// Bulk transform: dense matvec for f32, the bit-accurate integer
    /// forward path for fixed point (so reported accuracies reflect the
    /// quantized pipeline). Fixed-point tiles are sharded across
    /// `lanes` scoped threads — the merge is deterministic (each lane
    /// owns a disjoint output range), so the raw words are identical to
    /// the single-lane / per-sample path.
    fn transform_rows(&self, x: &Mat) -> Mat {
        match &self.engine {
            NativeEngine::F32 { unit, .. } => {
                let eff = unit.effective_matrix();
                let staged = match &self.rp_dense {
                    Some(r) => r.apply_rows(x),
                    None => x.clone(),
                };
                eff.apply_rows(&staged)
            }
            NativeEngine::Fxp {
                unit,
                rp,
                entry_spec,
                entry_prescale,
                ..
            } => {
                let r = x.rows_count();
                let n = unit.config.output_dim;
                let out_spec = unit.output_spec();
                let mut scratch = Scratch::new();
                fxp_ingress_tile(unit, rp, entry_spec, *entry_prescale, x, &mut scratch);
                let tile: &[i32] = if rp.is_some() {
                    &scratch.stage
                } else {
                    &scratch.xq
                };
                let mut raw = Vec::new();
                unit.transform_tile_raw_multilane(tile, r, self.lanes, &mut raw);
                Mat::from_vec(r, n, raw.iter().map(|&w| out_spec.dequantize(w)).collect())
            }
        }
    }

    fn reconfigure(&mut self, mode: PipelineMode) -> Result<()> {
        let rotate = rotation_active(mode)?;
        ensure!(
            mode.uses_rp() == self.mode.uses_rp(),
            "reconfigure cannot change the RP front end (state shapes would change)"
        );
        match &mut self.engine {
            NativeEngine::F32 { unit, .. } => unit.set_rotation(rotate),
            NativeEngine::Fxp { unit, .. } => unit.set_rotation(rotate),
        }
        self.mode = mode;
        Ok(())
    }
}

// -------------------------------------------------------------- PJRT

/// PJRT backend: state lives in Rust, steps execute compiled artifacts.
pub struct PjrtTrainer<'rt> {
    runtime: &'rt Runtime,
    mode: PipelineMode,
    names: ArtifactNames,
    batch: usize,
    /// (μ_w, var β, μ_rot) fed as a 3-vector input.
    mus: [f32; 3],
    rot_warmup: u64,
    samples_seen: u64,
    /// GHA subspace W (n × stage_in).
    w: Mat,
    /// λ̂ variance estimates (n).
    var: Vec<f32>,
    /// Rotation U (n × n).
    u: Mat,
    /// Dense scaled RP matrix (p × m), if the mode uses one.
    r: Option<Mat>,
    update_ema: f64,
    last_retract: u64,
}

impl<'rt> PjrtTrainer<'rt> {
    pub fn new(cfg: &ExperimentConfig, runtime: &'rt Runtime) -> Result<Self> {
        rotation_active(cfg.mode)?; // validate the mode
        let names = ArtifactNames::derive(
            cfg.mode.uses_rp(),
            cfg.input_dim,
            cfg.intermediate_dim,
            cfg.output_dim,
            cfg.batch,
        );
        for n in names.all() {
            runtime.manifest().get(n)?;
        }
        runtime.warm(&names.all())?;

        let stage_in = if cfg.mode.uses_rp() {
            cfg.intermediate_dim
        } else {
            cfg.input_dim
        };
        let n = cfg.output_dim;
        Ok(Self {
            runtime,
            mode: cfg.mode,
            names,
            batch: cfg.batch,
            mus: [cfg.mu_w, 5e-3, cfg.mu],
            rot_warmup: cfg.rot_warmup as u64,
            samples_seen: 0,
            w: crate::easi::random_orthonormal(n, stage_in, cfg.seed),
            var: vec![1.0; n],
            u: Mat::eye(n, n),
            r: build_rp(cfg).map(|p| p.to_dense()),
            update_ema: 1.0,
            last_retract: 0,
        })
    }

    /// Whether the rotation stage should be updating right now (mode mux
    /// + warm-up schedule).
    fn rotation_live(&self) -> bool {
        matches!(self.mode, PipelineMode::Easi | PipelineMode::RpEasi)
            && self.samples_seen >= self.rot_warmup
    }

    fn artifact_for(&self, tail: bool) -> &str {
        match (self.rotation_live(), tail) {
            (true, false) => &self.names.step,
            (true, true) => &self.names.step_tail,
            (false, false) => &self.names.step_whiten,
            (false, true) => &self.names.step_whiten_tail,
        }
    }

    fn exec_step(&mut self, artifact: &str, rows: &Mat) -> Result<()> {
        let mut inputs = vec![
            Tensor::from_mat(&self.w),
            Tensor::new(vec![self.var.len()], self.var.clone()),
            Tensor::from_mat(&self.u),
        ];
        if let Some(r) = &self.r {
            inputs.push(Tensor::from_mat(r));
        }
        inputs.push(Tensor::from_mat(rows));
        inputs.push(Tensor::new(vec![3], self.mus.to_vec()));
        let outs = self.runtime.execute(artifact, &inputs)?;
        ensure!(outs.len() == 3, "{artifact}: expected 3 state outputs");
        let mut it = outs.into_iter();
        let new_w = it.next().unwrap().into_mat()?;
        let new_var = it.next().unwrap().data;
        let new_u = it.next().unwrap().into_mat()?;

        // Convergence signal from consecutive W's.
        let mut delta2 = 0.0f64;
        let mut norm2 = 0.0f64;
        for (a, b) in new_w.as_slice().iter().zip(self.w.as_slice()) {
            delta2 += ((a - b) as f64).powi(2);
            norm2 += (*a as f64).powi(2);
        }
        let rel = delta2.sqrt() / (norm2.sqrt() + 1e-30);
        self.update_ema = 0.9 * self.update_ema + 0.1 * rel;

        self.w = new_w;
        self.var = new_var;
        self.u = new_u;
        self.samples_seen += rows.rows_count() as u64;

        // Host-side retraction of U at the same cadence the native unit
        // uses (between executable calls — cheap: O(n³)).
        if self.rotation_live() && self.samples_seen - self.last_retract >= RETRACT_INTERVAL {
            crate::linalg::orthonormalize_rows(&mut self.u);
            self.last_retract = self.samples_seen;
        }
        Ok(())
    }

    fn step(&mut self, batch: &Batch) -> Result<()> {
        match batch {
            Batch::Full(m) => {
                ensure!(
                    m.rows_count() == self.batch,
                    "full batch size {} != configured {}",
                    m.rows_count(),
                    self.batch
                );
                let name = self.artifact_for(false).to_string();
                self.exec_step(&name, m)
            }
            Batch::Tail(m) => {
                for i in 0..m.rows_count() {
                    let row = Mat::from_vec(1, m.cols_count(), m.row(i).to_vec());
                    let name = self.artifact_for(true).to_string();
                    self.exec_step(&name, &row)?;
                }
                Ok(())
            }
        }
    }

    /// `U·diag(λ̂^{-1/2})·W`, with U skipped in whiten-only mode.
    fn effective_matrix(&self) -> Mat {
        let (n, m) = self.w.shape();
        let wm = Mat::from_fn(n, m, |i, j| {
            self.w.get(i, j) / self.var[i].max(1e-9).sqrt()
        });
        if matches!(self.mode, PipelineMode::Easi | PipelineMode::RpEasi) {
            self.u.matmul(&wm)
        } else {
            wm
        }
    }

    fn reconfigure(&mut self, mode: PipelineMode) -> Result<()> {
        rotation_active(mode)?;
        ensure!(
            mode.uses_rp() == self.mode.uses_rp(),
            "reconfigure cannot change the RP front end (state shapes would change)"
        );
        // Same state tensors, different executable — nothing else moves.
        self.mode = mode;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_name_derivation() {
        let n = ArtifactNames::derive(true, 32, 16, 8, 256);
        assert_eq!(n.step, "rp_dr_full_m32_p16_n8_b256");
        assert_eq!(n.step_whiten, "rp_dr_whiten_m32_p16_n8_b256");
        assert_eq!(n.step_tail, "rp_dr_full_m32_p16_n8_b1");
        let n = ArtifactNames::derive(false, 32, 0, 16, 256);
        assert_eq!(n.step, "dr_full_m32_n16_b256");
        assert_eq!(n.step_whiten_tail, "dr_whiten_m32_n16_b1");
    }

    #[test]
    fn native_trainer_trains_and_transforms() {
        let cfg = ExperimentConfig {
            mode: PipelineMode::RpEasi,
            ..Default::default()
        };
        let mut t = Trainer::from_config(&cfg, None).unwrap();
        let data = Mat::from_fn(256, 32, |i, j| ((i * 31 + j * 7) % 17) as f32 / 17.0 - 0.5);
        t.step(&Batch::Full(data.clone())).unwrap();
        let y = t.transform_rows(&data);
        assert_eq!(y.shape(), (256, 8));
        assert!(t.rp_matrix().is_some());
    }

    #[test]
    fn native_trainer_fixed_precision_trains_and_transforms() {
        let cfg = ExperimentConfig {
            mode: PipelineMode::RpEasi,
            precision: Precision::parse("q4.12").unwrap(),
            ..Default::default()
        };
        let mut t = Trainer::from_config(&cfg, None).unwrap();
        let data = Mat::from_fn(256, 32, |i, j| ((i * 31 + j * 7) % 17) as f32 / 17.0 - 0.5);
        t.step(&Batch::Full(data.clone())).unwrap();
        let y = t.transform_rows(&data);
        assert_eq!(y.shape(), (256, 8));
        assert!(y.as_slice().iter().all(|v| v.is_finite()));
        assert!(t.rp_matrix().is_some());
        assert_eq!(t.separation_matrix().shape(), (8, 16));
        // The mux still reconfigures on the quantized engine.
        t.reconfigure(PipelineMode::PcaWhiten)
            .expect_err("rp-easi -> pca-whiten changes the RP front end");
    }

    #[test]
    fn fxp_transform_rows_bit_identical_across_lane_counts() {
        // The multi-lane forward merge is deterministic: any lane count
        // must reproduce the single-lane outputs exactly.
        let data = Mat::from_fn(200, 32, |i, j| ((i * 13 + j * 5) % 23) as f32 / 23.0 - 0.5);
        let run = |lanes: usize| {
            let cfg = ExperimentConfig {
                mode: PipelineMode::RpEasi,
                precision: Precision::parse("q4.12").unwrap(),
                lanes,
                train_classifier: false,
                ..Default::default()
            };
            let mut t = Trainer::from_config(&cfg, None).unwrap();
            t.step(&Batch::Full(data.clone())).unwrap();
            t.transform_rows(&data)
        };
        let one = run(1);
        for lanes in [2usize, 5, 64] {
            assert_eq!(one.as_slice(), run(lanes).as_slice(), "lanes={lanes}");
        }
    }

    #[test]
    fn native_reconfigure_mode_swap() {
        let cfg = ExperimentConfig {
            mode: PipelineMode::Easi,
            ..Default::default()
        };
        let mut t = Trainer::from_config(&cfg, None).unwrap();
        t.reconfigure(PipelineMode::PcaWhiten).unwrap();
        assert_eq!(t.mode(), PipelineMode::PcaWhiten);
        // Changing the RP front end is rejected.
        assert!(t.reconfigure(PipelineMode::RpEasi).is_err());
    }

    #[test]
    fn pjrt_backend_requires_runtime() {
        let cfg = ExperimentConfig {
            backend: Backend::Pjrt,
            ..Default::default()
        };
        assert!(Trainer::from_config(&cfg, None).is_err());
    }

}
