//! Streaming source → bounded batcher with backpressure.
//!
//! The FPGA consumes one sample per clock from a streaming front end;
//! the software coordinator's analogue is a producer thread pushing
//! fixed-size minibatches through a bounded channel
//! (`std::sync::mpsc::sync_channel`). A full queue blocks the producer —
//! that is the backpressure contract, and the number of waits is
//! surfaced in the metrics.

use crate::linalg::Mat;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

/// A minibatch travelling through the pipeline. The final partial batch
/// of a stream is sent as `Tail` (its rows count < the nominal batch) —
/// the trainer routes it through the b=1 executable rather than
/// zero-padding, because padding corrupts the whitening term.
#[derive(Debug, Clone)]
pub enum Batch {
    Full(Mat),
    Tail(Mat),
}

/// A batch refused at the ingest boundary: empty, wrong feature
/// dimension, or carrying non-finite values. A typed error (not just an
/// `anyhow` message) so the serving layer's circuit breaker can tell
/// "this batch was garbage — drop it" apart from "this tenant's session
/// failed — retry it".
#[derive(Debug, Clone)]
pub struct BatchRejected {
    pub reason: String,
}

impl std::fmt::Display for BatchRejected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "batch rejected: {}", self.reason)
    }
}

impl std::error::Error for BatchRejected {}

impl Batch {
    pub fn rows(&self) -> &Mat {
        match self {
            Batch::Full(m) | Batch::Tail(m) => m,
        }
    }

    pub fn len(&self) -> usize {
        self.rows().rows_count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Tear down into the underlying matrix (for buffer recycling).
    pub fn into_mat(self) -> Mat {
        match self {
            Batch::Full(m) | Batch::Tail(m) => m,
        }
    }

    /// Ingest-boundary validation: reject empty batches, wrong feature
    /// dimensions and non-finite payloads *before* any value reaches
    /// trainer state. One NaN through a fixed-point quantizer would
    /// saturate into a legal-looking raw word and silently corrupt the
    /// whitening statistics — rejection here is what keeps a poisoned
    /// tenant a scheduling event instead of a numerics event.
    pub fn validate(&self, expected_dim: usize) -> Result<(), BatchRejected> {
        let m = self.rows();
        if m.rows_count() == 0 {
            return Err(BatchRejected {
                reason: "empty batch".into(),
            });
        }
        if m.cols_count() != expected_dim {
            return Err(BatchRejected {
                reason: format!(
                    "dimension mismatch: got {} columns, expected {expected_dim}",
                    m.cols_count()
                ),
            });
        }
        if let Some(i) = m.as_slice().iter().position(|v| !v.is_finite()) {
            let (r, c) = (i / m.cols_count(), i % m.cols_count());
            return Err(BatchRejected {
                reason: format!("non-finite value {} at row {r}, col {c}", m.as_slice()[i]),
            });
        }
        Ok(())
    }
}

/// Anything that yields samples in order. Implemented for dataset
/// epochs and for synthetic infinite streams.
pub trait SampleSource: Send {
    /// Feature dimensionality of every sample.
    fn dim(&self) -> usize;
    /// Next sample, or `None` at end of stream.
    fn next_sample(&mut self) -> Option<Vec<f32>>;

    /// Copy the next sample into `out` (length [`SampleSource::dim`])
    /// without allocating; returns `false` at end of stream. The
    /// default delegates to [`SampleSource::next_sample`]; sources with
    /// borrowable storage override it so the producer's fill loop is
    /// allocation-free per sample.
    fn next_into(&mut self, out: &mut [f32]) -> bool {
        match self.next_sample() {
            Some(s) => {
                out.copy_from_slice(&s);
                true
            }
            None => false,
        }
    }
}

/// Replays the rows of a matrix for a fixed number of epochs.
pub struct EpochSource {
    data: Arc<Mat>,
    epochs: usize,
    cursor: usize,
}

impl EpochSource {
    pub fn new(data: Arc<Mat>, epochs: usize) -> Self {
        Self {
            data,
            epochs,
            cursor: 0,
        }
    }
}

impl SampleSource for EpochSource {
    fn dim(&self) -> usize {
        self.data.cols_count()
    }

    fn next_sample(&mut self) -> Option<Vec<f32>> {
        let mut out = vec![0.0; self.data.cols_count()];
        self.next_into(&mut out).then_some(out)
    }

    // The one copy of the epoch-replay cursor logic; `next_sample`
    // wraps it.
    fn next_into(&mut self, out: &mut [f32]) -> bool {
        let total = self.data.rows_count() * self.epochs;
        if self.cursor >= total {
            return false;
        }
        let row = self.cursor % self.data.rows_count();
        self.cursor += 1;
        out.copy_from_slice(self.data.row(row));
        true
    }
}

/// Handle to the producer thread.
pub struct Producer {
    pub handle: JoinHandle<Result<()>>,
    pub backpressure_waits: Arc<AtomicU64>,
    /// Return lane for drained batch buffers (see [`Producer::recycle`]).
    recycle_tx: SyncSender<Vec<f32>>,
}

impl Producer {
    /// Return a drained batch's buffer to the producer for reuse.
    /// Best-effort and never blocking: if the return lane is full or the
    /// producer has exited, the buffer is simply dropped. Once enough
    /// buffers circulate to cover the queue depth, the producer stops
    /// allocating entirely (steady state proven in `tests/alloc_free.rs`).
    pub fn recycle(&self, batch: Batch) {
        let _ = self.recycle_tx.try_send(batch.into_mat().into_vec());
    }
}

/// Spawn a producer thread that chops `source` into `batch`-sized
/// minibatches and pushes them through a bounded channel of depth
/// `queue_depth`. Returns the consumer end plus the producer handle.
pub fn spawn_producer(
    mut source: Box<dyn SampleSource>,
    batch: usize,
    queue_depth: usize,
) -> (Receiver<Batch>, Producer) {
    assert!(batch >= 1 && queue_depth >= 1);
    let (tx, rx): (SyncSender<Batch>, Receiver<Batch>) =
        std::sync::mpsc::sync_channel(queue_depth);
    // Buffer-return lane. Capacity covers every buffer that can be in
    // flight at once (producer's own + queue_depth queued + one at the
    // consumer), so a diligent consumer's `recycle` never drops.
    let (recycle_tx, recycle_rx): (SyncSender<Vec<f32>>, Receiver<Vec<f32>>) =
        std::sync::mpsc::sync_channel(queue_depth + 2);
    let waits = Arc::new(AtomicU64::new(0));
    let waits_clone = waits.clone();
    let handle = std::thread::Builder::new()
        .name("dimred-producer".into())
        .spawn(move || -> Result<()> {
            let dim = source.dim();
            let mut buf: Vec<f32> = vec![0.0; batch * dim];
            let mut rows = 0usize;
            let send = |tx: &SyncSender<Batch>, b: Batch, waits: &AtomicU64| {
                // try_send first so we can count backpressure events,
                // then fall back to the blocking send.
                match tx.try_send(b) {
                    Ok(()) => Ok(()),
                    Err(TrySendError::Full(b)) => {
                        waits.fetch_add(1, Ordering::Relaxed);
                        tx.send(b).map_err(|_| anyhow::anyhow!("consumer hung up"))
                    }
                    Err(TrySendError::Disconnected(_)) => {
                        Err(anyhow::anyhow!("consumer hung up"))
                    }
                }
            };
            // Fill row slots in place (`next_into`) — no per-sample
            // vector. Ownership travels through the channel, so the
            // outgoing buffer must be replaced; the replacement comes
            // from the recycle lane when the consumer returns drained
            // buffers, and is allocated fresh only on a recycle miss.
            // Each miss adds one buffer to circulation, so a recycling
            // consumer reaches an allocation-free steady state after at
            // most queue_depth + 2 batches.
            loop {
                if !source.next_into(&mut buf[rows * dim..(rows + 1) * dim]) {
                    buf.truncate(rows * dim);
                    break;
                }
                rows += 1;
                if rows == batch {
                    let mut fresh = recycle_rx.try_recv().unwrap_or_default();
                    fresh.clear();
                    fresh.resize(batch * dim, 0.0);
                    let full = std::mem::replace(&mut buf, fresh);
                    send(&tx, Batch::Full(Mat::from_vec(rows, dim, full)), &waits_clone)?;
                    rows = 0;
                }
            }
            if rows > 0 {
                let m = Mat::from_vec(rows, dim, buf);
                send(&tx, Batch::Tail(m), &waits_clone)?;
            }
            Ok(())
        })
        .expect("spawning producer thread");
    (
        rx,
        Producer {
            handle,
            backpressure_waits: waits,
            recycle_tx,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, dim: usize) -> Arc<Mat> {
        Arc::new(Mat::from_fn(rows, dim, |i, j| (i * dim + j) as f32))
    }

    #[test]
    fn epoch_source_replays() {
        let mut s = EpochSource::new(mat(3, 2), 2);
        let mut n = 0;
        while s.next_sample().is_some() {
            n += 1;
        }
        assert_eq!(n, 6);
    }

    #[test]
    fn batches_cover_stream_in_order() {
        let src = EpochSource::new(mat(10, 3), 1);
        let (rx, prod) = spawn_producer(Box::new(src), 4, 2);
        let batches: Vec<Batch> = rx.iter().collect();
        prod.handle.join().unwrap().unwrap();
        assert_eq!(batches.len(), 3); // 4 + 4 + 2
        assert!(matches!(batches[0], Batch::Full(_)));
        assert!(matches!(batches[2], Batch::Tail(_)));
        assert_eq!(batches[2].len(), 2);
        // Order preserved: first element of second batch is row 4.
        assert_eq!(batches[1].rows().get(0, 0), 12.0);
    }

    #[test]
    fn exact_multiple_has_no_tail() {
        let src = EpochSource::new(mat(8, 2), 1);
        let (rx, prod) = spawn_producer(Box::new(src), 4, 2);
        let batches: Vec<Batch> = rx.iter().collect();
        prod.handle.join().unwrap().unwrap();
        assert_eq!(batches.len(), 2);
        assert!(batches.iter().all(|b| matches!(b, Batch::Full(_))));
    }

    #[test]
    fn backpressure_counted_when_consumer_slow() {
        // Deterministic stall: with a depth-1 queue and 32 pending
        // batches, the producer is guaranteed to find the queue full.
        // Instead of sleeping an arbitrary 50 ms, hold off consuming
        // until the producer has *recorded* a backpressure wait (the
        // counter is bumped before the blocking send), then drain.
        let src = EpochSource::new(mat(64, 2), 4);
        let (rx, prod) = spawn_producer(Box::new(src), 8, 1);
        while prod.backpressure_waits.load(Ordering::Relaxed) == 0 {
            // Fail fast (not hang) if a regression kills the producer
            // before it ever finds the queue full.
            assert!(
                !prod.handle.is_finished(),
                "producer exited without recording backpressure"
            );
            std::thread::yield_now();
        }
        let mut n = 0;
        for b in rx.iter() {
            n += b.len();
        }
        prod.handle.join().unwrap().unwrap();
        assert_eq!(n, 256);
        assert!(
            prod.backpressure_waits.load(Ordering::Relaxed) > 0,
            "expected backpressure with a stalled consumer"
        );
    }

    #[test]
    fn recycled_buffers_keep_stream_intact() {
        // A consumer that returns every drained buffer must still see
        // the exact stream: recycled storage is re-filled in place, so
        // any stale-data bug would corrupt later batches.
        let src = EpochSource::new(mat(40, 3), 2); // 80 rows → 20 batches
        let (rx, prod) = spawn_producer(Box::new(src), 4, 2);
        let mut seen = 0usize;
        for b in rx.iter() {
            for r in 0..b.len() {
                let row = seen % 40;
                for j in 0..3 {
                    assert_eq!(b.rows().get(r, j), (row * 3 + j) as f32);
                }
                seen += 1;
            }
            prod.recycle(b);
        }
        prod.handle.join().unwrap().unwrap();
        assert_eq!(seen, 80);
    }

    #[test]
    fn validate_rejects_bad_batches_with_reasons() {
        let good = Batch::Full(Mat::from_fn(4, 3, |i, j| (i + j) as f32));
        good.validate(3).unwrap();
        // Wrong dimension.
        let err = good.validate(5).unwrap_err();
        assert!(err.reason.contains("got 3"), "{err}");
        assert!(err.reason.contains("expected 5"), "{err}");
        // Empty.
        let empty = Batch::Full(Mat::from_vec(0, 3, Vec::new()));
        assert!(empty.validate(3).unwrap_err().reason.contains("empty"));
        // NaN / Inf, with the offending coordinate named.
        let mut m = Mat::from_fn(4, 3, |i, j| (i + j) as f32);
        m.set(2, 1, f32::NAN);
        let err = Batch::Tail(m).validate(3).unwrap_err();
        assert!(err.reason.contains("row 2"), "{err}");
        assert!(err.reason.contains("col 1"), "{err}");
        let mut m = Mat::from_fn(4, 3, |i, j| (i + j) as f32);
        m.set(0, 0, f32::NEG_INFINITY);
        assert!(Batch::Full(m).validate(3).is_err());
    }

    #[test]
    fn dropped_consumer_stops_producer() {
        let src = EpochSource::new(mat(1000, 2), 100);
        let (rx, prod) = spawn_producer(Box::new(src), 8, 1);
        drop(rx);
        let result = prod.handle.join().unwrap();
        assert!(result.is_err(), "producer should report the hangup");
    }
}
