//! GHA — Sanger's Generalized Hebbian Algorithm: streaming principal-
//! subspace learning, the missing piece of the paper's whitening stage.
//!
//! # Why this module exists (see EXPERIMENTS.md §Discrepancies)
//!
//! The paper realises dimensionality reduction with the multiplicative
//! recursions Eq. 3 (`W ← W − μ[zzᵀ−I]W`) and Eq. 6. Both have the form
//! `B ← (I − μF)B`, whose row space can only *shrink*: a rectangular
//! (n < m) EASI/whitening stage is pinned to the subspace its
//! initialisation happened to span and can never rotate toward the
//! informative directions of the data. On the waveform task that caps
//! accuracy far below the paper's Table I (the first 8 coordinates
//! cannot even distinguish classes 0 and 1). The paper does not address
//! this; we complete the design with Sanger's rule, whose Hebbian term
//! `y xᵀ` injects the input directly and therefore converges to the
//! *principal* n-subspace — exactly the "whitening" half of the paper's
//! Fig. 2, in the same hardware operation class (adds + multiplies,
//! O(n·m) per sample, pipelineable one sample per clock).
//!
//! Update rule (row-sequential form):
//!
//! ```text
//! y = W x
//! W_i ← W_i + μ y_i (x − Σ_{j ≤ i} y_j W_j)
//! ```
//!
//! At convergence rows of `W` are the leading eigenvectors of the input
//! covariance (orthonormal), `Var(y_i) = λ_i`; dividing by a running
//! variance estimate yields whitened outputs.

use crate::linalg::Mat;

/// Configuration for the GHA whitener.
#[derive(Debug, Clone)]
pub struct GhaConfig {
    pub input_dim: usize,
    pub output_dim: usize,
    /// Hebbian learning rate.
    pub mu: f32,
    /// EMA coefficient for the per-component variance estimate.
    pub var_beta: f32,
    /// Per-sample relative step clip (like the EASI trainer's).
    pub clip: f32,
    /// Seed for the random orthonormal init.
    pub seed: u64,
}

impl Default for GhaConfig {
    fn default() -> Self {
        Self {
            input_dim: 32,
            output_dim: 8,
            mu: 5e-3,
            var_beta: 5e-3,
            clip: 0.1,
            seed: 2018,
        }
    }
}

/// Streaming principal-subspace whitener.
#[derive(Debug, Clone)]
pub struct GhaWhitener {
    pub config: GhaConfig,
    /// Weight matrix `W (n×m)`; rows converge to leading eigenvectors.
    w: Mat,
    /// Running estimate of `E[y_i²]` (the eigenvalue λ_i at
    /// convergence), used for the whitening division.
    var: Vec<f32>,
    steps: u64,
    // scratch
    y: Vec<f32>,
    cum: Vec<f32>,
    delta: Vec<f32>,
}

impl GhaWhitener {
    pub fn new(config: GhaConfig) -> Self {
        assert!(config.input_dim >= config.output_dim && config.output_dim >= 1);
        assert!(config.mu > 0.0 && config.var_beta > 0.0);
        let w = crate::easi::random_orthonormal(config.output_dim, config.input_dim, config.seed);
        let (n, m) = (config.output_dim, config.input_dim);
        Self {
            config,
            w,
            var: vec![1.0; n],
            steps: 0,
            y: vec![0.0; n],
            cum: vec![0.0; m],
            delta: vec![0.0; n * m],
        }
    }

    /// The subspace matrix `W (n×m)`.
    pub fn subspace(&self) -> &Mat {
        &self.w
    }

    /// Current per-component variance estimates (λ̂).
    pub fn variances(&self) -> &[f32] {
        &self.var
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// One streaming update; returns nothing (use [`Self::project`] /
    /// [`Self::whiten`] for outputs).
    pub fn step(&mut self, x: &[f32]) {
        let (n, m) = self.w.shape();
        assert_eq!(x.len(), m, "gha step shape mismatch");
        let mu = self.config.mu;

        // y = Wx
        for i in 0..n {
            self.y[i] = crate::linalg::dot(self.w.row(i), x);
        }
        // Row-sequential Sanger deltas with the cumulative reconstruction
        // c_i = Σ_{j<=i} y_j W_j built incrementally.
        self.cum.iter_mut().for_each(|c| *c = 0.0);
        let mut delta2 = 0.0f64;
        let mut w_norm2 = 0.0f64;
        for i in 0..n {
            let yi = self.y[i];
            let row = self.w.row(i);
            for j in 0..m {
                self.cum[j] += yi * row[j];
                let d = mu * yi * (x[j] - self.cum[j]);
                self.delta[i * m + j] = d;
                delta2 += (d as f64) * (d as f64);
                w_norm2 += (row[j] as f64) * (row[j] as f64);
            }
        }
        // Relative clip, as in the EASI trainer.
        let mut scale = 1.0f32;
        if self.config.clip > 0.0 {
            let limit = self.config.clip as f64 * w_norm2.sqrt();
            let dn = delta2.sqrt();
            if dn > limit {
                scale = (limit / dn) as f32;
            }
        }
        for (wij, &dij) in self.w.as_mut_slice().iter_mut().zip(self.delta.iter()) {
            *wij += scale * dij;
        }
        // Variance EMA.
        let beta = self.config.var_beta;
        for (v, &yi) in self.var.iter_mut().zip(&self.y) {
            *v = (1.0 - beta) * *v + beta * yi * yi;
        }
        self.steps += 1;
    }

    /// Consume every row of a sample matrix.
    pub fn step_rows(&mut self, x: &Mat) {
        for i in 0..x.rows_count() {
            self.step(x.row(i));
        }
    }

    /// Project (no variance normalisation): `y = Wx`.
    pub fn project(&self, x: &[f32]) -> Vec<f32> {
        self.w.matvec(x)
    }

    /// Whiten: `z_i = (Wx)_i / √λ̂_i`.
    pub fn whiten(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0f32; self.w.rows_count()];
        self.whiten_into(x, &mut y);
        y
    }

    /// [`GhaWhitener::whiten`] into a caller-owned buffer — identical
    /// arithmetic, no per-sample allocation (the composed unit's hot
    /// path stages through its scratch buffer with this).
    pub fn whiten_into(&self, x: &[f32], out: &mut [f32]) {
        self.w.matvec_into(x, out);
        for (o, &v) in out.iter_mut().zip(&self.var) {
            *o /= v.max(1e-9).sqrt();
        }
    }

    /// The whitening transform as a dense matrix `diag(λ̂^{-1/2}) W`.
    pub fn whitening_matrix(&self) -> Mat {
        let (n, m) = self.w.shape();
        Mat::from_fn(n, m, |i, j| self.w.get(i, j) / self.var[i].max(1e-9).sqrt())
    }

    /// Restore state (checkpoint / PJRT round-trip). `steps` is part of
    /// the state: schedules keyed on the step count (the composed
    /// unit's rotation warm-up, coefficient-refresh cadences) must
    /// resume where the checkpoint left off, not restart from zero.
    pub fn set_state(&mut self, w: Mat, var: Vec<f32>, steps: u64) {
        assert_eq!(w.shape(), self.w.shape(), "gha W shape");
        assert_eq!(var.len(), self.var.len(), "gha var length");
        self.w = w;
        self.var = var;
        self.steps = steps;
    }

    /// Mean absolute row-orthonormality error of `W` (→ 0 at
    /// convergence).
    pub fn orthonormality_error(&self) -> f64 {
        let (n, _) = self.w.shape();
        let mut err = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                let d = crate::linalg::dot(self.w.row(i), self.w.row(j)) as f64;
                let want = if i == j { 1.0 } else { 0.0 };
                err += (d - want).abs();
            }
        }
        err / (n * n) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{dot, whiteness_error};
    use crate::pca::BatchPca;
    use crate::rng::{Pcg64, RngExt};

    /// Data with a dominant 2-D structure embedded in 6-D noise.
    fn structured(samples: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed(seed);
        let mut data = Vec::with_capacity(samples * 6);
        for _ in 0..samples {
            let a = rng.next_gaussian() as f32 * 3.0;
            let b = rng.next_gaussian() as f32 * 2.0;
            for j in 0..6 {
                let signal = match j {
                    0 | 1 => a * if j == 0 { 0.8 } else { 0.6 },
                    2 | 3 => b * if j == 2 { 0.7 } else { -0.7 },
                    _ => 0.0,
                };
                data.push(signal + 0.3 * rng.next_gaussian() as f32);
            }
        }
        Mat::from_vec(samples, 6, data)
    }

    #[test]
    fn converges_to_principal_subspace() {
        let x = structured(6000, 71);
        let mut gha = GhaWhitener::new(GhaConfig {
            input_dim: 6,
            output_dim: 2,
            ..Default::default()
        });
        for _ in 0..6 {
            gha.step_rows(&x);
        }
        // Compare against batch PCA: the learned rows must lie in the
        // top-2 eigenvector span.
        let pca = BatchPca::fit(&x, 2);
        for i in 0..2 {
            let wi = gha.subspace().row(i);
            let proj: f32 = (0..2)
                .map(|k| dot(wi, pca.components.row(k)).powi(2))
                .sum();
            let total = dot(wi, wi);
            assert!(
                proj / total > 0.95,
                "row {i}: only {:.2} of its mass in the principal plane",
                proj / total
            );
        }
        assert!(gha.orthonormality_error() < 0.05);
    }

    #[test]
    fn whitened_outputs_are_white() {
        let x = structured(8000, 72);
        let mut gha = GhaWhitener::new(GhaConfig {
            input_dim: 6,
            output_dim: 2,
            ..Default::default()
        });
        for _ in 0..8 {
            gha.step_rows(&x);
        }
        let z = Mat::from_fn(x.rows_count(), 2, |i, j| gha.whiten(x.row(i))[j]);
        let w = whiteness_error(&z);
        assert!(w < 0.15, "whiteness {w}");
    }

    #[test]
    fn variance_estimates_track_eigenvalues() {
        let x = structured(8000, 73);
        let mut gha = GhaWhitener::new(GhaConfig {
            input_dim: 6,
            output_dim: 2,
            ..Default::default()
        });
        for _ in 0..8 {
            gha.step_rows(&x);
        }
        let pca = BatchPca::fit(&x, 2);
        for i in 0..2 {
            let rel = (gha.variances()[i] as f64 - pca.eigenvalues[i]).abs()
                / pca.eigenvalues[i];
            assert!(
                rel < 0.3,
                "λ̂_{i} = {} vs λ_{i} = {}",
                gha.variances()[i],
                pca.eigenvalues[i]
            );
        }
    }

    #[test]
    fn escapes_bad_initial_subspace() {
        // The whole point vs multiplicative whitening: start from a
        // subspace orthogonal to the signal, verify it still finds it.
        let x = structured(6000, 74);
        let mut gha = GhaWhitener::new(GhaConfig {
            input_dim: 6,
            output_dim: 2,
            seed: 99, // random init; signal lives in dims 0-3
            ..Default::default()
        });
        // Force the degenerate init: rows on the pure-noise axes 4, 5.
        gha.w = Mat::from_fn(2, 6, |i, j| if j == i + 4 { 1.0 } else { 0.0 });
        for _ in 0..8 {
            gha.step_rows(&x);
        }
        let pca = BatchPca::fit(&x, 2);
        let w0 = gha.subspace().row(0);
        let proj: f32 = (0..2).map(|k| dot(w0, pca.components.row(k)).powi(2)).sum();
        assert!(
            proj / dot(w0, w0) > 0.9,
            "GHA failed to escape the noise subspace"
        );
    }

    #[test]
    fn set_state_round_trips_steps() {
        // Regression: set_state used to restore W and λ̂ but not the
        // step count, so a restored whitener reported a stale steps()
        // (and step-keyed schedules restarted from zero).
        let x = structured(1000, 76);
        let mut gha = GhaWhitener::new(GhaConfig::default_for(6, 2));
        gha.step_rows(&x);
        assert_eq!(gha.steps(), 1000);
        let (w, var, steps) = (
            gha.subspace().clone(),
            gha.variances().to_vec(),
            gha.steps(),
        );
        let mut restored = GhaWhitener::new(GhaConfig::default_for(6, 2));
        assert_eq!(restored.steps(), 0);
        restored.set_state(w.clone(), var.clone(), steps);
        assert_eq!(restored.steps(), 1000, "steps must survive the round trip");
        assert_eq!(restored.subspace().as_slice(), w.as_slice());
        assert_eq!(restored.variances(), &var[..]);
        // The restored whitener continues identically to the original.
        let probe = structured(50, 77);
        gha.step_rows(&probe);
        restored.step_rows(&probe);
        assert_eq!(gha.steps(), restored.steps());
        assert_eq!(gha.subspace().as_slice(), restored.subspace().as_slice());
    }

    #[test]
    fn deterministic() {
        let x = structured(500, 75);
        let run = || {
            let mut g = GhaWhitener::new(GhaConfig::default_for(6, 2));
            g.step_rows(&x);
            g.subspace().clone()
        };
        assert_eq!(run().as_slice(), run().as_slice());
    }
}

impl GhaConfig {
    /// Convenience constructor used in tests/examples.
    pub fn default_for(input_dim: usize, output_dim: usize) -> Self {
        Self {
            input_dim,
            output_dim,
            ..Default::default()
        }
    }
}
