//! The downstream classifier of §V.B: an artificial neural network with
//! two hidden layers (64 neurons each), ReLU activations and a softmax
//! cross-entropy output, trained with SGD + momentum.
//!
//! Native Rust implementation — used for the Table I / Fig. 1 accuracy
//! experiments and as the oracle for the AOT-compiled JAX variant.

use crate::linalg::Mat;
use crate::rng::{Pcg64, RngExt};

/// Architecture + optimiser hyper-parameters.
#[derive(Debug, Clone)]
pub struct MlpConfig {
    pub input_dim: usize,
    pub hidden_dim: usize,
    pub num_classes: usize,
    pub lr: f32,
    pub momentum: f32,
    pub batch_size: usize,
    pub epochs: usize,
    pub seed: u64,
}

impl MlpConfig {
    /// The paper's classifier: two hidden layers, 64 neurons each.
    pub fn paper(input_dim: usize, num_classes: usize) -> Self {
        Self {
            input_dim,
            hidden_dim: 64,
            num_classes,
            lr: 0.05,
            momentum: 0.9,
            batch_size: 32,
            epochs: 30,
            seed: 2018,
        }
    }
}

/// One dense layer with SGD-momentum state.
#[derive(Debug, Clone)]
struct Layer {
    w: Mat,       // out×in
    b: Vec<f32>,  // out
    vw: Mat,      // momentum buffers
    vb: Vec<f32>,
}

impl Layer {
    fn new(inp: usize, out: usize, rng: &mut Pcg64) -> Self {
        // He initialisation (ReLU network).
        let std = (2.0 / inp as f64).sqrt();
        Self {
            w: Mat::from_fn(out, inp, |_, _| (rng.next_gaussian() * std) as f32),
            b: vec![0.0; out],
            vw: Mat::zeros(out, inp),
            vb: vec![0.0; out],
        }
    }

    fn forward(&self, x: &[f32], out: &mut Vec<f32>) {
        out.clear();
        for (row, &bias) in self.w.rows().zip(&self.b) {
            out.push(crate::linalg::dot(row, x) + bias);
        }
    }
}

/// The 2-hidden-layer MLP classifier.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub config: MlpConfig,
    l1: Layer,
    l2: Layer,
    l3: Layer,
}

/// Per-epoch training record, surfaced to EXPERIMENTS.md logging.
#[derive(Debug, Clone, Copy)]
pub struct EpochStats {
    pub epoch: usize,
    pub mean_loss: f64,
    pub train_accuracy: f64,
}

impl Mlp {
    pub fn new(config: MlpConfig) -> Self {
        let mut rng = Pcg64::seed_stream(config.seed, 0x4D4C_5057); // "MLPW"
        let l1 = Layer::new(config.input_dim, config.hidden_dim, &mut rng);
        let l2 = Layer::new(config.hidden_dim, config.hidden_dim, &mut rng);
        let l3 = Layer::new(config.hidden_dim, config.num_classes, &mut rng);
        Self { config, l1, l2, l3 }
    }

    /// Class logits for one sample.
    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        let mut h1 = Vec::new();
        let mut h2 = Vec::new();
        let mut out = Vec::new();
        self.l1.forward(x, &mut h1);
        relu(&mut h1);
        self.l2.forward(&h1, &mut h2);
        relu(&mut h2);
        self.l3.forward(&h2, &mut out);
        out
    }

    /// Most likely class.
    pub fn predict(&self, x: &[f32]) -> usize {
        argmax(&self.logits(x))
    }

    /// Accuracy on a labelled sample matrix.
    pub fn accuracy(&self, x: &Mat, y: &[usize]) -> f64 {
        let mut correct = 0usize;
        for (r, &label) in x.rows().zip(y) {
            if self.predict(r) == label {
                correct += 1;
            }
        }
        correct as f64 / y.len().max(1) as f64
    }

    /// Train with SGD + momentum on minibatches; returns per-epoch stats.
    pub fn train(&mut self, x: &Mat, y: &[usize]) -> Vec<EpochStats> {
        assert_eq!(x.rows_count(), y.len());
        let n = y.len();
        let mut order: Vec<usize> = (0..n).collect();
        let mut rng = Pcg64::seed_stream(self.config.seed, 0x4D4C_5053); // "MLPS"
        let mut stats = Vec::with_capacity(self.config.epochs);
        for epoch in 0..self.config.epochs {
            rng.shuffle(&mut order);
            let mut loss_sum = 0.0f64;
            for chunk in order.chunks(self.config.batch_size) {
                loss_sum += self.train_batch(x, y, chunk);
            }
            stats.push(EpochStats {
                epoch,
                mean_loss: loss_sum / (n as f64 / self.config.batch_size as f64).max(1.0),
                train_accuracy: self.accuracy(x, y),
            });
        }
        stats
    }

    /// One minibatch step; returns the summed batch loss.
    fn train_batch(&mut self, x: &Mat, y: &[usize], idx: &[usize]) -> f64 {
        let cfg = &self.config;
        let (h, c) = (cfg.hidden_dim, cfg.num_classes);
        // Gradient accumulators.
        let mut g1 = Mat::zeros(h, cfg.input_dim);
        let mut gb1 = vec![0.0f32; h];
        let mut g2 = Mat::zeros(h, h);
        let mut gb2 = vec![0.0f32; h];
        let mut g3 = Mat::zeros(c, h);
        let mut gb3 = vec![0.0f32; c];
        let mut loss = 0.0f64;

        let mut h1 = Vec::new();
        let mut h2 = Vec::new();
        let mut logits = Vec::new();
        for &i in idx {
            let xi = x.row(i);
            // Forward, keeping pre-ReLU masks via the activations.
            self.l1.forward(xi, &mut h1);
            relu(&mut h1);
            self.l2.forward(&h1, &mut h2);
            relu(&mut h2);
            self.l3.forward(&h2, &mut logits);
            let probs = softmax(&logits);
            loss -= (probs[y[i]].max(1e-12) as f64).ln();

            // Backward. dL/dlogits = p − onehot.
            let mut d3: Vec<f32> = probs;
            d3[y[i]] -= 1.0;
            for (k, &dk) in d3.iter().enumerate() {
                gb3[k] += dk;
                let row = g3.row_mut(k);
                for (r, &h2j) in row.iter_mut().zip(&h2) {
                    *r += dk * h2j;
                }
            }
            // d2 = (W3ᵀ d3) ⊙ relu'(h2)
            let mut d2 = self.l3.w.matvec_t(&d3);
            for (d, &a) in d2.iter_mut().zip(&h2) {
                if a <= 0.0 {
                    *d = 0.0;
                }
            }
            for (k, &dk) in d2.iter().enumerate() {
                gb2[k] += dk;
                let row = g2.row_mut(k);
                for (r, &h1j) in row.iter_mut().zip(&h1) {
                    *r += dk * h1j;
                }
            }
            // d1 = (W2ᵀ d2) ⊙ relu'(h1)
            let mut d1 = self.l2.w.matvec_t(&d2);
            for (d, &a) in d1.iter_mut().zip(&h1) {
                if a <= 0.0 {
                    *d = 0.0;
                }
            }
            for (k, &dk) in d1.iter().enumerate() {
                gb1[k] += dk;
                let row = g1.row_mut(k);
                for (r, &xj) in row.iter_mut().zip(xi) {
                    *r += dk * xj;
                }
            }
        }

        // SGD + momentum (scaled by batch size).
        let scale = 1.0 / idx.len() as f32;
        let (lr, mom) = (cfg.lr, cfg.momentum);
        for (layer, gw, gb) in [
            (&mut self.l1, &g1, &gb1),
            (&mut self.l2, &g2, &gb2),
            (&mut self.l3, &g3, &gb3),
        ] {
            for ((vw, w), &g) in layer
                .vw
                .as_mut_slice()
                .iter_mut()
                .zip(layer.w.as_mut_slice())
                .zip(gw.as_slice())
            {
                *vw = mom * *vw - lr * g * scale;
                *w += *vw;
            }
            for ((vb, b), &g) in layer.vb.iter_mut().zip(&mut layer.b).zip(gb) {
                *vb = mom * *vb - lr * g * scale;
                *b += *vb;
            }
        }
        loss
    }
}

#[inline]
fn relu(v: &mut [f32]) {
    for x in v {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

#[inline]
fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best
}

/// Numerically-stable softmax.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| (l - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two well-separated Gaussian blobs.
    fn blobs(n: usize, seed: u64) -> (Mat, Vec<usize>) {
        let mut rng = Pcg64::seed(seed);
        let mut xs = Vec::with_capacity(n * 2);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.next_below(2) as usize;
            let cx = if c == 0 { -2.0 } else { 2.0 };
            xs.push(cx + rng.next_gaussian() as f32 * 0.5);
            xs.push(-cx + rng.next_gaussian() as f32 * 0.5);
            ys.push(c);
        }
        (Mat::from_vec(n, 2, xs), ys)
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_stable_for_large_logits() {
        let p = softmax(&[1000.0, 1001.0]);
        assert!(p.iter().all(|v| v.is_finite()));
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn learns_separable_blobs() {
        let (x, y) = blobs(600, 61);
        let mut mlp = Mlp::new(MlpConfig {
            epochs: 15,
            ..MlpConfig::paper(2, 2)
        });
        let stats = mlp.train(&x, &y);
        let acc = mlp.accuracy(&x, &y);
        assert!(acc > 0.97, "train accuracy {acc}");
        // Loss decreased.
        assert!(stats.last().unwrap().mean_loss < stats[0].mean_loss);
    }

    #[test]
    fn learns_xor_nonlinear() {
        // XOR requires the hidden layers — a linear model can't do it.
        let mut rng = Pcg64::seed(62);
        let n = 800;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..n {
            let a = rng.next_f32() * 2.0 - 1.0;
            let b = rng.next_f32() * 2.0 - 1.0;
            xs.push(a);
            xs.push(b);
            ys.push(usize::from((a > 0.0) != (b > 0.0)));
        }
        let x = Mat::from_vec(n, 2, xs);
        let mut mlp = Mlp::new(MlpConfig {
            epochs: 60,
            lr: 0.1,
            ..MlpConfig::paper(2, 2)
        });
        mlp.train(&x, &ys);
        let acc = mlp.accuracy(&x, &ys);
        assert!(acc > 0.9, "XOR accuracy {acc}");
    }

    #[test]
    fn deterministic_training() {
        let (x, y) = blobs(200, 63);
        let train = || {
            let mut m = Mlp::new(MlpConfig {
                epochs: 3,
                ..MlpConfig::paper(2, 2)
            });
            m.train(&x, &y);
            m.accuracy(&x, &y)
        };
        assert_eq!(train(), train());
    }

    #[test]
    fn predict_in_class_range() {
        let mlp = Mlp::new(MlpConfig::paper(4, 3));
        assert!(mlp.predict(&[0.1, 0.2, 0.3, 0.4]) < 3);
    }
}
