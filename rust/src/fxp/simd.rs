//! Width-aware blocked accumulation for the fixed-point hot path —
//! the software analogue of the paper's DSP-cascade dot products.
//!
//! The scalar reference kernels ([`super::FxpSpec::dot_raw`], the
//! [`super::FxpMat`] matvecs, the EASI gradient pass) accumulate every
//! product in `i128`: exact, but each MAC is a wide multiword add the
//! compiler cannot vectorize. This module exploits the Q-format width
//! bound instead: raw words are `B ≤ 32` bits, so every product fits in
//! `2B − 1` bits and up to [`block_len`]`(B)` of them sum *exactly* in
//! an `i64` lane. The kernels therefore run the multiply-accumulate in
//! plain `i64` lanes — which LLVM keeps in integer vector registers —
//! and spill into the `i128` accumulator only once per block.
//!
//! **Bit-identity.** Every partial is exact (no lane can overflow by
//! construction) and integer addition is associative, so the final
//! `i128` sum — and hence the rounded, fitted word, and every
//! saturation/wrap telemetry event — is identical to the scalar walk
//! for all formats, overflow policies, and rounding modes. The grid in
//! `tests/simd_identity.rs` and `tests/stage_graph_identity.rs` proves
//! it, and the bench's preflight re-proves it before timing anything.
//!
//! **Dispatch.** The blocked kernels are compiled in only with the
//! `simd` cargo feature; [`set_force_scalar`] additionally lets a
//! `simd` build select the scalar reference at run time, so one process
//! can measure scalar-vs-simd pairs (`dimred bench`) or cross-check the
//! two paths against each other.

use std::sync::atomic::{AtomicBool, Ordering};

/// Unrolled lane count of the inner loop. Eight `i64` lanes span two
/// AVX2 / four NEON vector registers — wide enough to saturate the
/// integer multiply pipes, small enough to leave room for the per-row
/// blocking above it.
pub(crate) const LANES: usize = 8;

static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Whether the crate was built with the `simd` feature.
#[inline]
pub fn available() -> bool {
    cfg!(feature = "simd")
}

/// Whether dispatch selects the blocked kernels right now (feature
/// compiled in and not overridden by [`set_force_scalar`]).
#[inline]
pub fn enabled() -> bool {
    available() && !FORCE_SCALAR.load(Ordering::Relaxed)
}

/// Force the scalar reference kernels even in a `simd` build — the
/// bench uses this to time scalar-vs-simd row pairs and to run the
/// bit-identity preflight inside one process. No-op (already scalar)
/// without the feature. Global: flip it only from single-threaded
/// control code, never mid-tile.
pub fn set_force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::Relaxed);
}

/// How many products of `width`-bit words one `i64` lane can sum
/// exactly: |a·b| ≤ 2^(2B−2) (the −2^(B−1) · −2^(B−1) corner), so the
/// lane holds `⌊i64::MAX / 2^(2B−2)⌋` of them before any spill is
/// needed. For B = 32 (`q16.16`-class words) that is exactly 1 — every
/// product spills — and for B ≤ 16 it is astronomically large, clamped
/// to 2^16 so blocks stay cache-resident.
#[inline]
pub(crate) fn block_len(width: u32) -> usize {
    let shift = (2 * width).saturating_sub(2).min(126);
    (((i64::MAX as u128) >> shift) as usize).clamp(1, 1 << 16)
}

/// Exact Σ aᵢ·bᵢ as `i128`, computed in blocked `i64` lanes.
/// Bit-identical to the scalar `i128` walk (every partial is exact and
/// integer addition is associative); the caller applies the same
/// rescale/fit epilogue either way, so rounding and telemetry events
/// are untouched.
pub(crate) fn dot_acc(a: &[i32], b: &[i32], width: u32) -> i128 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let main = n - n % LANES;
    let block = block_len(width) * LANES;
    let mut acc: i128 = 0;
    let mut lanes = [0i64; LANES];
    let mut start = 0usize;
    while start < main {
        let end = (start + block).min(main);
        let mut j = start;
        while j < end {
            for l in 0..LANES {
                lanes[l] += a[j + l] as i64 * b[j + l] as i64;
            }
            j += LANES;
        }
        for l in lanes.iter_mut() {
            acc += *l as i128;
            *l = 0;
        }
        start = end;
    }
    for j in main..n {
        acc += a[j] as i128 * b[j] as i128;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scalar_acc(a: &[i32], b: &[i32]) -> i128 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| x as i128 * y as i128)
            .sum()
    }

    #[test]
    fn block_len_matches_width_bound() {
        // B = 32: |product| can be 2^62, so one product per lane.
        assert_eq!(block_len(32), 1);
        // B = 24: 2^46 per product → ⌊(2^63−1)/2^46⌋ = 2^17 − 1,
        // clamped to 2^16.
        assert_eq!(block_len(24), 1 << 16);
        // Narrow words hit the cache clamp.
        assert_eq!(block_len(16), 1 << 16);
        assert_eq!(block_len(8), 1 << 16);
    }

    #[test]
    fn blocked_sum_is_exact_at_the_extremes() {
        // All-extremal 32-bit words: every product is 2^62, the corner
        // the block bound exists for. 1000 of them overflow i64 by a
        // factor of ~250 — only exact blocking survives.
        let a = vec![i32::MIN; 1000];
        let b = vec![i32::MIN; 1000];
        assert_eq!(dot_acc(&a, &b, 32), scalar_acc(&a, &b));
        let c = vec![i32::MAX; 1000];
        assert_eq!(dot_acc(&a, &c, 32), scalar_acc(&a, &c));
    }

    #[test]
    fn blocked_sum_matches_scalar_across_lengths() {
        // Lengths straddling every lane/tail boundary.
        for n in [0usize, 1, 7, 8, 9, 15, 16, 17, 63, 64, 65, 257] {
            let a: Vec<i32> = (0..n)
                .map(|i| ((i as i64 * 2654435761 + 12345) as i32).wrapping_mul(31))
                .collect();
            let b: Vec<i32> = (0..n)
                .map(|i| ((i as i64 * 40503 + 99) as i32).wrapping_mul(-17))
                .collect();
            for width in [8u32, 16, 24, 32] {
                assert_eq!(
                    dot_acc(&a, &b, width),
                    scalar_acc(&a, &b),
                    "n={n} width={width}"
                );
            }
        }
    }
}
