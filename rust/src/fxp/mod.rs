//! Bit-accurate fixed-point arithmetic — the numeric substrate of the
//! FPGA datapath.
//!
//! The paper's resource savings come from a hardware-friendly datapath;
//! on a real FPGA that datapath computes in two's-complement fixed
//! point, not fp32 (an 18-bit multiply fits half an Arria-10 DSP, a
//! fixed-point add is a bare ALM carry chain — see
//! [`crate::hwmodel`]). This module simulates that arithmetic exactly:
//!
//! * [`QFormat`] — a Qi.f format: `i` integer bits (sign included, ARM
//!   convention) and `f` fraction bits, total width `i + f ≤ 32`.
//!   Q1.15 is the classic 16-bit audio/DSP format, range `[-1, 1)`.
//! * [`FxpSpec`] — a format plus overflow ([`Overflow::Saturate`] vs
//!   [`Overflow::Wrap`]) and rounding ([`Rounding::Nearest`] vs
//!   [`Rounding::Truncate`]) policies. All scalar/vector ops live here,
//!   on raw `i32` words with `i64`/`i128` intermediates, mirroring the
//!   wide DSP accumulators of the hardware.
//! * [`FxpConst`] — a block-scaled constant (learning rates, RP scale,
//!   whitening coefficients): the raw value carries its own fraction
//!   count, chosen to maximise precision, exactly as constants are
//!   baked into FPGA multiplier inputs.
//! * [`FxpMat`] ([`mat`]) — a quantized row-major matrix compatible
//!   with [`crate::linalg::Mat`] via `quantize`/`dequantize`.
//! * [`kernels`] — quantized forward + update kernels for the three DR
//!   stages (RP, GHA whitening, rotation-only EASI) and their composed
//!   unit, selected through [`Precision`] in `PipelineSpec` /
//!   `ExperimentConfig` / the CLI.
//!
//! Rounding semantics follow the common DSP datapath: "nearest" is
//! add-half-then-truncate (ties toward +∞), "truncate" is an arithmetic
//! right shift (toward −∞). Saturation clamps to the format's range;
//! wrapping keeps the low `width` bits with sign extension.
//!
//! # Mixed precision ([`PrecisionPlan`])
//!
//! A fixed-point pipeline carries one [`FxpSpec`] *per stage*: the RP
//! accumulator, the whitener and the rotation each get their own Q
//! format, as real datapaths do (wide RP accumulators for headroom,
//! narrow rotation because its inputs are σ-normalised). Raw words
//! crossing a stage boundary are requantized by a pure shift plus the
//! destination's rounding/overflow policy
//! ([`FxpSpec::requantize_from`]); when the two formats match the
//! boundary is a bit-exact no-op, so a uniform plan behaves exactly
//! like the single-format datapath. The CLI syntax is
//! `--precision rp=q8.16,whiten=q4.12,rot=q1.15[,qat=ste]`.
//!
//! # Quantization-aware training ([`QuantMode`])
//!
//! * [`QuantMode::BitExact`] — updates run in the integer datapath too:
//!   the bit-exact image of on-chip *training* hardware. At narrow
//!   widths the per-step update underflows the format's resolution and
//!   learning stalls — faithful, but a real limitation of deploying
//!   training at low precision.
//! * [`QuantMode::Ste`] — straight-through-estimator QAT: the forward
//!   path (projections, nonlinearity, every activation) still runs the
//!   quantized datapath, so the trained model *is* the deployed
//!   fixed-point model; the update is computed from those quantized
//!   forward values in f32 and applied to f32 shadow weights, which are
//!   requantized into the datapath after every step. The identity
//!   gradient is passed "straight through" the quantizer — updates
//!   smaller than one LSB accumulate in the shadow instead of rounding
//!   to zero. This is how the paper's "no accuracy degradation at
//!   reduced precision" claim is actually achieved at deployment
//!   widths.

pub mod kernels;
pub mod mat;
pub mod simd;

pub use kernels::{FxpDrUnit, FxpEasiRot, FxpGha, FxpRp, FxpUnitConfig, Scratch};
pub use mat::FxpMat;

use anyhow::{bail, Result};

/// A Qi.f fixed-point format. `int_bits` includes the sign bit (ARM
/// convention), so the total word width is `int_bits + frac_bits` and
/// the representable range is `[-2^(i-1), 2^(i-1) - 2^-f]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QFormat {
    /// Integer bits, sign included. At least 1.
    pub int_bits: u8,
    /// Fraction bits.
    pub frac_bits: u8,
}

impl QFormat {
    pub fn new(int_bits: u8, frac_bits: u8) -> Self {
        assert!(int_bits >= 1, "need at least the sign bit");
        assert!(
            int_bits as u32 + frac_bits as u32 >= 2
                && int_bits as u32 + frac_bits as u32 <= 32,
            "Q{int_bits}.{frac_bits}: width must be in 2..=32"
        );
        Self {
            int_bits,
            frac_bits,
        }
    }

    /// Total word width in bits.
    pub fn width(&self) -> u8 {
        self.int_bits + self.frac_bits
    }

    /// Largest representable raw word.
    pub fn max_raw(&self) -> i32 {
        ((1i64 << (self.width() - 1)) - 1) as i32
    }

    /// Smallest representable raw word.
    pub fn min_raw(&self) -> i32 {
        (-(1i64 << (self.width() - 1))) as i32
    }

    /// One least-significant bit, as a real value.
    pub fn resolution(&self) -> f32 {
        (2.0f32).powi(-(self.frac_bits as i32))
    }

    /// Largest representable real value.
    pub fn max_value(&self) -> f32 {
        self.max_raw() as f32 * self.resolution()
    }

    /// Smallest representable real value.
    pub fn min_value(&self) -> f32 {
        self.min_raw() as f32 * self.resolution()
    }
}

/// What happens when a result exceeds the format's range.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Overflow {
    /// Clamp to the representable range (the usual DSP choice).
    Saturate,
    /// Keep the low `width` bits, sign-extended (free in hardware,
    /// catastrophic numerically — provided for bit-exact modelling of
    /// designs that do it).
    Wrap,
}

/// How extra fraction bits are discarded after a multiply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rounding {
    /// Add half an LSB then truncate (ties toward +∞) — one adder.
    Nearest,
    /// Arithmetic right shift (toward −∞) — free.
    Truncate,
}

/// A complete fixed-point arithmetic specification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FxpSpec {
    pub format: QFormat,
    pub overflow: Overflow,
    pub rounding: Rounding,
}

impl FxpSpec {
    /// Saturating, round-to-nearest Qi.f — the datapath default.
    pub fn q(int_bits: u8, frac_bits: u8) -> Self {
        Self {
            format: QFormat::new(int_bits, frac_bits),
            overflow: Overflow::Saturate,
            rounding: Rounding::Nearest,
        }
    }

    /// Fit a wide intermediate into the format per the overflow policy.
    ///
    /// This is the single overflow choke point of the datapath — every
    /// quantize/add/sub/mul/dot funnels through it — so it is also
    /// where telemetry observes numeric health: an actual overflow
    /// bumps this thread's saturation/wrap counter
    /// ([`crate::telemetry::events`]). In-range values pay nothing
    /// beyond the range compare the policy already performs.
    #[inline]
    pub fn fit(&self, v: i64) -> i32 {
        let (lo, hi) = (self.format.min_raw() as i64, self.format.max_raw() as i64);
        match self.overflow {
            Overflow::Saturate => {
                if v < lo || v > hi {
                    crate::telemetry::events::note_sat();
                }
                v.clamp(lo, hi) as i32
            }
            Overflow::Wrap => {
                let w = self.format.width() as u32;
                let wrapped = (v << (64 - w)) >> (64 - w);
                if wrapped != v {
                    crate::telemetry::events::note_wrap();
                }
                wrapped as i32
            }
        }
    }

    /// Discard `shift` fraction bits per the rounding policy.
    #[inline]
    fn rescale(&self, p: i64, shift: u32) -> i64 {
        if shift == 0 {
            return p;
        }
        match self.rounding {
            Rounding::Nearest => (p + (1i64 << (shift - 1))) >> shift,
            Rounding::Truncate => p >> shift,
        }
    }

    #[inline]
    fn rescale_wide(&self, p: i128, shift: u32) -> i64 {
        if shift == 0 {
            return p.clamp(i64::MIN as i128, i64::MAX as i128) as i64;
        }
        let r = match self.rounding {
            Rounding::Nearest => (p + (1i128 << (shift - 1))) >> shift,
            Rounding::Truncate => p >> shift,
        };
        r.clamp(i64::MIN as i128, i64::MAX as i128) as i64
    }

    /// Quantize a real value to a raw word. NaN maps to 0; ±∞ saturate.
    pub fn quantize(&self, x: f32) -> i32 {
        if x.is_nan() {
            return 0;
        }
        if x.is_infinite() {
            // An infinite input is a saturation by definition.
            crate::telemetry::events::note_sat();
            return if x > 0.0 {
                self.format.max_raw()
            } else {
                self.format.min_raw()
            };
        }
        let scaled = x as f64 * (2.0f64).powi(self.format.frac_bits as i32);
        let r = match self.rounding {
            // Add-half-then-floor: ties toward +∞, bit-identical to the
            // datapath's `rescale` so grid/tie inputs quantize exactly
            // as the modeled hardware would.
            Rounding::Nearest => (scaled + 0.5).floor(),
            Rounding::Truncate => scaled.floor(),
        };
        // f64 → i64 casts saturate in Rust, so extreme values land on
        // the i64 edge and `fit` clamps/wraps from there.
        self.fit(r as i64)
    }

    /// Raw word back to a real value.
    #[inline]
    pub fn dequantize(&self, raw: i32) -> f32 {
        raw as f32 * self.format.resolution()
    }

    /// Quantize a slice.
    pub fn quantize_vec(&self, x: &[f32]) -> Vec<i32> {
        x.iter().map(|&v| self.quantize(v)).collect()
    }

    /// Dequantize a slice.
    pub fn dequantize_vec(&self, raw: &[i32]) -> Vec<f32> {
        raw.iter().map(|&r| self.dequantize(r)).collect()
    }

    /// Fixed-point add.
    #[inline]
    pub fn add(&self, a: i32, b: i32) -> i32 {
        self.fit(a as i64 + b as i64)
    }

    /// Fixed-point subtract.
    #[inline]
    pub fn sub(&self, a: i32, b: i32) -> i32 {
        self.fit(a as i64 - b as i64)
    }

    /// Fixed-point multiply: full-precision product, then one rescale
    /// by `frac_bits`, then the overflow policy.
    #[inline]
    pub fn mul(&self, a: i32, b: i32) -> i32 {
        let p = a as i64 * b as i64;
        self.fit(self.rescale(p, self.format.frac_bits as u32))
    }

    /// Multiply a raw word by a block-scaled constant: the product is
    /// rescaled by the *constant's* fraction count, so the result stays
    /// in this spec's format regardless of the constant's magnitude.
    #[inline]
    pub fn mul_const(&self, a: i32, c: &FxpConst) -> i32 {
        let p = a as i64 * c.raw as i64;
        self.fit(self.rescale(p, c.frac as u32))
    }

    /// Dot product with a wide accumulator (the DSP-cascade model):
    /// every product is kept at full precision, summed exactly, and
    /// rounded/saturated exactly once at the end. With the `simd`
    /// feature the sum runs in width-aware blocked `i64` lanes
    /// ([`simd::dot_acc`]) — bit-identical to the scalar `i128` walk,
    /// including every telemetry saturation/wrap event, because only
    /// this single final `fit` observes the (identical) sum.
    pub fn dot_raw(&self, a: &[i32], b: &[i32]) -> i32 {
        debug_assert_eq!(a.len(), b.len());
        let acc: i128 = if simd::enabled() {
            simd::dot_acc(a, b, self.format.width() as u32)
        } else {
            let mut acc: i128 = 0;
            for (&x, &y) in a.iter().zip(b) {
                acc += x as i128 * y as i128;
            }
            acc
        };
        self.fit(self.rescale_wide(acc, self.format.frac_bits as u32))
    }

    /// Convert a raw word of another spec's format into this one — the
    /// inter-stage format boundary of a mixed-precision datapath (a
    /// pure shift plus this spec's rounding/overflow; a no-op when the
    /// formats match, so uniform plans are bit-identical to the
    /// single-format datapath).
    #[inline]
    pub fn requantize_from(&self, raw: i32, from: &FxpSpec) -> i32 {
        if self.format == from.format {
            return raw;
        }
        let shift = self.format.frac_bits as i32 - from.format.frac_bits as i32;
        if shift >= 0 {
            self.fit((raw as i64) << shift)
        } else {
            self.fit(self.rescale(raw as i64, (-shift) as u32))
        }
    }

    /// [`FxpSpec::requantize_from`] over a slice.
    pub fn requantize_vec_from(&self, raw: &[i32], from: &FxpSpec) -> Vec<i32> {
        raw.iter().map(|&r| self.requantize_from(r, from)).collect()
    }

    /// [`FxpSpec::requantize_from`] in place over a slice — the hot-path
    /// form of a stage boundary: a matching format is a whole-slice
    /// no-op, otherwise one tight shift+fit loop the compiler can
    /// vectorize. Allocation-free.
    pub fn requantize_slice_from(&self, words: &mut [i32], from: &FxpSpec) {
        if self.format == from.format {
            return;
        }
        for v in words.iter_mut() {
            *v = self.requantize_from(*v, from);
        }
    }

    /// [`FxpSpec::requantize_vec_from`] into a caller-owned buffer
    /// (resized without shrinking capacity) — zero allocations once the
    /// buffer has grown to the tile size.
    pub fn requantize_vec_from_into(&self, raw: &[i32], from: &FxpSpec, out: &mut Vec<i32>) {
        kernels::resize_buf(out, raw.len());
        if self.format == from.format {
            out.copy_from_slice(raw);
            return;
        }
        for (o, &r) in out.iter_mut().zip(raw) {
            *o = self.requantize_from(r, from);
        }
    }

    /// Parse `"qI.F"` with optional policy suffixes: `:wrap` / `:sat`
    /// (overflow) and `:trunc` / `:nearest` (rounding), in any order —
    /// e.g. `"q4.12"`, `"q1.15:wrap"`, `"q4.12:wrap:trunc"`. Defaults
    /// are the datapath's saturate + round-to-nearest.
    pub fn parse(s: &str) -> Result<Self> {
        let t = s.trim().to_ascii_lowercase();
        let mut parts = t.split(':');
        let fmt = parts.next().unwrap_or("");
        let Some(rest) = fmt.strip_prefix('q') else {
            bail!("unknown format '{s}' (expected qI.F, e.g. q4.12)");
        };
        let Some((i, f)) = rest.split_once('.') else {
            bail!("malformed Q format '{s}' (expected qI.F, e.g. q4.12)");
        };
        let int_bits: u64 = i
            .parse()
            .map_err(|_| anyhow::anyhow!("malformed integer bits in format '{s}'"))?;
        let frac_bits: u64 = f
            .parse()
            .map_err(|_| anyhow::anyhow!("malformed fraction bits in format '{s}'"))?;
        // u64 math: absurd inputs must reach this ensure, not wrap into
        // a plausible width and panic in QFormat::new.
        anyhow::ensure!(
            int_bits >= 1
                && int_bits.saturating_add(frac_bits) >= 2
                && int_bits.saturating_add(frac_bits) <= 32,
            "format '{s}': need 1 <= I and 2 <= I+F <= 32"
        );
        let mut spec = FxpSpec::q(int_bits as u8, frac_bits as u8);
        for tok in parts {
            match tok {
                "wrap" => spec.overflow = Overflow::Wrap,
                "sat" | "saturate" => spec.overflow = Overflow::Saturate,
                "trunc" | "truncate" => spec.rounding = Rounding::Truncate,
                "nearest" | "round" => spec.rounding = Rounding::Nearest,
                other => bail!(
                    "unknown policy '{other}' in '{s}' (wrap|sat|trunc|nearest)"
                ),
            }
        }
        Ok(spec)
    }

    /// Canonical label: `"q4.12"`, with non-default policies suffixed
    /// in parse order (`"q1.15:wrap:trunc"`). Round-trips through
    /// [`FxpSpec::parse`].
    pub fn label(&self) -> String {
        let mut s = format!("q{}.{}", self.format.int_bits, self.format.frac_bits);
        if self.overflow == Overflow::Wrap {
            s.push_str(":wrap");
        }
        if self.rounding == Rounding::Truncate {
            s.push_str(":trunc");
        }
        s
    }
}

/// A constant baked into the datapath (learning rate, projection scale,
/// whitening coefficient): stored with its own fraction count chosen so
/// the raw word uses the full width — block scaling, exactly how
/// constant multiplier inputs are prepared for FPGA synthesis.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FxpConst {
    pub raw: i32,
    /// Fraction bits of `raw` (may exceed the datapath's, for small
    /// constants like μ).
    pub frac: u8,
}

impl FxpConst {
    /// Quantize `v` into `width` bits with the best power-of-two scale.
    pub fn from_f32(v: f32, width: u8) -> Self {
        assert!((2..=32).contains(&width));
        if !v.is_finite() || v == 0.0 {
            return Self { raw: 0, frac: 0 };
        }
        let max_raw = ((1i64 << (width - 1)) - 1) as f64;
        // Largest fraction count keeping |v|·2^f within the raw range,
        // capped at 30 (resolution floor for denormal-small constants).
        let mut frac = (max_raw / v.abs() as f64).log2().floor() as i32;
        frac = frac.clamp(0, 30);
        while frac > 0 && (v.abs() as f64 * (2.0f64).powi(frac)).round() > max_raw {
            frac -= 1;
        }
        let raw = (v as f64 * (2.0f64).powi(frac))
            .round()
            .clamp(-max_raw, max_raw) as i32;
        Self {
            raw,
            frac: frac as u8,
        }
    }

    /// The constant's real value after quantization.
    pub fn value(&self) -> f32 {
        self.raw as f32 * (2.0f32).powi(-(self.frac as i32))
    }
}

/// How a fixed-point pipeline trains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    /// Every update computed in the integer datapath — the bit-exact
    /// image of the deployed on-chip *training* hardware.
    BitExact,
    /// Quantization-aware training with a straight-through estimator:
    /// the forward path runs the quantized datapath (exactly what the
    /// deployed inference hardware computes), but updates are applied
    /// to f32 shadow weights that are requantized after every step —
    /// the standard QAT recipe for training models that *deploy* at
    /// narrow widths without the update underflow of bit-exact
    /// training.
    Ste,
}

impl QuantMode {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "bit-exact" | "bitexact" | "exact" => Ok(QuantMode::BitExact),
            "ste" | "qat" => Ok(QuantMode::Ste),
            other => bail!("unknown quant mode '{other}' (bit-exact|ste)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            QuantMode::BitExact => "bit-exact",
            QuantMode::Ste => "ste",
        }
    }
}

/// The precision role a stage of a composable DR graph plays — how a
/// [`PrecisionPlan`] assigns an arithmetic spec to each stage of a
/// [`crate::stage::StageGraph`]. Static front-end stages (RP, DCT,
/// identity) share the entry/accumulator format; the whitening and
/// rotation stages each have their own. A graph stage can still
/// override its role's format individually via the stage-list syntax
/// (`rp:ternary/16@q8.16` — see [`crate::stage::spec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageRole {
    /// RP front end / static entry stages (DCT, identity).
    Rp,
    /// GHA whitening stage.
    Whiten,
    /// EASI rotation (or standalone EASI) stage.
    Rot,
}

/// Per-stage arithmetic of a fixed-point pipeline — the mixed-precision
/// axis. Real datapaths are not uniform: the RP accumulator wants
/// headroom (wide integer part), the whitener mid width, the rotation
/// can run narrow (its inputs are σ-normalised). Stage boundaries
/// requantize raw words ([`FxpSpec::requantize_from`]); a uniform plan
/// makes every boundary a no-op and is bit-identical to the PR-1
/// single-format datapath.
///
/// Graph stages consume the plan through [`PrecisionPlan::spec_for`]
/// (keyed by [`StageRole`]) rather than as a hardwired rp/whiten/rot
/// triple, so any stage cascade — not just the paper's RP → unit shape
/// — gets a per-stage format assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrecisionPlan {
    /// RP front-end accumulator format.
    pub rp: FxpSpec,
    /// GHA whitening stage format.
    pub whiten: FxpSpec,
    /// EASI rotation stage format.
    pub rot: FxpSpec,
    /// Training mode (bit-exact integer updates vs STE QAT).
    pub quant: QuantMode,
}

impl PrecisionPlan {
    /// The same format everywhere, bit-exact — what a plain `"q4.12"`
    /// precision string means.
    pub fn uniform(spec: FxpSpec) -> Self {
        Self {
            rp: spec,
            whiten: spec,
            rot: spec,
            quant: QuantMode::BitExact,
        }
    }

    /// Whether all three stages share one arithmetic spec.
    pub fn is_uniform(&self) -> bool {
        self.rp == self.whiten && self.whiten == self.rot
    }

    /// The arithmetic spec this plan assigns to a graph stage of the
    /// given role — the per-graph-stage view of the plan (see
    /// [`StageRole`]).
    pub fn spec_for(&self, role: StageRole) -> FxpSpec {
        match role {
            StageRole::Rp => self.rp,
            StageRole::Whiten => self.whiten,
            StageRole::Rot => self.rot,
        }
    }

    /// The widest stage width in bits (storage/reporting upper bound).
    pub fn widest_width(&self) -> u8 {
        self.rp
            .format
            .width()
            .max(self.whiten.format.width())
            .max(self.rot.format.width())
    }

    /// Entry prescale for a pipeline with this plan: the most
    /// conservative of the formats the raw sample flows through before
    /// the whitener renormalises (the RP accumulator when an RP front
    /// end exists, and the trained stage's input format). Exact powers
    /// of two, invisible to accuracy — see [`input_prescale`].
    pub fn entry_prescale(&self, uses_rp: bool, stage_spec: &FxpSpec) -> f32 {
        let stage_ps = input_prescale(stage_spec);
        if uses_rp {
            stage_ps.min(input_prescale(&self.rp))
        } else {
            stage_ps
        }
    }
}

/// The precision a pipeline computes in — threaded through
/// `PipelineSpec`, `ExperimentConfig` and the CLI (`--precision`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    /// IEEE single precision (the reference datapath).
    F32,
    /// Bit-accurate fixed point, per-stage formats + training mode.
    Fixed(PrecisionPlan),
}

impl Precision {
    /// Parse a precision string:
    ///
    /// * `"f32"` / `"fp32"` — the reference datapath;
    /// * `"q4.12"` — uniform fixed point (optionally with policy
    ///   suffixes, `"q1.15:wrap:trunc"` — see [`FxpSpec::parse`]);
    /// * `"rp=q8.16,whiten=q4.12,rot=q1.15"` — per-stage mixed
    ///   precision. Keys: `rp`, `whiten`, `rot`, `all` (sets every
    ///   stage not given explicitly), `qat=ste|bit-exact`. Stages left
    ///   unset default to the widest spec given (headroom-safe). A bare
    ///   `qI.F` token inside a comma list means `all=qI.F`, so
    ///   `"q4.12,qat=ste"` selects uniform STE-trained Q4.12.
    pub fn parse(s: &str) -> Result<Self> {
        let t = s.trim().to_ascii_lowercase();
        if t == "f32" || t == "fp32" || t == "float" {
            return Ok(Precision::F32);
        }
        if !t.contains(',') && !t.contains('=') {
            // Plain uniform format.
            let spec = FxpSpec::parse(&t)
                .map_err(|e| anyhow::anyhow!("precision '{s}': {e}"))?;
            return Ok(Precision::Fixed(PrecisionPlan::uniform(spec)));
        }
        // Duplicate keys are rejected (naming the offending token)
        // rather than silently last-wins: a typo'd plan must fail loudly.
        fn set_spec(
            slot: &mut Option<FxpSpec>,
            key: &str,
            v: &str,
            whole: &str,
        ) -> Result<()> {
            anyhow::ensure!(
                slot.is_none(),
                "duplicate precision key '{key}' in '{whole}'"
            );
            *slot = Some(FxpSpec::parse(v)?);
            Ok(())
        }
        let (mut rp, mut whiten, mut rot, mut all) = (None, None, None, None);
        let mut quant = QuantMode::BitExact;
        let mut quant_seen = false;
        for item in t.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            match item.split_once('=') {
                Some(("rp", v)) => set_spec(&mut rp, "rp", v, &t)?,
                Some(("whiten", v)) => set_spec(&mut whiten, "whiten", v, &t)?,
                Some(("rot", v)) => set_spec(&mut rot, "rot", v, &t)?,
                Some(("all", v)) => set_spec(&mut all, "all", v, &t)?,
                Some(("qat", v)) => {
                    if quant_seen {
                        bail!("duplicate precision key 'qat' in '{t}'");
                    }
                    quant = QuantMode::parse(v)?;
                    quant_seen = true;
                }
                Some((k, _)) => {
                    bail!("unknown precision key '{k}' in '{s}' (rp|whiten|rot|all|qat)")
                }
                // Bare qI.F token in a list: shorthand for all=.
                None => set_spec(&mut all, "all", item, &t)?,
            }
        }
        // Unset stages inherit `all`, then the widest explicit spec.
        let fallback = all.or_else(|| {
            [rp, whiten, rot]
                .into_iter()
                .flatten()
                .max_by_key(|sp: &FxpSpec| sp.format.width())
        });
        let Some(fallback) = fallback else {
            bail!("precision '{s}' names no Q format (rp=|whiten=|rot=|all=qI.F)");
        };
        Ok(Precision::Fixed(PrecisionPlan {
            rp: rp.unwrap_or(fallback),
            whiten: whiten.unwrap_or(fallback),
            rot: rot.unwrap_or(fallback),
            quant,
        }))
    }

    /// Canonical label: `"f32"`, `"q4.12"` for uniform bit-exact plans,
    /// `"q4.12,qat=ste"` for uniform STE, and the full
    /// `"rp=…,whiten=…,rot=…[,qat=ste]"` form for mixed plans.
    /// Round-trips through [`Precision::parse`].
    pub fn label(&self) -> String {
        match self {
            Precision::F32 => "f32".to_string(),
            Precision::Fixed(p) => {
                let mut s = if p.is_uniform() {
                    p.whiten.label()
                } else {
                    format!(
                        "rp={},whiten={},rot={}",
                        p.rp.label(),
                        p.whiten.label(),
                        p.rot.label()
                    )
                };
                if p.quant == QuantMode::Ste {
                    s.push_str(",qat=ste");
                }
                s
            }
        }
    }

    pub fn is_fixed(&self) -> bool {
        matches!(self, Precision::Fixed(_))
    }

    /// The precision plan, if fixed.
    pub fn plan(&self) -> Option<PrecisionPlan> {
        match self {
            Precision::F32 => None,
            Precision::Fixed(p) => Some(*p),
        }
    }

    /// The single fixed-point spec of a *uniform* plan (None for f32
    /// and for mixed plans — per-stage consumers read [`Self::plan`]).
    pub fn spec(&self) -> Option<FxpSpec> {
        match self {
            Precision::Fixed(p) if p.is_uniform() => Some(p.whiten),
            _ => None,
        }
    }

    /// Operand width in bits: 32 for f32, the *widest* stage width for
    /// fixed plans (mixed-plan hardware is priced per stage by
    /// `hwmodel`; this is the reporting/storage upper bound).
    pub fn width_bits(&self) -> u8 {
        match self {
            Precision::F32 => 32,
            Precision::Fixed(p) => p.widest_width(),
        }
    }
}

/// Power-of-two input prescale giving standardized (unit-variance) data
/// ≈ ±8 of headroom in narrow-integer formats. Exact in binary fixed
/// point (a pure shift), and invisible to accuracy: every downstream
/// stage either renormalises (whitening) or feeds a classifier trained
/// on standardized features.
pub fn input_prescale(spec: &FxpSpec) -> f32 {
    let shift = (4 - spec.format.int_bits as i32).max(0);
    (2.0f32).powi(-shift)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q_format_ranges() {
        let q115 = QFormat::new(1, 15);
        assert_eq!(q115.width(), 16);
        assert_eq!(q115.max_raw(), 32767);
        assert_eq!(q115.min_raw(), -32768);
        assert!((q115.max_value() - (1.0 - 1.0 / 32768.0)).abs() < 1e-9);
        assert_eq!(q115.min_value(), -1.0);
        let q412 = QFormat::new(4, 12);
        assert_eq!(q412.width(), 16);
        assert!((q412.max_value() - (8.0 - q412.resolution())).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "width must be in 2..=32")]
    fn q_format_rejects_wide() {
        QFormat::new(16, 17);
    }

    #[test]
    fn quantize_dequantize_roundtrip() {
        let spec = FxpSpec::q(4, 12);
        for &v in &[0.0f32, 1.0, -1.0, 0.5, -0.125, 3.999, -7.5, 0.33333] {
            let r = spec.quantize(v);
            let back = spec.dequantize(r);
            assert!(
                (back - v).abs() <= spec.format.resolution() / 2.0 + 1e-9,
                "{v} -> {back}"
            );
        }
        // Values on the grid round-trip exactly.
        let exact = 1.25f32; // 1.25 = 5120 / 4096
        assert_eq!(spec.dequantize(spec.quantize(exact)), exact);
    }

    #[test]
    fn saturation_edges() {
        let spec = FxpSpec::q(1, 15);
        assert_eq!(spec.quantize(2.0), spec.format.max_raw());
        assert_eq!(spec.quantize(-2.0), spec.format.min_raw());
        assert_eq!(spec.quantize(f32::INFINITY), spec.format.max_raw());
        assert_eq!(spec.quantize(f32::NEG_INFINITY), spec.format.min_raw());
        assert_eq!(spec.quantize(f32::NAN), 0);
        // Additions saturate instead of wrapping.
        let max = spec.format.max_raw();
        assert_eq!(spec.add(max, max), max);
        assert_eq!(spec.sub(spec.format.min_raw(), 1), spec.format.min_raw());
    }

    #[test]
    fn wrapping_mode_wraps() {
        let mut spec = FxpSpec::q(1, 7); // 8-bit word
        spec.overflow = Overflow::Wrap;
        // 127 + 1 wraps to -128 in 8 bits.
        assert_eq!(spec.add(127, 1), -128);
        assert_eq!(spec.add(-128, -1), 127);
    }

    #[test]
    fn rounding_modes() {
        let nearest = FxpSpec::q(4, 4);
        let mut trunc = nearest;
        trunc.rounding = Rounding::Truncate;
        // 0.09375 = 1.5/16: nearest ties toward +inf => 2/16, truncate => 1/16.
        assert_eq!(nearest.quantize(0.09375), 2);
        assert_eq!(trunc.quantize(0.09375), 1);
        // Negative tie: nearest still goes toward +inf (add-half,
        // matching the datapath rescale); truncate goes toward -inf.
        assert_eq!(nearest.quantize(-0.09375), -1);
        assert_eq!(trunc.quantize(-0.09375), -2);
        // Multiply rounding: (0.25 * 0.375) = 0.09375 again.
        let a = nearest.quantize(0.25);
        let b = nearest.quantize(0.375);
        assert_eq!(nearest.mul(a, b), 2);
        assert_eq!(trunc.mul(a, b), 1);
    }

    #[test]
    fn mul_matches_f32_within_half_ulp() {
        let spec = FxpSpec::q(4, 12);
        for (x, y) in [(1.5f32, 2.25f32), (-0.75, 0.5), (3.0, -2.5), (0.1, 0.1)] {
            let r = spec.mul(spec.quantize(x), spec.quantize(y));
            let err = (spec.dequantize(r) - x * y).abs();
            // Input quantization (≤ half ulp each) plus product rounding.
            let tol = spec.format.resolution() * (0.5 + 0.5 * (x.abs() + y.abs()));
            assert!(err <= tol + 1e-6, "{x}*{y}: err {err} tol {tol}");
        }
    }

    #[test]
    fn dot_uses_wide_accumulator() {
        // Products that would overflow a narrow accumulator must still
        // come out right (saturated only at the final write-back).
        let spec = FxpSpec::q(8, 8);
        let a: Vec<i32> = vec![spec.quantize(100.0); 64];
        let b: Vec<i32> = vec![spec.quantize(1.0); 64];
        // true dot = 6400, saturates at max_value ≈ 127.996.
        assert_eq!(spec.dot_raw(&a, &b), spec.format.max_raw());
        // A non-saturating case is exact.
        let a2: Vec<i32> = (0..16).map(|i| spec.quantize(i as f32 * 0.25)).collect();
        let b2: Vec<i32> = (0..16).map(|_| spec.quantize(0.5)).collect();
        let want: f32 = (0..16).map(|i| i as f32 * 0.25 * 0.5).sum();
        let got = spec.dequantize(spec.dot_raw(&a2, &b2));
        assert!((got - want).abs() <= spec.format.resolution());
    }

    #[test]
    fn fxp_const_block_scaling() {
        // A tiny constant keeps almost-full relative precision…
        let mu = FxpConst::from_f32(1e-3, 16);
        assert!((mu.value() - 1e-3).abs() / 1e-3 < 1e-3, "{}", mu.value());
        // …and a large one fits without saturating.
        let big = FxpConst::from_f32(96.5, 16);
        assert!((big.value() - 96.5).abs() / 96.5 < 1e-3, "{}", big.value());
        // mul_const keeps the datapath format.
        let spec = FxpSpec::q(4, 12);
        let x = spec.quantize(2.0);
        let y = spec.mul_const(x, &mu);
        assert!((spec.dequantize(y) - 2e-3).abs() <= spec.format.resolution());
        let z = spec.mul_const(x, &big);
        assert_eq!(z, spec.format.max_raw(), "2*96.5 saturates Q4.12");
    }

    #[test]
    fn precision_parsing() {
        assert_eq!(Precision::parse("f32").unwrap(), Precision::F32);
        assert_eq!(Precision::parse("FP32").unwrap(), Precision::F32);
        let p = Precision::parse("q1.15").unwrap();
        assert_eq!(p.label(), "q1.15");
        assert_eq!(p.width_bits(), 16);
        assert_eq!(Precision::parse("Q4.12").unwrap().label(), "q4.12");
        assert!(Precision::parse("q0.16").is_err());
        assert!(Precision::parse("q17.16").is_err());
        // Absurd widths must error cleanly, not wrap/panic.
        assert!(Precision::parse("q4294967290.38").is_err());
        assert!(Precision::parse("q99999999999999999999.1").is_err());
        assert!(Precision::parse("int8").is_err());
        assert!(Precision::parse("q4").is_err());
    }

    #[test]
    fn spec_parse_policies() {
        let p = FxpSpec::parse("q4.12").unwrap();
        assert_eq!(p, FxpSpec::q(4, 12));
        let w = FxpSpec::parse("q1.15:wrap").unwrap();
        assert_eq!(w.overflow, Overflow::Wrap);
        assert_eq!(w.rounding, Rounding::Nearest);
        let t = FxpSpec::parse("q4.12:trunc").unwrap();
        assert_eq!(t.rounding, Rounding::Truncate);
        assert_eq!(t.overflow, Overflow::Saturate);
        let both = FxpSpec::parse("q8.16:wrap:trunc").unwrap();
        assert_eq!(both.overflow, Overflow::Wrap);
        assert_eq!(both.rounding, Rounding::Truncate);
        // Order-free, and explicit defaults accepted.
        assert_eq!(FxpSpec::parse("q8.16:trunc:wrap").unwrap(), both);
        assert_eq!(FxpSpec::parse("q4.12:sat:nearest").unwrap(), FxpSpec::q(4, 12));
        assert!(FxpSpec::parse("q4.12:fancy").is_err());
        // Labels round-trip, policies included.
        for s in ["q4.12", "q1.15:wrap", "q4.12:trunc", "q8.16:wrap:trunc"] {
            let spec = FxpSpec::parse(s).unwrap();
            assert_eq!(spec.label(), s);
            assert_eq!(FxpSpec::parse(&spec.label()).unwrap(), spec);
        }
    }

    #[test]
    fn precision_plan_parse_and_roundtrip() {
        // Mixed plan, all stages explicit.
        let p = Precision::parse("rp=q8.16,whiten=q4.12,rot=q1.15").unwrap();
        let plan = p.plan().unwrap();
        assert_eq!(plan.rp, FxpSpec::q(8, 16));
        assert_eq!(plan.whiten, FxpSpec::q(4, 12));
        assert_eq!(plan.rot, FxpSpec::q(1, 15));
        assert_eq!(plan.quant, QuantMode::BitExact);
        assert!(!plan.is_uniform());
        assert_eq!(p.width_bits(), 24);
        assert_eq!(p.label(), "rp=q8.16,whiten=q4.12,rot=q1.15");
        assert_eq!(Precision::parse(&p.label()).unwrap(), p);

        // STE flag, uniform shorthand.
        let u = Precision::parse("q4.12,qat=ste").unwrap();
        let uplan = u.plan().unwrap();
        assert!(uplan.is_uniform());
        assert_eq!(uplan.quant, QuantMode::Ste);
        assert_eq!(u.label(), "q4.12,qat=ste");
        assert_eq!(Precision::parse(&u.label()).unwrap(), u);

        // Plain uniform strings still mean what they did in PR 1.
        let plain = Precision::parse("q4.12").unwrap();
        assert_eq!(plain.plan().unwrap(), PrecisionPlan::uniform(FxpSpec::q(4, 12)));
        assert_eq!(plain.spec(), Some(FxpSpec::q(4, 12)));
        assert_eq!(plain.label(), "q4.12");

        // Unset stages default to the widest explicit spec.
        let partial = Precision::parse("rp=q8.16,rot=q1.15").unwrap();
        let pp = partial.plan().unwrap();
        assert_eq!(pp.whiten, FxpSpec::q(8, 16));
        // `all=` fills the gaps instead when present.
        let alled = Precision::parse("all=q4.12,rot=q1.15,qat=ste").unwrap();
        let ap = alled.plan().unwrap();
        assert_eq!(ap.rp, FxpSpec::q(4, 12));
        assert_eq!(ap.whiten, FxpSpec::q(4, 12));
        assert_eq!(ap.rot, FxpSpec::q(1, 15));
        assert_eq!(ap.quant, QuantMode::Ste);
        // Mixed plans have no single uniform spec.
        assert_eq!(partial.spec(), None);

        // Per-stage policy suffixes flow through the plan syntax (the
        // ROADMAP's wrap/trunc exposure).
        let pol = Precision::parse("rp=q8.16,whiten=q4.12:trunc,rot=q1.15:wrap").unwrap();
        let pl = pol.plan().unwrap();
        assert_eq!(pl.whiten.rounding, Rounding::Truncate);
        assert_eq!(pl.rot.overflow, Overflow::Wrap);
        assert_eq!(Precision::parse(&pol.label()).unwrap(), pol);

        // Errors: unknown keys, empty plans, bad modes.
        assert!(Precision::parse("gha=q4.12").is_err());
        assert!(Precision::parse("qat=ste").is_err());
        assert!(Precision::parse("q4.12,qat=sometimes").is_err());
    }

    #[test]
    fn precision_plan_rejects_duplicate_keys() {
        // Duplicate keys must fail naming the offending key, not
        // silently last-win.
        for s in [
            "rp=q4.12,rp=q8.16",
            "whiten=q4.12,whiten=q4.8",
            "rot=q1.15,rot=q4.12",
            "all=q4.12,all=q8.16",
            "q4.12,q8.16",       // two bare tokens both mean `all=`
            "all=q4.12,q8.16",   // explicit + bare `all=`
            "qat=ste,qat=ste",
            "q4.12,qat=ste,qat=bit-exact",
        ] {
            let err = Precision::parse(s).unwrap_err().to_string();
            assert!(err.contains("duplicate precision key"), "{s}: {err}");
        }
        // Distinct keys still compose fine.
        assert!(Precision::parse("rp=q8.16,whiten=q4.12,rot=q1.15,qat=ste").is_ok());
    }

    #[test]
    fn plan_spec_for_roles() {
        let plan = Precision::parse("rp=q8.16,whiten=q4.12,rot=q1.15")
            .unwrap()
            .plan()
            .unwrap();
        assert_eq!(plan.spec_for(StageRole::Rp), FxpSpec::q(8, 16));
        assert_eq!(plan.spec_for(StageRole::Whiten), FxpSpec::q(4, 12));
        assert_eq!(plan.spec_for(StageRole::Rot), FxpSpec::q(1, 15));
        // Uniform plans answer the same spec for every role.
        let u = PrecisionPlan::uniform(FxpSpec::q(4, 12));
        for role in [StageRole::Rp, StageRole::Whiten, StageRole::Rot] {
            assert_eq!(u.spec_for(role), FxpSpec::q(4, 12));
        }
    }

    #[test]
    fn requantize_between_formats() {
        let wide = FxpSpec::q(8, 16);
        let narrow = FxpSpec::q(4, 12);
        // Same format: identity on raw words.
        assert_eq!(wide.requantize_from(12345, &wide), 12345);
        // Wide -> narrow: shift right with rounding, value preserved.
        let v = 1.5f32;
        let raw_wide = wide.quantize(v);
        let raw_narrow = narrow.requantize_from(raw_wide, &wide);
        assert_eq!(narrow.dequantize(raw_narrow), v);
        // Narrow -> wide: shift left, exact.
        let back = wide.requantize_from(raw_narrow, &narrow);
        assert_eq!(wide.dequantize(back), v);
        // Out-of-range values saturate to the destination format.
        let big = wide.quantize(100.0);
        let sat = narrow.requantize_from(big, &wide);
        assert_eq!(sat, narrow.format.max_raw());
        // Rounding policy of the destination applies.
        let mut trunc = narrow;
        trunc.rounding = Rounding::Truncate;
        let tie = wide.quantize(narrow.format.resolution() * 0.5); // half a narrow LSB
        assert_eq!(narrow.requantize_from(tie, &wide), 1); // nearest: up
        assert_eq!(trunc.requantize_from(tie, &wide), 0); // trunc: down
    }

    #[test]
    fn requantize_slice_and_into_match_vec_form() {
        let wide = FxpSpec::q(8, 16);
        let narrow = FxpSpec::q(4, 12);
        let raw: Vec<i32> = (0..300)
            .map(|i| ((i * 7919) % 200001) as i32 - 100000)
            .collect();
        let want = narrow.requantize_vec_from(&raw, &wide);
        // In place.
        let mut inplace = raw.clone();
        narrow.requantize_slice_from(&mut inplace, &wide);
        assert_eq!(inplace, want);
        // Caller-owned buffer, including reuse from a larger prior size.
        let mut buf = vec![0i32; 1024];
        narrow.requantize_vec_from_into(&raw, &wide, &mut buf);
        assert_eq!(buf, want);
        // Matching formats are a pure copy / no-op.
        let mut same = raw.clone();
        wide.requantize_slice_from(&mut same, &wide);
        assert_eq!(same, raw);
        wide.requantize_vec_from_into(&raw, &wide, &mut buf);
        assert_eq!(buf, raw);
    }

    #[test]
    fn plan_entry_prescale() {
        let wide = FxpSpec::q(8, 16);
        let narrow = FxpSpec::q(1, 15);
        let plan = PrecisionPlan {
            rp: narrow,
            whiten: wide,
            rot: wide,
            quant: QuantMode::BitExact,
        };
        // The narrow RP accumulator forces the conservative prescale.
        assert_eq!(plan.entry_prescale(true, &plan.whiten), 0.125);
        // Without RP only the stage format matters.
        assert_eq!(plan.entry_prescale(false, &plan.whiten), 1.0);
        // Uniform wide plan: no prescale at all.
        let u = PrecisionPlan::uniform(wide);
        assert_eq!(u.entry_prescale(true, &u.whiten), 1.0);
    }

    #[test]
    fn prescale_only_for_narrow_int() {
        assert_eq!(input_prescale(&FxpSpec::q(4, 12)), 1.0);
        assert_eq!(input_prescale(&FxpSpec::q(6, 10)), 1.0);
        assert_eq!(input_prescale(&FxpSpec::q(1, 15)), 0.125);
        assert_eq!(input_prescale(&FxpSpec::q(2, 14)), 0.25);
    }
}
