//! Quantized row-major matrix — the fixed-point image of
//! [`crate::linalg::Mat`].

use super::FxpSpec;
use crate::linalg::Mat;

/// Row-major matrix of raw fixed-point words, all sharing one
/// [`FxpSpec`]. Mirrors the subset of [`Mat`]'s API the quantized
/// kernels need; convert at the boundary with [`FxpMat::quantize`] /
/// [`FxpMat::dequantize`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FxpMat {
    rows: usize,
    cols: usize,
    raw: Vec<i32>,
    pub spec: FxpSpec,
}

impl FxpMat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize, spec: FxpSpec) -> Self {
        Self {
            rows,
            cols,
            raw: vec![0; rows * cols],
            spec,
        }
    }

    /// Quantize an f32 matrix entry-wise.
    pub fn quantize(m: &Mat, spec: FxpSpec) -> Self {
        let (rows, cols) = m.shape();
        Self {
            rows,
            cols,
            raw: m.as_slice().iter().map(|&v| spec.quantize(v)).collect(),
            spec,
        }
    }

    /// Requantize an f32 matrix of the same shape into the existing
    /// raw buffer — the per-step shadow→datapath write of STE training,
    /// kept allocation-free on the streaming hot path.
    pub fn quantize_from(&mut self, m: &Mat) {
        assert_eq!((self.rows, self.cols), m.shape(), "fxp quantize_from shape");
        let spec = self.spec;
        for (r, &v) in self.raw.iter_mut().zip(m.as_slice()) {
            *r = spec.quantize(v);
        }
    }

    /// Dequantize back to f32.
    pub fn dequantize(&self) -> Mat {
        Mat::from_vec(
            self.rows,
            self.cols,
            self.raw.iter().map(|&r| self.spec.dequantize(r)).collect(),
        )
    }

    /// Dequantize into an existing same-shape matrix — the
    /// allocation-free form used by the host-side retraction so the
    /// periodic cadence stays off the heap too.
    pub fn dequantize_into(&self, m: &mut Mat) {
        assert_eq!((self.rows, self.cols), m.shape(), "fxp dequantize_into shape");
        for (o, &r) in m.as_mut_slice().iter_mut().zip(&self.raw) {
            *o = self.spec.dequantize(r);
        }
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn rows_count(&self) -> usize {
        self.rows
    }

    pub fn cols_count(&self) -> usize {
        self.cols
    }

    /// Borrow row `i` (raw words).
    #[inline]
    pub fn row(&self, i: usize) -> &[i32] {
        &self.raw[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get_raw(&self, i: usize, j: usize) -> i32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.raw[i * self.cols + j]
    }

    #[inline]
    pub fn set_raw(&mut self, i: usize, j: usize, v: i32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.raw[i * self.cols + j] = v;
    }

    /// Borrow the raw backing slice.
    pub fn as_raw(&self) -> &[i32] {
        &self.raw
    }

    /// Mutably borrow the raw backing slice.
    pub fn as_raw_mut(&mut self) -> &mut [i32] {
        &mut self.raw
    }

    /// `y = M x`, one wide-accumulator dot per row.
    pub fn matvec_raw(&self, x: &[i32]) -> Vec<i32> {
        let mut out = vec![0i32; self.rows];
        self.matvec_raw_into(x, &mut out);
        out
    }

    /// [`FxpMat::matvec_raw`] into a caller-owned buffer — the
    /// allocation-free form the tiled datapath runs on. Bit-identical
    /// to the allocating call.
    pub fn matvec_raw_into(&self, x: &[i32], out: &mut [i32]) {
        assert_eq!(x.len(), self.cols, "fxp matvec shape mismatch");
        assert_eq!(out.len(), self.rows, "fxp matvec out shape mismatch");
        for (i, o) in out.iter_mut().enumerate() {
            *o = self.spec.dot_raw(self.row(i), x);
        }
    }

    /// `y = Mᵀ x`: wide accumulators per output column, rounded and
    /// saturated once at write-back (same arithmetic as
    /// [`FxpSpec::dot_raw`]).
    pub fn matvec_t_raw(&self, x: &[i32]) -> Vec<i32> {
        let mut out = vec![0i32; self.cols];
        self.matvec_t_raw_into(x, &mut out);
        out
    }

    /// [`FxpMat::matvec_t_raw`] into a caller-owned buffer. The scalar
    /// reference walks the matrix column-wise with one `i128`
    /// accumulator; the `simd` path walks **row-major** over contiguous
    /// row segments with a stack tile of per-column `i64` partials
    /// ([`FxpMat::matvec_t_raw_blocked`]). Integer sums are exact in
    /// any order, so both forms — and the row-streamed oracle — produce
    /// bit-identical raw words.
    pub fn matvec_t_raw_into(&self, x: &[i32], out: &mut [i32]) {
        assert_eq!(x.len(), self.rows, "fxp matvec_t shape mismatch");
        assert_eq!(out.len(), self.cols, "fxp matvec_t out shape mismatch");
        let shift = self.spec.format.frac_bits as u32;
        if super::simd::enabled() {
            self.matvec_t_raw_blocked(x, out, shift);
            return;
        }
        for (j, o) in out.iter_mut().enumerate() {
            let mut acc: i128 = 0;
            for (i, &xi) in x.iter().enumerate() {
                acc += xi as i128 * self.raw[i * self.cols + j] as i128;
            }
            *o = self.spec.fit(self.spec.rescale_wide(acc, shift));
        }
    }

    /// Row-major `Mᵀx` on a stack tile of column accumulators: each
    /// input row contributes a contiguous segment (unit-stride loads,
    /// vectorizable i64 MACs), and the per-column partials spill into
    /// `i128` every [`super::simd::block_len`] rows, so no lane can
    /// overflow whatever the word width. Allocation-free (the tiles
    /// live on the stack).
    fn matvec_t_raw_blocked(&self, x: &[i32], out: &mut [i32], shift: u32) {
        const TILE: usize = 64;
        let cap = super::simd::block_len(self.spec.format.width() as u32);
        let cols = self.cols;
        for (t, out_tile) in out.chunks_mut(TILE).enumerate() {
            let j0 = t * TILE;
            let tw = out_tile.len();
            let mut acc = [0i128; TILE];
            let mut part = [0i64; TILE];
            let mut pending = 0usize;
            for (i, &xi) in x.iter().enumerate() {
                let seg = &self.raw[i * cols + j0..i * cols + j0 + tw];
                let xi = xi as i64;
                for (p, &w) in part[..tw].iter_mut().zip(seg) {
                    *p += xi * w as i64;
                }
                pending += 1;
                if pending == cap {
                    for (a, p) in acc[..tw].iter_mut().zip(part[..tw].iter_mut()) {
                        *a += *p as i128;
                        *p = 0;
                    }
                    pending = 0;
                }
            }
            if pending > 0 {
                for (a, &p) in acc[..tw].iter_mut().zip(part[..tw].iter()) {
                    *a += p as i128;
                }
            }
            for (o, &a) in out_tile.iter_mut().zip(acc[..tw].iter()) {
                *o = self.spec.fit(self.spec.rescale_wide(a, shift));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_dequantize_matrix_roundtrip() {
        let spec = FxpSpec::q(4, 12);
        let m = Mat::from_fn(5, 7, |i, j| ((i * 7 + j) as f32 * 0.37).sin() * 3.0);
        let q = FxpMat::quantize(&m, spec);
        let back = q.dequantize();
        for (a, b) in m.as_slice().iter().zip(back.as_slice()) {
            assert!((a - b).abs() <= spec.format.resolution() / 2.0 + 1e-9);
        }
    }

    #[test]
    fn matvec_matches_f32_within_tolerance() {
        let spec = FxpSpec::q(6, 14); // 20-bit datapath
        let m = Mat::from_fn(8, 32, |i, j| ((i + j * 3) as f32 * 0.21).cos());
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.13).sin()).collect();
        let q = FxpMat::quantize(&m, spec);
        let xq = spec.quantize_vec(&x);
        let y = spec.dequantize_vec(&q.matvec_raw(&xq));
        let want = m.matvec(&x);
        // Error budget: input/weight quantization (≤ ulp/2 each over 32
        // products) + one final rounding.
        let tol = spec.format.resolution() * 32.0;
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() <= tol, "{a} vs {b}");
        }
    }

    #[test]
    fn matvec_t_matches_transposed_matvec() {
        let spec = FxpSpec::q(4, 12);
        let m = Mat::from_fn(6, 10, |i, j| ((i * 10 + j) as f32 * 0.11) - 3.0);
        let q = FxpMat::quantize(&m, spec);
        let x: Vec<i32> = (0..6).map(|i| spec.quantize(i as f32 * 0.3 - 1.0)).collect();
        let direct = q.matvec_t_raw(&x);
        // Oracle: transpose in f32 space, quantize, matvec.
        let mt = FxpMat::quantize(&m.dequantize_via(spec).transpose(), spec);
        let oracle = mt.matvec_raw(&x);
        for (a, b) in direct.iter().zip(&oracle) {
            assert!((a - b).abs() <= 1, "{a} vs {b}");
        }
    }

    #[test]
    fn matvec_t_blocked_bit_identical_to_column_walk() {
        // Direct comparison of the two matvec_t kernels, independent of
        // dispatch state — including q16.16-class 32-bit words where
        // the spill threshold is 1 and every row boundary spills.
        for spec in [FxpSpec::q(4, 12), FxpSpec::q(16, 16), FxpSpec::q(1, 15)] {
            let (rows, cols) = (37, 130); // non-multiples of tile/lane widths
            let mut m = FxpMat::zeros(rows, cols, spec);
            for i in 0..rows {
                for j in 0..cols {
                    let v = ((i * 131 + j * 17) as i64 * 2654435761 % (1 << 31)) as i32;
                    m.set_raw(i, j, spec.fit(v as i64));
                }
            }
            // Adversarial extremal stripe: whole rows at min_raw.
            for j in 0..cols {
                m.set_raw(0, j, spec.format.min_raw());
                m.set_raw(rows - 1, j, spec.format.min_raw());
            }
            let x: Vec<i32> = (0..rows)
                .map(|i| {
                    if i % 3 == 0 {
                        spec.format.min_raw()
                    } else {
                        spec.format.max_raw() - i as i32
                    }
                })
                .collect();
            let shift = spec.format.frac_bits as u32;
            let mut scalar = vec![0i32; cols];
            for (j, o) in scalar.iter_mut().enumerate() {
                let mut acc: i128 = 0;
                for (i, &xi) in x.iter().enumerate() {
                    acc += xi as i128 * m.get_raw(i, j) as i128;
                }
                *o = spec.fit(spec.rescale_wide(acc, shift));
            }
            let mut blocked = vec![0i32; cols];
            m.matvec_t_raw_blocked(&x, &mut blocked, shift);
            assert_eq!(blocked, scalar, "{}", spec.label());
        }
    }

    // Small helper so the oracle above uses the same quantized weights.
    trait DeqVia {
        fn dequantize_via(&self, spec: FxpSpec) -> Mat;
    }
    impl DeqVia for Mat {
        fn dequantize_via(&self, spec: FxpSpec) -> Mat {
            FxpMat::quantize(self, spec).dequantize()
        }
    }
}
