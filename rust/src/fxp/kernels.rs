//! Bit-accurate quantized kernels for the three DR stages.
//!
//! Each kernel mirrors its f32 counterpart's update rule with every
//! datapath operation performed in fixed point ([`FxpSpec`] arithmetic:
//! wide accumulators, one rounding per MAC chain, saturation on
//! write-back):
//!
//! * [`FxpRp`] — the RP front end. The conditional add/sub network is
//!   *exact* in fixed point (integer adds lose nothing); only the
//!   optional output scale is a rounded constant multiply.
//! * [`FxpGha`] — Sanger's rule ([`crate::gha`]). The variance EMA uses
//!   an extended-precision accumulator (`frac + 16` bits), the standard
//!   trick for slow EMAs whose per-step increment would otherwise
//!   round to zero at narrow widths.
//! * [`FxpEasiRot`] — the paper's rotation-only EASI datapath
//!   ([`crate::easi`], `EasiMode::RotationOnly`), rectangular or
//!   square.
//! * [`FxpDrUnit`] — the composed whiten→rotate unit, the fixed-point
//!   image of [`crate::pipeline::unit::DrUnit`].
//!
//! # Host-side helpers (documented deviations from pure streaming)
//!
//! Two small computations run outside the integer datapath, at the same
//! cadence the PJRT backend applies its host-side retraction
//! (`RETRACT_INTERVAL = 256` samples):
//!
//! * the whitening coefficients `σ/√λ̂` (a reciprocal square root — in
//!   hardware a small sequential LUT/CORDIC unit, not the pipeline);
//! * the rotation retraction (dequantize → modified Gram–Schmidt →
//!   requantize), exactly like the PJRT backend.
//!
//! # Narrow-format scaling
//!
//! Formats with fewer than 4 integer bits cannot hold standardized data
//! (±~6σ); [`super::input_prescale`] shifts inputs down by an exact
//! power of two. The whitener then targets output σ = `2^-(3-i)` for
//! `i` integer bits (so ±4σ fits the format), and the rotation's μ is
//! compensated by σ⁻⁴ (its update terms scale as σ⁴) — both host-side
//! constant folding, exact in binary. In a mixed-precision unit the σ
//! target honours the *narrower* of the whitening and rotation formats,
//! so a narrow rotation stage still sees in-range inputs.
//!
//! # Training modes ([`QuantMode`])
//!
//! Every kernel trains in one of two modes (see [`super`] docs):
//! bit-exact integer updates, or STE QAT where the quantized forward
//! values drive an f32 shadow-weight update that is requantized into
//! the datapath after each step. The forward/transform path is
//! identical in both modes — only where the *update* arithmetic runs
//! differs.

use super::{input_prescale, FxpConst, FxpMat, FxpSpec, QuantMode};
use crate::linalg::{orthonormalize_rows, Mat};
use crate::rp::{RandomProjection, SparseSignMatrix};

/// Cadence (samples) of the host-side helpers: whitening-coefficient
/// refresh and rotation retraction. Matches the PJRT backend's
/// `RETRACT_INTERVAL`.
pub const HOST_REFRESH_INTERVAL: u64 = 256;

/// Caller-owned scratch workspaces for the tiled fixed-point datapath.
///
/// The tile kernels (`apply_tile_raw` / `transform_tile_*`) write every
/// intermediate into these buffers instead of allocating. Buffers only
/// grow ([`resize_buf`] never shrinks capacity), so one `Scratch`
/// reused across steps — even across differently-shaped tiles — is
/// allocation-free once it has seen the largest shape.
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    /// Quantized entry tile (rows × entry dim).
    pub xq: Vec<i32>,
    /// Inter-stage tile (RP outputs / whitened rows).
    pub stage: Vec<i32>,
    /// Output tile (rows × n).
    pub out: Vec<i32>,
}

impl Scratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Size a scratch vector to `len` words without shrinking its capacity:
/// reallocation happens only when a larger tile arrives, so steady-state
/// reuse is allocation-free. Only newly-grown words are zeroed (no
/// per-tile memset) — every tile kernel fully overwrites the words it
/// hands out, so stale content never leaks.
#[inline]
pub(crate) fn resize_buf(buf: &mut Vec<i32>, len: usize) {
    if buf.len() != len {
        buf.resize(len, 0);
    }
}

/// Extra fraction bits of the variance-EMA accumulator.
const VAR_EXTRA_FRAC: u32 = 16;

// ------------------------------------------------------------------ RP

/// Quantized random projection: the exact add/sub network on raw words.
#[derive(Debug, Clone)]
pub struct FxpRp {
    pub in_dim: usize,
    pub out_dim: usize,
    pub spec: FxpSpec,
    /// Ternary/Achlioptas sign pattern (adds only).
    sparse: Option<SparseSignMatrix>,
    /// Dense quantized matrix for the Gaussian variant (scale folded
    /// in, as `to_dense` bakes it).
    dense: Option<FxpMat>,
    /// Output scale for sparse variants, when ≠ 1.
    scale: Option<FxpConst>,
}

impl FxpRp {
    /// Quantize an existing projection (same pattern, same scale).
    pub fn from_rp(rp: &RandomProjection, spec: FxpSpec) -> Self {
        match rp.sparse_pattern() {
            Some(s) => Self {
                in_dim: rp.in_dim,
                out_dim: rp.out_dim,
                spec,
                sparse: Some(s.clone()),
                dense: None,
                scale: (rp.scale != 1.0)
                    .then(|| FxpConst::from_f32(rp.scale, spec.format.width())),
            },
            None => Self {
                in_dim: rp.in_dim,
                out_dim: rp.out_dim,
                spec,
                sparse: None,
                dense: Some(FxpMat::quantize(&rp.to_dense(), spec)),
                scale: None,
            },
        }
    }

    /// `y = scale · R x` on raw words. The output scale is applied to
    /// the *wide* accumulator sum before the format write-back, so a
    /// sub-unity scale (the unit-variance √(p/m)) can rescue sums that
    /// would otherwise saturate — the adder network itself stays exact.
    pub fn apply_raw(&self, x: &[i32]) -> Vec<i32> {
        let mut out = vec![0i32; self.out_dim];
        self.apply_row_into(x, &mut out);
        out
    }

    /// One sample through the projection into a caller-owned buffer —
    /// the allocation-free primitive both [`FxpRp::apply_raw`] and
    /// [`FxpRp::apply_tile_raw`] are built on (bit-identical to each
    /// other by construction).
    pub fn apply_row_into(&self, x: &[i32], out: &mut [i32]) {
        assert_eq!(x.len(), self.in_dim, "fxp rp apply shape mismatch");
        assert_eq!(out.len(), self.out_dim, "fxp rp apply out shape mismatch");
        match (&self.sparse, &self.dense) {
            (Some(s), _) => s.apply_raw_each(x, |i, sum| {
                out[i] = match &self.scale {
                    Some(c) => {
                        let p = sum as i128 * c.raw as i128;
                        self.spec.fit(self.spec.rescale_wide(p, c.frac as u32))
                    }
                    None => self.spec.fit(sum),
                };
            }),
            (None, Some(d)) => d.matvec_raw_into(x, out),
            (None, None) => unreachable!("FxpRp holds sparse or dense"),
        }
    }

    /// Tiled [`FxpRp::apply_raw`]: `x` is `rows` row-major samples
    /// (`rows × in_dim` raw words); writes the projected tile
    /// (`rows × out_dim`) into `out`, which is resized but never shrunk
    /// — zero allocations in steady state.
    pub fn apply_tile_raw(&self, x: &[i32], rows: usize, out: &mut Vec<i32>) {
        assert_eq!(x.len(), rows * self.in_dim, "fxp rp tile shape mismatch");
        resize_buf(out, rows * self.out_dim);
        for r in 0..rows {
            let xin = &x[r * self.in_dim..(r + 1) * self.in_dim];
            let orow = &mut out[r * self.out_dim..(r + 1) * self.out_dim];
            self.apply_row_into(xin, orow);
        }
    }

    /// Convenience f32 boundary: quantize in, dequantize out.
    pub fn apply(&self, x: &[f32]) -> Vec<f32> {
        let xq = self.spec.quantize_vec(x);
        self.spec.dequantize_vec(&self.apply_raw(&xq))
    }
}

/// Quantize a whole f32 sample tile (`rows` row-major samples in `x`)
/// at the fixed-point pipeline ingress and cross the RP→stage format
/// boundary, staging through caller-owned scratch: `scratch.xq`
/// receives the quantized entry tile and, with an RP front end,
/// `scratch.stage` the projected/requantized stage tile. It is
/// row-for-row identical to quantizing each sample on its own.
///
/// This is the two-boundary ingress of the paper's fixed RP → unit
/// shape; [`crate::stage::StageGraph`] generalises the same arithmetic
/// (`entry.quantize(v·prescale)` + per-boundary `requantize_from`) to
/// arbitrary cascades, and the bit-identity tests
/// (`tests/stage_graph_identity.rs`) pin the graph against this
/// definition for every legacy configuration, while the bench harness
/// keeps calling it directly as the per-sample baseline.
pub fn ingress_tile(
    rp: Option<&FxpRp>,
    entry_spec: &FxpSpec,
    stage_spec: &FxpSpec,
    prescale: f32,
    x: &[f32],
    rows: usize,
    scratch: &mut Scratch,
) {
    resize_buf(&mut scratch.xq, x.len());
    for (q, &v) in scratch.xq.iter_mut().zip(x) {
        *q = entry_spec.quantize(v * prescale);
    }
    if let Some(f) = rp {
        f.apply_tile_raw(&scratch.xq, rows, &mut scratch.stage);
        stage_spec.requantize_slice_from(&mut scratch.stage, entry_spec);
    }
}

// ----------------------------------------------------------------- GHA

/// Quantized streaming principal-subspace whitener (Sanger's rule).
#[derive(Debug, Clone)]
pub struct FxpGha {
    pub spec: FxpSpec,
    input_dim: usize,
    output_dim: usize,
    w: FxpMat,
    /// Extended-precision second-moment accumulators, raw with
    /// `frac_bits + VAR_EXTRA_FRAC` fraction bits.
    var_acc: Vec<i64>,
    mu: FxpConst,
    beta: FxpConst,
    /// Whitening coefficients `σ/√λ̂`, refreshed every
    /// [`HOST_REFRESH_INTERVAL`] samples.
    coeff: Vec<FxpConst>,
    /// Whitening target σ = 2^-sigma_shift (1 for ≥ 3 integer bits).
    sigma_shift: i32,
    steps: u64,
    /// Training mode; [`QuantMode::Ste`] keeps `shadow` weights.
    quant: QuantMode,
    /// f32 shadow weights (STE QAT); `w` is always their quantization.
    shadow: Option<Mat>,
    /// Full-precision learning rate for the shadow update.
    mu_f: f32,
    y: Vec<i32>,
    cum: Vec<i32>,
    delta: Vec<i32>,
    cum_f: Vec<f32>,
}

impl FxpGha {
    pub fn new(
        input_dim: usize,
        output_dim: usize,
        mu: f32,
        var_beta: f32,
        seed: u64,
        spec: FxpSpec,
        quant: QuantMode,
    ) -> Self {
        assert!(input_dim >= output_dim && output_dim >= 1);
        assert!(mu > 0.0 && var_beta > 0.0);
        let w0 = crate::easi::random_orthonormal(output_dim, input_dim, seed);
        let w = FxpMat::quantize(&w0, spec);
        let width = spec.format.width();
        let init_var = 1i64 << (spec.format.frac_bits as u32 + VAR_EXTRA_FRAC);
        let mut g = Self {
            spec,
            input_dim,
            output_dim,
            w,
            var_acc: vec![init_var; output_dim],
            mu: FxpConst::from_f32(mu, width),
            beta: FxpConst::from_f32(var_beta, width),
            coeff: vec![FxpConst { raw: 0, frac: 0 }; output_dim],
            sigma_shift: (3 - spec.format.int_bits as i32).max(0),
            steps: 0,
            quant,
            shadow: (quant == QuantMode::Ste).then_some(w0),
            mu_f: mu,
            y: vec![0; output_dim],
            cum: vec![0; input_dim],
            delta: vec![0; output_dim * input_dim],
            cum_f: vec![0.0; input_dim],
        };
        g.refresh_coeffs();
        g
    }

    /// The subspace, dequantized.
    pub fn subspace(&self) -> Mat {
        self.w.dequantize()
    }

    /// Stage input dimensionality.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Stage output dimensionality.
    pub fn output_dim(&self) -> usize {
        self.output_dim
    }

    /// Real value of one raw LSB of the extended variance accumulator —
    /// the single definition of the `frac_bits + VAR_EXTRA_FRAC`
    /// scaling shared by [`FxpGha::variances`] and
    /// [`FxpGha::refresh_coeffs`].
    fn var_resolution(&self) -> f64 {
        (2.0f64).powi(-(self.spec.format.frac_bits as i32 + VAR_EXTRA_FRAC as i32))
    }

    /// λ̂ estimate for component `i` (prescaled-input domain).
    fn variance_at(&self, i: usize) -> f32 {
        (self.var_acc[i] as f64 * self.var_resolution()) as f32
    }

    /// λ̂ estimates (in the prescaled-input domain).
    pub fn variances(&self) -> Vec<f32> {
        (0..self.output_dim).map(|i| self.variance_at(i)).collect()
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Whitening target standard deviation (a power of two).
    pub fn target_sigma(&self) -> f32 {
        (2.0f32).powi(-self.sigma_shift)
    }

    /// Raise the whitening σ target to `2^-shift` (host-side constant
    /// folding). The composed unit uses this so a *narrower* rotation
    /// format downstream still receives in-range (±4σ) inputs; callers
    /// must set it before training starts.
    pub fn set_sigma_shift(&mut self, shift: i32) {
        self.sigma_shift = shift.max(0);
        self.refresh_coeffs();
    }

    /// The training mode this whitener was built with.
    pub fn quant_mode(&self) -> QuantMode {
        self.quant
    }

    /// Recompute the whitening coefficients `σ/√λ̂` (host/LUT side; see
    /// module docs). Between refreshes the forward path is all-integer.
    /// Reads the extended accumulators directly (no temporary λ̂ vector)
    /// so the periodic refresh stays off the heap like the rest of the
    /// steady-state training step.
    pub fn refresh_coeffs(&mut self) {
        let width = self.spec.format.width();
        let sigma = self.target_sigma();
        let floor = self.spec.format.resolution();
        for i in 0..self.output_dim {
            let v = self.variance_at(i);
            self.coeff[i] = FxpConst::from_f32(sigma / v.max(floor).sqrt(), width);
        }
    }

    /// One streaming Sanger update on raw words.
    pub fn step_raw(&mut self, x: &[i32]) {
        let spec = self.spec;
        let (n, m) = (self.output_dim, self.input_dim);
        assert_eq!(x.len(), m, "fxp gha step shape mismatch");
        for i in 0..n {
            self.y[i] = spec.dot_raw(self.w.row(i), x);
        }
        match self.quant {
            QuantMode::BitExact => {
                for c in self.cum.iter_mut() {
                    *c = 0;
                }
                // Deltas from the pre-update W (buffered, like the f32
                // kernel).
                for i in 0..n {
                    let yi = self.y[i];
                    let row = self.w.row(i);
                    for j in 0..m {
                        self.cum[j] = spec.add(self.cum[j], spec.mul(yi, row[j]));
                        let t = spec.sub(x[j], self.cum[j]);
                        let p = spec.mul(yi, t);
                        self.delta[i * m + j] = spec.mul_const(p, &self.mu);
                    }
                }
                for (w, &d) in self.w.as_raw_mut().iter_mut().zip(self.delta.iter()) {
                    *w = spec.add(*w, d);
                }
            }
            QuantMode::Ste => {
                // STE: the Sanger delta is computed from the *quantized*
                // forward values (y and the datapath weights — what the
                // deployed hardware saw), in f32, and applied to the
                // shadow; the datapath weights are then the shadow
                // requantized. Sub-LSB updates accumulate instead of
                // rounding to zero.
                //
                // Unlike the EASI STE pass, this backward pass canNOT be
                // sharded across rows: `cum_f[j]` is a running prefix
                // sum over rows i (Sanger's lower-triangular deflation),
                // so row i's delta depends on every row before it. It
                // stays a single sequential lane by construction.
                let shadow = self
                    .shadow
                    .as_mut()
                    .expect("STE mode keeps shadow weights");
                for c in self.cum_f.iter_mut() {
                    *c = 0.0;
                }
                for i in 0..n {
                    let yi = spec.dequantize(self.y[i]);
                    let row = self.w.row(i);
                    for j in 0..m {
                        self.cum_f[j] += yi * spec.dequantize(row[j]);
                        let d = self.mu_f
                            * yi
                            * (spec.dequantize(x[j]) - self.cum_f[j]);
                        shadow.as_mut_slice()[i * m + j] += d;
                    }
                }
                self.w.quantize_from(shadow);
            }
        }
        // Variance EMA in the extended accumulator: λ̂ += β(y² − λ̂).
        for (va, &yi) in self.var_acc.iter_mut().zip(&self.y) {
            let y2_ext = (spec.mul(yi, yi) as i64) << VAR_EXTRA_FRAC;
            let diff = y2_ext - *va;
            let upd = ((diff as i128 * self.beta.raw as i128) >> self.beta.frac) as i64;
            *va = (*va + upd).max(0);
        }
        self.steps += 1;
        if self.steps % HOST_REFRESH_INTERVAL == 0 {
            self.refresh_coeffs();
        }
    }

    /// One streaming Sanger update per tile row, in row order — the
    /// update recursion is inherently sequential, so the tile form is
    /// bit-identical to per-sample stepping by construction.
    pub fn step_tile_raw(&mut self, x: &[i32], rows: usize) {
        let m = self.input_dim;
        assert_eq!(x.len(), rows * m, "fxp gha tile shape mismatch");
        for r in 0..rows {
            self.step_raw(&x[r * m..(r + 1) * m]);
        }
    }

    /// Project without normalisation: `y = Wx`.
    pub fn project_raw(&self, x: &[i32]) -> Vec<i32> {
        self.w.matvec_raw(x)
    }

    /// Tiled [`FxpGha::project_raw`] into a caller-owned buffer.
    pub fn project_tile_raw(&self, x: &[i32], rows: usize, out: &mut Vec<i32>) {
        let (n, m) = (self.output_dim, self.input_dim);
        assert_eq!(x.len(), rows * m, "fxp gha tile shape mismatch");
        resize_buf(out, rows * n);
        for r in 0..rows {
            self.w
                .matvec_raw_into(&x[r * m..(r + 1) * m], &mut out[r * n..(r + 1) * n]);
        }
    }

    /// Whiten: `z_i = coeff_i · (Wx)_i` with `coeff = σ/√λ̂`.
    pub fn whiten_raw(&self, x: &[i32]) -> Vec<i32> {
        let mut out = vec![0i32; self.output_dim];
        self.whiten_into(x, &mut out);
        out
    }

    /// [`FxpGha::whiten_raw`] into a caller-owned buffer (bit-identical;
    /// the allocation-free form the composed unit's hot path uses).
    pub fn whiten_into(&self, x: &[i32], out: &mut [i32]) {
        self.w.matvec_raw_into(x, out);
        for (o, c) in out.iter_mut().zip(&self.coeff) {
            *o = self.spec.mul_const(*o, c);
        }
    }

    /// Tiled [`FxpGha::whiten_raw`] into a caller-owned buffer.
    pub fn whiten_tile_raw(&self, x: &[i32], rows: usize, out: &mut Vec<i32>) {
        let (n, m) = (self.output_dim, self.input_dim);
        assert_eq!(x.len(), rows * m, "fxp gha tile shape mismatch");
        resize_buf(out, rows * n);
        for r in 0..rows {
            self.whiten_into(&x[r * m..(r + 1) * m], &mut out[r * n..(r + 1) * n]);
        }
    }

    /// The whitening map as a dense f32 matrix `diag(coeff)·W`.
    pub fn whitening_matrix(&self) -> Mat {
        let w = self.w.dequantize();
        let (n, m) = w.shape();
        Mat::from_fn(n, m, |i, j| w.get(i, j) * self.coeff[i].value())
    }

    /// Checkpoint the whitener's datapath state: raw subspace words,
    /// the extended-precision variance accumulators, the sample count,
    /// the *current* whitening coefficients (refreshed only every
    /// [`HOST_REFRESH_INTERVAL`] samples, so they cannot be recomputed
    /// from the accumulators without breaking bit-exactness), and (STE)
    /// the f32 shadow weights. Restoring through
    /// [`FxpGha::restore_state`] reproduces the training trajectory
    /// bit-for-bit — including the shadow, so STE checkpoints carry
    /// their sub-LSB accumulation across reconfigurations.
    #[allow(clippy::type_complexity)]
    pub fn save_state(&self) -> (Vec<i32>, Vec<i64>, u64, Vec<FxpConst>, Option<Mat>) {
        (
            self.w.as_raw().to_vec(),
            self.var_acc.clone(),
            self.steps,
            self.coeff.clone(),
            self.shadow.clone(),
        )
    }

    /// Restore a [`FxpGha::save_state`] checkpoint — bit-exact
    /// continuation (the saved coefficients are reinstated verbatim;
    /// the next periodic refresh recomputes them on schedule).
    pub fn restore_state(
        &mut self,
        w_raw: &[i32],
        var_acc: &[i64],
        steps: u64,
        coeff: &[FxpConst],
        shadow: Option<&Mat>,
    ) {
        assert_eq!(w_raw.len(), self.output_dim * self.input_dim);
        assert_eq!(var_acc.len(), self.output_dim);
        assert_eq!(coeff.len(), self.output_dim);
        self.w.as_raw_mut().copy_from_slice(w_raw);
        self.var_acc.copy_from_slice(var_acc);
        self.steps = steps;
        self.coeff.copy_from_slice(coeff);
        if let (Some(dst), Some(src)) = (self.shadow.as_mut(), shadow) {
            assert_eq!(src.shape(), dst.shape());
            dst.as_mut_slice().copy_from_slice(src.as_slice());
        }
    }

    /// Mean absolute row-orthonormality error of W (→ 0 at
    /// convergence), on dequantized values.
    pub fn orthonormality_error(&self) -> f64 {
        let w = self.subspace();
        let n = w.rows_count();
        let mut err = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                let d = crate::linalg::dot(w.row(i), w.row(j)) as f64;
                let want = if i == j { 1.0 } else { 0.0 };
                err += (d - want).abs();
            }
        }
        err / (n * n) as f64
    }
}

// ---------------------------------------------------- rotation-only EASI

/// Quantized rotation-only EASI (the paper's modified datapath):
/// `B ← B − μ(g uᵀ − y vᵀ)` with `y = Bz`, `g = y³`, `u = Bᵀy`,
/// `v = Bᵀg`. Rectangular (n×m) or square.
#[derive(Debug, Clone)]
pub struct FxpEasiRot {
    pub spec: FxpSpec,
    input_dim: usize,
    output_dim: usize,
    b: FxpMat,
    mu: FxpConst,
    steps: u64,
    /// Training mode; [`QuantMode::Ste`] keeps `shadow` weights.
    quant: QuantMode,
    /// f32 shadow matrix (STE QAT); `b` is always its quantization.
    shadow: Option<Mat>,
    /// Full-precision learning rate for the shadow update.
    mu_f: f32,
    /// EMA of ‖ΔB‖/‖B‖ — the same convergence monitor the f32
    /// `EasiTrainer` keeps. Computed from the integer deltas; the EMA
    /// itself is a host-side observability counter, not datapath state.
    update_ema: f64,
    y: Vec<i32>,
    g: Vec<i32>,
    u: Vec<i32>,
    v: Vec<i32>,
    /// Wide accumulators for the fused row-streamed u/v pass (u = Bᵀy,
    /// v = Bᵀg share one contiguous walk over B).
    acc_u: Vec<i128>,
    acc_v: Vec<i128>,
    /// `i64` lane accumulators for the blocked u/v fast path (exact
    /// whenever `output_dim ≤ `[`super::simd::block_len`], which is
    /// every format narrower than 32 bits at realistic dims).
    part_u: Vec<i64>,
    part_v: Vec<i64>,
    /// Lanes for the sharded STE backward pass (1 = sequential; see
    /// [`FxpEasiRot::set_train_lanes`]).
    train_lanes: usize,
    /// Host-side f32 view of `b` for the bit-exact retraction, reused
    /// so the periodic dequantize→MGS→requantize stays off the heap.
    host_buf: Mat,
}

impl FxpEasiRot {
    /// `random_init: Some(seed)` starts from a random orthonormal
    /// subspace (the rectangular case); `None` starts from the identity
    /// embedding (square rotations). `mu` is the *effective* learning
    /// rate — callers fold in any σ compensation.
    pub fn new(
        input_dim: usize,
        output_dim: usize,
        mu: f32,
        random_init: Option<u64>,
        spec: FxpSpec,
        quant: QuantMode,
    ) -> Self {
        assert!(input_dim >= output_dim && output_dim >= 1);
        assert!(mu > 0.0);
        let b0 = match random_init {
            Some(seed) => crate::easi::random_orthonormal(output_dim, input_dim, seed),
            None => Mat::eye(output_dim, input_dim),
        };
        Self {
            spec,
            input_dim,
            output_dim,
            b: FxpMat::quantize(&b0, spec),
            mu: FxpConst::from_f32(mu, spec.format.width()),
            steps: 0,
            quant,
            shadow: (quant == QuantMode::Ste).then_some(b0),
            mu_f: mu,
            update_ema: 1.0,
            y: vec![0; output_dim],
            g: vec![0; output_dim],
            u: vec![0; input_dim],
            v: vec![0; input_dim],
            acc_u: vec![0; input_dim],
            acc_v: vec![0; input_dim],
            part_u: vec![0; input_dim],
            part_v: vec![0; input_dim],
            train_lanes: 1,
            host_buf: Mat::zeros(output_dim, input_dim),
        }
    }

    /// Shard the STE backward pass across `lanes` scoped threads, each
    /// owning a disjoint block of shadow rows. The per-element shadow
    /// update depends only on that element and the shared (y, g, u, v)
    /// forward values, so the updates commute and the sharded shadow —
    /// hence the requantized datapath matrix — is bit-identical to the
    /// sequential pass for every lane count. Only the f64 ‖ΔB‖/‖B‖
    /// monitor sums in lane order (a host-side observability counter,
    /// not datapath state). [`QuantMode::BitExact`] ignores this and
    /// stays sequential: its update writes `b` through the saturating
    /// integer pipeline in row-major order, and we keep that order the
    /// single source of truth. `lanes = 1` (the default) never spawns.
    pub fn set_train_lanes(&mut self, lanes: usize) {
        self.train_lanes = lanes.max(1);
    }

    /// The training mode this rotation was built with.
    pub fn quant_mode(&self) -> QuantMode {
        self.quant
    }

    /// Stage input dimensionality.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Stage output dimensionality.
    pub fn output_dim(&self) -> usize {
        self.output_dim
    }

    /// EMA of ‖ΔB‖_F/‖B‖_F — approaches 0 as the rotation converges
    /// (same semantics as `EasiTrainer::update_magnitude`).
    pub fn update_magnitude(&self) -> f64 {
        self.update_ema
    }

    /// The separation/rotation matrix, dequantized.
    pub fn matrix(&self) -> Mat {
        self.b.dequantize()
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Forward transform `y = Bz` on raw words.
    pub fn transform_raw(&self, z: &[i32]) -> Vec<i32> {
        self.b.matvec_raw(z)
    }

    /// [`FxpEasiRot::transform_raw`] into a caller-owned buffer.
    pub fn transform_into(&self, z: &[i32], out: &mut [i32]) {
        self.b.matvec_raw_into(z, out);
    }

    /// Tiled forward transform into a caller-owned buffer.
    pub fn transform_tile_raw(&self, z: &[i32], rows: usize, out: &mut Vec<i32>) {
        let (n, m) = (self.output_dim, self.input_dim);
        assert_eq!(z.len(), rows * m, "fxp easi tile shape mismatch");
        resize_buf(out, rows * n);
        for r in 0..rows {
            self.transform_into(&z[r * m..(r + 1) * m], &mut out[r * n..(r + 1) * n]);
        }
    }

    /// One rotation-only update per tile row, in row order (the update
    /// is sequential; bit-identical to per-sample stepping).
    pub fn step_tile_raw(&mut self, z: &[i32], rows: usize) {
        let m = self.input_dim;
        assert_eq!(z.len(), rows * m, "fxp easi tile shape mismatch");
        for r in 0..rows {
            self.step_raw(&z[r * m..(r + 1) * m]);
        }
    }

    /// One rotation-only update on raw words.
    pub fn step_raw(&mut self, z: &[i32]) {
        let spec = self.spec;
        let (n, m) = (self.output_dim, self.input_dim);
        assert_eq!(z.len(), m, "fxp easi step shape mismatch");
        for i in 0..n {
            self.y[i] = spec.dot_raw(self.b.row(i), z);
        }
        for i in 0..n {
            let yi = self.y[i];
            self.g[i] = spec.mul(spec.mul(yi, yi), yi);
        }
        // Fused row-streamed u = Bᵀy, v = Bᵀg: one contiguous walk over
        // B feeds both wide accumulators; integer sums are exact in any
        // order, so the raw words are bit-identical to two separate
        // `matvec_t_raw` passes.
        let shift = spec.format.frac_bits as u32;
        if super::simd::enabled()
            && n <= super::simd::block_len(spec.format.width() as u32)
        {
            // All n products per column fit one i64 lane exactly (the
            // same width bound as `simd::dot_acc`), so the whole pass
            // runs in vectorizable i64 MACs and converts to i128 only
            // at the rescale — bit-identical to the wide walk below.
            for p in self.part_u.iter_mut() {
                *p = 0;
            }
            for p in self.part_v.iter_mut() {
                *p = 0;
            }
            for i in 0..n {
                let (yi, gi) = (self.y[i] as i64, self.g[i] as i64);
                let row = self.b.row(i);
                for ((pu, pv), &w) in self
                    .part_u
                    .iter_mut()
                    .zip(self.part_v.iter_mut())
                    .zip(row)
                {
                    let bij = w as i64;
                    *pu += yi * bij;
                    *pv += gi * bij;
                }
            }
            for j in 0..m {
                self.u[j] = spec.fit(spec.rescale_wide(self.part_u[j] as i128, shift));
                self.v[j] = spec.fit(spec.rescale_wide(self.part_v[j] as i128, shift));
            }
        } else {
            for a in self.acc_u.iter_mut() {
                *a = 0;
            }
            for a in self.acc_v.iter_mut() {
                *a = 0;
            }
            for i in 0..n {
                let (yi, gi) = (self.y[i] as i128, self.g[i] as i128);
                let row = self.b.row(i);
                for j in 0..m {
                    let bij = row[j] as i128;
                    self.acc_u[j] += yi * bij;
                    self.acc_v[j] += gi * bij;
                }
            }
            for j in 0..m {
                self.u[j] = spec.fit(spec.rescale_wide(self.acc_u[j], shift));
                self.v[j] = spec.fit(spec.rescale_wide(self.acc_v[j], shift));
            }
        }
        let rel = match self.quant {
            QuantMode::BitExact => {
                let mut delta2: i128 = 0;
                let mut b_norm2: i128 = 0;
                for i in 0..n {
                    let (yi, gi) = (self.y[i], self.g[i]);
                    for j in 0..m {
                        let t = spec.sub(spec.mul(gi, self.u[j]), spec.mul(yi, self.v[j]));
                        let d = spec.mul_const(t, &self.mu);
                        let bij = self.b.get_raw(i, j);
                        delta2 += d as i128 * d as i128;
                        b_norm2 += bij as i128 * bij as i128;
                        self.b.set_raw(i, j, spec.sub(bij, d));
                    }
                }
                (delta2 as f64).sqrt() / ((b_norm2 as f64).sqrt() + 1e-30)
            }
            QuantMode::Ste => {
                // STE: the factored update terms (y, g, u, v) are the
                // quantized forward values; the delta is applied to the
                // f32 shadow, then the datapath matrix is requantized.
                let shadow = self
                    .shadow
                    .as_mut()
                    .expect("STE mode keeps shadow weights");
                let lanes = self.train_lanes.clamp(1, n);
                let (delta2, b_norm2) = if lanes > 1 {
                    // Sharded backward pass: each lane owns a disjoint
                    // contiguous block of shadow rows; every (i, j)
                    // update reads only the shared forward values and
                    // its own element, so the updates commute and the
                    // shadow words are bit-identical to the sequential
                    // walk for every lane count. The f64 monitor
                    // partials are reduced in lane order, deterministic
                    // per lane count.
                    let chunk = (n + lanes - 1) / lanes;
                    let (y, g, u, v) = (&self.y, &self.g, &self.u, &self.v);
                    let mu_f = self.mu_f;
                    std::thread::scope(|s| {
                        let mut handles = Vec::with_capacity(lanes);
                        for (lane, sh_chunk) in
                            shadow.as_mut_slice().chunks_mut(chunk * m).enumerate()
                        {
                            let i0 = lane * chunk;
                            handles.push(s.spawn(move || {
                                let (mut d2, mut b2) = (0.0f64, 0.0f64);
                                for (r, sh_row) in sh_chunk.chunks_mut(m).enumerate() {
                                    let yf = spec.dequantize(y[i0 + r]);
                                    let gf = spec.dequantize(g[i0 + r]);
                                    for (sv, (&uj, &vj)) in
                                        sh_row.iter_mut().zip(u.iter().zip(v))
                                    {
                                        let d = mu_f
                                            * (gf * spec.dequantize(uj)
                                                - yf * spec.dequantize(vj));
                                        let sv0 = *sv;
                                        d2 += (d as f64) * (d as f64);
                                        b2 += (sv0 as f64) * (sv0 as f64);
                                        *sv = sv0 - d;
                                    }
                                }
                                (d2, b2)
                            }));
                        }
                        handles.into_iter().fold((0.0f64, 0.0f64), |(a, b), h| {
                            let (d2, b2) = h.join().expect("STE lane panicked");
                            (a + d2, b + b2)
                        })
                    })
                } else {
                    let mut delta2 = 0.0f64;
                    let mut b_norm2 = 0.0f64;
                    for i in 0..n {
                        let yf = spec.dequantize(self.y[i]);
                        let gf = spec.dequantize(self.g[i]);
                        for j in 0..m {
                            let d = self.mu_f
                                * (gf * spec.dequantize(self.u[j])
                                    - yf * spec.dequantize(self.v[j]));
                            let s = shadow.as_slice()[i * m + j];
                            delta2 += (d as f64) * (d as f64);
                            b_norm2 += (s as f64) * (s as f64);
                            shadow.as_mut_slice()[i * m + j] = s - d;
                        }
                    }
                    (delta2, b_norm2)
                };
                self.b.quantize_from(shadow);
                delta2.sqrt() / (b_norm2.sqrt() + 1e-30)
            }
        };
        // Convergence monitor (host-side counter, same recursion as the
        // f32 trainer's): EMA of ‖ΔB‖/‖B‖.
        self.update_ema = 0.99 * self.update_ema + 0.01 * rel;
        self.steps += 1;
        if self.steps % HOST_REFRESH_INTERVAL == 0 {
            self.retract();
        }
    }

    /// Checkpoint the rotation's datapath state: raw matrix words, the
    /// step count (which pins the retraction cadence), and (STE) the
    /// f32 shadow matrix.
    pub fn save_state(&self) -> (Vec<i32>, u64, Option<Mat>) {
        (self.b.as_raw().to_vec(), self.steps, self.shadow.clone())
    }

    /// Restore a [`FxpEasiRot::save_state`] checkpoint — bit-exact
    /// continuation, shadow included.
    pub fn restore_state(&mut self, b_raw: &[i32], steps: u64, shadow: Option<&Mat>) {
        assert_eq!(b_raw.len(), self.output_dim * self.input_dim);
        self.b.as_raw_mut().copy_from_slice(b_raw);
        self.steps = steps;
        if let (Some(dst), Some(src)) = (self.shadow.as_mut(), shadow) {
            assert_eq!(src.shape(), dst.shape());
            dst.as_mut_slice().copy_from_slice(src.as_slice());
        }
    }

    /// Host-side retraction to the orthonormal manifold, same cadence
    /// and rationale as the PJRT backend's. Bit-exact mode retracts the
    /// datapath matrix (dequantize → modified Gram–Schmidt →
    /// requantize, through the reusable host buffer); STE retracts the
    /// f32 shadow and requantizes.
    pub fn retract(&mut self) {
        match &mut self.shadow {
            Some(shadow) => {
                orthonormalize_rows(shadow);
                self.b.quantize_from(shadow);
            }
            None => {
                self.b.dequantize_into(&mut self.host_buf);
                orthonormalize_rows(&mut self.host_buf);
                self.b.quantize_from(&self.host_buf);
            }
        }
    }
}

// --------------------------------------------------------- composed unit

/// Configuration of the composed fixed-point DR unit (mirrors
/// `pipeline::unit::DrUnitConfig` plus the per-stage arithmetic and
/// training mode).
#[derive(Debug, Clone, Copy)]
pub struct FxpUnitConfig {
    pub input_dim: usize,
    pub output_dim: usize,
    /// GHA (whitening) learning rate.
    pub mu_w: f32,
    /// EASI rotation learning rate (σ compensation applied internally).
    pub mu_rot: f32,
    /// Whether the HOS rotation stage is active (the paper's mux).
    pub rotate: bool,
    /// Whitener-only warm-up samples before the rotation learns.
    pub rot_warmup: u64,
    pub seed: u64,
    /// Whitening-stage arithmetic (also the unit's input format).
    pub whiten_spec: FxpSpec,
    /// Rotation-stage arithmetic (may be narrower — mixed precision).
    pub rot_spec: FxpSpec,
    /// Bit-exact integer training vs STE QAT.
    pub quant: QuantMode,
}

/// The composed streaming fixed-point unit: GHA whitening (+σ/√λ̂
/// scaling) followed by a square EASI rotation — the bit-accurate image
/// of [`crate::pipeline::unit::DrUnit`].
#[derive(Debug, Clone)]
pub struct FxpDrUnit {
    pub config: FxpUnitConfig,
    gha: FxpGha,
    rot: FxpEasiRot,
    /// ±4σ clamp on whitened inputs to the rotation (mirrors DrUnit's
    /// ±4 clamp in the σ=1 domain).
    clamp_raw: i32,
    /// Reusable whitened-sample buffer for the training step (the
    /// whiten→clamp→requantize staging between the two kernels).
    zbuf: Vec<i32>,
}

impl FxpDrUnit {
    pub fn new(config: FxpUnitConfig) -> Self {
        let wspec = config.whiten_spec;
        let mut gha = FxpGha::new(
            config.input_dim,
            config.output_dim,
            config.mu_w,
            5e-3,
            config.seed,
            wspec,
            config.quant,
        );
        // The σ target must satisfy the *narrower* of the two stage
        // formats: the whitener writes in its own format, but its
        // outputs feed the rotation after requantization — ±4σ has to
        // fit both.
        let narrow_int = config
            .whiten_spec
            .format
            .int_bits
            .min(config.rot_spec.format.int_bits);
        gha.set_sigma_shift((3 - narrow_int as i32).max(0));
        // The rotation's update terms scale as σ⁴ on σ-scaled whitened
        // inputs; fold σ⁻⁴ into μ (host-side constant folding, exact —
        // σ is a power of two).
        let sigma = gha.target_sigma();
        let mu_eff = config.mu_rot / (sigma * sigma * sigma * sigma);
        let rot = FxpEasiRot::new(
            config.output_dim,
            config.output_dim,
            mu_eff,
            None,
            config.rot_spec,
            config.quant,
        );
        let clamp_raw = wspec.quantize(4.0 * sigma);
        Self {
            config,
            gha,
            rot,
            clamp_raw,
            zbuf: vec![0; config.output_dim],
        }
    }

    /// The power-of-two input prescale for the unit's input (whitening)
    /// format (see module docs); applied by
    /// [`FxpDrUnit::quantize_input`].
    pub fn prescale(&self) -> f32 {
        input_prescale(&self.config.whiten_spec)
    }

    /// The format of [`FxpDrUnit::transform_raw`] outputs: the rotation
    /// format with the rotation stage on, the whitening format with it
    /// muxed out.
    pub fn output_spec(&self) -> FxpSpec {
        if self.config.rotate {
            self.config.rot_spec
        } else {
            self.config.whiten_spec
        }
    }

    /// Quantize an f32 sample into the unit's input domain.
    pub fn quantize_input(&self, x: &[f32]) -> Vec<i32> {
        let mut out = vec![0i32; x.len()];
        self.quantize_input_into(x, &mut out);
        out
    }

    /// [`FxpDrUnit::quantize_input`] into a caller-owned buffer.
    pub fn quantize_input_into(&self, x: &[f32], out: &mut [i32]) {
        assert_eq!(x.len(), out.len(), "fxp unit quantize shape mismatch");
        let ps = self.prescale();
        for (o, &v) in out.iter_mut().zip(x) {
            *o = self.config.whiten_spec.quantize(v * ps);
        }
    }

    /// One streaming sample (raw words, already prescaled/quantized).
    /// Allocation-free: the whiten→clamp→requantize staging between the
    /// two kernels runs in the unit's reusable buffer.
    pub fn step_raw(&mut self, x: &[i32]) {
        self.gha.step_raw(x);
        if self.config.rotate && self.gha.steps() > self.config.rot_warmup {
            self.gha.whiten_into(x, &mut self.zbuf);
            let (wspec, rspec) = (self.config.whiten_spec, self.config.rot_spec);
            let clamp = self.clamp_raw;
            for v in &mut self.zbuf {
                // ±4σ clamp in the whitening domain, then the
                // stage-boundary requantization (no-op for uniform
                // plans) — same per-element sequence as the original
                // whiten_for_rotation staging.
                *v = rspec.requantize_from((*v).clamp(-clamp, clamp), &wspec);
            }
            self.rot.step_raw(&self.zbuf);
        }
    }

    /// Consume a whole tile of raw samples (`rows × input_dim`,
    /// row-major), in row order — bit-identical to per-sample stepping
    /// (the Sanger/EASI update order is preserved; only the staging
    /// allocations go away).
    pub fn step_tile_raw(&mut self, x: &[i32], rows: usize) {
        let m = self.config.input_dim;
        assert_eq!(x.len(), rows * m, "fxp unit tile shape mismatch");
        for r in 0..rows {
            self.step_raw(&x[r * m..(r + 1) * m]);
        }
    }

    /// One streaming sample from f32 (quantizes at the boundary).
    pub fn step(&mut self, x: &[f32]) {
        let xq = self.quantize_input(x);
        self.step_raw(&xq);
    }

    /// Consume every row of an f32 sample matrix.
    pub fn step_rows(&mut self, x: &Mat) {
        for i in 0..x.rows_count() {
            self.step(x.row(i));
        }
    }

    /// Forward transform on raw words. Output words are in
    /// [`FxpDrUnit::output_spec`]'s format.
    pub fn transform_raw(&self, x: &[i32]) -> Vec<i32> {
        let mut out = vec![0i32; self.config.output_dim];
        let mut scratch = Scratch::new();
        self.transform_into(x, &mut scratch, &mut out);
        out
    }

    /// One-sample forward into a caller-owned buffer, staging through
    /// `scratch` — the allocation-free primitive behind every forward
    /// path (per-sample, tiled and multi-lane), so all of them are
    /// bit-identical by construction.
    pub fn transform_into(&self, x: &[i32], scratch: &mut Scratch, out: &mut [i32]) {
        let n = self.config.output_dim;
        assert_eq!(out.len(), n, "fxp unit transform out shape mismatch");
        if self.config.rotate {
            resize_buf(&mut scratch.stage, n);
            self.gha.whiten_into(x, &mut scratch.stage);
            let (wspec, rspec) = (self.config.whiten_spec, self.config.rot_spec);
            rspec.requantize_slice_from(&mut scratch.stage, &wspec);
            self.rot.transform_into(&scratch.stage, out);
        } else {
            self.gha.whiten_into(x, out);
        }
    }

    /// Tiled forward transform into a caller-owned slice
    /// (`rows × output_dim`).
    pub fn transform_tile_into(
        &self,
        x: &[i32],
        rows: usize,
        scratch: &mut Scratch,
        out: &mut [i32],
    ) {
        let (n, m) = (self.config.output_dim, self.config.input_dim);
        assert_eq!(x.len(), rows * m, "fxp unit tile shape mismatch");
        assert_eq!(out.len(), rows * n, "fxp unit tile out shape mismatch");
        for r in 0..rows {
            self.transform_into(
                &x[r * m..(r + 1) * m],
                scratch,
                &mut out[r * n..(r + 1) * n],
            );
        }
    }

    /// Tiled forward transform, resizing the caller-owned output tile.
    pub fn transform_tile_raw(
        &self,
        x: &[i32],
        rows: usize,
        scratch: &mut Scratch,
        out: &mut Vec<i32>,
    ) {
        resize_buf(out, rows * self.config.output_dim);
        self.transform_tile_into(x, rows, scratch, out);
    }

    /// Multi-lane tiled forward transform: shards the tile's rows into
    /// `lanes` contiguous chunks, one scoped thread per chunk, each
    /// writing its own disjoint slice of `out`. The merge is therefore
    /// deterministic by construction and the raw words are bit-identical
    /// to the per-sample path (every row's computation is independent
    /// in the forward direction; only training updates are sequential).
    pub fn transform_tile_raw_multilane(
        &self,
        x: &[i32],
        rows: usize,
        lanes: usize,
        out: &mut Vec<i32>,
    ) {
        let (n, m) = (self.config.output_dim, self.config.input_dim);
        assert_eq!(x.len(), rows * m, "fxp unit tile shape mismatch");
        resize_buf(out, rows * n);
        if rows == 0 {
            return;
        }
        // Lane counts the tile cannot feed short-circuit to the
        // sequential kernel without spawning a single thread: one lane
        // is sequential by definition, and more lanes than rows would
        // degenerate to one thread per row — pure scheduling overhead
        // for the same bit-identical words.
        if lanes <= 1 || lanes > rows {
            let mut scratch = Scratch::new();
            self.transform_tile_into(x, rows, &mut scratch, out);
            return;
        }
        // Ceil-divide so every lane gets a contiguous run of rows and
        // the chunk boundaries are a pure function of (rows, lanes).
        let chunk = (rows + lanes - 1) / lanes;
        std::thread::scope(|s| {
            for (lane, out_chunk) in out.chunks_mut(chunk * n).enumerate() {
                let rows_here = out_chunk.len() / n;
                let start = lane * chunk;
                let x_chunk = &x[start * m..(start + rows_here) * m];
                s.spawn(move || {
                    let mut scratch = Scratch::new();
                    self.transform_tile_into(x_chunk, rows_here, &mut scratch, out_chunk);
                });
            }
        });
    }

    /// Forward transform from f32 (quantize → integer datapath →
    /// dequantize).
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        let xq = self.quantize_input(x);
        self.output_spec().dequantize_vec(&self.transform_raw(&xq))
    }

    /// Shard the rotation's STE backward pass across `lanes` (see
    /// [`FxpEasiRot::set_train_lanes`]). The whitener's STE pass is a
    /// sequential prefix recursion (see the comment in
    /// [`FxpGha::step_raw`]) and always stays on one lane, as does
    /// every bit-exact update.
    pub fn set_train_lanes(&mut self, lanes: usize) {
        self.rot.set_train_lanes(lanes);
    }

    /// Toggle the rotation stage (the paper's reconfiguration mux).
    pub fn set_rotation(&mut self, on: bool) {
        self.config.rotate = on;
    }

    pub fn rotation_enabled(&self) -> bool {
        self.config.rotate
    }

    pub fn steps(&self) -> u64 {
        self.gha.steps()
    }

    /// The unit as one dense f32 matrix — `U·diag(σ/√λ̂)·W` times the
    /// input prescale, so it maps *unscaled* samples like
    /// `DrUnit::effective_matrix` (up to quantization).
    pub fn effective_matrix(&self) -> Mat {
        let mut eff = if self.config.rotate {
            self.rot.matrix().matmul(&self.gha.whitening_matrix())
        } else {
            self.gha.whitening_matrix()
        };
        eff.scale(self.prescale());
        eff
    }

    /// Convergence signal: the larger of the whitener's orthonormality
    /// error and the rotation's update EMA — same composition as
    /// `DrUnit::update_magnitude`, so fixed-precision runs interact
    /// with the coordinator's stop rules like f32 runs do.
    pub fn update_magnitude(&self) -> f64 {
        let gha_like = self.gha.orthonormality_error();
        if self.config.rotate {
            gha_like.max(self.rot.update_magnitude())
        } else {
            gha_like
        }
    }

    /// Access the whitener (tests, diagnostics).
    pub fn whitener(&self) -> &FxpGha {
        &self.gha
    }

    /// Access the rotation stage.
    pub fn rotation(&self) -> &FxpEasiRot {
        &self.rot
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gha::{GhaConfig, GhaWhitener};
    use crate::linalg::whiteness_error;
    use crate::rng::{Pcg64, RngExt};
    use crate::rp::RpDistribution;

    // ------------------------------------------------------------- RP

    #[test]
    fn fxp_rp_ternary_matches_f32() {
        // Ternary RP has scale 1 — the add/sub network is exact, so the
        // only error is input quantization: ≤ nnz_row · ulp/2 per
        // output. Documented tolerance: m · ulp.
        let spec = FxpSpec::q(8, 16);
        let rp = RandomProjection::new(64, 16, RpDistribution::Ternary, 11);
        let frp = FxpRp::from_rp(&rp, spec);
        let x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 2.0).collect();
        let want = rp.apply(&x);
        let got = frp.apply(&x);
        let tol = 64.0 * spec.format.resolution();
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() <= tol, "{a} vs {b}");
        }
    }

    #[test]
    fn fxp_rp_exact_on_grid_inputs() {
        // Inputs on the quantization grid (scale 1): bit-exact.
        let spec = FxpSpec::q(8, 8);
        let rp = RandomProjection::new(32, 8, RpDistribution::Ternary, 3);
        let frp = FxpRp::from_rp(&rp, spec);
        let x: Vec<f32> = (0..32).map(|i| (i % 7) as f32 * 0.25 - 0.75).collect();
        let want = rp.apply(&x);
        let got = frp.apply(&x);
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a, b, "grid inputs must project exactly");
        }
    }

    #[test]
    fn fxp_rp_scaled_variants_close() {
        // unit_variance folds a √(p/m) constant in: one rounded
        // multiply per output. Tolerance: (m + |y|/ulp·relerr) · ulp ≈
        // m · ulp + |y| · 2⁻¹⁵.
        let spec = FxpSpec::q(8, 16);
        let rp = RandomProjection::new(64, 16, RpDistribution::Ternary, 5).unit_variance();
        let frp = FxpRp::from_rp(&rp, spec);
        let x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.23).cos()).collect();
        for (a, b) in frp.apply(&x).iter().zip(&rp.apply(&x)) {
            assert!((a - b).abs() <= 64.0 * spec.format.resolution() + b.abs() * 1e-3);
        }
    }

    // ------------------------------------------------------------ GHA

    fn bounded_data(samples: usize, dim: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed(seed);
        // Low-rank structure + noise, bounded in [-2, 2] so the f32
        // oracle's clip guard never engages.
        Mat::from_fn(samples, dim, |_, j| {
            let a = rng.next_f32() * 2.0 - 1.0;
            (a * ((j as f32 * 0.7).sin() + 1.2)).clamp(-2.0, 2.0)
        })
    }

    #[test]
    fn fxp_gha_single_step_parity() {
        // One update from an identical starting point, 24-bit datapath,
        // against the f32 kernel (clip disabled). Documented tolerance:
        // 32 ulp per entry (init quantization + per-MAC rounding).
        let spec = FxpSpec::q(8, 16);
        let (m, n, seed) = (12usize, 4usize, 77u64);
        let mut f32_gha = GhaWhitener::new(GhaConfig {
            input_dim: m,
            output_dim: n,
            mu: 2e-3,
            var_beta: 5e-3,
            clip: 0.0,
            seed,
        });
        let mut fxp_gha = FxpGha::new(m, n, 2e-3, 5e-3, seed, spec, QuantMode::BitExact);
        let x: Vec<f32> = (0..m).map(|j| ((j * 5 % 7) as f32 * 0.2 - 0.6)).collect();
        f32_gha.step(&x);
        fxp_gha.step_raw(&spec.quantize_vec(&x));
        let a = f32_gha.subspace();
        let b = fxp_gha.subspace();
        let tol = 32.0 * spec.format.resolution();
        for (u, v) in a.as_slice().iter().zip(b.as_slice()) {
            assert!((u - v).abs() <= tol, "{u} vs {v}");
        }
    }

    #[test]
    fn fxp_gha_converges_to_principal_subspace() {
        // Functional parity at 18 bits: the quantized whitener finds
        // the same principal plane batch PCA does.
        use crate::pca::BatchPca;
        let spec = FxpSpec::q(6, 12);
        let x = bounded_data(4000, 6, 71);
        let mut gha = FxpGha::new(6, 2, 5e-3, 5e-3, 2018, spec, QuantMode::BitExact);
        for _ in 0..6 {
            for i in 0..x.rows_count() {
                gha.step_raw(&spec.quantize_vec(x.row(i)));
            }
        }
        let pca = BatchPca::fit(&x, 2);
        for i in 0..2 {
            let w = gha.subspace();
            let wi = w.row(i);
            let proj: f32 = (0..2)
                .map(|k| crate::linalg::dot(wi, pca.components.row(k)).powi(2))
                .sum();
            let total = crate::linalg::dot(wi, wi);
            assert!(
                proj / total > 0.9,
                "row {i}: {:.2} of its mass in the principal plane",
                proj / total
            );
        }
        assert!(gha.orthonormality_error() < 0.1);
    }

    // ----------------------------------------------------------- EASI

    #[test]
    fn fxp_easi_single_step_parity_vs_f32_oracle() {
        // One rotation-only update against a literal f32 computation of
        // the same factored form. Documented tolerance: 32 ulp.
        let spec = FxpSpec::q(8, 16);
        let (m, n, mu) = (6usize, 6usize, 1e-3f32);
        let mut rot = FxpEasiRot::new(m, n, mu, None, spec, QuantMode::BitExact);
        let z: Vec<f32> = (0..m).map(|j| (j as f32 * 0.9).sin() * 1.5).collect();
        let b0 = rot.matrix(); // quantized identity, the shared start
        rot.step_raw(&spec.quantize_vec(&z));

        // f32 oracle on the same (quantized) starting state.
        let y = b0.matvec(&z);
        let g: Vec<f32> = y.iter().map(|v| v * v * v).collect();
        let u = b0.matvec_t(&y);
        let v = b0.matvec_t(&g);
        let mut want = b0.clone();
        for i in 0..n {
            for j in 0..m {
                let d = mu * (g[i] * u[j] - y[i] * v[j]);
                want.set(i, j, want.get(i, j) - d);
            }
        }
        let got = rot.matrix();
        let tol = 32.0 * spec.format.resolution();
        for (a, b) in got.as_slice().iter().zip(want.as_slice()) {
            assert!((a - b).abs() <= tol, "{a} vs {b}");
        }
    }

    #[test]
    fn fxp_rotation_keeps_white_inputs_white() {
        // Mirror of the f32 rotation-only test: a skew update cannot
        // destroy whiteness, quantized or not.
        let spec = FxpSpec::q(4, 12);
        let mut rng = Pcg64::seed(37);
        let x = Mat::from_fn(4000, 4, |_, _| (rng.next_f32() * 2.0 - 1.0) * 3f32.sqrt());
        let mut rot = FxpEasiRot::new(4, 4, 1e-3, None, spec, QuantMode::BitExact);
        for _ in 0..2 {
            for i in 0..x.rows_count() {
                rot.step_raw(&spec.quantize_vec(x.row(i)));
            }
        }
        let y = Mat::from_fn(x.rows_count(), 4, |i, j| {
            spec.dequantize(rot.transform_raw(&spec.quantize_vec(x.row(i)))[j])
        });
        let w = whiteness_error(&y);
        assert!(w < 0.2, "rotation destroyed whiteness: {w}");
    }

    // ----------------------------------------------------------- unit

    #[test]
    fn fxp_unit_whitens_at_16_bits() {
        let spec = FxpSpec::q(4, 12);
        let x = bounded_data(5000, 8, 81);
        let mut unit = FxpDrUnit::new(FxpUnitConfig {
            input_dim: 8,
            output_dim: 3,
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rotate: true,
            rot_warmup: 1000,
            seed: 2018,
            whiten_spec: spec,
            rot_spec: spec,
            quant: QuantMode::BitExact,
        });
        for _ in 0..6 {
            unit.step_rows(&x);
        }
        let y = Mat::from_fn(x.rows_count(), 3, |i, j| unit.transform(x.row(i))[j]);
        let w = whiteness_error(&y);
        // The σ target rescales outputs uniformly, so whiteness (a
        // correlation-shaped metric on covariance/σ²) still applies.
        let sigma2 = (unit.whitener().target_sigma() as f64).powi(2);
        let cov = y.covariance(true, false);
        let mut err = 0.0f64;
        let n = 3;
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { sigma2 } else { 0.0 };
                err += ((cov.get(i, j) as f64 / sigma2) - want / sigma2).abs();
            }
        }
        err /= (n * n) as f64;
        assert!(err < 0.35, "unit outputs far from white: {err} (raw {w})");
    }

    #[test]
    fn fxp_unit_narrow_format_trains_without_divergence() {
        // Q1.15: prescale + σ-target machinery. The unit must stay
        // finite and keep learning signal (subspace must move off init).
        let spec = FxpSpec::q(1, 15);
        let x = bounded_data(3000, 8, 83);
        let mut unit = FxpDrUnit::new(FxpUnitConfig {
            input_dim: 8,
            output_dim: 3,
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rotate: true,
            rot_warmup: 500,
            seed: 7,
            whiten_spec: spec,
            rot_spec: spec,
            quant: QuantMode::BitExact,
        });
        let w0 = unit.whitener().subspace();
        for _ in 0..4 {
            unit.step_rows(&x);
        }
        let w1 = unit.whitener().subspace();
        let mut moved = 0.0f64;
        for (a, b) in w0.as_slice().iter().zip(w1.as_slice()) {
            moved += ((a - b) as f64).abs();
        }
        assert!(moved > 1e-3, "Q1.15 whitener never updated");
        assert!(w1.as_slice().iter().all(|v| v.is_finite()));
        assert!(unit.whitener().orthonormality_error() < 0.5);
    }

    #[test]
    fn fxp_unit_effective_matrix_matches_transform() {
        let spec = FxpSpec::q(4, 12);
        let x = bounded_data(1500, 8, 85);
        let mut unit = FxpDrUnit::new(FxpUnitConfig {
            input_dim: 8,
            output_dim: 4,
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rotate: true,
            rot_warmup: 200,
            seed: 9,
            whiten_spec: spec,
            rot_spec: spec,
            quant: QuantMode::BitExact,
        });
        unit.step_rows(&x);
        let eff = unit.effective_matrix();
        // The dense composition is an f32 approximation of the integer
        // forward path; agreement within a generous quantization budget.
        for i in 0..10 {
            let direct = unit.transform(x.row(i));
            let via = eff.matvec(x.row(i));
            for (a, b) in direct.iter().zip(&via) {
                assert!(
                    (a - b).abs() < 64.0 * spec.format.resolution(),
                    "{a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn fxp_unit_mux_toggle() {
        let spec = FxpSpec::q(4, 12);
        let mut unit = FxpDrUnit::new(FxpUnitConfig {
            input_dim: 8,
            output_dim: 4,
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rotate: true,
            rot_warmup: 0,
            seed: 1,
            whiten_spec: spec,
            rot_spec: spec,
            quant: QuantMode::BitExact,
        });
        assert!(unit.rotation_enabled());
        unit.set_rotation(false);
        assert!(!unit.rotation_enabled());
        let x = vec![0.5f32; 8];
        unit.step(&x);
        assert_eq!(unit.transform(&x).len(), 4);
    }

    // ------------------------------------------------- STE / mixed

    #[test]
    fn ste_gha_learns_where_bit_exact_stalls() {
        // Q4.4 (8-bit): the bit-exact Sanger delta μ·y·(x−c) is far
        // below one LSB (1/16) at μ=2e-3, so integer training barely
        // moves; the STE shadow accumulates the same sub-LSB updates
        // and converges toward the principal subspace.
        use crate::pca::BatchPca;
        let spec = FxpSpec::q(4, 4);
        let x = bounded_data(4000, 6, 71);
        let mut exact = FxpGha::new(6, 2, 2e-3, 5e-3, 2018, spec, QuantMode::BitExact);
        let mut ste = FxpGha::new(6, 2, 2e-3, 5e-3, 2018, spec, QuantMode::Ste);
        for _ in 0..6 {
            for i in 0..x.rows_count() {
                let xq = spec.quantize_vec(x.row(i));
                exact.step_raw(&xq);
                ste.step_raw(&xq);
            }
        }
        let pca = BatchPca::fit(&x, 2);
        let alignment = |w: &Mat| -> f32 {
            let mut worst = 1.0f32;
            for i in 0..2 {
                let wi = w.row(i);
                let proj: f32 = (0..2)
                    .map(|k| crate::linalg::dot(wi, pca.components.row(k)).powi(2))
                    .sum();
                worst = worst.min(proj / crate::linalg::dot(wi, wi).max(1e-12));
            }
            worst
        };
        let a_ste = alignment(&ste.subspace());
        let a_exact = alignment(&exact.subspace());
        assert!(a_ste > 0.8, "STE failed to find the principal plane: {a_ste}");
        assert!(
            a_ste >= a_exact - 0.05,
            "STE ({a_ste:.2}) must not trail bit-exact ({a_exact:.2}) at 8 bits"
        );
    }

    #[test]
    fn ste_forward_path_is_quantized() {
        // The STE whitener's datapath weights must always be exactly
        // the quantization of its shadow — the deployed model *is* the
        // quantized model.
        let spec = FxpSpec::q(4, 8);
        let x = bounded_data(300, 6, 91);
        let mut g = FxpGha::new(6, 3, 5e-3, 5e-3, 11, spec, QuantMode::Ste);
        for i in 0..x.rows_count() {
            g.step_raw(&spec.quantize_vec(x.row(i)));
        }
        let w = g.subspace();
        for &v in w.as_slice() {
            let q = spec.dequantize(spec.quantize(v));
            assert!((v - q).abs() < 1e-9, "datapath weight off-grid: {v}");
        }
        assert_eq!(g.quant_mode(), QuantMode::Ste);
    }

    #[test]
    fn ste_rotation_keeps_white_inputs_white() {
        let spec = FxpSpec::q(4, 8);
        let mut rng = Pcg64::seed(53);
        let x = Mat::from_fn(3000, 4, |_, _| (rng.next_f32() * 2.0 - 1.0) * 3f32.sqrt());
        let mut rot = FxpEasiRot::new(4, 4, 1e-3, None, spec, QuantMode::Ste);
        for _ in 0..2 {
            for i in 0..x.rows_count() {
                rot.step_raw(&spec.quantize_vec(x.row(i)));
            }
        }
        let y = Mat::from_fn(x.rows_count(), 4, |i, j| {
            spec.dequantize(rot.transform_raw(&spec.quantize_vec(x.row(i)))[j])
        });
        let w = whiteness_error(&y);
        assert!(w < 0.25, "STE rotation destroyed whiteness: {w}");
    }

    #[test]
    fn mixed_precision_unit_trains_and_requantizes() {
        // Wide whitener + narrow rotation (the real-datapath shape):
        // the unit must stay finite, learn, and emit outputs in the
        // rotation's format.
        let whiten_spec = FxpSpec::q(8, 16);
        let rot_spec = FxpSpec::q(1, 15);
        let x = bounded_data(3000, 8, 95);
        let mut unit = FxpDrUnit::new(FxpUnitConfig {
            input_dim: 8,
            output_dim: 3,
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rotate: true,
            rot_warmup: 500,
            seed: 7,
            whiten_spec,
            rot_spec,
            quant: QuantMode::Ste,
        });
        // σ target honours the narrow rotation: 2^-(3-1) = 1/4.
        assert_eq!(unit.whitener().target_sigma(), 0.25);
        assert_eq!(unit.output_spec(), rot_spec);
        for _ in 0..4 {
            unit.step_rows(&x);
        }
        let y = unit.transform(x.row(0));
        assert_eq!(y.len(), 3);
        assert!(y.iter().all(|v| v.is_finite()));
        // Outputs live on the rotation format's grid.
        for &v in &y {
            let q = rot_spec.dequantize(rot_spec.quantize(v));
            assert!((v - q).abs() < 1e-9, "output off the rot grid: {v}");
        }
        // Mux off: outputs revert to the whitening format.
        unit.set_rotation(false);
        assert_eq!(unit.output_spec(), whiten_spec);
    }

    #[test]
    fn uniform_plan_unit_identical_to_pr1_datapath() {
        // A uniform plan's stage boundaries must be bit-exact no-ops:
        // drive the PR-1 datapath reconstructed from its parts (GHA +
        // clamp + rotation, with NO requantization between them) and
        // demand raw-word equality with the composed unit at every
        // output. If requantize_from ever stopped being the identity
        // for equal formats, this diverges.
        let spec = FxpSpec::q(4, 12);
        let (m, n, warmup) = (8usize, 4usize, 100u64);
        let (mu_w, mu_rot, seed) = (5e-3f32, 1e-3f32, 3u64);
        let x = bounded_data(1200, m, 97);

        let mut unit = FxpDrUnit::new(FxpUnitConfig {
            input_dim: m,
            output_dim: n,
            mu_w,
            mu_rot,
            rotate: true,
            rot_warmup: warmup,
            seed,
            whiten_spec: spec,
            rot_spec: spec,
            quant: QuantMode::BitExact,
        });

        // The PR-1 single-format composition, by hand.
        let mut gha = FxpGha::new(m, n, mu_w, 5e-3, seed, spec, QuantMode::BitExact);
        let sigma = gha.target_sigma();
        let mu_eff = mu_rot / (sigma * sigma * sigma * sigma);
        let mut rot =
            FxpEasiRot::new(n, n, mu_eff, None, spec, QuantMode::BitExact);
        let clamp = spec.quantize(4.0 * sigma);

        for i in 0..x.rows_count() {
            let xq = unit.quantize_input(x.row(i));
            unit.step_raw(&xq);
            gha.step_raw(&xq);
            if gha.steps() > warmup {
                let mut z = gha.whiten_raw(&xq);
                for v in &mut z {
                    *v = (*v).clamp(-clamp, clamp);
                }
                rot.step_raw(&z);
            }
        }
        for i in 0..20 {
            let xq = unit.quantize_input(x.row(i));
            let via_unit = unit.transform_raw(&xq);
            let via_parts = rot.transform_raw(&gha.whiten_raw(&xq));
            assert_eq!(via_unit, via_parts, "uniform boundary must be a no-op");
        }
        // And STE differs from bit-exact only through the update path —
        // its transform still returns rot-format outputs of same shape.
        let mut ste = FxpDrUnit::new(FxpUnitConfig {
            input_dim: m,
            output_dim: n,
            mu_w,
            mu_rot,
            rotate: true,
            rot_warmup: warmup,
            seed,
            whiten_spec: spec,
            rot_spec: spec,
            quant: QuantMode::Ste,
        });
        ste.step_rows(&x);
        assert_eq!(ste.transform(x.row(0)).len(), n);
    }

    // -------------------------------------------- tiled / multi-lane

    use crate::fxp::{Overflow, Rounding};

    /// (whiten, rot) spec pairs covering uniform and mixed plans and
    /// both overflow/rounding policy axes.
    fn tile_plan_grid() -> Vec<(FxpSpec, FxpSpec)> {
        let q412 = FxpSpec::q(4, 12);
        let mut wrap = q412;
        wrap.overflow = Overflow::Wrap;
        let mut trunc = q412;
        trunc.rounding = Rounding::Truncate;
        let mut wrap_trunc = FxpSpec::q(4, 8);
        wrap_trunc.overflow = Overflow::Wrap;
        wrap_trunc.rounding = Rounding::Truncate;
        vec![
            (q412, q412),             // uniform, sat+nearest
            (wrap, wrap),             // uniform, wrap
            (trunc, trunc),           // uniform, truncate
            (wrap_trunc, wrap_trunc), // uniform, wrap+truncate
            (FxpSpec::q(8, 16), FxpSpec::q(1, 15)), // mixed widths
            (FxpSpec::q(8, 16), trunc), // mixed width + policy
        ]
    }

    #[test]
    fn rp_tile_matches_per_sample() {
        let spec = FxpSpec::q(8, 16);
        for rp in [
            RandomProjection::new(32, 8, RpDistribution::Ternary, 3).unit_variance(),
            RandomProjection::new(32, 8, RpDistribution::Ternary, 5),
            RandomProjection::new(32, 8, RpDistribution::Gaussian, 4),
        ] {
            let frp = FxpRp::from_rp(&rp, spec);
            let rows = 17;
            let x: Vec<i32> = (0..rows * 32)
                .map(|i| ((i * 37) % 4001) as i32 - 2000)
                .collect();
            let mut tile = Vec::new();
            frp.apply_tile_raw(&x, rows, &mut tile);
            assert_eq!(tile.len(), rows * 8);
            for r in 0..rows {
                assert_eq!(
                    &tile[r * 8..(r + 1) * 8],
                    frp.apply_raw(&x[r * 32..(r + 1) * 32]).as_slice(),
                    "row {r} diverged"
                );
            }
        }
    }

    #[test]
    fn gha_tile_step_and_outputs_match_per_sample() {
        let spec = FxpSpec::q(4, 12);
        let (m, n, rows) = (10usize, 4usize, 300usize);
        let x = bounded_data(rows, m, 103);
        let tile: Vec<i32> = x.as_slice().iter().map(|&v| spec.quantize(v)).collect();
        let mut a = FxpGha::new(m, n, 5e-3, 5e-3, 11, spec, QuantMode::BitExact);
        let mut b = a.clone();
        for r in 0..rows {
            a.step_raw(&tile[r * m..(r + 1) * m]);
        }
        b.step_tile_raw(&tile, rows);
        assert_eq!(a.subspace().as_slice(), b.subspace().as_slice());
        let mut whiten_tile = Vec::new();
        b.whiten_tile_raw(&tile, rows, &mut whiten_tile);
        let mut project_tile = Vec::new();
        b.project_tile_raw(&tile, rows, &mut project_tile);
        for r in 0..rows {
            let xr = &tile[r * m..(r + 1) * m];
            assert_eq!(&whiten_tile[r * n..(r + 1) * n], a.whiten_raw(xr).as_slice());
            assert_eq!(
                &project_tile[r * n..(r + 1) * n],
                a.project_raw(xr).as_slice()
            );
        }
    }

    #[test]
    fn easi_tile_step_and_transform_match_per_sample() {
        let spec = FxpSpec::q(4, 12);
        let (m, rows) = (5usize, 400usize);
        let x = bounded_data(rows, m, 107);
        let tile: Vec<i32> = x.as_slice().iter().map(|&v| spec.quantize(v)).collect();
        for quant in [QuantMode::BitExact, QuantMode::Ste] {
            let mut a = FxpEasiRot::new(m, m, 1e-3, None, spec, quant);
            let mut b = a.clone();
            for r in 0..rows {
                a.step_raw(&tile[r * m..(r + 1) * m]);
            }
            b.step_tile_raw(&tile, rows);
            assert_eq!(a.matrix().as_slice(), b.matrix().as_slice(), "{quant:?}");
            let mut out = Vec::new();
            b.transform_tile_raw(&tile, rows, &mut out);
            for r in 0..rows {
                let zr = &tile[r * m..(r + 1) * m];
                assert_eq!(&out[r * m..(r + 1) * m], a.transform_raw(zr).as_slice());
            }
        }
    }

    #[test]
    fn unit_tile_and_multilane_bit_identical_across_plans() {
        // The acceptance-bar test: for uniform and mixed plans across
        // saturate/wrap × nearest/truncate, tile training must leave
        // the unit in exactly the per-sample state, and the tiled and
        // multi-lane forward paths must emit exactly the per-sample
        // raw words.
        for (wspec, rspec) in tile_plan_grid() {
            let (m, n, rows) = (8usize, 3usize, 500usize);
            let x = bounded_data(rows, m, 109);
            let cfg = FxpUnitConfig {
                input_dim: m,
                output_dim: n,
                mu_w: 5e-3,
                mu_rot: 1e-3,
                rotate: true,
                rot_warmup: 100,
                seed: 5,
                whiten_spec: wspec,
                rot_spec: rspec,
                quant: QuantMode::BitExact,
            };
            let mut per_sample = FxpDrUnit::new(cfg);
            let mut tiled = FxpDrUnit::new(cfg);
            let mut tile: Vec<i32> = Vec::with_capacity(rows * m);
            for i in 0..rows {
                tile.extend(per_sample.quantize_input(x.row(i)));
            }
            for r in 0..rows {
                per_sample.step_raw(&tile[r * m..(r + 1) * m]);
            }
            tiled.step_tile_raw(&tile, rows);
            assert_eq!(
                per_sample.effective_matrix().as_slice(),
                tiled.effective_matrix().as_slice(),
                "tile training diverged (w={} r={})",
                wspec.label(),
                rspec.label()
            );

            let mut want: Vec<i32> = Vec::with_capacity(rows * n);
            for r in 0..rows {
                want.extend(per_sample.transform_raw(&tile[r * m..(r + 1) * m]));
            }
            let mut scratch = Scratch::new();
            let mut got_tiled = Vec::new();
            tiled.transform_tile_raw(&tile, rows, &mut scratch, &mut got_tiled);
            assert_eq!(got_tiled, want, "tiled forward (w={})", wspec.label());
            for lanes in [1usize, 2, 3, 8, rows + 7] {
                let mut got_lanes = Vec::new();
                tiled.transform_tile_raw_multilane(&tile, rows, lanes, &mut got_lanes);
                assert_eq!(
                    got_lanes, want,
                    "multilane forward lanes={lanes} (w={})",
                    wspec.label()
                );
            }
        }
    }

    #[test]
    fn unit_tile_bit_identical_under_ste() {
        // Same bit-identity bar for the STE trainer (shadow-weight
        // path) on a uniform and a mixed plan.
        for (wspec, rspec) in [
            (FxpSpec::q(4, 12), FxpSpec::q(4, 12)),
            (FxpSpec::q(8, 16), FxpSpec::q(1, 15)),
        ] {
            let (m, n, rows) = (8usize, 3usize, 400usize);
            let x = bounded_data(rows, m, 113);
            let cfg = FxpUnitConfig {
                input_dim: m,
                output_dim: n,
                mu_w: 5e-3,
                mu_rot: 1e-3,
                rotate: true,
                rot_warmup: 50,
                seed: 9,
                whiten_spec: wspec,
                rot_spec: rspec,
                quant: QuantMode::Ste,
            };
            let mut per_sample = FxpDrUnit::new(cfg);
            let mut tiled = FxpDrUnit::new(cfg);
            let mut tile: Vec<i32> = Vec::with_capacity(rows * m);
            for i in 0..rows {
                tile.extend(per_sample.quantize_input(x.row(i)));
            }
            for r in 0..rows {
                per_sample.step_raw(&tile[r * m..(r + 1) * m]);
            }
            tiled.step_tile_raw(&tile, rows);
            assert_eq!(
                per_sample.effective_matrix().as_slice(),
                tiled.effective_matrix().as_slice()
            );
            let mut want: Vec<i32> = Vec::new();
            for r in 0..rows {
                want.extend(per_sample.transform_raw(&tile[r * m..(r + 1) * m]));
            }
            let mut got = Vec::new();
            tiled.transform_tile_raw_multilane(&tile, rows, 4, &mut got);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn scratch_reuse_across_shapes_is_safe() {
        // One Scratch driven through units of different (m, n) shapes,
        // interleaved, must produce exactly what fresh scratch produces
        // — the buffers are sized per call, never assumed.
        let spec = FxpSpec::q(4, 12);
        let mut shared = Scratch::new();
        for (m, n, rows, seed) in
            [(12usize, 5usize, 40usize, 1u64), (5, 2, 90, 2), (16, 7, 11, 3), (5, 2, 90, 2)]
        {
            let x = bounded_data(rows, m, 200 + seed);
            let mut unit = FxpDrUnit::new(FxpUnitConfig {
                input_dim: m,
                output_dim: n,
                mu_w: 5e-3,
                mu_rot: 1e-3,
                rotate: true,
                rot_warmup: 10,
                seed,
                whiten_spec: spec,
                rot_spec: spec,
                quant: QuantMode::BitExact,
            });
            let mut tile: Vec<i32> = Vec::with_capacity(rows * m);
            for i in 0..rows {
                tile.extend(unit.quantize_input(x.row(i)));
            }
            unit.step_tile_raw(&tile, rows);
            let mut via_shared = Vec::new();
            unit.transform_tile_raw(&tile, rows, &mut shared, &mut via_shared);
            let mut fresh = Scratch::new();
            let mut via_fresh = Vec::new();
            unit.transform_tile_raw(&tile, rows, &mut fresh, &mut via_fresh);
            assert_eq!(via_shared, via_fresh, "shape ({m},{n}) corrupted scratch");
        }
    }

    #[test]
    fn multilane_empty_and_tiny_tiles() {
        let spec = FxpSpec::q(4, 12);
        let unit = FxpDrUnit::new(FxpUnitConfig {
            input_dim: 6,
            output_dim: 2,
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rotate: true,
            rot_warmup: 0,
            seed: 1,
            whiten_spec: spec,
            rot_spec: spec,
            quant: QuantMode::BitExact,
        });
        let mut out = vec![99i32; 4];
        unit.transform_tile_raw_multilane(&[], 0, 4, &mut out);
        assert!(out.is_empty(), "empty tile must clear the output");
        // One row, many lanes: clamps to one lane.
        let tile: Vec<i32> = (0..6).map(|i| spec.quantize(i as f32 * 0.1)).collect();
        let mut got = Vec::new();
        unit.transform_tile_raw_multilane(&tile, 1, 16, &mut got);
        assert_eq!(got, unit.transform_raw(&tile));
    }

    #[test]
    fn multilane_lane_count_edge_cases() {
        // lanes == 1 and lanes > rows both take the sequential
        // short-circuit (no threads) and must emit exactly the tiled
        // kernel's words; lanes == rows still shards (one row per lane).
        let spec = FxpSpec::q(4, 12);
        let (m, n, rows) = (6usize, 2usize, 5usize);
        let unit = FxpDrUnit::new(FxpUnitConfig {
            input_dim: m,
            output_dim: n,
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rotate: true,
            rot_warmup: 0,
            seed: 1,
            whiten_spec: spec,
            rot_spec: spec,
            quant: QuantMode::BitExact,
        });
        let tile: Vec<i32> = (0..rows * m)
            .map(|i| spec.quantize(((i * 7 % 13) as f32 - 6.0) * 0.1))
            .collect();
        let mut scratch = Scratch::new();
        let mut want = Vec::new();
        unit.transform_tile_raw(&tile, rows, &mut scratch, &mut want);
        for lanes in [1usize, rows, rows + 1, 64] {
            let mut got = Vec::new();
            unit.transform_tile_raw_multilane(&tile, rows, lanes, &mut got);
            assert_eq!(got, want, "lanes={lanes}");
        }
    }

    #[test]
    fn ste_sharded_backward_pass_bit_identical() {
        // The sharded STE shadow update must leave the rotation in
        // exactly the sequential state for every lane count (including
        // lanes > rows, which clamps), on uniform and mixed plans.
        let spec = FxpSpec::q(4, 8);
        let (m, rows) = (6usize, 300usize);
        let x = bounded_data(rows, m, 131);
        let tile: Vec<i32> = x.as_slice().iter().map(|&v| spec.quantize(v)).collect();
        let mut seq = FxpEasiRot::new(m, m, 1e-3, None, spec, QuantMode::Ste);
        seq.step_tile_raw(&tile, rows);
        for lanes in [2usize, 3, m, m + 5] {
            let mut sharded = FxpEasiRot::new(m, m, 1e-3, None, spec, QuantMode::Ste);
            sharded.set_train_lanes(lanes);
            sharded.step_tile_raw(&tile, rows);
            assert_eq!(
                seq.matrix().as_slice(),
                sharded.matrix().as_slice(),
                "lanes={lanes}"
            );
            // Forward path after training matches too.
            for r in 0..5 {
                let zr = &tile[r * m..(r + 1) * m];
                assert_eq!(seq.transform_raw(zr), sharded.transform_raw(zr));
            }
        }
        // And through the composed unit's knob.
        let cfg = FxpUnitConfig {
            input_dim: 8,
            output_dim: 3,
            mu_w: 5e-3,
            mu_rot: 1e-3,
            rotate: true,
            rot_warmup: 50,
            seed: 9,
            whiten_spec: FxpSpec::q(8, 16),
            rot_spec: FxpSpec::q(1, 15),
            quant: QuantMode::Ste,
        };
        let xu = bounded_data(400, 8, 137);
        let mut a = FxpDrUnit::new(cfg);
        let mut b = FxpDrUnit::new(cfg);
        b.set_train_lanes(3);
        a.step_rows(&xu);
        b.step_rows(&xu);
        assert_eq!(
            a.effective_matrix().as_slice(),
            b.effective_matrix().as_slice()
        );
    }

    #[test]
    fn fxp_unit_deterministic() {
        let spec = FxpSpec::q(4, 12);
        let x = bounded_data(500, 8, 87);
        let run = || {
            let mut u = FxpDrUnit::new(FxpUnitConfig {
                input_dim: 8,
                output_dim: 4,
                mu_w: 5e-3,
                mu_rot: 1e-3,
                rotate: true,
                rot_warmup: 100,
                seed: 3,
                whiten_spec: spec,
                rot_spec: spec,
                quant: QuantMode::BitExact,
            });
            u.step_rows(&x);
            u.effective_matrix()
        };
        assert_eq!(run().as_slice(), run().as_slice());
    }
}
