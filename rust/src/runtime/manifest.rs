//! The artifact manifest written by `python/compile/aot.py`.
//!
//! `manifest.json` is the contract between the build-time Python layers
//! and the run-time Rust layer: per executable variant it records the
//! HLO text file, the positional input shapes/dtypes and the output
//! arity. The loader validates every execution against it, so shape
//! drift between the layers fails loudly instead of corrupting state.

use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor crossing the PJRT boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    /// Only "f32" today (matching the paper's 32-bit floating point
    /// implementation); kept as a string for forward compatibility.
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        let shape = v
            .field("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?;
        let dtype = v.field("dtype")?.as_str()?.to_string();
        ensure!(dtype == "f32", "unsupported dtype {dtype}");
        ensure!(!shape.is_empty() || dtype == "f32", "scalar outputs allowed");
        Ok(Self { shape, dtype })
    }
}

/// One executable variant.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    pub description: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub fingerprint: String,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated from I/O for testability).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let root = Json::parse(text).context("parsing manifest.json")?;
        let version = root.field("version")?.as_usize()?;
        ensure!(version == 1, "unsupported manifest version {version}");
        let fingerprint = root
            .get("fingerprint")
            .and_then(|f| f.as_str().ok())
            .unwrap_or("")
            .to_string();
        let mut artifacts = BTreeMap::new();
        for entry in root.field("artifacts")?.as_arr()? {
            let name = entry.field("name")?.as_str()?.to_string();
            let spec = ArtifactSpec {
                name: name.clone(),
                file: entry.field("file")?.as_str()?.to_string(),
                description: entry
                    .get("description")
                    .and_then(|d| d.as_str().ok())
                    .unwrap_or("")
                    .to_string(),
                inputs: entry
                    .field("inputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: entry
                    .field("outputs")?
                    .as_arr()?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_>>()?,
            };
            ensure!(!spec.inputs.is_empty(), "artifact {name} has no inputs");
            ensure!(!spec.outputs.is_empty(), "artifact {name} has no outputs");
            if artifacts.insert(name.clone(), spec).is_some() {
                bail!("duplicate artifact name {name}");
            }
        }
        ensure!(!artifacts.is_empty(), "manifest lists no artifacts");
        Ok(Self {
            dir: dir.to_path_buf(),
            fingerprint,
            artifacts,
        })
    }

    /// Look up an artifact by exact name.
    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact '{name}' not in manifest (have: {})",
                self.artifacts.keys().cloned().collect::<Vec<_>>().join(", ")
            )
        })
    }

    /// Absolute path of an artifact's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// All names, sorted (BTreeMap order).
    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(String::as_str).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "fingerprint": "abc",
      "artifacts": [
        {"name": "easi", "file": "easi.hlo.txt", "description": "d",
         "inputs": [{"shape": [8, 32], "dtype": "f32"},
                    {"shape": [256, 32], "dtype": "f32"},
                    {"shape": [1], "dtype": "f32"}],
         "outputs": [{"shape": [8, 32], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/art")).unwrap();
        let a = m.get("easi").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![8, 32]);
        assert_eq!(a.inputs[0].elements(), 256);
        assert_eq!(m.path_of(a), Path::new("/tmp/art/easi.hlo.txt"));
        assert_eq!(m.fingerprint, "abc");
    }

    #[test]
    fn unknown_artifact_error_lists_names() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("easi"), "{err}");
    }

    #[test]
    fn rejects_bad_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_non_f32() {
        let bad = SAMPLE.replace("\"f32\"", "\"bf16\"");
        assert!(Manifest::parse(&bad, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let dup = SAMPLE.replace(
            "]\n    }",
            r#", {"name": "easi", "file": "x", "inputs": [{"shape": [1], "dtype": "f32"}], "outputs": [{"shape": [1], "dtype": "f32"}]}]
    }"#,
        );
        assert!(Manifest::parse(&dup, Path::new("/tmp")).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // Integration touch-point: if `make artifacts` has run, the real
        // manifest must parse and contain the Table I variants.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.get("easi_full_norm_m32_n16_b256").is_ok());
            assert!(m.get("rp_easi_norm_m32_p16_n8_b256").is_ok());
        }
    }
}
