//! PJRT runtime — loads the AOT-compiled HLO artifacts and executes
//! them from the coordinator's hot path.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT plugin):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `executable.execute`. One compiled executable per
//! model variant, cached after first use; all executions are validated
//! against the manifest's shapes before they reach PJRT, so layer drift
//! fails with a readable error instead of a C++ abort.
//!
//! Python is NEVER on this path — the HLO text was produced once at
//! build time by `python/compile/aot.py`.
//!
//! Offline builds use the in-crate `xla` stub (see `runtime/xla.rs`):
//! identical API surface, with client construction failing cleanly.
//! Linking the real bindings changes no code here.

mod xla;

pub mod manifest;

pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

use crate::linalg::Mat;
use anyhow::{ensure, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

/// A tensor crossing the PJRT boundary (host side).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "tensor shape/data mismatch"
        );
        Self { shape, data }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            shape: vec![1],
            data: vec![v],
        }
    }

    pub fn from_mat(m: &Mat) -> Self {
        Self {
            shape: vec![m.rows_count(), m.cols_count()],
            data: m.as_slice().to_vec(),
        }
    }

    pub fn into_mat(self) -> Result<Mat> {
        ensure!(self.shape.len() == 2, "tensor is not rank-2: {:?}", self.shape);
        Ok(Mat::from_vec(self.shape[0], self.shape[1], self.data))
    }

    fn matches(&self, spec: &TensorSpec) -> bool {
        // Outputs may be scalars (shape []) which we surface as len-1.
        self.shape == spec.shape || (spec.shape.is_empty() && self.data.len() == 1)
    }
}

/// The runtime: a PJRT CPU client plus a lazily-populated executable
/// cache keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    // RefCell: compilation populates the cache behind a shared receiver
    // so call sites can hold `&Runtime`.
    executables: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Create a runtime over an artifact directory (must contain
    /// `manifest.json`; see `make artifacts`).
    pub fn load(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            executables: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact.
    fn ensure_compiled(&self, name: &str) -> Result<()> {
        if self.executables.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.get(name)?;
        let path = self.manifest.path_of(spec);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let computation = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&computation)
            .with_context(|| format!("compiling artifact {name}"))?;
        self.executables.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    /// Eagerly compile a set of artifacts (the coordinator warms its
    /// variants at startup so the hot path never compiles).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.ensure_compiled(n)?;
        }
        Ok(())
    }

    /// Execute an artifact with host tensors, returning host tensors.
    ///
    /// Inputs are validated against the manifest; outputs are unwrapped
    /// from the tuple that `return_tuple=True` lowering produces and
    /// validated too.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let spec = self.manifest.get(name)?.clone();
        ensure!(
            inputs.len() == spec.inputs.len(),
            "{name}: expected {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            ensure!(
                t.matches(s),
                "{name}: input {i} shape {:?} does not match manifest {:?}",
                t.shape,
                s.shape
            );
        }
        self.ensure_compiled(name)?;

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .with_context(|| format!("staging input for {name}"))
            })
            .collect::<Result<_>>()?;

        let cache = self.executables.borrow();
        let exe = cache.get(name).expect("ensured above");
        let result = exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {name}"))?;
        let root = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: the root is a tuple with
        // one element per logical output.
        let elements = root.to_tuple().context("untupling result")?;
        ensure!(
            elements.len() == spec.outputs.len(),
            "{name}: expected {} outputs, got {}",
            spec.outputs.len(),
            elements.len()
        );
        let mut outs = Vec::with_capacity(elements.len());
        for (lit, ospec) in elements.into_iter().zip(&spec.outputs) {
            let data = lit.to_vec::<f32>().context("reading output literal")?;
            ensure!(
                data.len() == ospec.elements().max(1),
                "{name}: output element count {} vs spec {:?}",
                data.len(),
                ospec.shape
            );
            let shape = if ospec.shape.is_empty() {
                vec![1]
            } else {
                ospec.shape.clone()
            };
            outs.push(Tensor { shape, data });
        }
        Ok(outs)
    }

    /// Convenience: execute an artifact that returns exactly one tensor.
    pub fn execute1(&self, name: &str, inputs: &[Tensor]) -> Result<Tensor> {
        let mut outs = self.execute(name, inputs)?;
        ensure!(
            outs.len() == 1,
            "{name}: expected single output, got {}",
            outs.len()
        );
        Ok(outs.pop().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert!(t.matches(&TensorSpec {
            shape: vec![2, 3],
            dtype: "f32".into()
        }));
        assert!(!t.matches(&TensorSpec {
            shape: vec![3, 2],
            dtype: "f32".into()
        }));
    }

    #[test]
    #[should_panic(expected = "tensor shape/data mismatch")]
    fn tensor_rejects_bad_len() {
        Tensor::new(vec![2, 2], vec![0.0; 3]);
    }

    #[test]
    fn tensor_mat_roundtrip() {
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let t = Tensor::from_mat(&m);
        assert_eq!(t.shape, vec![2, 2]);
        assert_eq!(t.into_mat().unwrap(), m);
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar(0.5);
        assert_eq!(t.shape, vec![1]);
        assert!(t.matches(&TensorSpec {
            shape: vec![1],
            dtype: "f32".into()
        }));
    }
}
