//! In-crate stub of the `xla` crate's API surface (xla_extension 0.5.1).
//!
//! The offline build environment cannot fetch (or link) the real PJRT
//! bindings, so this module provides the exact types and signatures
//! `runtime/mod.rs` consumes, with [`PjRtClient::cpu`] failing cleanly
//! at construction time. Every downstream method is only reachable
//! through a constructed client, which the stub makes uninhabited, so
//! the compiler proves the execution paths dead — swapping in the real
//! `xla` crate (delete this module, add the dependency) changes no
//! call-site code.
//!
//! Tests and benches already gate on the artifact manifest being
//! present; on a stub build `Runtime::load` fails before any of this is
//! reached unless someone has run `make artifacts`, in which case the
//! client construction error below explains what is missing.

use std::borrow::Borrow;
use std::fmt;
use std::path::Path;

/// Error type mirroring the real bindings' (string-carrying) errors.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

type Result<T> = std::result::Result<T, XlaError>;

fn unavailable() -> XlaError {
    XlaError(
        "PJRT is unavailable: this build uses the in-crate xla stub \
         (src/runtime/xla.rs); link the real `xla` crate to execute \
         AOT artifacts"
            .to_string(),
    )
}

/// Uninhabited marker: types holding it can never be constructed, so
/// their methods are statically dead code on stub builds.
enum Never {}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    never: Never,
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        match self.never {}
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.never {}
    }
}

/// Parsed HLO module (stub: parsing always fails — nothing to feed it
/// to without a client anyway).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(unavailable())
    }
}

/// An XLA computation wrapping a parsed HLO module.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A compiled executable (stub: unconstructible).
pub struct PjRtLoadedExecutable {
    never: Never,
}

impl PjRtLoadedExecutable {
    /// Execute with per-device argument lists; the real API returns one
    /// buffer list per device.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.never {}
    }
}

/// A device buffer (stub: unconstructible).
pub struct PjRtBuffer {
    never: Never,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.never {}
    }
}

/// Element types a literal can be read back as.
pub trait ArrayElement: Sized {
    fn read(lit: &Literal) -> Vec<Self>;
}

impl ArrayElement for f32 {
    fn read(lit: &Literal) -> Vec<Self> {
        lit.data.clone()
    }
}

/// Host literal: flat f32 payload plus dimensions. Constructible (the
/// staging path runs before execution fails), so it behaves faithfully.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: data.to_vec(),
        }
    }

    /// Reshape without moving data (row-major, like the real API).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let elements: i64 = dims.iter().product();
        if elements as usize != self.data.len() {
            return Err(XlaError(format!(
                "reshape to {:?} incompatible with {} elements",
                dims,
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
        })
    }

    /// Destructure a tuple literal. The stub never produces tuples
    /// (results require execution), so this is always an error here.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    /// Read the payload back as a typed host vector.
    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        Ok(T::read(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("stub"));
    }

    #[test]
    fn literal_staging_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 4]).is_err());
    }
}
