//! PCG64 (XSL-RR 128/64) — O'Neill 2014. The workhorse generator for all
//! stochastic components: 128-bit LCG state, 64-bit xorshift-rotate
//! output. Statistically strong, tiny, and trivially reproducible.

use super::{Rng, SplitMix64};

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

/// PCG64 generator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pcg64 {
    state: u128,
    /// Stream selector (must be odd); distinct increments give
    /// independent sequences from the same state.
    inc: u128,
}

impl Pcg64 {
    /// Seed via SplitMix64 expansion of a single `u64`.
    pub fn seed(seed: u64) -> Self {
        let mut sm = SplitMix64::seed(seed);
        let s = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let i = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        Self::from_state(s, i)
    }

    /// Seed a named sub-stream: `seed` picks the state, `stream` the
    /// increment. Streams with the same seed but different `stream` are
    /// independent — used to give each subsystem (datasets, R matrix,
    /// MLP init, batcher) its own generator.
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::seed(seed).split(stream);
        let s = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let i = ((stream as u128) << 64) | sm.next_u64() as u128;
        Self::from_state(s, i)
    }

    fn from_state(state: u128, inc: u128) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (inc << 1) | 1, // increment must be odd
        };
        rng.step();
        rng.state = rng.state.wrapping_add(state);
        rng.step();
        rng
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }
}

impl Rng for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.step();
        // XSL-RR output function: xor-fold the halves, rotate by the top
        // six bits.
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::RngExt;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seed(11);
        let mut b = Pcg64::seed(11);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::seed_stream(11, 0);
        let mut b = Pcg64::seed_stream(11, 1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn equidistribution_rough() {
        // Chi-square-ish sanity over 16 buckets.
        let mut rng = Pcg64::seed(12);
        let mut buckets = [0usize; 16];
        let n = 160_000;
        for _ in 0..n {
            buckets[(rng.next_u64() >> 60) as usize] += 1;
        }
        let expected = n as f64 / 16.0;
        for b in buckets {
            assert!(
                (b as f64 - expected).abs() < expected * 0.05,
                "bucket {b} vs {expected}"
            );
        }
    }

    #[test]
    fn f32_variant_in_range() {
        let mut rng = Pcg64::seed(13);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
