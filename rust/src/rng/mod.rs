//! Deterministic pseudo-random number generation.
//!
//! The paper's experiments depend on reproducible random draws (the
//! ternary projection matrix R, dataset generators, weight inits). We
//! implement SplitMix64 (seeding / stream splitting) and PCG64 (the
//! workhorse generator) from scratch so results are bit-reproducible
//! across platforms and independent of external crate versions — the
//! same reasoning that makes an FPGA LFSR preferable to a software RNG
//! in the original hardware.

mod pcg;
mod splitmix;

pub use pcg::Pcg64;
pub use splitmix::SplitMix64;

/// Derive an independent sub-seed from a master seed and a stream tag
/// (dataset draw, model init, classifier init, …). One SplitMix64 split
/// plus one output, so adjacent tags and adjacent master seeds are
/// decorrelated — experiment sweeps must not couple their data draw to
/// their weight-init noise.
pub fn derive_seed(master: u64, tag: u64) -> u64 {
    SplitMix64::seed(master).split(tag).next_u64()
}

/// A uniform source of random `u64`s.
///
/// Implemented by [`Pcg64`] and [`SplitMix64`]; all higher-level samplers
/// ([`RngExt`]) are provided generically on top of it.
pub trait Rng {
    /// Next raw 64-bit output.
    fn next_u64(&mut self) -> u64;
}

/// Derived samplers over any [`Rng`].
pub trait RngExt: Rng {
    /// Uniform `f64` in `[0, 1)` using the top 53 bits.
    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)` using the top 24 bits.
    fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift with
    /// rejection (unbiased).
    fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound {
                return (m >> 64) as u64;
            }
            // rejection zone: low < bound — only reject within the biased
            // remainder band
            let threshold = bound.wrapping_neg() % bound;
            if low >= threshold {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box–Muller (polar form would need caching; the
    /// trig form keeps the generator stateless w.r.t. sampling).
    fn next_gaussian(&mut self) -> f64 {
        // Avoid ln(0): nudge u1 away from zero.
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Gaussian with the given mean / standard deviation.
    fn next_gaussian_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.next_gaussian()
    }

    /// The ternary random-projection distribution of Fox et al. (FPT'16),
    /// used by the paper's RP front end:
    /// `+1` w.p. `1/(2n)`, `-1` w.p. `1/(2n)`, `0` w.p. `1 - 1/n`.
    ///
    /// Multiplication-free in hardware: each nonzero becomes one
    /// adder/subtractor input.
    fn next_ternary(&mut self, n: usize) -> i8 {
        debug_assert!(n >= 1);
        let u = self.next_f64();
        let p = 1.0 / (2.0 * n as f64);
        if u < p {
            1
        } else if u < 2.0 * p {
            -1
        } else {
            0
        }
    }

    /// Achlioptas's database-friendly distribution:
    /// `±√3` w.p. 1/6 each, `0` w.p. 2/3. Returned as the ternary sign;
    /// callers scale by √3.
    fn next_achlioptas(&mut self) -> i8 {
        match self.next_below(6) {
            0 => 1,
            1 => -1,
            _ => 0,
        }
    }

    /// Fisher–Yates shuffle.
    fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..len` (partial Fisher–Yates).
    fn sample_indices(&mut self, len: usize, k: usize) -> Vec<usize> {
        assert!(k <= len);
        let mut idx: Vec<usize> = (0..len).collect();
        for i in 0..k {
            let j = i + self.next_below((len - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

impl<T: Rng + ?Sized> RngExt for T {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seed(1);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn next_below_is_in_range_and_covers() {
        let mut rng = Pcg64::seed(2);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let x = rng.next_below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::seed(3);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_gaussian();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn ternary_distribution_matches_fox() {
        let mut rng = Pcg64::seed(4);
        let n = 8;
        let trials = 400_000;
        let mut counts = [0usize; 3]; // -1, 0, +1
        for _ in 0..trials {
            match rng.next_ternary(n) {
                -1 => counts[0] += 1,
                0 => counts[1] += 1,
                1 => counts[2] += 1,
                _ => unreachable!(),
            }
        }
        let p = 1.0 / (2.0 * n as f64);
        let f = |c: usize| c as f64 / trials as f64;
        assert!((f(counts[0]) - p).abs() < 0.003);
        assert!((f(counts[2]) - p).abs() < 0.003);
        assert!((f(counts[1]) - (1.0 - 2.0 * p)).abs() < 0.005);
    }

    #[test]
    fn ternary_has_zero_mean_unit_like_scaling() {
        // E[r] = 0, E[r^2] = 1/n — the JL scaling factor is sqrt(n).
        let mut rng = Pcg64::seed(5);
        let n = 4;
        let trials = 400_000;
        let mut sum = 0.0;
        let mut sum2 = 0.0;
        for _ in 0..trials {
            let r = rng.next_ternary(n) as f64;
            sum += r;
            sum2 += r * r;
        }
        assert!((sum / trials as f64).abs() < 0.005);
        assert!((sum2 / trials as f64 - 1.0 / n as f64).abs() < 0.005);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(6);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "shuffle moved something");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seed(7);
        let idx = rng.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn derive_seed_decorrelates_tags_and_masters() {
        assert_eq!(derive_seed(2018, 1), derive_seed(2018, 1));
        assert_ne!(derive_seed(2018, 1), derive_seed(2018, 2));
        assert_ne!(derive_seed(2018, 1), derive_seed(2019, 1));
        assert_ne!(derive_seed(2018, 1), 2018);
    }

    #[test]
    fn streams_are_reproducible() {
        let a: Vec<u64> = {
            let mut r = Pcg64::seed(42);
            (0..16).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Pcg64::seed(42);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Pcg64::seed(43);
            (0..16).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }
}
