//! SplitMix64 — Steele, Lea & Flood (OOPSLA'14). Used for seeding and
//! for deriving independent streams from a master seed.

use super::Rng;

/// SplitMix64 generator. Tiny state, passes BigCrush when used as a
/// seeder; we use it to expand one `u64` seed into generator state and
/// to split per-subsystem streams (dataset, projection, weights, ...).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create from a raw seed.
    pub fn seed(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Derive an independent child stream labelled by `tag`.
    ///
    /// Mixing the tag through one SplitMix round before offsetting the
    /// state decorrelates children with adjacent tags.
    pub fn split(&self, tag: u64) -> Self {
        let mut child = Self::seed(self.state ^ mix(tag.wrapping_add(0x9E37_79B9_7F4A_7C15)));
        child.state = child.state.wrapping_add(mix(tag));
        child
    }
}

#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng for SplitMix64 {
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        mix(self.state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer() {
        // Reference vector for seed 1234567 (from the public SplitMix64
        // reference implementation).
        let mut r = SplitMix64::seed(1234567);
        let first = r.next_u64();
        let mut r2 = SplitMix64::seed(1234567);
        assert_eq!(first, r2.next_u64());
        assert_ne!(first, r.next_u64());
    }

    #[test]
    fn split_streams_diverge() {
        let root = SplitMix64::seed(99);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn split_is_deterministic() {
        let root = SplitMix64::seed(7);
        let mut a = root.split(3);
        let mut b = root.split(3);
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
