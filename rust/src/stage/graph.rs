//! [`StageGraph`] — a composed cascade of [`Stage`]s, one datapath for
//! both numeric domains.
//!
//! The graph owns boxed stages plus the entry arithmetic of its domain:
//! f32 graphs stream `&[f32]` tiles straight through; fixed-point
//! graphs quantize samples once at the entry (`entry.quantize(v ·
//! prescale)` — the shared-ingress arithmetic) and thread raw words
//! stage to stage, requantizing at every format boundary with the
//! destination stage's rounding/overflow policy (a bit-exact no-op when
//! formats match, so uniform plans behave exactly like the
//! single-format datapath).
//!
//! A training pass walks the stage list once per tile: every stage
//! before the last active adaptive stage emits its per-row
//! training-path outputs into graph-owned ping-pong scratch buffers
//! (allocation-free in steady state), the last trainable stage consumes
//! without emitting, and muxed-out adaptive stages have their sample
//! counters advanced so warm-up gates stay in sync with the stream.
//! Because each adaptive stage emits a row's output immediately after
//! that row's update, this stage-by-stage pass is bit-identical to the
//! legacy fused per-row recursions (`DrUnit::step` / `FxpDrUnit::
//! step_raw`) — the downstream stage sees the same words in the same
//! order.
//!
//! Forward paths: [`StageGraph::transform_rows`] chains stage
//! transforms tile-at-a-time (the pipeline semantics);
//! [`StageGraph::forward_rows`] is the coordinator's bulk path — the
//! folded dense matrix for f32 (exactly the legacy effective-matrix
//! arithmetic) and the multi-lane row-sharded quantized forward for
//! fixed point (deterministic disjoint-slice merge, bit-identical to
//! single-lane).

use super::adapters::{FxpRpStage, RpStage};
use super::{Stage, StageRole, StageState};
use crate::fxp::kernels::resize_buf;
use crate::fxp::{input_prescale, FxpSpec};
use crate::linalg::Mat;
use crate::rp::RandomProjection;
use crate::telemetry::{Telemetry, TelemetrySnapshot};
use anyhow::{ensure, Result};

/// The numeric domain a graph computes in.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Domain {
    /// IEEE single precision end to end.
    F32,
    /// Bit-accurate fixed point: samples are quantized once at the
    /// entry format (after the power-of-two prescale) and flow as raw
    /// words from there.
    Fxp { entry: FxpSpec, prescale: f32 },
}

/// Pre-staged input for [`StageGraph::step_staged`]: the entry work
/// already ran off the compute path.
#[derive(Debug, Clone, Copy)]
pub enum StagedInput<'a> {
    /// An entry-quantized raw tile (fixed-point graphs), plus the
    /// timing/overflow deltas captured around the off-thread quantize
    /// pass (attributed to the ingress telemetry slot at commit).
    Raw {
        words: &'a [i32],
        ns: u64,
        sat: u64,
        wrap: u64,
    },
    /// Validated f32 row segments, concatenated in order into one tile
    /// (f32 staging is validation only — there is nothing to precompute).
    F32 { segments: &'a [&'a [f32]] },
}

/// Reusable tile workspaces for the training pass (ping-pong between
/// consecutive stages; buffers only grow, so steady-state training is
/// allocation-free).
#[derive(Default)]
struct GraphScratch {
    raw_a: Vec<i32>,
    raw_b: Vec<i32>,
    f_a: Vec<f32>,
    f_b: Vec<f32>,
}

/// A fitted / trainable cascade of stages (see module docs).
pub struct StageGraph {
    stages: Vec<Box<dyn Stage>>,
    domain: Domain,
    input_dim: usize,
    output_dim: usize,
    scratch: GraphScratch,
    /// Lanes for the training pass's embarrassingly-parallel work
    /// (entry quantization; forwarded to stages whose backward pass
    /// commutes). 1 = sequential, never spawns.
    train_lanes: usize,
    /// Per-stage instrumentation ([`Telemetry::Disabled`] by default:
    /// one branch per stage call, nothing recorded, nothing allocated).
    telemetry: Telemetry,
}

impl StageGraph {
    /// Compose a graph from built stages. Panics on inconsistent
    /// chaining (dimension mismatch, missing fixed-point specs) —
    /// construction errors are caught by [`super::spec::GraphSpec`]
    /// before stages are built, so this is a programming-error check.
    pub fn new(
        stages: Vec<Box<dyn Stage>>,
        domain: Domain,
        input_dim: usize,
        output_dim: usize,
    ) -> Self {
        let mut dim = input_dim;
        for s in &stages {
            assert_eq!(
                s.in_dim(),
                dim,
                "stage '{}' input dim mismatch in graph",
                s.name()
            );
            dim = s.out_dim();
            if let Domain::Fxp { .. } = domain {
                assert!(
                    s.input_spec().is_some() && s.output_spec().is_some(),
                    "stage '{}' has no fixed-point datapath",
                    s.name()
                );
            }
        }
        assert_eq!(dim, output_dim, "graph output dim mismatch");
        Self {
            stages,
            domain,
            input_dim,
            output_dim,
            scratch: GraphScratch::default(),
            train_lanes: 1,
            telemetry: Telemetry::Disabled,
        }
    }

    /// Shard lane-parallel *training* work across `lanes` (the forward
    /// path has its own `lanes` knob on [`StageGraph::forward_rows`]):
    /// the entry quantizer shards its tile into contiguous row chunks,
    /// and the hint is forwarded to every stage so the ones whose
    /// backward pass commutes (the EASI STE shadow update — see
    /// [`Stage::set_train_lanes`]) shard too. Training stays
    /// bit-identical for every lane count; `1` (the default) keeps the
    /// whole pass sequential and spawn-free.
    pub fn set_train_lanes(&mut self, lanes: usize) {
        let lanes = lanes.max(1);
        self.train_lanes = lanes;
        for s in self.stages.iter_mut() {
            s.set_train_lanes(lanes);
        }
    }

    pub fn domain(&self) -> Domain {
        self.domain
    }

    /// Turn on per-stage instrumentation: preallocates one
    /// [`crate::telemetry::StageStats`] slot per stage (plus the entry
    /// quantizer), so recording is allocation-free from here on. Stage
    /// formats are captured for occupancy/headroom reporting when the
    /// graph runs fixed point.
    pub fn enable_telemetry(&mut self) {
        let fxp = matches!(self.domain, Domain::Fxp { .. });
        let slots: Vec<(String, Option<FxpSpec>)> = self
            .stages
            .iter()
            .map(|s| {
                (
                    s.name().to_string(),
                    if fxp { s.output_spec() } else { None },
                )
            })
            .collect();
        let ingress = match self.domain {
            Domain::Fxp { entry, .. } => Some(entry),
            Domain::F32 => None,
        };
        self.telemetry = Telemetry::for_stages(slots, ingress);
    }

    /// The graph's instrumentation handle.
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// Point-in-time copy of the per-stage counters (None while
    /// telemetry is disabled).
    pub fn telemetry_snapshot(&self) -> Option<TelemetrySnapshot> {
        self.telemetry.snapshot()
    }

    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    pub fn output_dim(&self) -> usize {
        self.output_dim
    }

    /// The composed stages (reports, tests).
    pub fn stages(&self) -> &[Box<dyn Stage>] {
        &self.stages
    }

    /// The leading random-projection front end, if the graph has one
    /// (either backend — the fixed-point stage keeps its f32 image).
    pub fn random_projection(&self) -> Option<&RandomProjection> {
        let s = self.stages.first()?;
        if let Some(rp) = s.as_any().downcast_ref::<RpStage>() {
            return Some(&rp.rp);
        }
        if let Some(rp) = s.as_any().downcast_ref::<FxpRpStage>() {
            return Some(&rp.rp_f32);
        }
        None
    }

    /// The leading RP stage's dense scaled matrix (materialised once at
    /// stage construction), if the graph has one.
    fn leading_rp_dense(&self) -> Option<&Mat> {
        let s = self.stages.first()?;
        if let Some(rp) = s.as_any().downcast_ref::<RpStage>() {
            return Some(&rp.dense);
        }
        if let Some(rp) = s.as_any().downcast_ref::<FxpRpStage>() {
            return Some(&rp.dense);
        }
        None
    }

    /// Toggle every stage of the given role (the paper's
    /// reconfiguration mux — `Rot` toggles ICA ↔ PCA-whitening).
    /// Returns whether any stage matched.
    pub fn set_role_active(&mut self, role: StageRole, on: bool) -> bool {
        let mut found = false;
        for s in self.stages.iter_mut() {
            if s.role() == role {
                s.set_active(on);
                found = true;
            }
        }
        found
    }

    /// Whether the graph contains a stage of the given role.
    pub fn has_role(&self, role: StageRole) -> bool {
        self.stages.iter().any(|s| s.role() == role)
    }

    // ------------------------------------------------------- training

    /// Fit on a full training matrix: batch stages first (one prefix
    /// pass), then `epochs` streaming passes for the adaptive stages.
    pub fn fit(&mut self, x: &Mat, epochs: usize) {
        self.fit_batch_stages(x);
        let trains = self
            .stages
            .iter()
            .any(|s| s.is_adaptive() && !s.bypassed());
        if trains {
            for _ in 0..epochs.max(1) {
                self.step_rows(x);
            }
        }
    }

    fn fit_batch_stages(&mut self, x: &Mat) {
        let last = match self.stages.iter().rposition(|s| s.is_batch()) {
            Some(l) => l,
            None => return,
        };
        assert!(
            matches!(self.domain, Domain::F32),
            "batch stages have no fixed-point datapath"
        );
        let mut cur = x.clone();
        for i in 0..=last {
            if self.stages[i].bypassed() {
                continue;
            }
            if self.stages[i].is_batch() {
                self.stages[i].fit_batch(&cur);
            }
            if i < last {
                let rows = cur.rows_count();
                let mut out = Vec::new();
                self.stages[i].transform_tile(cur.as_slice(), rows, &mut out);
                cur = Mat::from_vec(rows, self.stages[i].out_dim(), out);
            }
        }
    }

    /// One streaming training pass over a tile of samples — the single
    /// tile loop the coordinator drives, whatever the stage cascade.
    pub fn step_rows(&mut self, x: &Mat) {
        assert_eq!(x.cols_count(), self.input_dim, "graph step input dim");
        let rows = x.rows_count();
        if rows == 0 {
            return;
        }
        // Streaming bootstrap: batch stages (PCA) fit on the first tile
        // the stream delivers (a full-fit path exists via `fit`).
        if self.stages.iter().any(|s| s.is_batch() && !s.batch_fitted()) {
            self.fit_batch_stages(x);
        }
        match self.domain {
            Domain::F32 => self.step_pass_f32(x, rows),
            Domain::Fxp { entry, prescale } => self.step_pass_raw(x, rows, entry, prescale),
        }
    }

    fn step_pass_f32(&mut self, x: &Mat, rows: usize) {
        let Self {
            stages,
            scratch,
            telemetry,
            ..
        } = self;
        let last = match stages
            .iter()
            .rposition(|s| s.is_adaptive() && !s.bypassed())
        {
            Some(l) => l,
            None => {
                advance_adaptive(stages, 0, rows);
                return;
            }
        };
        let mut cur = std::mem::take(&mut scratch.f_a);
        let mut next = std::mem::take(&mut scratch.f_b);
        walk_f32_stages(
            stages,
            telemetry,
            &mut cur,
            &mut next,
            Some(x.as_slice()),
            rows,
            last,
        );
        scratch.f_a = cur;
        scratch.f_b = next;
    }

    fn step_pass_raw(&mut self, x: &Mat, rows: usize, entry: FxpSpec, prescale: f32) {
        let Self {
            stages,
            scratch,
            telemetry,
            train_lanes,
            input_dim,
            ..
        } = self;
        let last = match stages
            .iter()
            .rposition(|s| s.is_adaptive() && !s.bypassed())
        {
            Some(l) => l,
            None => {
                advance_adaptive(stages, 0, rows);
                return;
            }
        };
        let mut cur = std::mem::take(&mut scratch.raw_a);
        let mut next = std::mem::take(&mut scratch.raw_b);
        // Entry quantization — the shared-ingress arithmetic. Rows are
        // independent, so with `train_lanes > 1` the tile shards into
        // contiguous row chunks across scoped threads. Each worker
        // opens and closes its *own* telemetry window: the overflow
        // counters are thread-local, so the per-chunk deltas attribute
        // every saturation to the ingress slot exactly as the
        // sequential walk does (and the recorded row counts sum to the
        // tile's).
        resize_buf(&mut cur, x.as_slice().len());
        let lanes = (*train_lanes).min(rows).max(1);
        if lanes > 1 {
            let cols = *input_dim;
            let chunk = rows.div_ceil(lanes);
            let xs = x.as_slice();
            let tel = &*telemetry;
            std::thread::scope(|s| {
                for (lane, out_chunk) in cur.chunks_mut(chunk * cols).enumerate() {
                    let start = lane * chunk * cols;
                    let src = &xs[start..start + out_chunk.len()];
                    s.spawn(move || {
                        let wmark = tel.begin();
                        for (q, &v) in out_chunk.iter_mut().zip(src) {
                            *q = entry.quantize(v * prescale);
                        }
                        tel.record_step(
                            None,
                            wmark,
                            out_chunk.len() / cols,
                            Some(out_chunk),
                        );
                    });
                }
            });
        } else {
            let mark = telemetry.begin();
            for (q, &v) in cur.iter_mut().zip(x.as_slice()) {
                *q = entry.quantize(v * prescale);
            }
            telemetry.record_step(None, mark, rows, Some(&cur));
        }
        walk_raw_stages(stages, telemetry, &mut cur, &mut next, entry, rows, last);
        scratch.raw_a = cur;
        scratch.raw_b = next;
    }

    /// Whether every batch stage (if any) has been fitted. Staged/fused
    /// commits bypass [`StageGraph::step_rows`]'s streaming bootstrap,
    /// so callers gate them on this.
    pub fn staged_ready(&self) -> bool {
        !self.stages.iter().any(|s| s.is_batch() && !s.batch_fitted())
    }

    /// One training pass from *pre-staged* input: the entry work
    /// (validation and, for fixed point, entry quantization) already
    /// happened off the compute path — typically on a serving shard's
    /// stager thread — so this runs only the stage walk. Bit-identical
    /// to [`StageGraph::step_rows`] on the same samples: entry
    /// quantization is per-sample deterministic, and the walk is the
    /// same code. Multi-batch fused tiles are bit-identical too, because
    /// the per-row recursions inside `step_tile_raw`/`step_tile` do not
    /// depend on tile boundaries (warm-up gates count global samples).
    pub fn step_staged(&mut self, input: StagedInput<'_>, rows: usize) {
        if rows == 0 {
            return;
        }
        let Self {
            stages,
            scratch,
            telemetry,
            input_dim,
            domain,
            ..
        } = self;
        let last = match stages
            .iter()
            .rposition(|s| s.is_adaptive() && !s.bypassed())
        {
            Some(l) => l,
            None => {
                // Parity with the serial early return: no ingress record
                // when nothing trains this pass.
                advance_adaptive(stages, 0, rows);
                return;
            }
        };
        match (input, *domain) {
            (
                StagedInput::Raw {
                    words,
                    ns,
                    sat,
                    wrap,
                },
                Domain::Fxp { entry, .. },
            ) => {
                assert_eq!(words.len(), rows * *input_dim, "staged raw tile shape");
                let mut cur = std::mem::take(&mut scratch.raw_a);
                let mut next = std::mem::take(&mut scratch.raw_b);
                resize_buf(&mut cur, words.len());
                cur.copy_from_slice(words);
                // The stager measured the quantize pass; attribute it to
                // the ingress slot exactly as the inline path would.
                telemetry.record_staged_ingress(ns, sat, wrap, rows, Some(&cur));
                walk_raw_stages(stages, telemetry, &mut cur, &mut next, entry, rows, last);
                scratch.raw_a = cur;
                scratch.raw_b = next;
            }
            (StagedInput::F32 { segments }, Domain::F32) => {
                assert!(
                    !stages.iter().any(|s| s.is_batch() && !s.batch_fitted()),
                    "staged f32 commits need batch stages fitted"
                );
                let mut cur = std::mem::take(&mut scratch.f_a);
                let mut next = std::mem::take(&mut scratch.f_b);
                cur.clear();
                cur.reserve(rows * *input_dim);
                for seg in segments {
                    cur.extend_from_slice(seg);
                }
                assert_eq!(cur.len(), rows * *input_dim, "staged f32 tile shape");
                walk_f32_stages(stages, telemetry, &mut cur, &mut next, None, rows, last);
                scratch.f_a = cur;
                scratch.f_b = next;
            }
            _ => panic!("staged input does not match the graph's domain"),
        }
    }

    // -------------------------------------------------------- forward

    /// Transform one sample `input_dim → output_dim` (the per-sample
    /// pipeline path; bit-identical to the tiled forms).
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.input_dim, "graph transform input dim");
        let m = Mat::from_vec(1, self.input_dim, x.to_vec());
        self.transform_rows(&m).into_vec()
    }

    /// Transform every row of a sample matrix, chaining stage
    /// transforms tile-at-a-time (muxed-out stages are skipped, format
    /// boundaries requantize).
    pub fn transform_rows(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols_count(), self.input_dim, "graph transform input dim");
        let rows = x.rows_count();
        match self.domain {
            Domain::F32 => {
                let mut cur: Vec<f32> = x.as_slice().to_vec();
                let mut cur_dim = self.input_dim;
                let mut next: Vec<f32> = Vec::new();
                for (i, s) in self.stages.iter().enumerate() {
                    if s.bypassed() {
                        continue;
                    }
                    let mark = self.telemetry.begin();
                    s.transform_tile(&cur, rows, &mut next);
                    std::mem::swap(&mut cur, &mut next);
                    cur_dim = s.out_dim();
                    self.telemetry.record_transform(Some(i), mark, rows, None);
                }
                Mat::from_vec(rows, cur_dim, cur)
            }
            Domain::Fxp { entry, prescale } => {
                let (raw, spec, dim) = self.forward_chunk_raw(x.as_slice(), rows, entry, prescale);
                Mat::from_vec(rows, dim, raw.iter().map(|&w| spec.dequantize(w)).collect())
            }
        }
    }

    /// The quantized forward chain on one row chunk. Returns the raw
    /// output tile, its format, and its row width.
    fn forward_chunk_raw(
        &self,
        x: &[f32],
        rows: usize,
        entry: FxpSpec,
        prescale: f32,
    ) -> (Vec<i32>, FxpSpec, usize) {
        let mark = self.telemetry.begin();
        let mut cur: Vec<i32> = x.iter().map(|&v| entry.quantize(v * prescale)).collect();
        self.telemetry.record_transform(None, mark, rows, Some(&cur));
        let mut cur_spec = entry;
        let mut cur_dim = self.input_dim;
        let mut next: Vec<i32> = Vec::new();
        for (i, s) in self.stages.iter().enumerate() {
            if s.bypassed() {
                continue;
            }
            let mark = self.telemetry.begin();
            let want = s.input_spec().expect("fixed-point graph stage");
            want.requantize_slice_from(&mut cur, &cur_spec);
            s.transform_tile_raw(&cur, rows, &mut next);
            std::mem::swap(&mut cur, &mut next);
            cur_spec = s.output_spec().expect("fixed-point graph stage");
            cur_dim = s.out_dim();
            self.telemetry.record_transform(Some(i), mark, rows, Some(&cur));
        }
        (cur, cur_spec, cur_dim)
    }

    /// The coordinator's bulk transform: the folded dense matrix for
    /// f32 (the legacy effective-matrix arithmetic, bit-for-bit), the
    /// multi-lane row-sharded quantized forward for fixed point (each
    /// lane owns a disjoint output slice, so the merge is deterministic
    /// and the raw words are identical to the single-lane path).
    pub fn forward_rows(&self, x: &Mat, lanes: usize) -> Mat {
        match self.domain {
            Domain::F32 => {
                // Affine stages (batch PCA) cannot be folded into one
                // matrix; those graphs take the sequential chain.
                if self.stages.iter().any(|s| !s.bypassed() && s.is_affine()) {
                    return self.transform_rows(x);
                }
                let staged = match self.leading_rp_dense() {
                    Some(r) => r.apply_rows(x),
                    None => x.clone(),
                };
                self.separation_matrix().apply_rows(&staged)
            }
            Domain::Fxp { entry, prescale } => {
                let rows = x.rows_count();
                let n = self.forward_out_dim();
                let out_spec = self.forward_out_spec(entry);
                if rows == 0 {
                    return Mat::zeros(0, n);
                }
                // Lane counts the tile cannot feed run the sequential
                // chain without spawning a single thread (mirrors
                // `FxpDrUnit::transform_tile_raw_multilane`): one lane
                // is sequential by definition, and more lanes than rows
                // would degenerate to one thread per row.
                if lanes <= 1 || lanes > rows {
                    let (raw, _, _) =
                        self.forward_chunk_raw(x.as_slice(), rows, entry, prescale);
                    return Mat::from_vec(
                        rows,
                        n,
                        raw.iter().map(|&w| out_spec.dequantize(w)).collect(),
                    );
                }
                let m = self.input_dim;
                let mut raw = vec![0i32; rows * n];
                // Ceil-divide so every lane gets a contiguous run of
                // rows and the chunk boundaries are a pure function of
                // (rows, lanes).
                let chunk = rows.div_ceil(lanes);
                std::thread::scope(|scope| {
                    for (lane, out_chunk) in raw.chunks_mut(chunk * n).enumerate() {
                        let rows_here = out_chunk.len() / n;
                        let start = lane * chunk;
                        let xs = &x.as_slice()[start * m..(start + rows_here) * m];
                        scope.spawn(move || {
                            let (got, _, _) =
                                self.forward_chunk_raw(xs, rows_here, entry, prescale);
                            out_chunk.copy_from_slice(&got);
                        });
                    }
                });
                Mat::from_vec(rows, n, raw.iter().map(|&w| out_spec.dequantize(w)).collect())
            }
        }
    }

    fn forward_out_dim(&self) -> usize {
        self.stages
            .iter()
            .rev()
            .find(|s| !s.bypassed())
            .map_or(self.input_dim, |s| s.out_dim())
    }

    fn forward_out_spec(&self, entry: FxpSpec) -> FxpSpec {
        self.stages
            .iter()
            .rev()
            .find(|s| !s.bypassed())
            .and_then(|s| s.output_spec())
            .unwrap_or(entry)
    }

    // ------------------------------------------------------ reporting

    /// The trained stages as one dense matrix — the fold of every
    /// active stage's linearization *behind* the RP front end (RP is
    /// reported separately, as the legacy trainer did). Fixed-point
    /// graphs fold in the adaptive stages' input prescale, so the
    /// matrix maps unscaled samples like the f32 one. Affine stages
    /// contribute their linear part only (the mean offset of batch PCA
    /// is not representable in a matrix fold — use the transform paths
    /// for exact outputs).
    pub fn separation_matrix(&self) -> Mat {
        let skip = usize::from(self.random_projection().is_some());
        let mut eff: Option<Mat> = None;
        for s in self.stages.iter().skip(skip) {
            if s.bypassed() {
                continue;
            }
            let m = s
                .dense_matrix()
                .unwrap_or_else(|| panic!("stage '{}' has no dense linearization", s.name()));
            eff = Some(match eff {
                None => m,
                Some(e) => m.matmul(&e),
            });
        }
        let mut eff = eff.unwrap_or_else(|| Mat::eye(self.output_dim, self.output_dim));
        if let Domain::Fxp { .. } = self.domain {
            eff.scale(self.fxp_unit_prescale());
        }
        eff
    }

    /// The power-of-two prescale the *trained* stages see (the first
    /// adaptive stage's input format) — what the fused unit folded into
    /// its effective matrix.
    fn fxp_unit_prescale(&self) -> f32 {
        self.stages
            .iter()
            .find(|s| s.is_adaptive())
            .and_then(|s| s.input_spec())
            .map(|sp| input_prescale(&sp))
            .unwrap_or(1.0)
    }

    /// Convergence signal: the max over the active adaptive stages'
    /// monitors (the whitener dominates early, the rotation late) —
    /// same composition as the fused units'.
    pub fn update_magnitude(&self) -> f64 {
        let mut mag = 0.0f64;
        for s in &self.stages {
            if s.bypassed() {
                continue;
            }
            if let Some(u) = s.update_magnitude() {
                mag = mag.max(u);
            }
        }
        mag
    }

    /// Checkpoint every stage's state, in graph order.
    pub fn save_state(&self) -> Vec<StageState> {
        self.stages.iter().map(|s| s.save_state()).collect()
    }

    /// Restore a [`StageGraph::save_state`] checkpoint into a graph of
    /// the same shape.
    pub fn restore_state(&mut self, st: &[StageState]) -> Result<()> {
        ensure!(
            st.len() == self.stages.len(),
            "checkpoint has {} stages, graph has {}",
            st.len(),
            self.stages.len()
        );
        for (s, state) in self.stages.iter_mut().zip(st) {
            s.restore_state(state)?;
        }
        Ok(())
    }
}

/// Advance the sample counters of adaptive stages from `from` on —
/// stages that did not train this pass (muxed out, or behind the last
/// trainable stage) still observe the stream length, so warm-up gates
/// match the fused units' global-step gating.
fn advance_adaptive(stages: &mut [Box<dyn Stage>], from: usize, rows: usize) {
    for s in stages.iter_mut().skip(from) {
        if s.is_adaptive() {
            s.advance(rows);
        }
    }
}

/// The f32 training walk over stages `0..=last`, ping-ponging through
/// `cur`/`next`. With `x = Some(tile)` the first active stage reads the
/// caller's tile; with `x = None` the tile is already in `cur` (the
/// staged path).
fn walk_f32_stages(
    stages: &mut [Box<dyn Stage>],
    telemetry: &Telemetry,
    cur: &mut Vec<f32>,
    next: &mut Vec<f32>,
    x: Option<&[f32]>,
    rows: usize,
    last: usize,
) {
    let mut have_cur = x.is_none();
    for i in 0..=last {
        if stages[i].bypassed() {
            stages[i].advance(rows);
            continue;
        }
        let mark = telemetry.begin();
        if i == last {
            let input: &[f32] = if have_cur { cur } else { x.expect("input tile") };
            stages[i].step_tile(input, rows, None);
        } else {
            let input: &[f32] = if have_cur { cur } else { x.expect("input tile") };
            stages[i].step_tile(input, rows, Some(&mut *next));
            std::mem::swap(cur, next);
            have_cur = true;
        }
        telemetry.record_step(Some(i), mark, rows, None);
    }
    advance_adaptive(stages, last + 1, rows);
}

/// The fixed-point training walk over stages `0..=last`: `cur` holds
/// the entry-quantized tile in format `cur_spec`; each format boundary
/// requantizes with the destination stage's policy, then the stage
/// consumes the tile (emitting into `next` unless it is the last
/// trainable one).
fn walk_raw_stages(
    stages: &mut [Box<dyn Stage>],
    telemetry: &Telemetry,
    cur: &mut Vec<i32>,
    next: &mut Vec<i32>,
    mut cur_spec: FxpSpec,
    rows: usize,
    last: usize,
) {
    for i in 0..=last {
        if stages[i].bypassed() {
            stages[i].advance(rows);
            continue;
        }
        // Begin before the boundary requantize: its cost and any
        // overflow belong to the stage whose policy it applies.
        let mark = telemetry.begin();
        let want = stages[i].input_spec().expect("fixed-point graph stage");
        want.requantize_slice_from(cur, &cur_spec);
        if i == last {
            stages[i].step_tile_raw(cur, rows, None);
            telemetry.record_step(Some(i), mark, rows, None);
        } else {
            stages[i].step_tile_raw(cur, rows, Some(&mut *next));
            std::mem::swap(cur, next);
            cur_spec = stages[i].output_spec().expect("fixed-point graph stage");
            telemetry.record_step(Some(i), mark, rows, Some(cur));
        }
    }
    advance_adaptive(stages, last + 1, rows);
}
