//! The unified stage abstraction — one composable datapath over f32
//! and fixed point.
//!
//! Prior to this module the crate held *two* pipelines: an f32 path
//! (`DrPipeline`'s fitted-stage dispatch) and a fixed-point special
//! case (`FxpIo` + the `Fxp*` kernels), forked again inside the
//! coordinator's trainer. A [`Stage`] is the common shape of every
//! datapath element — RP, GHA whitening, EASI rotation, batch PCA, DCT,
//! identity — with the two numeric domains as two *backends* of the
//! same trait:
//!
//! * the f32 backend works on `&[f32]` row-major tiles
//!   ([`Stage::step_tile`] / [`Stage::transform_tile`]);
//! * the fixed-point backend works on raw `i32` words
//!   ([`Stage::step_tile_raw`] / [`Stage::transform_tile_raw`]), with
//!   the stage's arithmetic published through [`Stage::input_spec`] /
//!   [`Stage::output_spec`] so the graph can requantize at every
//!   boundary exactly as the fused kernels did.
//!
//! Training is *streaming*: `step_tile` walks a tile's rows in order,
//! updates state per row, and emits the per-row training-path outputs
//! (what a downstream adaptive stage trains on) into a caller-owned
//! scratch buffer — the `_into` shape of the PR 3 tiled datapath, so a
//! [`graph::StageGraph`] training step is allocation-free in steady
//! state. The emitted rows are computed immediately after that row's
//! update, which makes a stage-by-stage tile pass bit-identical to the
//! legacy fused per-row recursion (the downstream stage sees exactly
//! the same words in the same order).
//!
//! [`graph::StageGraph`] composes boxed stages; [`spec::GraphSpec`]
//! declares and builds them (including the `--stages` CLI syntax and
//! the mapping from the legacy `StageSpec` forms).

pub mod adapters;
pub mod graph;
pub mod spec;

pub use adapters::{
    DctStage, EasiStage, FxpDctStage, FxpEasiStage, FxpGhaStage, FxpRpStage, GhaStage,
    IdentityStage, PcaStage, RpStage,
};
pub use graph::{Domain, StageGraph, StagedInput};
pub use spec::{GraphSpec, StageDecl, StageOp};

use crate::fxp::FxpSpec;
use crate::linalg::Mat;

pub use crate::fxp::StageRole;

/// Opaque per-stage checkpoint: dense f32 matrices (subspaces, shadow
/// weights), f32 vectors (variance estimates), raw word buffers
/// (quantized state), wide accumulators (the whitener's extended
/// variance EMA) and counters (sample counts — without them a restored
/// stage re-runs warm-up gates and retraction cadences from zero).
#[derive(Debug, Clone, Default)]
pub struct StageState {
    pub mats: Vec<Mat>,
    pub vecs: Vec<Vec<f32>>,
    pub words: Vec<Vec<i32>>,
    pub wide: Vec<Vec<i64>>,
    pub counters: Vec<u64>,
}

/// Size an f32 scratch vector without shrinking capacity (the `f32`
/// mirror of [`crate::fxp::kernels::resize_buf`]).
#[inline]
pub(crate) fn resize_f32(buf: &mut Vec<f32>, len: usize) {
    if buf.len() != len {
        buf.resize(len, 0.0);
    }
}

/// One element of a composable DR datapath. See the module docs for the
/// two-backend contract; a concrete stage implements the backend(s) it
/// supports and panics (programming error, not runtime input) on the
/// other — the [`spec::GraphSpec`] builder only ever composes stages
/// within one domain.
pub trait Stage: Send + Sync {
    /// Short label used in errors and reports (e.g. `"whiten:gha"`).
    fn name(&self) -> &'static str;

    /// The precision role this stage plays in a [`crate::fxp::PrecisionPlan`].
    fn role(&self) -> StageRole;

    fn in_dim(&self) -> usize;

    fn out_dim(&self) -> usize;

    /// Whether the stage learns from streamed samples.
    fn is_adaptive(&self) -> bool {
        false
    }

    /// Whether the stage fits on a full batch before streaming starts.
    fn is_batch(&self) -> bool {
        false
    }

    /// Whether the stage's transform is affine rather than purely
    /// linear (batch PCA's mean subtraction) — such stages cannot be
    /// folded into one dense matrix, so bulk forwards take the
    /// sequential chain (and [`Stage::dense_matrix`] reports the linear
    /// part only).
    fn is_affine(&self) -> bool {
        false
    }

    /// Whether the stage is currently muxed out of the datapath (the
    /// paper's reconfiguration mux). Only square stages may be
    /// bypassed.
    fn bypassed(&self) -> bool {
        false
    }

    /// Toggle the stage's mux (no-op for stages without one).
    fn set_active(&mut self, _on: bool) {}

    /// Advance the stage's sample counter without training — keeps
    /// warm-up gates in sync with the stream while the stage is muxed
    /// out, exactly as the fused units gated on the *whitener's* global
    /// sample count.
    fn advance(&mut self, _rows: usize) {}

    /// Hint how many lanes the stage may use for its *training* work
    /// (the forward path has its own `lanes` knob). Default: no-op —
    /// most stages are order-dependent recursions that must stay
    /// sequential; stages whose backward pass commutes (the STE shadow
    /// update on disjoint row blocks) override this.
    fn set_train_lanes(&mut self, _lanes: usize) {}

    // ------------------------------------------------------------ f32

    /// One streaming training pass over a row-major tile
    /// (`rows × in_dim`), in row order. When `out` is given it is
    /// resized to `rows × out_dim` and receives the per-row
    /// training-path outputs (computed right after that row's update).
    fn step_tile(&mut self, _x: &[f32], _rows: usize, _out: Option<&mut Vec<f32>>) {
        panic!("stage '{}' has no f32 training path", self.name());
    }

    /// Pure forward transform of a tile into a caller-owned buffer.
    fn transform_tile(&self, _x: &[f32], _rows: usize, _out: &mut Vec<f32>) {
        panic!("stage '{}' has no f32 forward path", self.name());
    }

    /// Batch fit (PCA-style stages) on a full sample matrix.
    fn fit_batch(&mut self, _x: &Mat) {
        panic!("stage '{}' is not a batch stage", self.name());
    }

    /// Whether a batch stage has been fitted (always true for
    /// streaming/static stages). The graph bootstraps unfitted batch
    /// stages on the first tile a streaming pass delivers.
    fn batch_fitted(&self) -> bool {
        true
    }

    // ------------------------------------------------------ raw words

    /// The fixed-point format this stage consumes (None for f32-only
    /// stages). The graph requantizes incoming words into it.
    fn input_spec(&self) -> Option<FxpSpec> {
        None
    }

    /// The fixed-point format this stage emits.
    fn output_spec(&self) -> Option<FxpSpec> {
        None
    }

    /// Raw-word mirror of [`Stage::step_tile`].
    fn step_tile_raw(&mut self, _x: &[i32], _rows: usize, _out: Option<&mut Vec<i32>>) {
        panic!("stage '{}' has no fixed-point training path", self.name());
    }

    /// Raw-word mirror of [`Stage::transform_tile`].
    fn transform_tile_raw(&self, _x: &[i32], _rows: usize, _out: &mut Vec<i32>) {
        panic!("stage '{}' has no fixed-point forward path", self.name());
    }

    // ------------------------------------------------------ reporting

    /// Convergence signal, if the stage has one (the graph folds the
    /// max over adaptive stages, like the fused units did).
    fn update_magnitude(&self) -> Option<f64> {
        None
    }

    /// The stage as a dense f32 matrix (`out_dim × in_dim`) — used for
    /// the folded separation matrix and reports. Affine stages
    /// ([`Stage::is_affine`]) report their *linear part* (batch PCA's
    /// mean offset is not representable here — bulk forwards route
    /// around the fold for them); stages with no dense image return
    /// None.
    fn dense_matrix(&self) -> Option<Mat> {
        None
    }

    /// Checkpoint the stage's state (see [`StageState`]).
    fn save_state(&self) -> StageState {
        StageState::default()
    }

    /// Restore a [`Stage::save_state`] checkpoint.
    fn restore_state(&mut self, _st: &StageState) -> anyhow::Result<()> {
        Ok(())
    }

    /// Typed access for callers that need the concrete stage (the
    /// pipeline's `rp()` accessor, tests).
    fn as_any(&self) -> &dyn std::any::Any;
}
